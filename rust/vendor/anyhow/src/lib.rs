//! Offline micro-implementation of the `anyhow` API surface this
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics match the real crate where it matters here: `Error` is a
//! cheap, `Send + Sync` error value convertible from any
//! `std::error::Error`, context wraps are prepended to the message chain,
//! and `Error` deliberately does **not** implement `std::error::Error`
//! (that is what makes the blanket `From` conversion coherent).

use std::fmt;

/// A flattened error chain (most recent context first).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` macro calls this).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, mirroring `anyhow::Error::context`.
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain into one readable line.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Internal conversion hook so [`Context`] can be implemented both for
/// `Result<T, E: std::error::Error>` and for `Result<T, Error>` without
/// overlap (the same shape the real crate uses).
pub trait IntoAnyhow {
    fn into_anyhow(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
    fn into_anyhow(self) -> Error {
        Error::from(self)
    }
}

impl IntoAnyhow for Error {
    fn into_anyhow(self) -> Error {
        self
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoAnyhow> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_num(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("parsing number")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn conversion_and_context_chain() {
        let err = parse_num("abc").unwrap_err();
        assert!(err.to_string().starts_with("parsing number: "), "{err}");
        assert_eq!(parse_num("41").unwrap(), 41);
        assert!(parse_num("-2").unwrap_err().to_string().contains("negative"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(anyhow!("x={}", 3).to_string(), "x=3");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let err = r.with_context(|| "outer").unwrap_err();
        assert_eq!(err.to_string(), "outer: inner");
    }
}
