//! Offline stub of the `xla` PJRT binding surface the m2ru runtime links
//! against. Host-side literal plumbing ([`Literal`]) is fully functional
//! so it can be unit-tested; everything that would need the real XLA
//! runtime (HLO parsing, compilation, execution) returns a descriptive
//! [`XlaError`] instead. Swap this path dependency for the real `xla`
//! crate to execute AOT artifacts (see DESIGN.md §6).

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `anyhow` interop.
#[derive(Clone, Debug)]
pub struct XlaError {
    pub msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "{what} is unavailable: this build links the offline `xla` stub \
             (vendor/xla-stub); link the real xla crate to execute artifacts"
        ),
    }
}

/// Element types the stub can read back out of a literal.
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

/// Host literal: flat f32 buffer plus dimensions. The constructors and
/// reshape/readback paths are real; device transfer is not.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl From<f32> for Literal {
    fn from(v: f32) -> Literal {
        Literal { data: vec![v], dims: vec![] }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.data.len() {
            return Err(XlaError {
                msg: format!("reshape {:?} -> {:?}: element count mismatch", self.dims, dims),
            });
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements back to the host.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Unpack a tuple literal. The stub never produces tuples, so this is
    /// only reachable through a (stubbed-out) execution path.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple (tuple results only come from execution)"))
    }
}

/// Parsed HLO module handle (parsing needs the real runtime).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation handle wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle. Construction succeeds (so `m2ru info` can report
/// the platform); compilation is where the stub stops.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu (no XLA runtime linked)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let m = l.reshape(&[2, 3]).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        let s = Literal::from(7.5);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn runtime_paths_error_descriptively() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(client.compile(&XlaComputation).is_err());
        let err = PjRtLoadedExecutable.execute::<Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }
}
