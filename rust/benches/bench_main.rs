//! Benchmark harness (`cargo bench`) — no criterion in the offline
//! environment, so this is a self-contained harness with warm-up,
//! repetition and mean/min/max reporting.
//!
//! Three families:
//!  1. **Pure-rust microbenches** — run everywhere, no artifacts needed:
//!     the blocked-vs-ikj matmul comparison (§Perf acceptance: blocked
//!     must win at ≥256×256), backend train/eval steps through the
//!     registry, parallel-eval worker scaling, replay pipeline, crossbar
//!     programming.
//!  2. **Paper artifacts** — regenerates every table/figure (fig4 and
//!     fig5b in scaled-down "quick" mode; fig5a/c/d, table1, headline in
//!     full) and archives the reports under `results/bench_*`.
//!  3. **XLA hot-path microbenches** — train/eval step latency through
//!     the AOT artifacts. Families 2–3 are skipped with a notice when no
//!     artifacts/PJRT runtime are present.
//!
//! Select with `cargo bench -- <filter>` (substring match).
//!
//! Machine-readable output: every pure-rust microbench also lands in
//! `results/BENCH_serve.json` as `{name, iters, ns_per_iter, throughput}`
//! records, so the perf trajectory is trackable across PRs.

use std::time::Instant;

use m2ru::backend::{BackendCtx, BackendRegistry, ComputeBackend};
use m2ru::config::{Manifest, NetConfig, RunConfig, ServeConfig};
use m2ru::coordinator::{Engine, HardwareEngine, ParallelEngine, RustDfaEngine, XlaDfaEngine};
use m2ru::data::{permuted_task_stream, synthetic_mnist, Example};
use m2ru::device::{DeviceParams, DifferentialCrossbar, ZiksaProgrammer};
use m2ru::experiments::{
    run_fig4, run_fig5a, run_fig5b, run_fig5c, run_fig5d, run_headline, run_table1, Fig4Options,
    Fig5bOptions,
};
use m2ru::linalg::bitplane::{wbs_mac_bitloop, wbs_mac_packed, wbs_mac_packed_i32, BitPlanes};
use m2ru::linalg::{kernels, Mat};
use m2ru::nn::SeqBatch;
use m2ru::quant::QuantizedMat;
use m2ru::replay::ReplayBuffer;
use m2ru::rng::GaussianRng;
use m2ru::net::{decode_frame, encode_frame, Message, RouterCore, FLAG_TICK};
use m2ru::runtime::{ModelBundle, Runtime};
use m2ru::serve::{
    run_serve, save_checkpoint, save_delta, session_id_for_user, DynamicBatcher, ServeCore,
    ServeOptions, SessionStore, StepRequest, SyntheticWorkload, WeightSnapshot,
};

/// One benchmark result, serialized to `results/BENCH_serve.json`.
struct BenchRecord {
    name: String,
    iters: usize,
    ns_per_iter: f64,
}

impl BenchRecord {
    /// Iterations per second.
    fn throughput(&self) -> f64 {
        1e9 / self.ns_per_iter.max(1e-9)
    }
}

fn timeit<F: FnMut()>(recs: &mut Vec<BenchRecord>, name: &str, iters: usize, mut f: F) {
    // warm-up
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!("{name:<46} {mean:>10.3} ms/iter  (min {min:>8.3}, max {max:>8.3}, n={iters})");
    recs.push(BenchRecord { name: name.to_string(), iters, ns_per_iter: mean * 1e6 });
}

fn render_record(r: &BenchRecord) -> String {
    format!(
        "{{\"name\": \"{}\", \"iters\": {}, \"ns_per_iter\": {:.1}, \"throughput\": {:.3}}}",
        r.name,
        r.iters,
        r.ns_per_iter,
        r.throughput()
    )
}

/// Hand-rolled JSON (no serde in the offline build); bench names contain
/// no characters needing escapes.
///
/// Rows are keyed by `name` and **merged** with any existing file: a
/// filtered rerun (`cargo bench -- matmul`) updates its rows in place
/// and keeps everything else, instead of dropping the other rows or
/// appending duplicates. Existing rows keep their file order; genuinely
/// new names append at the end.
fn write_bench_json(path: &str, recs: &[BenchRecord]) -> std::io::Result<()> {
    // (name, rendered row) pairs from the previous run, if any — one
    // record per line is this writer's own format, so a line parse is
    // exact, not a heuristic
    let mut rows: Vec<(String, String)> = Vec::new();
    if let Ok(prev) = std::fs::read_to_string(path) {
        for line in prev.lines() {
            let t = line.trim().trim_end_matches(',');
            if let Some(rest) = t.strip_prefix("{\"name\": \"") {
                if let Some(end) = rest.find('"') {
                    rows.push((rest[..end].to_string(), t.to_string()));
                }
            }
        }
    }
    for r in recs {
        let rendered = render_record(r);
        match rows.iter_mut().find(|(name, _)| *name == r.name) {
            Some(slot) => slot.1 = rendered,
            None => rows.push((r.name.clone(), rendered)),
        }
    }
    let mut s = String::from("[\n");
    for (i, (_, row)) in rows.iter().enumerate() {
        s.push_str("  ");
        s.push_str(row);
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("]\n");
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, s)
}

fn batch_from(examples: &[Example], b: usize, nt: usize, nx: usize) -> SeqBatch {
    let mut sb = SeqBatch::zeros(b, nt, nx);
    for i in 0..b {
        let e = &examples[i % examples.len()];
        sb.sample_mut(i).copy_from_slice(&e.features);
        sb.labels[i] = e.label;
    }
    sb
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_default();
    let runs = |name: &str| filter.is_empty() || name.contains(&filter);
    let mut recs: Vec<BenchRecord> = Vec::new();

    let cfg = NetConfig::PMNIST100;
    let stream = permuted_task_stream(1, 64, 16, 0);
    let train_b = batch_from(&stream.tasks[0].train, cfg.b_train, cfg.nt, cfg.nx);
    let eval_b = batch_from(&stream.tasks[0].train, cfg.b_eval, cfg.nt, cfg.nx);
    let registry = BackendRegistry::with_defaults();
    let ctx = BackendCtx::from_run(cfg, &RunConfig::default());

    println!("== pure-rust microbenches ======================================");
    if runs("matmul") {
        // §Perf acceptance: matmul_blocked must beat matmul_ikj at >=256
        for &n in &[128usize, 256, 512] {
            let a = Mat::from_fn(n, n, |r, c| ((r * 31 + c * 7) % 13) as f32 * 0.1 - 0.6);
            let b = Mat::from_fn(n, n, |r, c| ((r * 17 + c * 3) % 11) as f32 * 0.1 - 0.5);
            let iters = if n >= 512 { 8 } else { 20 };
            timeit(&mut recs, &format!("matmul_ikj ({n}x{n})"), iters, || {
                let _ = a.matmul_ikj(&b);
            });
            timeit(&mut recs, &format!("matmul_blocked ({n}x{n})"), iters, || {
                let _ = a.matmul_blocked(&b);
            });
        }
    }
    if runs("matmul_kernel") {
        // the same product under each forced kernel — the SIMD payoff on
        // this machine (results are bitwise-identical, only speed moves)
        let n = 256usize;
        let a = Mat::from_fn(n, n, |r, c| ((r * 31 + c * 7) % 13) as f32 * 0.1 - 0.6);
        let b = Mat::from_fn(n, n, |r, c| ((r * 17 + c * 3) % 11) as f32 * 0.1 - 0.5);
        for kern in ["scalar", "simd"] {
            kernels::force(kern)?;
            timeit(&mut recs, &format!("matmul_kernel ({n}x{n}, kernel={kern})"), 20, || {
                let _ = a.matmul(&b);
            });
        }
        kernels::force("")?;
    }
    if runs("matmul_i8_kernel") {
        // the integer MAC under each forced kernel: the raw i8xi8->i32
        // speedup the int8 serving path is built on (results are exactly
        // identical — integer accumulation is associative)
        let n = 256usize;
        let a: Vec<i8> = (0..n * n).map(|i| ((i * 31) % 255) as i8).collect();
        let b: Vec<i8> = (0..n * n).map(|i| ((i * 17) % 255) as i8).collect();
        let mut out = vec![0i32; n * n];
        for kern in ["scalar", "simd"] {
            kernels::force(kern)?;
            timeit(&mut recs, &format!("matmul_i8_kernel ({n}x{n}, kernel={kern})"), 20, || {
                kernels::matmul_i8(&a, &b, &mut out, n, n, n);
            });
        }
        kernels::force("")?;
    }
    if runs("crossbar_mac") {
        // bit-serial WBS MAC at pmnist100 hidden-layer shape: the packed
        // bit-plane path (64 input bits per word, popcount-free row adds)
        // vs the per-bit reference loop it must match bitwise.
        // §Perf acceptance: packed must be >= 2x the bitloop.
        let nin = cfg.nx + cfg.nh; // 128-wide hidden drive
        let g = Mat::from_fn(nin, cfg.nh, |r, c| ((r * 13 + c * 5) % 17) as f32 * 0.01 - 0.08);
        let xs: Vec<f32> =
            (0..nin).map(|i| if i % 6 == 0 { 0.0 } else { ((i % 9) as f32 / 9.0) - 0.45 }).collect();
        let nb = 8;
        timeit(&mut recs, "crossbar_mac_bitloop (128x100, nb=8, 100 macs)", 20, || {
            for _ in 0..100 {
                let _ = wbs_mac_bitloop(&xs, &g, nb);
            }
        });
        timeit(&mut recs, "crossbar_mac_packed (128x100, nb=8, 100 macs)", 20, || {
            for _ in 0..100 {
                let _ = wbs_mac_packed(&BitPlanes::pack(&xs, nb), &g);
            }
        });
        // the int8 serving variant: the same packed planes folded over
        // pre-quantized i8 columns in pure integer domain (one rescale
        // at the end) — what the crossbar backend runs under int8
        let q = QuantizedMat::from_mat(&g);
        timeit(&mut recs, "crossbar_mac_packed_i32 (128x100, nb=8, 100 macs)", 20, || {
            for _ in 0..100 {
                let _ = wbs_mac_packed_i32(&BitPlanes::pack(&xs, nb), &q);
            }
        });
    }
    if runs("backend_train_step") {
        for name in ["dense", "crossbar"] {
            let mut be = registry.create(name, &ctx)?;
            timeit(&mut recs, &format!("backend_train_step ({name}, b=32, pmnist100)"), 10, || {
                be.train_dfa(&train_b).unwrap();
            });
        }
    }
    if runs("backend_eval") {
        for name in ["dense", "crossbar"] {
            let be = registry.create(name, &ctx)?;
            timeit(&mut recs, &format!("backend_eval ({name}, b=200, pmnist100)"), 10, || {
                be.forward(&eval_b).unwrap();
            });
        }
    }
    if runs("parallel_eval") {
        // worker scaling of the serving engine; merged metrics are
        // identical across worker counts (see tests/backend_parity.rs)
        for workers in [1usize, 2, 4] {
            let be = registry.create("crossbar", &ctx)?;
            let mut eng = ParallelEngine::new(be, workers);
            timeit(&mut recs, &format!("parallel_eval (crossbar, b=200, workers={workers})"), 10, || {
                eng.eval_batch(&eval_b).unwrap();
            });
        }
    }
    if runs("rust_train_step") {
        let mut eng = RustDfaEngine::new(28, 100, 10, 0.96, 0.3, 0.3, Some(0.53), 1);
        timeit(&mut recs, "rust_train_step (digital baseline, b=32)", 10, || {
            eng.train_batch(&train_b).unwrap();
        });
    }
    if runs("l3_host_overhead") {
        // host-side share of one train step: batch assembly + all
        // literal uploads, with no XLA execution. Quantifies whether the
        // coordinator (L3) is ever the bottleneck (paper: it must not be).
        use m2ru::nn::{make_psi, MiruParams};
        use m2ru::runtime::host_overhead_probe;
        let p = MiruParams::init(cfg.nx, cfg.nh, cfg.ny, 1);
        let psi = make_psi(cfg.ny, cfg.nh, 2);
        timeit(&mut recs, "l3_host_overhead (literals for 1 train step)", 50, || {
            host_overhead_probe(&p, &psi, &train_b).unwrap();
        });
    }
    if runs("replay_pipeline") {
        let digits = synthetic_mnist(256, 0);
        timeit(&mut recs, "replay_pipeline (reservoir+squant, 256 imgs)", 20, || {
            let mut buf = ReplayBuffer::new(64, 0.0, 1.0, 42);
            buf.begin_task();
            for e in &digits {
                buf.offer(e);
            }
        });
    }
    if runs("replay_sample") {
        let digits = synthetic_mnist(256, 0);
        let mut buf = ReplayBuffer::new(128, 0.0, 1.0, 42);
        buf.begin_task();
        for e in &digits {
            buf.offer(e);
        }
        buf.begin_task();
        let mut rng = GaussianRng::new(1);
        timeit(&mut recs, "replay_sample (draw+dequant 32 examples)", 50, || {
            let _ = buf.sample_past(32, &mut rng);
        });
    }
    if runs("crossbar_program") {
        let mut xb = DifferentialCrossbar::new(128, 100, 1.0, DeviceParams::default(), 0);
        let w = Mat::from_fn(128, 100, |r, c| ((r + c) % 13) as f32 * 0.01);
        let mut prog = ZiksaProgrammer::new();
        timeit(&mut recs, "crossbar_program (12.8k devices)", 20, || {
            prog.apply(&mut xb, &w);
        });
    }
    if runs("crossbar_read") {
        let xb = DifferentialCrossbar::new(128, 100, 1.0, DeviceParams::default(), 0);
        timeit(&mut recs, "crossbar_read (12.8k devices)", 50, || {
            let _ = xb.read_weights();
        });
    }
    if runs("serve_session_store") {
        let mut store = SessionStore::new(cfg.nh, cfg.nx, cfg.nt, 4096, 0);
        let row = vec![0.1f32; cfg.nx];
        let mut tick = 0u64;
        timeit(&mut recs, "serve_session_store (1k lookups+history)", 50, || {
            for u in 0..1000u64 {
                let slot = store.get_or_create(u % 5000, tick);
                store.push_history(slot, &row);
                tick += 1;
            }
        });
    }
    if runs("serve_dynamic_batcher") {
        timeit(&mut recs, "serve_dynamic_batcher (1k reqs, b=32)", 50, || {
            let mut b = DynamicBatcher::new(32, 4);
            for i in 0..1000u64 {
                b.push(StepRequest {
                    session: i % 200,
                    x: vec![0.0; 4],
                    label: None,
                    enqueued_tick: i / 32,
                    enqueued_at: Instant::now(),
                    tag: 0,
                });
            }
            let mut tick = 0;
            while b.drain(tick).is_some() {
                tick += 1;
            }
        });
    }
    if runs("serve_step_batch") {
        // the serving hot path: one padded single-timestep dispatch
        for (name, workers) in [("dense", 1usize), ("dense", 4), ("crossbar", 4)] {
            let be = registry.create(name, &ctx)?;
            let eng = ParallelEngine::new(be, workers);
            let h = Mat::zeros(32, cfg.nh);
            let x = Mat::from_fn(32, cfg.nx, |r, c| ((r * 13 + c) % 9) as f32 * 0.1 - 0.4);
            timeit(
                &mut recs,
                &format!("serve_step_batch ({name}, b=32, workers={workers})"),
                50,
                || {
                    eng.step_sessions(&h, &x).unwrap();
                },
            );
        }
    }
    if runs("serve_step_kernel") {
        // the padded single-timestep dispatch under each forced kernel:
        // how much of the SIMD matmul win survives the serving overhead
        for (name, kern) in
            [("dense", "scalar"), ("dense", "simd"), ("crossbar", "scalar"), ("crossbar", "simd")]
        {
            kernels::force(kern)?;
            let be = registry.create(name, &ctx)?;
            let eng = ParallelEngine::new(be, 1);
            let h = Mat::zeros(32, cfg.nh);
            let x = Mat::from_fn(32, cfg.nx, |r, c| ((r * 13 + c) % 9) as f32 * 0.1 - 0.4);
            timeit(&mut recs, &format!("serve_step ({name}, b=32, kernel={kern})"), 50, || {
                eng.step_sessions(&h, &x).unwrap();
            });
        }
        kernels::force("")?;
    }
    if runs("serve_step_int8") {
        // the same padded dispatch through the int8 path: pre-quantized
        // snapshot planes + i8xi8->i32 MACs (acceptance: the simd row
        // must clear 1.5x the f32 simd serve_step row)
        kernels::force_precision("int8")?;
        for kern in ["scalar", "simd"] {
            kernels::force(kern)?;
            let be = registry.create("dense", &ctx)?;
            let eng = ParallelEngine::new(be, 1);
            let snap = WeightSnapshot::new(0, eng.backend().effective_params());
            let h = Mat::zeros(32, cfg.nh);
            let x = Mat::from_fn(32, cfg.nx, |r, c| ((r * 13 + c) % 9) as f32 * 0.1 - 0.4);
            timeit(&mut recs, &format!("serve_step (dense, int8, b=32, kernel={kern})"), 50, || {
                eng.step_sessions_snap(&snap, &h, &x).unwrap();
            });
        }
        kernels::force("")?;
        kernels::force_precision("")?;
    }
    if runs("net_encode") {
        // wire-codec encode cost per 1k Step frames at serving width
        let x: Vec<f32> = (0..cfg.nx).map(|i| (i as f32 * 0.37).sin()).collect();
        timeit(&mut recs, "net_encode (1k Step frames, nx=28)", 50, || {
            for s in 0..1000u64 {
                let _ = encode_frame(FLAG_TICK, &Message::Step { session: s, x: x.clone() });
            }
        });
    }
    if runs("net_decode") {
        let x: Vec<f32> = (0..cfg.nx).map(|i| (i as f32 * 0.37).cos()).collect();
        let buf = encode_frame(FLAG_TICK, &Message::Step { session: 7, x });
        timeit(&mut recs, "net_decode (1k Step frames, nx=28)", 50, || {
            for _ in 0..1000 {
                let _ = decode_frame(&buf).unwrap();
            }
        });
    }
    if runs("checkpoint_write") {
        // snapshot cost for a pmnist100 core with 64 live sessions and
        // some replay history (the durability hot path)
        let mut run = RunConfig::default();
        run.serve.max_batch = 16;
        run.serve.update_every = 16;
        let mut core = ServeCore::new(cfg, &run).unwrap();
        let mut wl = SyntheticWorkload::new(&cfg, 64, 1);
        for _ in 0..40 {
            for _ in 0..16 {
                let (u, x, label) = wl.next();
                core.submit(session_id_for_user(u), x, label, 0);
            }
            core.drain_ready().unwrap();
            core.advance_tick();
        }
        core.flush_all().unwrap();
        let dir = std::env::temp_dir().join(format!("m2ru_bench_ckpt_{}", std::process::id()));
        timeit(&mut recs, "checkpoint_write (pmnist100, 64 sessions)", 20, || {
            save_checkpoint(&mut core, &dir).unwrap();
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
    if runs("snapshot_delta_write") {
        // the incremental path: same serving shape as checkpoint_write,
        // but each iteration dirties one 16-request wave and writes only
        // the delta against the chain base — vs rewriting the full state
        let mut run = RunConfig::default();
        run.serve.max_batch = 16;
        run.serve.update_every = 16;
        let mut core = ServeCore::new(cfg, &run).unwrap();
        let mut wl = SyntheticWorkload::new(&cfg, 64, 1);
        for _ in 0..40 {
            for _ in 0..16 {
                let (u, x, label) = wl.next();
                core.submit(session_id_for_user(u), x, label, 0);
            }
            core.drain_ready().unwrap();
            core.advance_tick();
        }
        core.flush_all().unwrap();
        let dir = std::env::temp_dir().join(format!("m2ru_bench_delta_{}", std::process::id()));
        save_checkpoint(&mut core, &dir).unwrap(); // chain base
        timeit(&mut recs, "snapshot_delta_write (16-req wave dirty)", 20, || {
            for _ in 0..16 {
                let (u, x, label) = wl.next();
                core.submit(session_id_for_user(u), x, label, 0);
            }
            core.drain_ready().unwrap();
            core.advance_tick();
            save_delta(&mut core, &dir).unwrap();
        });
        let _ = std::fs::remove_dir_all(&dir);
    }
    if runs("router_dispatch") {
        // pure routing overhead: hash-mod dispatch of one 128-request
        // wave into 4 in-process shards + the lock-step wave barrier
        // (shards idle-tick; inference only, pmnist100 width)
        let mut run = RunConfig::default();
        run.serve = ServeConfig { max_batch: 32, capacity: 4096, update_every: 0, ..ServeConfig::default() };
        run.router.shards = 4;
        let mut rc = RouterCore::new(cfg, &run)?;
        let x: Vec<f32> = (0..cfg.nx).map(|i| (i as f32 * 0.13).sin()).collect();
        let mut user = 0u64;
        timeit(&mut recs, "router_dispatch (4 shards, 128-req wave)", 30, || {
            for _ in 0..128 {
                let sid = rc.session_id(user % 512);
                rc.submit(sid, x.clone(), None, 0).unwrap();
                user += 1;
            }
            rc.wave(true, true).unwrap();
        });
        rc.finish()?;
    }
    if runs("router_serve") {
        // shard-count throughput: the same 512-request synthetic run
        // through 1/2/4 in-process shards (construction included, like
        // serve_e2e) — the scaling row of results/BENCH_serve.json
        for shards in [1usize, 2, 4] {
            let mut run = RunConfig::default();
            run.serve = ServeConfig {
                max_batch: 32,
                capacity: 256,
                update_every: 4,
                ..ServeConfig::default()
            };
            run.router.shards = shards;
            let mut wl_master = SyntheticWorkload::new(&cfg, 16, 1);
            let waves: Vec<Vec<(u64, Vec<f32>, Option<usize>)>> = (0..16)
                .map(|_| (0..32).map(|_| wl_master.next()).collect())
                .collect();
            timeit(&mut recs, &format!("router_serve (512 reqs, shards={shards})"), 5, || {
                let mut rc = RouterCore::new(cfg, &run).unwrap();
                for (i, wave) in waves.iter().enumerate() {
                    for (u, x, label) in wave {
                        let sid = rc.session_id(*u);
                        rc.submit(sid, x.clone(), *label, 0).unwrap();
                    }
                    rc.wave(true, i + 1 == waves.len()).unwrap();
                }
                rc.finish().unwrap();
            });
        }
    }
    if runs("commit_async_p99") {
        // serve-loop latency during a commit burst: p99 over per-wave
        // `drain_ready` calls, with ~500 µs of inter-wave frontend work
        // (the open-loop arrival gap commits overlap into). The async
        // pipeline enqueues commits and keeps dispatching; the `sync`
        // baseline applies each commit inline on the serve thread.
        let small = NetConfig::SMALL;
        let mut p99_drain = |name: &str, sync: bool| {
            let mut run = RunConfig::default();
            run.serve = ServeConfig {
                max_batch: 16,
                max_wait: 2,
                capacity: 64,
                update_every: 8,
                ..ServeConfig::default()
            };
            let mut core = ServeCore::new(small, &run).unwrap();
            core.set_collect_logits(false);
            core.set_commit_sync(sync);
            let mut wl = SyntheticWorkload::new(&small, 32, 3);
            let mut lat_ns: Vec<f64> = Vec::with_capacity(400);
            for _ in 0..400 {
                for _ in 0..16 {
                    let (u, x, label) = wl.next();
                    core.submit(session_id_for_user(u), x, label, 0);
                }
                let t = Instant::now();
                core.drain_ready().unwrap();
                lat_ns.push(t.elapsed().as_nanos() as f64);
                core.advance_tick();
                std::thread::sleep(std::time::Duration::from_micros(500));
            }
            core.sync_commits().unwrap();
            lat_ns.sort_by(f64::total_cmp);
            let p99 = lat_ns[(lat_ns.len() * 99 / 100).min(lat_ns.len() - 1)];
            println!("{name:<46} {:>10.3} ms/p99-drain  (n=400 waves)", p99 / 1e6);
            recs.push(BenchRecord { name: name.to_string(), iters: 400, ns_per_iter: p99 });
        };
        p99_drain("commit_async_p99 (small, update_every=8)", false);
        p99_drain("commit_sync_p99 (inline-commit baseline)", true);
    }
    if runs("serve_e2e") {
        // whole serve loop: batcher + store + sharded stepping (workers=4,
        // padded b=32) + online commits (16 sessions x ~32 steps each on
        // nt=28 yields ~16 labels => several update_every=4 commits)
        let mut run = RunConfig::default();
        run.workers = 4;
        run.serve =
            ServeConfig { max_batch: 32, capacity: 256, update_every: 4, ..ServeConfig::default() };
        let mut opts = ServeOptions::new(NetConfig::PMNIST100, run);
        opts.requests = 512;
        opts.sessions = 16;
        timeit(&mut recs, "serve_e2e (dense, 512 reqs, 16 sessions, workers=4)", 5, || {
            run_serve(&opts).unwrap();
        });
    }
    if runs("obs_overhead") {
        // cost of the observability layer on the whole serve loop: the
        // serve_e2e operating point with the registry + spans off, fully
        // on, and sampled (1-in-16 span timing, exact mirrors either
        // way). The signatures are bitwise-identical across all three
        // (tests/obs_invariance.rs); only the wall clock may move.
        for mode in ["off", "on", "sampled"] {
            let mut run = RunConfig::default();
            run.workers = 4;
            run.serve = ServeConfig {
                max_batch: 32,
                capacity: 256,
                update_every: 4,
                ..ServeConfig::default()
            };
            run.obs.mode = mode.to_string();
            let mut opts = ServeOptions::new(NetConfig::PMNIST100, run);
            opts.requests = 512;
            opts.sessions = 16;
            timeit(&mut recs, &format!("obs_overhead (512 reqs, obs={mode})"), 5, || {
                run_serve(&opts).unwrap();
            });
        }
    }

    write_bench_json("results/BENCH_serve.json", &recs)?;
    println!("[wrote results/BENCH_serve.json: {} records]", recs.len());

    // everything below needs a real PJRT runtime + `make artifacts`;
    // probing all the way through ModelBundle::load also catches the
    // offline xla stub (client constructs, HLO parsing errors)
    let xla_env = (|| -> anyhow::Result<(Runtime, Manifest, ModelBundle)> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load("artifacts")?;
        let bundle = ModelBundle::load(&rt, &manifest, cfg)?;
        Ok((rt, manifest, bundle))
    })();
    let (rt, manifest, bundle) = match xla_env {
        Ok(pair) => pair,
        Err(e) => {
            println!();
            println!("== artifact + XLA benches skipped ==============================");
            println!("   ({e})");
            println!("\nbench_main done");
            return Ok(());
        }
    };

    println!();
    println!("== paper artifacts ==============================================");
    if runs("table1") {
        let t = Instant::now();
        run_table1()?.save("results/bench")?;
        println!("table1 regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("headline") {
        let t = Instant::now();
        run_headline()?.save("results/bench")?;
        println!("headline regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5c") {
        let t = Instant::now();
        run_fig5c()?.save("results/bench")?;
        println!("fig5c regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5d") {
        let t = Instant::now();
        run_fig5d()?.save("results/bench")?;
        println!("fig5d regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5a") {
        let t = Instant::now();
        run_fig5a(20, 0)?.save("results/bench")?;
        println!("fig5a regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5b") {
        let t = Instant::now();
        let mut opts = Fig5bOptions::default();
        opts.run.train_per_task = 160;
        opts.run.test_per_task = 60;
        opts.run.epochs = 1;
        run_fig5b(&rt, &manifest, &opts)?.save("results/bench")?;
        println!("fig5b (quick) regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig4") {
        let t = Instant::now();
        let opts = Fig4Options {
            dataset: "pmnist".into(),
            nh: 100,
            engines: vec!["dfa".into(), "hw".into()],
            run: RunConfig {
                num_tasks: 2,
                train_per_task: 300,
                test_per_task: 100,
                epochs: 3,
                replay_per_task: 150,
                ..RunConfig::default()
            },
        };
        let (rep, _) = run_fig4(&rt, &manifest, &opts)?;
        rep.save("results/bench")?;
        println!("fig4 (quick, pmnist/100) regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }

    println!();
    println!("== XLA hot-path microbenches ====================================");
    if runs("xla_train_step") {
        let mut eng = XlaDfaEngine::new(&bundle, 0.96, 0.3, 0.3, 1);
        timeit(&mut recs, "xla_train_step (dfa, b=32, pmnist100)", 20, || {
            eng.train_batch(&train_b).unwrap();
        });
    }
    if runs("xla_eval") {
        let mut eng = XlaDfaEngine::new(&bundle, 0.96, 0.3, 0.3, 1);
        timeit(&mut recs, "xla_eval (sw forward, b=200)", 20, || {
            eng.eval_batch(&eval_b).unwrap();
        });
    }
    if runs("hw_eval") {
        let mut eng = HardwareEngine::new(&bundle, 0.96, 0.3, 0.3, DeviceParams::default(), 1);
        timeit(&mut recs, "hw_eval (WBS+ADC forward, b=200)", 5, || {
            eng.eval_batch(&eval_b).unwrap();
        });
    }
    if runs("hw_train_step") {
        let mut eng = HardwareEngine::new(&bundle, 0.96, 0.3, 0.3, DeviceParams::default(), 1);
        timeit(&mut recs, "hw_train_step (dfa + ziksa writes, b=32)", 10, || {
            eng.train_batch(&train_b).unwrap();
        });
    }
    // refresh the JSON so the XLA records land too
    write_bench_json("results/BENCH_serve.json", &recs)?;
    println!("\nbench_main done");
    Ok(())
}
