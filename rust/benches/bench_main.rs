//! Benchmark harness (`cargo bench`) — no criterion in the offline
//! environment, so this is a self-contained harness with warm-up,
//! repetition and mean/min/max reporting.
//!
//! Three families:
//!  1. **Pure-rust microbenches** — run everywhere, no artifacts needed:
//!     the blocked-vs-ikj matmul comparison (§Perf acceptance: blocked
//!     must win at ≥256×256), backend train/eval steps through the
//!     registry, parallel-eval worker scaling, replay pipeline, crossbar
//!     programming.
//!  2. **Paper artifacts** — regenerates every table/figure (fig4 and
//!     fig5b in scaled-down "quick" mode; fig5a/c/d, table1, headline in
//!     full) and archives the reports under `results/bench_*`.
//!  3. **XLA hot-path microbenches** — train/eval step latency through
//!     the AOT artifacts. Families 2–3 are skipped with a notice when no
//!     artifacts/PJRT runtime are present.
//!
//! Select with `cargo bench -- <filter>` (substring match).

use std::time::Instant;

use m2ru::backend::{BackendCtx, BackendRegistry, ComputeBackend};
use m2ru::config::{Manifest, NetConfig, RunConfig};
use m2ru::coordinator::{Engine, HardwareEngine, ParallelEngine, RustDfaEngine, XlaDfaEngine};
use m2ru::data::{permuted_task_stream, synthetic_mnist, Example};
use m2ru::device::{DeviceParams, DifferentialCrossbar, ZiksaProgrammer};
use m2ru::experiments::{
    run_fig4, run_fig5a, run_fig5b, run_fig5c, run_fig5d, run_headline, run_table1, Fig4Options,
    Fig5bOptions,
};
use m2ru::linalg::Mat;
use m2ru::nn::SeqBatch;
use m2ru::replay::ReplayBuffer;
use m2ru::rng::GaussianRng;
use m2ru::runtime::{ModelBundle, Runtime};

fn timeit<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warm-up
    f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!("{name:<46} {mean:>10.3} ms/iter  (min {min:>8.3}, max {max:>8.3}, n={iters})");
}

fn batch_from(examples: &[Example], b: usize, nt: usize, nx: usize) -> SeqBatch {
    let mut sb = SeqBatch::zeros(b, nt, nx);
    for i in 0..b {
        let e = &examples[i % examples.len()];
        sb.sample_mut(i).copy_from_slice(&e.features);
        sb.labels[i] = e.label;
    }
    sb
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_default();
    let runs = |name: &str| filter.is_empty() || name.contains(&filter);

    let cfg = NetConfig::PMNIST100;
    let stream = permuted_task_stream(1, 64, 16, 0);
    let train_b = batch_from(&stream.tasks[0].train, cfg.b_train, cfg.nt, cfg.nx);
    let eval_b = batch_from(&stream.tasks[0].train, cfg.b_eval, cfg.nt, cfg.nx);
    let registry = BackendRegistry::with_defaults();
    let ctx = BackendCtx::from_run(cfg, &RunConfig::default());

    println!("== pure-rust microbenches ======================================");
    if runs("matmul") {
        // §Perf acceptance: matmul_blocked must beat matmul_ikj at >=256
        for &n in &[128usize, 256, 512] {
            let a = Mat::from_fn(n, n, |r, c| ((r * 31 + c * 7) % 13) as f32 * 0.1 - 0.6);
            let b = Mat::from_fn(n, n, |r, c| ((r * 17 + c * 3) % 11) as f32 * 0.1 - 0.5);
            let iters = if n >= 512 { 8 } else { 20 };
            timeit(&format!("matmul_ikj ({n}x{n})"), iters, || {
                let _ = a.matmul_ikj(&b);
            });
            timeit(&format!("matmul_blocked ({n}x{n})"), iters, || {
                let _ = a.matmul_blocked(&b);
            });
        }
    }
    if runs("backend_train_step") {
        for name in ["dense", "crossbar"] {
            let mut be = registry.create(name, &ctx)?;
            timeit(&format!("backend_train_step ({name}, b=32, pmnist100)"), 10, || {
                be.train_dfa(&train_b).unwrap();
            });
        }
    }
    if runs("backend_eval") {
        for name in ["dense", "crossbar"] {
            let be = registry.create(name, &ctx)?;
            timeit(&format!("backend_eval ({name}, b=200, pmnist100)"), 10, || {
                be.forward(&eval_b).unwrap();
            });
        }
    }
    if runs("parallel_eval") {
        // worker scaling of the serving engine; merged metrics are
        // identical across worker counts (see tests/backend_parity.rs)
        for workers in [1usize, 2, 4] {
            let be = registry.create("crossbar", &ctx)?;
            let mut eng = ParallelEngine::new(be, workers);
            timeit(&format!("parallel_eval (crossbar, b=200, workers={workers})"), 10, || {
                eng.eval_batch(&eval_b).unwrap();
            });
        }
    }
    if runs("rust_train_step") {
        let mut eng = RustDfaEngine::new(28, 100, 10, 0.96, 0.3, 0.3, Some(0.53), 1);
        timeit("rust_train_step (digital baseline, b=32)", 10, || {
            eng.train_batch(&train_b).unwrap();
        });
    }
    if runs("l3_host_overhead") {
        // host-side share of one train step: batch assembly + all
        // literal uploads, with no XLA execution. Quantifies whether the
        // coordinator (L3) is ever the bottleneck (paper: it must not be).
        use m2ru::nn::{make_psi, MiruParams};
        use m2ru::runtime::host_overhead_probe;
        let p = MiruParams::init(cfg.nx, cfg.nh, cfg.ny, 1);
        let psi = make_psi(cfg.ny, cfg.nh, 2);
        timeit("l3_host_overhead (literals for 1 train step)", 50, || {
            host_overhead_probe(&p, &psi, &train_b).unwrap();
        });
    }
    if runs("replay_pipeline") {
        let digits = synthetic_mnist(256, 0);
        timeit("replay_pipeline (reservoir+squant, 256 imgs)", 20, || {
            let mut buf = ReplayBuffer::new(64, 0.0, 1.0, 42);
            buf.begin_task();
            for e in &digits {
                buf.offer(e);
            }
        });
    }
    if runs("replay_sample") {
        let digits = synthetic_mnist(256, 0);
        let mut buf = ReplayBuffer::new(128, 0.0, 1.0, 42);
        buf.begin_task();
        for e in &digits {
            buf.offer(e);
        }
        buf.begin_task();
        let mut rng = GaussianRng::new(1);
        timeit("replay_sample (draw+dequant 32 examples)", 50, || {
            let _ = buf.sample_past(32, &mut rng);
        });
    }
    if runs("crossbar_program") {
        let mut xb = DifferentialCrossbar::new(128, 100, 1.0, DeviceParams::default(), 0);
        let w = Mat::from_fn(128, 100, |r, c| ((r + c) % 13) as f32 * 0.01);
        let mut prog = ZiksaProgrammer::new();
        timeit("crossbar_program (12.8k devices)", 20, || {
            prog.apply(&mut xb, &w);
        });
    }
    if runs("crossbar_read") {
        let xb = DifferentialCrossbar::new(128, 100, 1.0, DeviceParams::default(), 0);
        timeit("crossbar_read (12.8k devices)", 50, || {
            let _ = xb.read_weights();
        });
    }

    // everything below needs a real PJRT runtime + `make artifacts`;
    // probing all the way through ModelBundle::load also catches the
    // offline xla stub (client constructs, HLO parsing errors)
    let xla_env = (|| -> anyhow::Result<(Runtime, Manifest, ModelBundle)> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load("artifacts")?;
        let bundle = ModelBundle::load(&rt, &manifest, cfg)?;
        Ok((rt, manifest, bundle))
    })();
    let (rt, manifest, bundle) = match xla_env {
        Ok(pair) => pair,
        Err(e) => {
            println!();
            println!("== artifact + XLA benches skipped ==============================");
            println!("   ({e})");
            println!("\nbench_main done");
            return Ok(());
        }
    };

    println!();
    println!("== paper artifacts ==============================================");
    if runs("table1") {
        let t = Instant::now();
        run_table1()?.save("results/bench")?;
        println!("table1 regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("headline") {
        let t = Instant::now();
        run_headline()?.save("results/bench")?;
        println!("headline regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5c") {
        let t = Instant::now();
        run_fig5c()?.save("results/bench")?;
        println!("fig5c regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5d") {
        let t = Instant::now();
        run_fig5d()?.save("results/bench")?;
        println!("fig5d regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5a") {
        let t = Instant::now();
        run_fig5a(20, 0)?.save("results/bench")?;
        println!("fig5a regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig5b") {
        let t = Instant::now();
        let mut opts = Fig5bOptions::default();
        opts.run.train_per_task = 160;
        opts.run.test_per_task = 60;
        opts.run.epochs = 1;
        run_fig5b(&rt, &manifest, &opts)?.save("results/bench")?;
        println!("fig5b (quick) regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }
    if runs("fig4") {
        let t = Instant::now();
        let opts = Fig4Options {
            dataset: "pmnist".into(),
            nh: 100,
            engines: vec!["dfa".into(), "hw".into()],
            run: RunConfig {
                num_tasks: 2,
                train_per_task: 300,
                test_per_task: 100,
                epochs: 3,
                replay_per_task: 150,
                ..RunConfig::default()
            },
        };
        let (rep, _) = run_fig4(&rt, &manifest, &opts)?;
        rep.save("results/bench")?;
        println!("fig4 (quick, pmnist/100) regenerated in {:.2}s", t.elapsed().as_secs_f64());
    }

    println!();
    println!("== XLA hot-path microbenches ====================================");
    if runs("xla_train_step") {
        let mut eng = XlaDfaEngine::new(&bundle, 0.96, 0.3, 0.3, 1);
        timeit("xla_train_step (dfa, b=32, pmnist100)", 20, || {
            eng.train_batch(&train_b).unwrap();
        });
    }
    if runs("xla_eval") {
        let mut eng = XlaDfaEngine::new(&bundle, 0.96, 0.3, 0.3, 1);
        timeit("xla_eval (sw forward, b=200)", 20, || {
            eng.eval_batch(&eval_b).unwrap();
        });
    }
    if runs("hw_eval") {
        let mut eng = HardwareEngine::new(&bundle, 0.96, 0.3, 0.3, DeviceParams::default(), 1);
        timeit("hw_eval (WBS+ADC forward, b=200)", 5, || {
            eng.eval_batch(&eval_b).unwrap();
        });
    }
    if runs("hw_train_step") {
        let mut eng = HardwareEngine::new(&bundle, 0.96, 0.3, 0.3, DeviceParams::default(), 1);
        timeit("hw_train_step (dfa + ziksa writes, b=32)", 10, || {
            eng.train_batch(&train_b).unwrap();
        });
    }
    println!("\nbench_main done");
    Ok(())
}
