//! Multi-worker serving engine: shards batches across `std::thread`
//! workers with per-worker backend instances and merges the results
//! deterministically (DESIGN.md §7).
//!
//! * **Eval** — rows are split into contiguous shards, one per worker;
//!   each worker runs the forward pass on its own forked backend
//!   instance and predictions are concatenated in shard order. Because
//!   the forward math is row-independent, the merged predictions are
//!   *identical* for every worker count.
//! * **Train** — workers compute dense unit-lr DFA gradients on their
//!   row shards from the same (read-shared) backend; the master merges
//!   them weighted by shard size in shard order, applies ζ and the
//!   learning rate once on the merged tensor (sparsifying per-shard
//!   would change which entries win), and commits a single update. The
//!   math is exactly the whole-batch step; results differ from
//!   single-worker only by f32 re-association across the shard sums.
//! * Backends lowered with static batch shapes
//!   ([`ComputeBackend::prefers_whole_batch`]) are never sharded.

use anyhow::{anyhow, Result};

use crate::backend::{finalize_update, ColumnWear, ComputeBackend};
use crate::linalg::{argmax_rows, Mat};
use crate::nn::{DfaDeltas, MiruParams, SeqBatch};

use super::engine::Engine;

/// An [`Engine`] that drives one [`ComputeBackend`] with a worker pool.
/// `workers == 1` is the plain sequential path.
pub struct ParallelEngine {
    backend: Box<dyn ComputeBackend>,
    workers: usize,
    /// Cached per-worker instances for eval sharding; refreshed after
    /// every weight update.
    forks: Vec<Box<dyn ComputeBackend>>,
    forks_stale: bool,
}

impl ParallelEngine {
    pub fn new(backend: Box<dyn ComputeBackend>, workers: usize) -> ParallelEngine {
        ParallelEngine { backend, workers: workers.max(1), forks: Vec::new(), forks_stale: true }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Resize the worker pool (fork cache is rebuilt lazily). Metrics are
    /// worker-count-invariant, so this is purely a throughput knob.
    pub fn set_workers(&mut self, workers: usize) {
        self.workers = workers.max(1);
        self.forks_stale = true;
    }

    /// The wrapped backend (current weights, substrate statistics).
    pub fn backend(&self) -> &dyn ComputeBackend {
        &*self.backend
    }

    /// Substrate statistics (write pressure, endurance) for reports.
    pub fn stats(&self) -> Vec<String> {
        self.backend.stats()
    }

    fn use_sharding(&self, b: usize) -> bool {
        self.workers > 1 && !self.backend.prefers_whole_batch() && b >= 2 * self.workers
    }

    /// Contiguous `(start, len)` row ranges, one per worker (first
    /// `b % parts` ranges take the extra row); empty ranges are dropped.
    fn shard_ranges(b: usize, parts: usize) -> Vec<(usize, usize)> {
        let base = b / parts;
        let rem = b % parts;
        let mut out = Vec::with_capacity(parts);
        let mut start = 0;
        for w in 0..parts {
            let len = base + usize::from(w < rem);
            if len == 0 {
                continue;
            }
            out.push((start, len));
            start += len;
        }
        out
    }

    /// Contiguous row shards, one per worker.
    fn shard(x: &SeqBatch, parts: usize) -> Vec<SeqBatch> {
        let row = x.nt * x.nx;
        Self::shard_ranges(x.b, parts)
            .into_iter()
            .map(|(start, len)| {
                let mut sb = SeqBatch::zeros(len, x.nt, x.nx);
                sb.data.copy_from_slice(&x.data[start * row..(start + len) * row]);
                sb.labels.copy_from_slice(&x.labels[start..start + len]);
                sb
            })
            .collect()
    }

    /// Advance many independent per-session hidden-state rows by one
    /// timestep and read out logits — the streaming-serving analogue of
    /// [`Engine::eval_batch`]. `h` is `[b, nh]` (one session per row), `x`
    /// is `[b, nx]`; returns `(new_h, logits)`. The substrate is read
    /// *once* per dispatch (a crossbar read walks every memristor — the
    /// same snapshot discipline as the train path) and shared by all
    /// workers; rows are sharded with the same range discipline as
    /// eval/train sharding. The step math is row-independent, so the
    /// merged result is identical for every worker count.
    pub fn step_sessions(&self, h: &Mat, x: &Mat) -> Result<(Mat, Mat)> {
        self.step_sessions_at(&self.backend.effective_params(), h, x)
    }

    /// [`ParallelEngine::step_sessions`] against a caller-supplied weight
    /// snapshot — the async-commit serve path: the serve loop steps
    /// against the atomically swapped immutable snapshot published by
    /// the committer thread, never reading this engine's own (stale)
    /// substrate. Bitwise-identical to `step_sessions` when `snapshot`
    /// equals this backend's effective weights.
    pub fn step_sessions_at(&self, snapshot: &MiruParams, h: &Mat, x: &Mat) -> Result<(Mat, Mat)> {
        self.shard_step(h, x, |backend, hs, xs| {
            let hn = backend.step_hidden_from(snapshot, hs, xs)?;
            let logits = backend.readout_from(snapshot, &hn)?;
            Ok((hn, logits))
        })
    }

    /// [`ParallelEngine::step_sessions_at`] against a full serve
    /// snapshot, dispatching on its precision: a snapshot carrying
    /// pre-quantized i8 planes routes through the backend's int8 step
    /// and readout (DESIGN.md §15); an f32 snapshot takes the exact
    /// path of `step_sessions_at`. Both are row-independent (activation
    /// scales are per row, never per batch), so the merged result stays
    /// identical for every worker count.
    pub fn step_sessions_snap(
        &self,
        snap: &crate::serve::WeightSnapshot,
        h: &Mat,
        x: &Mat,
    ) -> Result<(Mat, Mat)> {
        match &snap.quant {
            Some(q) => self.shard_step(h, x, |backend, hs, xs| {
                let hn = backend.step_hidden_int8(&snap.params, q, hs, xs)?;
                let logits = backend.readout_int8(&snap.params, q, &hn)?;
                Ok((hn, logits))
            }),
            None => self.step_sessions_at(&snap.params, h, x),
        }
    }

    /// The sharding scaffold behind the session-step entry points: run
    /// `step` on the whole batch (no sharding) or on contiguous row
    /// shards across scoped worker threads, merging rows in shard order.
    /// `step` must be row-independent for the worker-count invariance
    /// contract to hold.
    fn shard_step<F>(&self, h: &Mat, x: &Mat, step: F) -> Result<(Mat, Mat)>
    where
        F: Fn(&dyn ComputeBackend, &Mat, &Mat) -> Result<(Mat, Mat)> + Sync,
    {
        anyhow::ensure!(h.rows == x.rows, "state rows {} != input rows {}", h.rows, x.rows);
        let b = h.rows;
        if !self.use_sharding(b) {
            return step(&*self.backend, h, x);
        }
        let shards: Vec<(Mat, Mat)> = Self::shard_ranges(b, self.workers)
            .into_iter()
            .map(|(start, len)| (h.rows_copy(start, len), x.rows_copy(start, len)))
            .collect();
        let results: Vec<Result<(Mat, Mat)>> = std::thread::scope(|s| {
            let backend: &dyn ComputeBackend = &*self.backend;
            let step = &step;
            let handles: Vec<_> = shards
                .iter()
                .map(|(hs, xs)| s.spawn(move || step(backend, hs, xs)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("step worker panicked"))))
                .collect()
        });
        let mut outs = Vec::with_capacity(results.len());
        for r in results {
            outs.push(r?);
        }
        let ny = outs[0].1.cols;
        let mut hn = Mat::zeros(b, h.cols);
        let mut logits = Mat::zeros(b, ny);
        let mut row = 0;
        for (hs, ls) in &outs {
            for r in 0..hs.rows {
                hn.row_mut(row).copy_from_slice(hs.row(r));
                logits.row_mut(row).copy_from_slice(ls.row(r));
                row += 1;
            }
        }
        Ok((hn, logits))
    }

    /// One whole-batch DFA step with **no sharding**, regardless of the
    /// worker count — the online-serving commit path. The weight snapshot
    /// is read once, gradients are computed once, and a single writer
    /// commits, so serve metrics stay bit-identical for any `--workers`
    /// (sharded training merges differ by f32 re-association).
    pub fn train_whole(&mut self, x: &SeqBatch) -> Result<f32> {
        self.forks_stale = true;
        self.backend.train_dfa(x)
    }

    /// [`ParallelEngine::train_whole`] with wear-aware write rationing:
    /// before committing, consult the substrate's per-column device write
    /// counts and zero the finalized deltas of every column whose
    /// cumulative writes exceed `wear_ratio ×` the column mean — those
    /// bitlines skip this commit's programming pulses entirely, letting
    /// the rest of the array catch up. Returns `(loss, rationed columns)`.
    /// A `wear_ratio` of 0 or a substrate without wear accounting (dense
    /// weights) falls through to the plain commit, bit-identical to
    /// `train_whole`.
    pub fn train_whole_guarded(&mut self, x: &SeqBatch, wear_ratio: f32) -> Result<(f32, u64)> {
        self.forks_stale = true;
        if wear_ratio > 0.0 {
            if let Some(wear) = self.backend.column_write_counts() {
                let mut d = self.backend.dfa_raw_grads(x)?;
                finalize_update(&mut d, &self.backend.hyper());
                let rationed = ration_overstressed(&mut d, &wear, wear_ratio);
                self.backend.apply_update(&d)?;
                return Ok((d.loss, rationed));
            }
        }
        Ok((self.backend.train_dfa(x)?, 0))
    }

    /// Overwrite the backend's weights from a checkpointed snapshot (see
    /// [`ComputeBackend::restore_params`]) and invalidate the fork cache.
    pub fn restore_params(&mut self, p: &MiruParams) -> Result<()> {
        self.forks_stale = true;
        self.backend.restore_params(p)
    }

    /// Overwrite the substrate's wear record from a checkpoint (see
    /// [`ComputeBackend::restore_wear`]); called after `restore_params`
    /// so the reload's own programming pulses are not double-counted.
    pub fn restore_wear(&mut self, w: &crate::backend::WearState) -> Result<()> {
        self.backend.restore_wear(w)
    }

    /// Shutdown/drain hook: release the cached per-worker backend forks
    /// (each holds a full substrate copy) and mark them stale, so a
    /// stopping serve loop frees per-worker memory before checkpointing
    /// and a restarted loop re-forks from the restored master weights.
    pub fn drain(&mut self) {
        self.forks.clear();
        self.forks_stale = true;
    }

    fn refresh_forks(&mut self) -> Result<()> {
        if !self.forks_stale && self.forks.len() == self.workers {
            return Ok(());
        }
        self.forks.clear();
        for _ in 0..self.workers {
            self.forks.push(self.backend.fork()?);
        }
        self.forks_stale = false;
        Ok(())
    }
}

/// Zero the delta columns of over-stressed bitlines. The hidden crossbar
/// stacks `[W_h; U_h]`, so a hidden wear column maps to the same column
/// of both delta matrices; readout wear maps to `W_o` columns. Biases
/// live in digital registers and are never rationed.
fn ration_overstressed(d: &mut DfaDeltas, wear: &ColumnWear, ratio: f32) -> u64 {
    let mut rationed = 0;
    rationed += ration_cols(&mut [&mut d.d_wh, &mut d.d_uh], &wear.hidden, ratio);
    rationed += ration_cols(&mut [&mut d.d_wo], &wear.readout, ratio);
    rationed
}

/// Zero column `c` of every matrix when `counts[c] > ratio × mean(counts)`.
/// Returns the number of rationed columns.
fn ration_cols(mats: &mut [&mut Mat], counts: &[u64], ratio: f32) -> u64 {
    if counts.is_empty() {
        return 0;
    }
    let mean = counts.iter().sum::<u64>() as f64 / counts.len() as f64;
    if mean <= 0.0 {
        return 0;
    }
    let cut = mean * f64::from(ratio);
    let mut rationed = 0;
    for (c, &w) in counts.iter().enumerate() {
        if w as f64 > cut {
            for m in mats.iter_mut() {
                debug_assert_eq!(m.cols, counts.len(), "wear column count mismatch");
                for r in 0..m.rows {
                    *m.at_mut(r, c) = 0.0;
                }
            }
            rationed += 1;
        }
    }
    rationed
}

fn scale_deltas(d: &mut DfaDeltas, w: f32) {
    d.d_wh.scale(w);
    d.d_uh.scale(w);
    d.d_wo.scale(w);
    for v in &mut d.d_bh {
        *v *= w;
    }
    for v in &mut d.d_bo {
        *v *= w;
    }
    d.loss *= w;
}

fn add_deltas(acc: &mut DfaDeltas, d: &DfaDeltas) {
    acc.d_wh.add_scaled(&d.d_wh, 1.0);
    acc.d_uh.add_scaled(&d.d_uh, 1.0);
    acc.d_wo.add_scaled(&d.d_wo, 1.0);
    for (a, &v) in acc.d_bh.iter_mut().zip(&d.d_bh) {
        *a += v;
    }
    for (a, &v) in acc.d_bo.iter_mut().zip(&d.d_bo) {
        *a += v;
    }
    acc.loss += d.loss;
}

impl Engine for ParallelEngine {
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32> {
        self.forks_stale = true;
        if !self.use_sharding(x.b) {
            return self.backend.train_dfa(x);
        }
        let shards = Self::shard(x, self.workers);
        // one substrate read per step, shared by all workers (a crossbar
        // read walks every memristor — doing it per worker would erode
        // the sharding speedup)
        let snapshot = self.backend.effective_params();
        let grads: Vec<Result<DfaDeltas>> = std::thread::scope(|s| {
            let backend: &dyn ComputeBackend = &*self.backend;
            let snapshot = &snapshot;
            let handles: Vec<_> = shards
                .iter()
                .map(|sh| s.spawn(move || backend.dfa_raw_grads_from(snapshot, sh)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("train worker panicked"))))
                .collect()
        });
        // merge weighted by shard size, in shard order (deterministic)
        let b_total = x.b as f32;
        let mut merged: Option<DfaDeltas> = None;
        for (sh, g) in shards.iter().zip(grads) {
            let mut g = g?;
            scale_deltas(&mut g, sh.b as f32 / b_total);
            match merged.as_mut() {
                None => merged = Some(g),
                Some(m) => add_deltas(m, &g),
            }
        }
        let mut d = merged.expect("sharding produced no shards");
        finalize_update(&mut d, &self.backend.hyper());
        self.backend.apply_update(&d)?;
        Ok(d.loss)
    }

    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>> {
        if !self.use_sharding(x.b) {
            return Ok(argmax_rows(&self.backend.forward(x)?));
        }
        self.refresh_forks()?;
        let shards = Self::shard(x, self.workers);
        let results: Vec<Result<Vec<usize>>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .forks
                .iter()
                .zip(&shards)
                .map(|(f, sh)| {
                    s.spawn(move || -> Result<Vec<usize>> {
                        Ok(argmax_rows(&f.forward(sh)?))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("eval worker panicked"))))
                .collect()
        });
        let mut preds = Vec::with_capacity(x.b);
        for r in results {
            preds.extend(r?);
        }
        Ok(preds)
    }

    fn name(&self) -> &'static str {
        self.backend.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::tests::toy_batch;
    use crate::backend::{BackendCtx, BackendRegistry};
    use crate::config::NetConfig;

    fn engine(workers: usize, seed: u64) -> ParallelEngine {
        let ctx = BackendCtx { lam: 0.5, beta: 0.7, lr: 0.5, seed, ..BackendCtx::new(NetConfig::SMALL) };
        let be = BackendRegistry::with_defaults().create("dense", &ctx).unwrap();
        ParallelEngine::new(be, workers)
    }

    #[test]
    fn shard_partitions_rows_in_order() {
        let net = NetConfig::SMALL;
        let mut x = toy_batch(&net, 11, 1);
        x.labels = (0..11).map(|i| i % net.ny).collect();
        let shards = ParallelEngine::shard(&x, 3);
        assert_eq!(shards.iter().map(|s| s.b).collect::<Vec<_>>(), vec![4, 4, 3]);
        let relabels: Vec<usize> = shards.iter().flat_map(|s| s.labels.clone()).collect();
        assert_eq!(relabels, x.labels);
        assert_eq!(shards[1].sample(0), x.sample(4));
        assert_eq!(shards[2].sample(2), x.sample(10));
    }

    #[test]
    fn single_worker_matches_direct_backend() {
        let net = NetConfig::SMALL;
        let mut par = engine(1, 3);
        let ctx = BackendCtx { lam: 0.5, beta: 0.7, lr: 0.5, seed: 3, ..BackendCtx::new(NetConfig::SMALL) };
        let mut direct = BackendRegistry::with_defaults().create("dense", &ctx).unwrap();
        for i in 0..5 {
            let b = toy_batch(&net, 8, 20 + i);
            let l1 = par.train_batch(&b).unwrap();
            let l2 = direct.train_dfa(&b).unwrap();
            assert_eq!(l1, l2, "step {i}");
        }
        let test = toy_batch(&net, 32, 0);
        assert_eq!(
            par.eval_batch(&test).unwrap(),
            argmax_rows(&direct.forward(&test).unwrap())
        );
    }

    #[test]
    fn sharded_eval_is_identical_to_sequential() {
        let net = NetConfig::SMALL;
        let test = toy_batch(&net, 37, 5);
        let baseline = engine(1, 7).eval_batch(&test).unwrap();
        for workers in [2, 3, 4] {
            let preds = engine(workers, 7).eval_batch(&test).unwrap();
            assert_eq!(preds, baseline, "workers={workers}");
        }
    }

    #[test]
    fn sharded_train_first_step_loss_matches() {
        let net = NetConfig::SMALL;
        let b = toy_batch(&net, 16, 9);
        // the loss is computed on the pre-update weights, so across
        // worker counts it differs only by f32 re-association
        let l1 = engine(1, 11).train_batch(&b).unwrap();
        let l4 = engine(4, 11).train_batch(&b).unwrap();
        assert!((l1 - l4).abs() < 1e-4, "losses {l1} vs {l4}");
    }

    #[test]
    fn step_sessions_identical_across_worker_counts() {
        let net = NetConfig::SMALL;
        let x = Mat::from_fn(16, net.nx, |r, c| ((r * 7 + c * 3) % 11) as f32 * 0.1 - 0.5);
        let h0 = Mat::zeros(16, net.nh);
        let e1 = engine(1, 21);
        let (h1, l1) = e1.step_sessions(&h0, &x).unwrap();
        let direct_h = e1.backend().step_hidden(&h0, &x).unwrap();
        assert_eq!(h1.data, direct_h.data, "engine must match the direct backend step");
        for workers in [2, 4] {
            let ew = engine(workers, 21);
            let (hw, lw) = ew.step_sessions(&h0, &x).unwrap();
            assert_eq!(hw.data, h1.data, "hidden state, workers={workers}");
            assert_eq!(lw.data, l1.data, "logits, workers={workers}");
        }
    }

    #[test]
    fn snap_step_dispatches_on_precision_and_is_worker_invariant() {
        use crate::serve::WeightSnapshot;
        let net = NetConfig::SMALL;
        let x = Mat::from_fn(16, net.nx, |r, c| ((r * 7 + c * 3) % 11) as f32 * 0.1 - 0.5);
        let h0 = Mat::from_fn(16, net.nh, |r, c| ((r * 5 + c) % 9) as f32 * 0.1 - 0.4);
        let e1 = engine(1, 21);
        let params = e1.backend().effective_params();
        // snapshots built directly (not via WeightSnapshot::new) so this
        // test never touches the process-wide precision selection
        let f32_snap = WeightSnapshot { gen: 0, params: params.clone(), quant: None };
        let i8_snap = WeightSnapshot {
            gen: 0,
            params: params.clone(),
            quant: Some(crate::quant::QuantizedParams::build(&params)),
        };
        let (hf, lf) = e1.step_sessions_snap(&f32_snap, &h0, &x).unwrap();
        // f32 snapshot ≡ the plain snapshot step
        let (hat, lat) = e1.step_sessions_at(&params, &h0, &x).unwrap();
        assert_eq!(hf.data, hat.data);
        assert_eq!(lf.data, lat.data);
        // int8 engages a genuinely different path…
        let (hq, lq) = e1.step_sessions_snap(&i8_snap, &h0, &x).unwrap();
        assert_ne!(lq.data, lf.data, "int8 snapshot must take the integer path");
        // …that stays close to f32…
        for (a, b) in hq.data.iter().zip(&hf.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        // …and is bitwise worker-count-invariant (per-row activation scales)
        for workers in [2, 4] {
            let ew = engine(workers, 21);
            let (hw, lw) = ew.step_sessions_snap(&i8_snap, &h0, &x).unwrap();
            assert_eq!(hw.data, hq.data, "hidden state, workers={workers}");
            assert_eq!(lw.data, lq.data, "logits, workers={workers}");
        }
    }

    #[test]
    fn train_whole_matches_direct_backend_step() {
        let net = NetConfig::SMALL;
        let mut par = engine(4, 33);
        let ctx =
            BackendCtx { lam: 0.5, beta: 0.7, lr: 0.5, seed: 33, ..BackendCtx::new(net) };
        let mut direct = BackendRegistry::with_defaults().create("dense", &ctx).unwrap();
        for i in 0..3 {
            let b = toy_batch(&net, 16, 40 + i);
            // whole-batch commits must be bit-identical regardless of workers
            assert_eq!(par.train_whole(&b).unwrap(), direct.train_dfa(&b).unwrap(), "step {i}");
        }
    }

    #[test]
    fn ration_zeroes_only_overstressed_columns() {
        let net = NetConfig::SMALL;
        let mut d = DfaDeltas {
            d_wh: Mat::from_fn(net.nx, net.nh, |_, _| 1.0),
            d_uh: Mat::from_fn(net.nh, net.nh, |_, _| 1.0),
            d_bh: vec![1.0; net.nh],
            d_wo: Mat::from_fn(net.nh, net.ny, |_, _| 1.0),
            d_bo: vec![1.0; net.ny],
            loss: 0.5,
        };
        // hidden column nh-1 at 10x the rest; readout column 0 likewise
        let mut hidden = vec![1u64; net.nh];
        hidden[net.nh - 1] = 100;
        let mut readout = vec![1u64; net.ny];
        readout[0] = 100;
        let wear = ColumnWear { hidden, readout };
        let rationed = ration_overstressed(&mut d, &wear, 4.0);
        assert_eq!(rationed, 2);
        for r in 0..net.nx {
            assert_eq!(d.d_wh.at(r, net.nh - 1), 0.0);
            assert_eq!(d.d_wh.at(r, 0), 1.0, "healthy columns untouched");
        }
        for r in 0..net.nh {
            assert_eq!(d.d_uh.at(r, net.nh - 1), 0.0);
            assert_eq!(d.d_wo.at(r, 0), 0.0);
            assert_eq!(d.d_wo.at(r, net.ny - 1), 1.0);
        }
        assert!(d.d_bh.iter().all(|&v| v == 1.0), "biases are never rationed");
        // uniform wear rations nothing
        let uniform = ColumnWear { hidden: vec![5; net.nh], readout: vec![5; net.ny] };
        assert_eq!(ration_overstressed(&mut d, &uniform, 1.5), 0);
    }

    #[test]
    fn guarded_train_on_dense_matches_train_whole() {
        let net = NetConfig::SMALL;
        let b = toy_batch(&net, 8, 17);
        let mut plain = engine(1, 19);
        let mut guarded = engine(1, 19);
        let l1 = plain.train_whole(&b).unwrap();
        // dense backends have no wear accounting: guarded falls through
        let (l2, rationed) = guarded.train_whole_guarded(&b, 4.0).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(rationed, 0);
        assert_eq!(
            plain.backend().effective_params().flatten(),
            guarded.backend().effective_params().flatten()
        );
    }

    #[test]
    fn restore_params_roundtrips_dense_weights() {
        let net = NetConfig::SMALL;
        let mut src = engine(1, 23);
        src.train_whole(&toy_batch(&net, 8, 2)).unwrap();
        let snapshot = src.backend().effective_params();
        let mut dst = engine(2, 99);
        assert_ne!(dst.backend().effective_params().flatten(), snapshot.flatten());
        dst.restore_params(&snapshot).unwrap();
        assert_eq!(
            dst.backend().effective_params().flatten(),
            snapshot.flatten(),
            "dense restore must be bit-exact"
        );
        dst.drain(); // shutdown hook is callable any time
    }

    #[test]
    fn small_batches_skip_sharding() {
        let net = NetConfig::SMALL;
        let mut e = engine(4, 13);
        // b < 2*workers: whole-batch path must be taken (and still work)
        let b = toy_batch(&net, 5, 1);
        e.train_batch(&b).unwrap();
        assert_eq!(e.eval_batch(&b).unwrap().len(), 5);
    }
}
