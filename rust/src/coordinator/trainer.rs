//! Domain-incremental continual training loop (§VI-A protocol).
//!
//! For each task in the stream: open a replay segment, stream the task's
//! training data for `epochs` passes (every streamed example is offered to
//! the data-preparation unit exactly once, on its first appearance), train
//! on batches mixed with replayed examples from past tasks, then evaluate
//! on the test sets of *all tasks seen so far* (no task identity given —
//! shared head).

use anyhow::Result;

use crate::config::RunConfig;
use crate::data::TaskStream;
use crate::replay::ReplayBuffer;

use super::batcher::{make_eval_batches, TrainBatcher};
use super::engine::Engine;
use super::metrics::AccuracyMatrix;

/// Per-task outcome.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub task: usize,
    pub mean_loss: f32,
    /// Accuracy on each seen task's test set after training this task.
    pub acc_per_task: Vec<f32>,
    pub mean_acc: f32,
}

/// Drives one engine through the whole task stream.
pub struct ContinualTrainer<'a> {
    pub stream: &'a TaskStream,
    pub cfg: RunConfig,
    pub buffer: Option<ReplayBuffer>,
    pub matrix: AccuracyMatrix,
    batcher: TrainBatcher,
    b_eval: usize,
}

impl<'a> ContinualTrainer<'a> {
    pub fn new(stream: &'a TaskStream, cfg: RunConfig, b_train: usize, b_eval: usize) -> Self {
        let buffer = cfg.replay.then(|| {
            ReplayBuffer::new(
                cfg.replay_per_task,
                stream.feat_offset,
                stream.feat_scale,
                cfg.seed as u32 ^ 0x5EED_0B0F,
            )
        });
        let batcher =
            TrainBatcher::new(b_train, stream.nt, stream.nx, cfg.replay_mix, cfg.seed ^ 0xBA7C);
        Self { stream, cfg, buffer, matrix: AccuracyMatrix::default(), batcher, b_eval }
    }

    /// Train on task `t` and evaluate on tasks 0..=t. Returns the result
    /// row (also recorded in `self.matrix`).
    pub fn run_task(&mut self, engine: &mut dyn Engine, t: usize) -> Result<TaskResult> {
        let task = &self.stream.tasks[t];
        if let Some(buf) = &mut self.buffer {
            buf.begin_task();
            // the data-preparation unit samples the incoming stream once
            for ex in &task.train {
                buf.offer(ex);
            }
        }

        let mut losses = Vec::new();
        for _epoch in 0..self.cfg.epochs {
            let batches = self.batcher.epoch_batches(&task.train, self.buffer.as_ref());
            for b in &batches {
                losses.push(engine.train_batch(b)?);
            }
        }

        // evaluate on every seen task
        let mut acc_per_task = Vec::with_capacity(t + 1);
        for i in 0..=t {
            let test = &self.stream.tasks[i].test;
            let mut correct = 0usize;
            let mut total = 0usize;
            for (batch, valid) in
                make_eval_batches(test, self.b_eval, self.stream.nt, self.stream.nx)
            {
                let preds = engine.eval_batch(&batch)?;
                for k in 0..valid {
                    total += 1;
                    if preds[k] == batch.labels[k] {
                        correct += 1;
                    }
                }
            }
            acc_per_task.push(correct as f32 / total.max(1) as f32);
        }
        self.matrix.push_row(acc_per_task.clone());

        let mean_loss = losses.iter().sum::<f32>() / losses.len().max(1) as f32;
        let mean_acc = self.matrix.mean_after(t);
        Ok(TaskResult { task: t, mean_loss, acc_per_task, mean_acc })
    }

    /// Run the full stream; returns one result per task.
    pub fn run_all(&mut self, engine: &mut dyn Engine) -> Result<Vec<TaskResult>> {
        (0..self.cfg.num_tasks.min(self.stream.num_tasks()))
            .map(|t| self.run_task(engine, t))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::RustDfaEngine;
    use crate::data::permuted_task_stream;

    // The tuned operating point (see RunConfig::default docs) scaled down
    // for unit-test wallclock.
    fn quick_cfg() -> RunConfig {
        RunConfig {
            num_tasks: 2,
            train_per_task: 300,
            test_per_task: 80,
            epochs: 4,
            replay_per_task: 150,
            replay_mix: 0.5,
            ..RunConfig::default()
        }
    }

    #[test]
    fn replay_mitigates_forgetting_on_permuted_stream() {
        let stream = permuted_task_stream(2, 300, 80, 7);
        let run = |replay: bool| -> (f32, f32) {
            let cfg = RunConfig { replay, ..quick_cfg() };
            let mut tr = ContinualTrainer::new(&stream, cfg, 16, 40);
            let mut eng = RustDfaEngine::new(28, 48, 10, 0.96, 0.3, 0.3, Some(0.53), 3);
            let results = tr.run_all(&mut eng).unwrap();
            (results.last().unwrap().mean_acc, tr.matrix.forgetting())
        };
        let (acc_replay, forget_replay) = run(true);
        let (acc_none, forget_none) = run(false);
        // replay must reduce forgetting and improve final mean accuracy
        assert!(
            forget_replay < forget_none,
            "forgetting with replay {forget_replay} vs without {forget_none}"
        );
        assert!(
            acc_replay > acc_none,
            "mean acc with replay {acc_replay} vs without {acc_none}"
        );
    }

    #[test]
    fn accuracy_rows_have_expected_shape() {
        let stream = permuted_task_stream(2, 60, 30, 1);
        let mut tr =
            ContinualTrainer::new(&stream, RunConfig { epochs: 1, ..quick_cfg() }, 16, 30);
        let mut eng = RustDfaEngine::new(28, 24, 10, 0.96, 0.3, 0.3, Some(0.53), 3);
        let results = tr.run_all(&mut eng).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].acc_per_task.len(), 1);
        assert_eq!(results[1].acc_per_task.len(), 2);
        assert_eq!(tr.matrix.r.len(), 2);
    }

    #[test]
    fn first_task_learns_above_chance() {
        let stream = permuted_task_stream(1, 300, 80, 5);
        let mut tr =
            ContinualTrainer::new(&stream, RunConfig { num_tasks: 1, ..quick_cfg() }, 16, 40);
        let mut eng = RustDfaEngine::new(28, 48, 10, 0.96, 0.3, 0.3, Some(0.53), 9);
        let results = tr.run_all(&mut eng).unwrap();
        assert!(results[0].mean_acc > 0.5, "acc {}", results[0].mean_acc);
    }
}
