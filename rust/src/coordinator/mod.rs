//! Layer-3 coordinator — the continual-learning runtime around the
//! crossbar (the paper's system contribution).
//!
//! * [`batcher`] — fixed-shape batch assembly + replay mixing (the
//!   artifacts are lowered with static batch sizes; the batcher owns
//!   padding and truncation).
//! * [`engine`] — the training/inference engines: pure-rust digital
//!   baseline, XLA software (DFA and Adam), and the device-aware hardware
//!   engine that routes every update through the memristive crossbars.
//! * [`parallel`] — the multi-worker serving engine: drives any
//!   [`crate::backend::ComputeBackend`] and shards eval/train batches
//!   across `std::thread` workers with deterministic merging.
//! * [`trainer`] — the domain-incremental training loop: stream tasks,
//!   feed the data-preparation unit, mix replay, evaluate after each task.
//! * [`tiles`] — the hidden-layer tile scheduler (SIPO/SISO dataflow).
//! * [`metrics`] — accuracy matrices, mean accuracy, forgetting.

mod batcher;
mod engine;
mod metrics;
mod parallel;
mod tiles;
mod trainer;

pub use batcher::{make_eval_batches, make_seq_batch, TrainBatcher};
pub use engine::{
    Engine, HardwareEngine, RustAdamEngine, RustDfaEngine, XlaAdamEngine, XlaDfaEngine,
};
pub use metrics::AccuracyMatrix;
pub use parallel::ParallelEngine;
pub use tiles::TileScheduler;
pub use trainer::{ContinualTrainer, TaskResult};
