//! Continual-learning metrics (§VI-A, Eq. 20).

/// R[t][i] = accuracy on task i after finishing training task t (i ≤ t).
#[derive(Clone, Debug, Default)]
pub struct AccuracyMatrix {
    pub r: Vec<Vec<f32>>,
}

impl AccuracyMatrix {
    pub fn push_row(&mut self, row: Vec<f32>) {
        assert_eq!(row.len(), self.r.len() + 1, "row t must cover tasks 0..=t");
        self.r.push(row);
    }

    /// Mean accuracy after task t (Eq. 20 restricted to seen tasks).
    pub fn mean_after(&self, t: usize) -> f32 {
        let row = &self.r[t];
        row.iter().sum::<f32>() / row.len() as f32
    }

    /// Final mean accuracy (Eq. 20).
    pub fn mean_final(&self) -> f32 {
        self.mean_after(self.r.len() - 1)
    }

    /// Average forgetting: max past accuracy minus final accuracy, over
    /// tasks 0..T-1.
    pub fn forgetting(&self) -> f32 {
        let t_last = self.r.len() - 1;
        if t_last == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        for i in 0..t_last {
            let best = (i..=t_last).map(|t| self.r[t][i]).fold(f32::MIN, f32::max);
            total += best - self.r[t_last][i];
        }
        total / t_last as f32
    }

    /// The "average test accuracy after each task" series of Fig. 4.
    pub fn curve(&self) -> Vec<f32> {
        (0..self.r.len()).map(|t| self.mean_after(t)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> AccuracyMatrix {
        let mut m = AccuracyMatrix::default();
        m.push_row(vec![0.9]);
        m.push_row(vec![0.8, 0.85]);
        m.push_row(vec![0.7, 0.75, 0.88]);
        m
    }

    #[test]
    fn mean_after_each_task() {
        let m = demo();
        assert!((m.mean_after(0) - 0.9).abs() < 1e-6);
        assert!((m.mean_after(1) - 0.825).abs() < 1e-6);
        assert!((m.mean_final() - (0.7 + 0.75 + 0.88) / 3.0).abs() < 1e-6);
    }

    #[test]
    fn forgetting_uses_peak_accuracy() {
        let m = demo();
        // task0: peak 0.9, final 0.7 → 0.2; task1: peak 0.85, final 0.75 → 0.1
        assert!((m.forgetting() - 0.15).abs() < 1e-6);
    }

    #[test]
    fn curve_matches_means() {
        let m = demo();
        let c = m.curve();
        assert_eq!(c.len(), 3);
        assert!((c[2] - m.mean_final()).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn wrong_row_length_panics() {
        let mut m = AccuracyMatrix::default();
        m.push_row(vec![0.9, 0.8]);
    }
}
