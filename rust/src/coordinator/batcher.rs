//! Batch assembly for fixed-shape artifacts.
//!
//! The AOT executables have static batch dimensions (b_train / b_eval), so
//! the coordinator pads partial batches (cyclic repetition) and truncates
//! the corresponding predictions. `TrainBatcher` additionally owns the
//! replay mix: each training batch is `replay_mix` replayed examples from
//! past tasks and the rest fresh stream data.

use crate::data::Example;
use crate::nn::SeqBatch;
use crate::replay::ReplayBuffer;
use crate::rng::GaussianRng;

/// Assemble exactly `b` examples into a SeqBatch, padding cyclically from
/// the given slice if it is short. Panics on an empty slice.
pub fn make_seq_batch(examples: &[&Example], b: usize, nt: usize, nx: usize) -> SeqBatch {
    assert!(!examples.is_empty(), "cannot batch zero examples");
    let mut sb = SeqBatch::zeros(b, nt, nx);
    for i in 0..b {
        let e = examples[i % examples.len()];
        assert_eq!(e.features.len(), nt * nx, "example geometry mismatch");
        sb.sample_mut(i).copy_from_slice(&e.features);
        sb.labels[i] = e.label;
    }
    sb
}

/// Split an evaluation set into fixed-size batches plus the number of
/// valid rows in the final one.
pub fn make_eval_batches(
    examples: &[Example],
    b_eval: usize,
    nt: usize,
    nx: usize,
) -> Vec<(SeqBatch, usize)> {
    let refs: Vec<&Example> = examples.iter().collect();
    refs.chunks(b_eval)
        .map(|chunk| (make_seq_batch(chunk, b_eval, nt, nx), chunk.len()))
        .collect()
}

/// Iterates one task's training stream in epochs, mixing replay.
///
/// The replay mix only activates once the buffer holds *past* task
/// segments (mixing current-task examples back in would be a no-op):
///
/// ```
/// use m2ru::coordinator::TrainBatcher;
/// use m2ru::data::Example;
/// use m2ru::replay::ReplayBuffer;
///
/// // past task, captured by the data-preparation unit
/// let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 1);
/// buf.begin_task();
/// for _ in 0..8 {
///     buf.offer(&Example { features: vec![0.5; 6], label: 3 });
/// }
/// buf.begin_task(); // current task opens; stored examples become "past"
///
/// // fresh stream for the current task
/// let fresh: Vec<Example> =
///     (0..8).map(|_| Example { features: vec![0.25; 6], label: 7 }).collect();
///
/// // replay_mix = 0.5: every 4-row batch is 2 fresh + 2 replayed rows
/// let mut tb = TrainBatcher::new(4, 2, 3, 0.5, 0);
/// for batch in tb.epoch_batches(&fresh, Some(&buf)) {
///     assert_eq!(batch.labels.iter().filter(|&&l| l == 3).count(), 2);
/// }
/// ```
pub struct TrainBatcher {
    pub b_train: usize,
    pub nt: usize,
    pub nx: usize,
    /// target fraction of the batch drawn from the replay buffer.
    pub replay_mix: f32,
    rng: GaussianRng,
}

impl TrainBatcher {
    pub fn new(b_train: usize, nt: usize, nx: usize, replay_mix: f32, seed: u64) -> Self {
        Self { b_train, nt, nx, replay_mix, rng: GaussianRng::new(seed) }
    }

    /// Build the batch schedule for one epoch over `task_data`: shuffled
    /// indices chunked to `b_train` fresh slots per batch.
    pub fn epoch_batches(
        &mut self,
        task_data: &[Example],
        replay: Option<&ReplayBuffer>,
    ) -> Vec<SeqBatch> {
        let mut order: Vec<usize> = (0..task_data.len()).collect();
        self.rng.shuffle(&mut order);

        // how many replay slots per batch?
        let n_replay = if replay.map_or(0, ReplayBuffer::num_tasks) > 1 {
            ((self.b_train as f32) * self.replay_mix).round() as usize
        } else {
            0
        };
        let n_fresh = self.b_train - n_replay;

        let mut batches = Vec::new();
        for chunk in order.chunks(n_fresh.max(1)) {
            let mut members: Vec<Example> =
                chunk.iter().map(|&i| task_data[i].clone()).collect();
            if let Some(buf) = replay {
                if n_replay > 0 {
                    members.extend(buf.sample_past(n_replay, &mut self.rng));
                }
            }
            let refs: Vec<&Example> = members.iter().collect();
            batches.push(make_seq_batch(&refs, self.b_train, self.nt, self.nx));
        }
        batches
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(v: f32, label: usize, n: usize) -> Example {
        Example { features: vec![v; n], label }
    }

    #[test]
    fn pads_cyclically() {
        let e1 = ex(1.0, 1, 6);
        let e2 = ex(2.0, 2, 6);
        let sb = make_seq_batch(&[&e1, &e2], 5, 2, 3);
        assert_eq!(sb.labels, vec![1, 2, 1, 2, 1]);
        assert_eq!(sb.sample(4)[0], 1.0);
    }

    #[test]
    fn eval_batches_cover_everything_once() {
        let data: Vec<Example> = (0..23).map(|i| ex(i as f32, i % 4, 6)).collect();
        let batches = make_eval_batches(&data, 10, 2, 3);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].1, 10);
        assert_eq!(batches[2].1, 3);
        let total: usize = batches.iter().map(|b| b.1).sum();
        assert_eq!(total, 23);
    }

    #[test]
    fn no_replay_slots_without_past_tasks() {
        let data: Vec<Example> = (0..10).map(|i| ex(i as f32, 0, 6)).collect();
        let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 1);
        buf.begin_task(); // only current task — no past segments
        let mut tb = TrainBatcher::new(4, 2, 3, 0.5, 0);
        let batches = tb.epoch_batches(&data, Some(&buf));
        // all-fresh batches: 10 items / 4 per batch = 3 batches
        assert_eq!(batches.len(), 3);
    }

    #[test]
    fn replay_mix_injects_past_examples() {
        let data: Vec<Example> = (0..8).map(|_| ex(0.25, 7, 6)).collect();
        let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 1);
        buf.begin_task();
        for _ in 0..8 {
            buf.offer(&ex(0.5, 3, 6));
        }
        buf.begin_task(); // current task; past = segment with label 3
        let mut tb = TrainBatcher::new(4, 2, 3, 0.5, 0);
        let batches = tb.epoch_batches(&data, Some(&buf));
        // each batch: 2 fresh (label 7) + 2 replay (label 3)
        for b in &batches {
            let replayed = b.labels.iter().filter(|&&l| l == 3).count();
            assert_eq!(replayed, 2, "labels {:?}", b.labels);
        }
    }

    #[test]
    fn epoch_covers_all_fresh_examples() {
        let data: Vec<Example> = (0..12).map(|i| ex(i as f32 + 1.0, i % 2, 6)).collect();
        let mut tb = TrainBatcher::new(4, 2, 3, 0.0, 1);
        let batches = tb.epoch_batches(&data, None);
        let mut seen: Vec<f32> = batches
            .iter()
            .flat_map(|b| (0..b.b).map(move |i| b.sample(i)[0]))
            .collect();
        seen.sort_by(f32::total_cmp);
        seen.dedup();
        assert_eq!(seen.len(), 12, "every fresh example appears");
    }
}
