//! Hidden-layer tile scheduler (§IV-B1).
//!
//! The final MiRU interpolation h_t = λh_{t-1} + (1-λ)h̃_t is computed
//! hybrid-style: tiles work concurrently at the layer level and
//! sequentially within a tile, fed by shift registers in SIPO mode during
//! candidate computation and SISO otherwise. This scheduler produces the
//! per-cycle unit assignment the datapath would execute; `hw_model`
//! consumes only its cycle count, the tests check the functional
//! guarantees (every unit exactly once, ≤16 cycles when tiled per paper).

/// Static schedule: `plan[cycle][tile]` = hidden unit index (or None when
/// a tile has run out of units).
#[derive(Clone, Debug)]
pub struct TileScheduler {
    pub nh: usize,
    pub tiles: usize,
    pub plan: Vec<Vec<Option<usize>>>,
}

impl TileScheduler {
    pub fn new(nh: usize, tiles: usize) -> Self {
        assert!(tiles >= 1);
        let per_tile = nh.div_ceil(tiles);
        let mut plan = Vec::with_capacity(per_tile);
        for cycle in 0..per_tile {
            let row: Vec<Option<usize>> = (0..tiles)
                .map(|t| {
                    let unit = t * per_tile + cycle;
                    (unit < nh && cycle < per_tile).then_some(unit).filter(|&u| u / per_tile == t)
                })
                .collect();
            plan.push(row);
        }
        Self { nh, tiles, plan }
    }

    /// Cycles to interpolate the whole layer.
    pub fn cycles(&self) -> usize {
        self.plan.len()
    }

    /// Execute the schedule functionally: interpolate `cand` into `h`.
    pub fn interpolate(&self, h: &mut [f32], cand: &[f32], lam: f32) {
        assert_eq!(h.len(), self.nh);
        assert_eq!(cand.len(), self.nh);
        for row in &self.plan {
            for &slot in row {
                if let Some(u) = slot {
                    h[u] = lam * h[u] + (1.0 - lam) * cand[u];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_unit_scheduled_exactly_once() {
        for (nh, tiles) in [(100, 8), (256, 16), (10, 3), (16, 16), (7, 1)] {
            let s = TileScheduler::new(nh, tiles);
            let mut seen = vec![0u32; nh];
            for row in &s.plan {
                for &slot in row {
                    if let Some(u) = slot {
                        seen[u] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "nh={nh} tiles={tiles}: {seen:?}");
        }
    }

    #[test]
    fn cycles_equal_ceil_nh_over_tiles() {
        assert_eq!(TileScheduler::new(100, 8).cycles(), 13);
        assert_eq!(TileScheduler::new(256, 16).cycles(), 16);
        assert_eq!(TileScheduler::new(100, 1).cycles(), 100);
    }

    #[test]
    fn paper_cap_16_cycles_with_right_tile_count() {
        for nh in [64usize, 100, 256, 512] {
            let tiles = nh.div_ceil(16);
            assert!(TileScheduler::new(nh, tiles).cycles() <= 16, "nh={nh}");
        }
    }

    #[test]
    fn interpolation_matches_direct_formula() {
        let s = TileScheduler::new(10, 3);
        let mut h: Vec<f32> = (0..10).map(|i| i as f32 * 0.1).collect();
        let cand: Vec<f32> = (0..10).map(|i| 1.0 - i as f32 * 0.1).collect();
        let want: Vec<f32> =
            h.iter().zip(&cand).map(|(&a, &b)| 0.4 * a + 0.6 * b).collect();
        s.interpolate(&mut h, &cand, 0.4);
        for (a, b) in h.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
