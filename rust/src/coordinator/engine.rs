//! Training/inference engines — the curves of Fig. 4.
//!
//! * [`RustDfaEngine`] / [`RustAdamEngine`] — pure-rust digital baselines
//!   (no XLA), used by unit tests and the Table-I digital comparator.
//! * [`XlaDfaEngine`] / [`XlaAdamEngine`] — the software models executed
//!   through the AOT artifacts (the "software trained with DFA / Adam"
//!   curves).
//! * [`HardwareEngine`] — the M2RU model: DFA deltas are programmed into
//!   memristive crossbars (Ziksa), evaluation runs the WBS/ADC datapath on
//!   the *effective* device weights, and every write is endurance-counted.

use anyhow::Result;

use crate::device::{DeviceParams, DifferentialCrossbar, ZiksaProgrammer};
use crate::linalg::{argmax_rows, Mat};
use crate::nn::{bptt_grads, dfa_grads, make_psi, AdamState, MiruParams, SeqBatch};
use crate::runtime::ModelBundle;

/// A continual-learning engine: consumes fixed-shape batches.
pub trait Engine {
    /// One parameter update on a b_train batch; returns the loss.
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32>;
    /// Predictions for a b_eval batch.
    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>>;
    /// Engine label for reports.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Pure-rust engines (digital baseline)
// ---------------------------------------------------------------------------

pub struct RustDfaEngine {
    pub params: MiruParams,
    pub psi: Mat,
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
    pub keep_frac: Option<f32>,
}

impl RustDfaEngine {
    pub fn new(
        nx: usize,
        nh: usize,
        ny: usize,
        lam: f32,
        beta: f32,
        lr: f32,
        keep_frac: Option<f32>,
        seed: u64,
    ) -> Self {
        Self {
            params: MiruParams::init(nx, nh, ny, seed),
            psi: make_psi(ny, nh, seed ^ 0xD0F4),
            lam,
            beta,
            lr,
            keep_frac,
        }
    }
}

impl Engine for RustDfaEngine {
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32> {
        let d = dfa_grads(&self.params, x, self.lam, self.beta, self.lr, &self.psi, self.keep_frac);
        self.params.apply(&d);
        Ok(d.loss)
    }

    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>> {
        Ok(argmax_rows(&self.params.forward(x, self.lam, self.beta)))
    }

    fn name(&self) -> &'static str {
        "rust-dfa"
    }
}

pub struct RustAdamEngine {
    pub params: MiruParams,
    pub state: AdamState,
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
}

impl RustAdamEngine {
    pub fn new(nx: usize, nh: usize, ny: usize, lam: f32, beta: f32, lr: f32, seed: u64) -> Self {
        let params = MiruParams::init(nx, nh, ny, seed);
        let n = params.count();
        Self { params, state: AdamState::new(n), lam, beta, lr }
    }
}

impl Engine for RustAdamEngine {
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32> {
        let (g, loss) = bptt_grads(&self.params, x, self.lam, self.beta);
        let upd = self.state.step(&g, self.lr);
        self.params.apply_flat_update(&upd);
        Ok(loss)
    }

    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>> {
        Ok(argmax_rows(&self.params.forward(x, self.lam, self.beta)))
    }

    fn name(&self) -> &'static str {
        "rust-adam"
    }
}

// ---------------------------------------------------------------------------
// XLA software engines (the Fig. 4 software curves)
// ---------------------------------------------------------------------------

pub struct XlaDfaEngine<'a> {
    pub bundle: &'a ModelBundle,
    pub params: MiruParams,
    pub psi: Mat,
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
}

impl<'a> XlaDfaEngine<'a> {
    pub fn new(bundle: &'a ModelBundle, lam: f32, beta: f32, lr: f32, seed: u64) -> Self {
        let c = bundle.cfg;
        Self {
            bundle,
            params: MiruParams::init(c.nx, c.nh, c.ny, seed),
            psi: make_psi(c.ny, c.nh, seed ^ 0xD0F4),
            lam,
            beta,
            lr,
        }
    }
}

impl Engine for XlaDfaEngine<'_> {
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32> {
        let d = self.bundle.train_step_dfa(&self.params, x, self.lam, self.beta, self.lr, &self.psi)?;
        self.params.apply(&d);
        Ok(d.loss)
    }

    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>> {
        Ok(argmax_rows(&self.bundle.eval_logits(&self.params, x, self.lam, self.beta)?))
    }

    fn name(&self) -> &'static str {
        "xla-dfa"
    }
}

pub struct XlaAdamEngine<'a> {
    pub bundle: &'a ModelBundle,
    pub params: MiruParams,
    pub state: AdamState,
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
}

impl<'a> XlaAdamEngine<'a> {
    pub fn new(bundle: &'a ModelBundle, lam: f32, beta: f32, lr: f32, seed: u64) -> Self {
        let c = bundle.cfg;
        let params = MiruParams::init(c.nx, c.nh, c.ny, seed);
        let n = params.count();
        Self { bundle, params, state: AdamState::new(n), lam, beta, lr }
    }
}

impl Engine for XlaAdamEngine<'_> {
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32> {
        self.bundle.train_step_adam(&mut self.params, &mut self.state, x, self.lam, self.beta, self.lr)
    }

    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>> {
        Ok(argmax_rows(&self.bundle.eval_logits(&self.params, x, self.lam, self.beta)?))
    }

    fn name(&self) -> &'static str {
        "xla-adam"
    }
}

// ---------------------------------------------------------------------------
// Hardware (M2RU) engine
// ---------------------------------------------------------------------------

/// Device-aware engine: weights live in two differential crossbars
/// (hidden: (nx+nh)×nh holding [W_h; U_h]; readout: nh×ny holding W_o).
/// Training computes DFA deltas from the *effective* weights, programs
/// them via Ziksa (write-counted), and evaluation runs the mixed-signal
/// forward artifact.
pub struct HardwareEngine<'a> {
    pub bundle: &'a ModelBundle,
    pub psi: Mat,
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
    /// biases stay digital (registers)
    pub bh: Vec<f32>,
    pub bo: Vec<f32>,
    pub xbar_hidden: DifferentialCrossbar,
    pub xbar_out: DifferentialCrossbar,
    pub programmer: ZiksaProgrammer,
    /// ADC full-scale voltages for the two layers.
    pub vscale_h: f32,
    pub vscale_o: f32,
    /// Use the dense (no-ζ) train artifact — the Fig. 5(b) baseline.
    pub use_dense: bool,
}

impl<'a> HardwareEngine<'a> {
    pub fn new(
        bundle: &'a ModelBundle,
        lam: f32,
        beta: f32,
        lr: f32,
        device: DeviceParams,
        seed: u64,
    ) -> Self {
        let c = bundle.cfg;
        let init = MiruParams::init(c.nx, c.nh, c.ny, seed);
        // w_max sized to the init distribution with training headroom
        let w_max = 1.0;
        let mut xbar_hidden =
            DifferentialCrossbar::new(c.nx + c.nh, c.nh, w_max, device, seed ^ 0xBAD1);
        let mut xbar_out = DifferentialCrossbar::new(c.nh, c.ny, w_max, device, seed ^ 0xBAD2);
        xbar_hidden.program_weights(&Mat::vcat(&init.wh, &init.uh));
        xbar_out.program_weights(&init.wo);
        Self {
            bundle,
            psi: make_psi(c.ny, c.nh, seed ^ 0xD0F4),
            lam,
            beta,
            lr,
            bh: init.bh,
            bo: init.bo,
            xbar_hidden,
            xbar_out,
            programmer: ZiksaProgrammer::new(),
            vscale_h: 4.0,
            vscale_o: 4.0,
            use_dense: false,
        }
    }

    /// ADC full-scale ranges for the current weights — the paper's
    /// "shift operation controlling the dynamic range of the synaptic
    /// weights" (§IV-B1): the integrator swing is bounded by the L1 norm
    /// of the heaviest bitline, and the ADC range follows it so training
    /// growth never clips the read-out (clipped logits collapse argmax).
    fn adaptive_vscales(&mut self, eff: &MiruParams) {
        let l1max = |m: &Mat| -> f32 {
            let mut best = 0.0f32;
            for c in 0..m.cols {
                let mut s = 0.0;
                for r in 0..m.rows {
                    s += m.at(r, c).abs();
                }
                best = best.max(s);
            }
            best
        };
        // hidden drive: |x| ≤ 1 on nx lines, |βh| ≤ β on nh lines; typical
        // activity is far below the bound — half the bound keeps LSB fine
        // while tanh saturation forgives the rare clip.
        let bound_h = l1max(&Mat::vcat(&eff.wh, &eff.uh));
        self.vscale_h = (0.3 * bound_h).max(1.0);
        // readout: logits must never clip (argmax!), use the full bound.
        let bound_o = l1max(&eff.wo);
        self.vscale_o = bound_o.max(1.0);
    }

    /// Effective parameters as realized by the devices right now.
    pub fn effective_params(&self) -> MiruParams {
        let c = self.bundle.cfg;
        let hidden = self.xbar_hidden.read_weights();
        let wh = Mat::from_fn(c.nx, c.nh, |r, col| hidden.at(r, col));
        let uh = Mat::from_fn(c.nh, c.nh, |r, col| hidden.at(c.nx + r, col));
        MiruParams {
            wh,
            uh,
            bh: self.bh.clone(),
            wo: self.xbar_out.read_weights(),
            bo: self.bo.clone(),
        }
    }

    /// Write counters of every memristor (for the endurance report).
    pub fn write_counts(&self) -> Vec<u64> {
        let mut c = self.xbar_hidden.write_counts();
        c.extend(self.xbar_out.write_counts());
        c
    }
}

impl Engine for HardwareEngine<'_> {
    fn train_batch(&mut self, x: &SeqBatch) -> Result<f32> {
        let eff = self.effective_params();
        let d = if self.use_dense {
            self.bundle.train_step_dfa_dense(&eff, x, self.lam, self.beta, self.lr, &self.psi)?
        } else {
            self.bundle.train_step_dfa(&eff, x, self.lam, self.beta, self.lr, &self.psi)?
        };
        // program the crossbars (write-counted, quantized, noisy)
        let hidden_delta = Mat::vcat(&d.d_wh, &d.d_uh);
        self.programmer.apply(&mut self.xbar_hidden, &hidden_delta);
        self.programmer.apply(&mut self.xbar_out, &d.d_wo);
        // biases update digitally
        for (b, &v) in self.bh.iter_mut().zip(&d.d_bh) {
            *b += v;
        }
        for (b, &v) in self.bo.iter_mut().zip(&d.d_bo) {
            *b += v;
        }
        Ok(d.loss)
    }

    fn eval_batch(&mut self, x: &SeqBatch) -> Result<Vec<usize>> {
        let eff = self.effective_params();
        self.adaptive_vscales(&eff);
        let logits = self.bundle.eval_logits_hw(
            &eff,
            x,
            self.lam,
            self.beta,
            self.vscale_h,
            self.vscale_o,
        )?;
        Ok(argmax_rows(&logits))
    }

    fn name(&self) -> &'static str {
        "m2ru-hw"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianRng;

    fn toy_batch(b: usize, nt: usize, nx: usize, ny: usize, seed: u64) -> SeqBatch {
        let mut proto_rng = GaussianRng::new(99);
        let protos: Vec<Vec<f32>> =
            (0..ny).map(|_| (0..nx).map(|_| proto_rng.normal()).collect()).collect();
        let mut rng = GaussianRng::new(seed);
        let mut sb = SeqBatch::zeros(b, nt, nx);
        for i in 0..b {
            let label = rng.below(ny);
            sb.labels[i] = label;
            for t in 0..nt {
                for j in 0..nx {
                    sb.sample_mut(i)[t * nx + j] =
                        (0.25 * rng.normal() + 0.75 * protos[label][j]).clamp(-1.0, 1.0);
                }
            }
        }
        sb
    }

    #[test]
    fn rust_dfa_engine_improves_accuracy() {
        let mut e = RustDfaEngine::new(8, 16, 4, 0.5, 0.7, 0.5, Some(0.53), 1);
        let test = toy_batch(64, 5, 8, 4, 0);
        let acc = |e: &mut RustDfaEngine, t: &SeqBatch| -> f32 {
            let preds = e.eval_batch(t).unwrap();
            preds.iter().zip(&t.labels).filter(|(a, b)| a == b).count() as f32 / t.b as f32
        };
        let before = acc(&mut e, &test);
        for i in 0..50 {
            e.train_batch(&toy_batch(8, 5, 8, 4, 10 + i)).unwrap();
        }
        let after = acc(&mut e, &test);
        assert!(after > before + 0.2, "before {before} after {after}");
    }

    #[test]
    fn rust_adam_engine_improves_accuracy() {
        let mut e = RustAdamEngine::new(8, 16, 4, 0.5, 0.7, 0.01, 2);
        let test = toy_batch(64, 5, 8, 4, 0);
        let preds0 = e.eval_batch(&test).unwrap();
        let acc0 =
            preds0.iter().zip(&test.labels).filter(|(a, b)| a == b).count() as f32 / test.b as f32;
        for i in 0..50 {
            e.train_batch(&toy_batch(8, 5, 8, 4, 200 + i)).unwrap();
        }
        let preds1 = e.eval_batch(&test).unwrap();
        let acc1 =
            preds1.iter().zip(&test.labels).filter(|(a, b)| a == b).count() as f32 / test.b as f32;
        assert!(acc1 > acc0 + 0.2, "{acc0} -> {acc1}");
    }
}
