//! Minimal command-line argument parser (no clap in the offline build).
//!
//! Grammar: `m2ru [global flags] <subcommand> [flags] [positionals]` with
//! `--key value`, `--key=value` and boolean `--flag` forms. Unknown-flag
//! detection is the caller's job via [`Args::finish`], which errors on
//! unconsumed flags so typos never pass silently.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(flag.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(String::as_str)
    }

    /// String flag with default.
    pub fn get(&mut self, key: &str, default: &str) -> String {
        self.consumed.insert(key.to_string());
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn get_opt(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.consumed.insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("flag --{key}={raw}: {e}")),
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn get_bool(&mut self, key: &str) -> Result<bool> {
        self.consumed.insert(key.to_string());
        match self.flags.get(key).map(String::as_str) {
            None => Ok(false),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(other) => bail!("flag --{key} expects a boolean, got `{other}`"),
        }
    }

    /// Error on any flag that was never consumed (typo protection).
    pub fn finish(&self) -> Result<()> {
        for k in self.flags.keys() {
            if !self.consumed.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }

    pub fn subcommand(&self) -> Result<&str> {
        self.subcommand.as_deref().context("missing subcommand (try `m2ru help`)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let mut a = Args::parse(argv("experiment fig4 --nh 256 --dataset=pmnist --hw")).unwrap();
        assert_eq!(a.subcommand().unwrap(), "experiment");
        assert_eq!(a.positional(0), Some("fig4"));
        assert_eq!(a.get_parse("nh", 100usize).unwrap(), 256);
        assert_eq!(a.get("dataset", "x"), "pmnist");
        assert!(a.get_bool("hw").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = Args::parse(argv("train")).unwrap();
        assert_eq!(a.get_parse("seed", 42u64).unwrap(), 42);
        assert_eq!(a.get("net", "small"), "small");
        assert!(!a.get_bool("verbose").unwrap());
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected_by_finish() {
        let a = Args::parse(argv("train --typo 1")).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let mut a = Args::parse(argv("x --nh abc")).unwrap();
        assert!(a.get_parse("nh", 1usize).is_err());
    }

    #[test]
    fn boolean_flag_at_end_of_argv() {
        let mut a = Args::parse(argv("bench --quick")).unwrap();
        assert!(a.get_bool("quick").unwrap());
    }

    #[test]
    fn explicit_false_boolean() {
        let mut a = Args::parse(argv("x --replay false")).unwrap();
        assert!(!a.get_bool("replay").unwrap());
    }
}
