//! Artifact manifest — the build-time contract with `python/compile/aot.py`.
//!
//! `artifacts/manifest.txt` records, per network config, the shapes the
//! artifacts were lowered with. The runtime parses it and cross-checks
//! against the compiled-in [`NetConfig`]s before loading any HLO, so a
//! stale `make artifacts` fails loudly instead of feeding wrong shapes to
//! PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::netcfg::NetConfig;

#[derive(Clone, Debug)]
pub struct ManifestArtifact {
    pub name: String,
    pub file: String,
    pub nargs: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, NetConfig>,
    pub artifacts: BTreeMap<String, ManifestArtifact>,
}

fn kv(parts: &[&str], key: &str) -> Option<String> {
    parts
        .iter()
        .find_map(|p| p.strip_prefix(&format!("{key}=")).map(str::to_string))
}

impl Manifest {
    /// Parse `<dir>/manifest.txt` and validate against compiled-in configs.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let mut lines = text.lines();
        match lines.next() {
            Some("format 1") => {}
            other => bail!("unsupported manifest format line: {other:?}"),
        }
        let mut configs = BTreeMap::new();
        let mut artifacts = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.first() {
                Some(&"config") => {
                    let name = parts.get(1).context("config line missing name")?.to_string();
                    let get = |k: &str| -> Result<f64> {
                        kv(&parts, k)
                            .with_context(|| format!("config {name}: missing {k}"))?
                            .parse::<f64>()
                            .with_context(|| format!("config {name}: bad {k}"))
                    };
                    let built = NetConfig::by_name(&name)
                        .with_context(|| format!("manifest config `{name}` unknown to this binary"))?;
                    // cross-check every shape field
                    let checks = [
                        ("nx", built.nx as f64),
                        ("nh", built.nh as f64),
                        ("ny", built.ny as f64),
                        ("nt", built.nt as f64),
                        ("btrain", built.b_train as f64),
                        ("beval", built.b_eval as f64),
                        ("nb", f64::from(built.nb)),
                        ("adc", f64::from(built.adc_bits)),
                    ];
                    for (k, want) in checks {
                        let got = get(k)?;
                        if (got - want).abs() > 1e-9 {
                            bail!("config {name}: manifest {k}={got} but binary expects {want} — rebuild artifacts");
                        }
                    }
                    let keep = get("keep")?;
                    if (keep - f64::from(built.keep_frac)).abs() > 1e-6 {
                        bail!("config {name}: keep_frac mismatch");
                    }
                    configs.insert(name, built);
                }
                Some(&"artifact") => {
                    let name = parts.get(1).context("artifact line missing name")?.to_string();
                    let file = kv(&parts, "file")
                        .with_context(|| format!("artifact {name}: missing file"))?;
                    let nargs = kv(&parts, "nargs")
                        .with_context(|| format!("artifact {name}: missing nargs"))?
                        .parse()
                        .context("bad nargs")?;
                    if !dir.join(&file).exists() {
                        bail!("artifact {name}: file {file} missing from {}", dir.display());
                    }
                    artifacts.insert(name.clone(), ManifestArtifact { name, file, nargs });
                }
                Some(other) => bail!("manifest line {}: unknown record `{other}`", i + 2),
                None => {}
            }
        }
        if configs.is_empty() {
            bail!("manifest has no configs");
        }
        Ok(Manifest { dir, configs, artifacts })
    }

    /// Absolute path of an artifact by logical name (e.g. `forward_small`).
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let a = self
            .artifacts
            .get(name)
            .with_context(|| format!("artifact `{name}` not in manifest"))?;
        Ok(self.dir.join(&a.file))
    }

    /// Names of all artifacts for one config.
    pub fn artifacts_for(&self, cfg: &str) -> Vec<&ManifestArtifact> {
        self.artifacts.values().filter(|a| a.name.ends_with(&format!("_{cfg}"))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        writeln!(f, "format 1").unwrap();
        write!(f, "{body}").unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("m2ru_manifest_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const SMALL_LINE: &str = "config small nx=8 nh=16 ny=4 nt=5 btrain=8 beval=16 nb=8 adc=8 keep=0.53\n";

    #[test]
    fn loads_valid_manifest() {
        let d = tmpdir("ok");
        std::fs::write(d.join("forward_small.hlo.txt"), "HloModule x").unwrap();
        write_manifest(&d, &format!("{SMALL_LINE}artifact forward_small file=forward_small.hlo.txt nargs=8\n"));
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.configs.len(), 1);
        assert_eq!(m.artifacts["forward_small"].nargs, 8);
        assert!(m.artifact_path("forward_small").unwrap().exists());
        assert_eq!(m.artifacts_for("small").len(), 1);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let d = tmpdir("mismatch");
        write_manifest(
            &d,
            "config small nx=9 nh=16 ny=4 nt=5 btrain=8 beval=16 nb=8 adc=8 keep=0.53\n",
        );
        let e = Manifest::load(&d).unwrap_err().to_string();
        assert!(e.contains("rebuild artifacts"), "{e}");
    }

    #[test]
    fn rejects_missing_artifact_file() {
        let d = tmpdir("missing");
        write_manifest(&d, &format!("{SMALL_LINE}artifact forward_small file=nope.hlo.txt nargs=8\n"));
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_unknown_format() {
        let d = tmpdir("fmt");
        std::fs::write(d.join("manifest.txt"), "format 99\n").unwrap();
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn rejects_unknown_config_name() {
        let d = tmpdir("unknown");
        write_manifest(&d, "config mystery nx=1 nh=1 ny=1 nt=1 btrain=1 beval=1 nb=8 adc=8 keep=0.5\n");
        assert!(Manifest::load(&d).is_err());
    }
}
