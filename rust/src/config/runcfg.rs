//! Run configuration: training hyper-parameters and replay policy, loadable
//! from a TOML-subset file and overridable from the CLI.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::toml_lite::{parse_toml, TomlValue};

/// Everything a continual-learning run needs besides the network shapes.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// MiRU update coefficient λ (retention of previous hidden state).
    pub lam: f32,
    /// MiRU reset coefficient β (history contribution to the candidate).
    pub beta: f32,
    /// DFA / SGD learning rate.
    pub lr: f32,
    /// Number of tasks in the stream.
    pub num_tasks: usize,
    /// Train / test examples per task.
    pub train_per_task: usize,
    pub test_per_task: usize,
    /// Epochs over each task's stream.
    pub epochs: usize,
    /// Replay buffer capacity per task (paper: 1875 pMNIST, 312 CIFAR).
    pub replay_per_task: usize,
    /// Fraction of each training batch drawn from replay.
    pub replay_mix: f32,
    /// Experience replay on/off (ablation).
    pub replay: bool,
    /// Master seed.
    pub seed: u64,
    /// Compute backend name resolved through
    /// [`crate::backend::BackendRegistry`] (`dense`, `crossbar`,
    /// `artifact`, or a custom registration).
    pub backend: String,
    /// Worker threads for the parallel serving engine (1 = sequential).
    pub workers: usize,
    /// Streaming-session server policy (`m2ru serve` / `m2ru loadgen`).
    pub serve: ServeConfig,
    /// Network transport + durability policy (`m2ru serve --listen`).
    pub net: TransportConfig,
    /// Multi-shard session routing policy (`m2ru router`).
    pub router: RouterConfig,
    /// Serve-path observability policy (`rust/src/obs/`, DESIGN.md §13).
    pub obs: ObsConfig,
    /// Traffic + domain-shift scenario policy (`rust/src/serve/scenario.rs`,
    /// DESIGN.md §16).
    pub scenario: ScenarioConfig,
}

/// Scenario policy: deterministic arrival-curve shaping, client-behavior
/// mixes, and a permuted-task domain-shift schedule over the logical
/// clock (DESIGN.md §16). Everything here is consumed by the synthetic
/// workload and the serve report — a scenario run's per-session
/// signature is a pure function of this config + the seed (enforced by
/// `tests/scenario_shift.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioConfig {
    /// Comma-separated arrival phases `kind:waves` cycled over the run
    /// (the TOML subset has no arrays), e.g.
    /// `"steady:20,flash:10,lull:10,churn:15"`. Kinds: `steady` (base
    /// arrivals), `flash` (base × `flash_mult`), `lull`
    /// (base ÷ `lull_div`, min 1), `churn` (base arrivals, and
    /// reconnector users re-key their sessions each wave). Empty =
    /// steady forever.
    pub phases: String,
    /// Arrival multiplier during `flash` phases.
    pub flash_mult: usize,
    /// Arrival divisor during `lull` phases (floor 1 request per wave).
    pub lull_div: usize,
    /// Comma-separated domain shifts `wave:task`, e.g. `"40:1,80:0"`:
    /// from the given wave on, the workload's input/label mapping is the
    /// seeded permutation for `task` (task 0 = the identity — the
    /// pre-shift domain, enabling A→B→A revisits). Empty = no shifts.
    pub shifts: String,
    /// Fraction of users that are slow readers (emit on every other
    /// wave only).
    pub slow_frac: f32,
    /// Fraction of users that reconnect under churn (their session ids
    /// re-key each churn generation — old sessions go idle and churn
    /// the LRU).
    pub reconnect_frac: f32,
    /// Fraction of users that abandon sequences mid-window (their
    /// steps never complete a labeled window, so they never commit).
    pub abandon_frac: f32,
    /// Tenant classes for eviction-fairness reporting (`user %
    /// tenant_classes`); 0 = off.
    pub tenant_classes: usize,
    /// A shift counts as recovered when windowed accuracy re-crosses
    /// `recovery_threshold ×` the pre-shift windowed accuracy.
    pub recovery_threshold: f32,
    /// Labeled observations in the pre/post-shift accuracy window.
    pub recovery_window: usize,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        Self {
            phases: String::new(),
            flash_mult: 4,
            lull_div: 4,
            shifts: String::new(),
            slow_frac: 0.0,
            reconnect_frac: 0.0,
            abandon_frac: 0.0,
            tenant_classes: 0,
            recovery_threshold: 0.9,
            recovery_window: 32,
        }
    }
}

impl ScenarioConfig {
    /// Whether any scenario shaping is active (the report prints
    /// scenario lines only when it is).
    pub fn enabled(&self) -> bool {
        !self.phases.is_empty()
            || !self.shifts.is_empty()
            || self.slow_frac > 0.0
            || self.reconnect_frac > 0.0
            || self.abandon_frac > 0.0
            || self.tenant_classes > 0
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.flash_mult >= 1, "scenario.flash_mult must be >= 1");
        anyhow::ensure!(self.lull_div >= 1, "scenario.lull_div must be >= 1");
        for (name, f) in [
            ("scenario.slow_frac", self.slow_frac),
            ("scenario.reconnect_frac", self.reconnect_frac),
            ("scenario.abandon_frac", self.abandon_frac),
        ] {
            anyhow::ensure!((0.0..=1.0).contains(&f), "{name} must be in [0, 1]");
        }
        anyhow::ensure!(
            self.slow_frac + self.reconnect_frac + self.abandon_frac <= 1.0 + 1e-6,
            "scenario behavior fractions must sum to <= 1 (each user has one behavior)"
        );
        anyhow::ensure!(
            self.recovery_threshold > 0.0 && self.recovery_threshold <= 1.0,
            "scenario.recovery_threshold must be in (0, 1]"
        );
        anyhow::ensure!(self.recovery_window >= 1, "scenario.recovery_window must be >= 1");
        // phase/shift list syntax (`kind:waves`, `wave:task`) is checked
        // here too so a typo fails at config load, not at serve start
        for item in self.phases.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, ticks) = item
                .split_once(':')
                .with_context(|| format!("scenario.phases item `{item}`: expected kind:waves"))?;
            anyhow::ensure!(
                matches!(kind.trim(), "steady" | "flash" | "lull" | "churn"),
                "scenario.phases kind must be steady|flash|lull|churn (got `{kind}`)"
            );
            let n: u64 = ticks
                .trim()
                .parse()
                .with_context(|| format!("scenario.phases item `{item}`: waves must be integer"))?;
            anyhow::ensure!(n >= 1, "scenario.phases item `{item}`: waves must be >= 1");
        }
        let mut last_wave: Option<u64> = None;
        for item in self.shifts.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (wave, task) = item
                .split_once(':')
                .with_context(|| format!("scenario.shifts item `{item}`: expected wave:task"))?;
            let w: u64 = wave
                .trim()
                .parse()
                .with_context(|| format!("scenario.shifts item `{item}`: wave must be integer"))?;
            let _t: u64 = task
                .trim()
                .parse()
                .with_context(|| format!("scenario.shifts item `{item}`: task must be integer"))?;
            anyhow::ensure!(
                last_wave.map_or(true, |p| w > p),
                "scenario.shifts waves must be strictly increasing (got `{item}`)"
            );
            last_wave = Some(w);
        }
        Ok(())
    }
}

/// Observability policy: how much the serve path records into the
/// metrics registry and flight recorder (`rust/src/obs/`). Strictly
/// timing-plane — no value here can change a single served bit; the
/// deterministic signature is identical for every mode (enforced by
/// `tests/obs_invariance.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// `on` (record everything — cheap enough to leave enabled), `off`
    /// (instruments never touched from the hot path), or `sampled`
    /// (record every `sample_every`-th span; counters stay exact).
    pub mode: String,
    /// Span sampling stride for `mode = "sampled"`.
    pub sample_every: u64,
    /// Flight-recorder ring capacity (lifecycle events retained).
    pub flight_capacity: usize,
    /// Periodic metrics snapshot file: every `snapshot_every` ticks the
    /// Prometheus text lands here and the flight-recorder JSONL beside
    /// it at `<path>.jsonl` (empty = off).
    pub snapshot_path: String,
    /// Logical ticks between metrics file snapshots (0 = off).
    pub snapshot_every: u64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            mode: "on".to_string(),
            sample_every: 16,
            flight_capacity: 256,
            snapshot_path: String::new(),
            snapshot_every: 0,
        }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            matches!(self.mode.as_str(), "on" | "off" | "sampled"),
            "obs.mode must be `on`, `off` or `sampled` (got `{}`)",
            self.mode
        );
        anyhow::ensure!(self.sample_every >= 1, "obs.sample_every must be >= 1");
        anyhow::ensure!(self.flight_capacity >= 1, "obs.flight_capacity must be >= 1");
        anyhow::ensure!(
            self.snapshot_every == 0 || !self.snapshot_path.is_empty(),
            "obs.snapshot_every needs obs.snapshot_path (nowhere to write)"
        );
        Ok(())
    }
}

/// Multi-shard session router policy (`rust/src/net/router.rs`,
/// DESIGN.md §11): how many serve shards the front door partitions
/// session ids across, where they live, and where each shard's
/// checkpoint chain goes.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterConfig {
    /// In-process shard threads (ignored when `shard_addrs` is set; the
    /// remote fleet's size is the address list's length).
    pub shards: usize,
    /// Remote shard addresses (`host:port` of running
    /// `m2ru serve --listen` processes). Empty selects in-process shards.
    pub shard_addrs: Vec<String>,
    /// Checkpoint root for in-process shards: shard `k` restores from and
    /// snapshots into `<root>/shard-<k>/` (empty = durability off).
    /// Remote shards own their durability via their own
    /// `--checkpoint-dir`.
    pub checkpoint_root: String,
    /// Total steps the remote router may park for sessions whose
    /// migration is in flight during a drain/rebalance (DESIGN.md §14).
    /// A client that floods a migrating session past this bound is
    /// dropped — back-pressure, not unbounded buffering.
    pub max_parked: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            shard_addrs: Vec::new(),
            checkpoint_root: String::new(),
            max_parked: 4096,
        }
    }
}

impl RouterConfig {
    /// The effective fleet size.
    pub fn fleet_size(&self) -> usize {
        if self.shard_addrs.is_empty() {
            self.shards
        } else {
            self.shard_addrs.len()
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.shards >= 1, "router.shards must be >= 1");
        for (k, a) in self.shard_addrs.iter().enumerate() {
            anyhow::ensure!(
                !a.trim().is_empty(),
                "router.shard_addrs entry {k} is empty (expected host:port)"
            );
        }
        anyhow::ensure!(
            self.shard_addrs.is_empty() || self.checkpoint_root.is_empty(),
            "router.checkpoint_root applies to in-process shards only; remote shards \
             (router.shard_addrs) each own their durability via their --checkpoint-dir"
        );
        anyhow::ensure!(self.max_parked >= 1, "router.max_parked must be >= 1");
        Ok(())
    }
}

/// Policy knobs of the streaming session server (`rust/src/serve/`):
/// session-store sizing, dynamic-batcher dispatch, and the online
/// continual-learning commit cadence. Time-like fields are in *logical
/// ticks* of the serve loop, so runs are deterministic and testable under
/// a mock clock.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Requests coalesced into one padded dispatch batch.
    pub max_batch: usize,
    /// Ticks the oldest pending request may wait before a partial batch
    /// dispatches anyway.
    pub max_wait: u64,
    /// Session-store slots; at capacity the least-recently-used session
    /// is evicted.
    pub capacity: usize,
    /// Idle ticks before a session expires (0 = never).
    pub ttl: u64,
    /// Labeled steps per online DFA commit (0 = inference only).
    pub update_every: usize,
    /// Reservoir capacity of each online replay segment.
    pub replay_cap: usize,
    /// Fraction of each online training batch drawn from replay.
    pub replay_mix: f32,
    /// Wear-aware write rationing: columns whose cumulative device writes
    /// exceed `wear_ratio ×` the column mean skip the commit's programming
    /// pulses (0 disables; only substrates with wear accounting ration).
    pub wear_ratio: f32,
    /// Bounded depth of the serve-loop → committer-thread job queue
    /// (finalized training windows + snapshot writes). A serve loop
    /// outrunning its committer blocks on enqueue — back-pressure, not
    /// unbounded buffering.
    pub commit_queue_depth: usize,
    /// Compute-kernel selection for the whole process: `""`/`auto` (best
    /// SIMD the machine supports), `scalar` (portable floor), or `simd`
    /// (state the intent; falls back to scalar where unavailable). All
    /// kernels are bitwise-identical (DESIGN.md §12), so this is a perf
    /// and debugging knob, never a numerics one. Overrides the
    /// `M2RU_KERNEL` environment variable.
    pub kernel: String,
    /// Serving-precision selection for the whole process: `""`/`f32`
    /// (exact float path) or `int8` (pre-quantized i8 weight planes +
    /// integer MAC kernels, DESIGN.md §15). Unlike `kernel`, this *is* a
    /// numerics knob — int8 logits approximate f32 within the pinned
    /// accuracy gate — but stays bitwise-reproducible across kernels
    /// and worker counts. Overrides the `M2RU_PRECISION` environment
    /// variable.
    pub precision: String,
}

/// Network transport and durability policy of the TCP serving frontend
/// (`rust/src/net/`, DESIGN.md §9): where to listen, how deep the bounded
/// reader→serve queue is, and where/how often session snapshots land.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// TCP listen address (`host:port`; port 0 picks a free port). Empty
    /// selects the in-process synthetic driver instead of the transport.
    pub listen: String,
    /// Bounded depth of the per-connection-reader → serve-thread queue
    /// (back-pressure: readers block when the serve loop falls behind).
    pub queue_depth: usize,
    /// Snapshot directory for checkpoint/restore (empty = durability off).
    pub checkpoint_dir: String,
    /// Logical ticks between periodic snapshots (0 = only at shutdown).
    pub checkpoint_every: u64,
    /// Whether connected clients may administer the server: send
    /// `Shutdown` and drive the logical clock with TICK/FLUSH frame
    /// flags. The default suits the loopback harness (`m2ru connect`)
    /// and single-operator benches; for a server exposed to untrusted
    /// clients set `false` — client flags are then ignored, `Shutdown`
    /// is a protocol violation, and the clock is driven by `tick_ms`.
    pub client_admin: bool,
    /// Server-driven tick period in milliseconds (0 = client-driven
    /// clock). Required > 0 when `client_admin` is off, since nothing
    /// else would advance batching, TTL expiry or checkpoint cadence.
    pub tick_ms: u64,
    /// Frames buffered per connection between the serve thread and that
    /// connection's writer thread. A peer that stops reading fills its
    /// own outbox and is dropped — it never delays other clients.
    pub outbox_depth: usize,
    /// Every Nth snapshot is a full rewrite; the rest are incremental
    /// deltas against it (1 = always full, i.e. deltas off).
    pub snapshot_full_every: u64,
    /// Snapshot durability point: `always` fsyncs every snapshot file
    /// (and the directory), `full` fsyncs only full snapshots (a crash
    /// may lose the delta tail, never the full baseline), `never`
    /// trusts the OS cache (renames stay atomic — no torn files).
    pub fsync_policy: String,
}

/// Parsed `[net] fsync_policy` (see [`TransportConfig::fsync`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    Always,
    FullOnly,
    Never,
}

impl FsyncPolicy {
    pub fn parse(s: &str) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "full" => Ok(FsyncPolicy::FullOnly),
            "never" => Ok(FsyncPolicy::Never),
            other => anyhow::bail!(
                "net.fsync_policy must be `always`, `full` or `never` (got `{other}`)"
            ),
        }
    }
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            listen: String::new(),
            queue_depth: 256,
            checkpoint_dir: String::new(),
            checkpoint_every: 0,
            client_admin: true,
            tick_ms: 0,
            outbox_depth: 64,
            snapshot_full_every: 8,
            fsync_policy: "always".to_string(),
        }
    }
}

impl TransportConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.queue_depth >= 1, "net.queue_depth must be >= 1");
        anyhow::ensure!(self.outbox_depth >= 1, "net.outbox_depth must be >= 1");
        anyhow::ensure!(self.snapshot_full_every >= 1, "net.snapshot_full_every must be >= 1");
        let _ = self.fsync()?;
        anyhow::ensure!(
            self.client_admin || self.tick_ms >= 1,
            "net.client_admin = false needs net.tick_ms >= 1 (something must drive the clock)"
        );
        Ok(())
    }

    /// The parsed fsync policy (validated by [`TransportConfig::validate`]).
    pub fn fsync(&self) -> Result<FsyncPolicy> {
        FsyncPolicy::parse(&self.fsync_policy)
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: 4,
            capacity: 1024,
            ttl: 0,
            update_every: 64,
            replay_cap: 256,
            replay_mix: 0.5,
            wear_ratio: 4.0,
            commit_queue_depth: 4,
            kernel: String::new(),
            precision: String::new(),
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.max_batch >= 1, "serve.max_batch must be >= 1");
        anyhow::ensure!(
            self.capacity >= self.max_batch,
            "serve.capacity must be >= serve.max_batch (a dispatch batch holds distinct live sessions)"
        );
        anyhow::ensure!(self.replay_cap >= 1, "serve.replay_cap must be >= 1");
        anyhow::ensure!(
            (0.0..=0.9).contains(&self.replay_mix),
            "serve.replay_mix must be in [0, 0.9]"
        );
        anyhow::ensure!(
            self.wear_ratio == 0.0 || self.wear_ratio >= 1.0,
            "serve.wear_ratio must be 0 (off) or >= 1 (columns above ratio x mean writes ration)"
        );
        anyhow::ensure!(self.commit_queue_depth >= 1, "serve.commit_queue_depth must be >= 1");
        anyhow::ensure!(
            matches!(self.kernel.as_str(), "" | "auto" | "scalar" | "simd"),
            "serve.kernel must be `auto`, `scalar` or `simd` (got `{}`)",
            self.kernel
        );
        anyhow::ensure!(
            matches!(self.precision.as_str(), "" | "f32" | "int8"),
            "serve.precision must be `f32` or `int8` (got `{}`)",
            self.precision
        );
        Ok(())
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        // Operating point tuned on the synthetic permuted-digit stream
        // (EXPERIMENTS.md §Calibration): high λ keeps enough temporal
        // memory for permuted presentations, moderate β curbs recurrent
        // saturation under DFA.
        Self {
            lam: 0.96,
            beta: 0.3,
            lr: 0.3,
            num_tasks: 5,
            train_per_task: 1200,
            test_per_task: 200,
            epochs: 8,
            replay_per_task: 400,
            replay_mix: 0.5,
            replay: true,
            seed: 42,
            backend: "dense".to_string(),
            workers: 1,
            serve: ServeConfig::default(),
            net: TransportConfig::default(),
            router: RouterConfig::default(),
            obs: ObsConfig::default(),
            scenario: ScenarioConfig::default(),
        }
    }
}

impl RunConfig {
    /// Apply keys from a parsed TOML map (unknown keys are errors: typos
    /// in experiment configs must not pass silently).
    pub fn apply(&mut self, map: &BTreeMap<String, TomlValue>) -> Result<()> {
        for (k, v) in map {
            let fget = || v.as_float().with_context(|| format!("{k}: expected number"));
            let iget = || -> Result<usize> {
                let i = v.as_int().with_context(|| format!("{k}: expected integer"))?;
                usize::try_from(i).with_context(|| format!("{k}: must be non-negative"))
            };
            match k.as_str() {
                "lam" | "lambda" => self.lam = fget()? as f32,
                "beta" => self.beta = fget()? as f32,
                "lr" => self.lr = fget()? as f32,
                "num_tasks" => self.num_tasks = iget()?,
                "train_per_task" => self.train_per_task = iget()?,
                "test_per_task" => self.test_per_task = iget()?,
                "epochs" => self.epochs = iget()?,
                "seed" => self.seed = v.as_int().context("seed: integer")? as u64,
                "backend" => {
                    self.backend =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "workers" => self.workers = iget()?,
                "replay.per_task" => self.replay_per_task = iget()?,
                "replay.mix" => self.replay_mix = fget()? as f32,
                "replay.enabled" => {
                    self.replay = v.as_bool().context("replay.enabled: bool")?;
                }
                "serve.max_batch" => self.serve.max_batch = iget()?,
                "serve.max_wait" => self.serve.max_wait = iget()? as u64,
                "serve.capacity" => self.serve.capacity = iget()?,
                "serve.ttl" => self.serve.ttl = iget()? as u64,
                "serve.update_every" => self.serve.update_every = iget()?,
                "serve.replay_cap" => self.serve.replay_cap = iget()?,
                "serve.replay_mix" => self.serve.replay_mix = fget()? as f32,
                "serve.wear_ratio" => self.serve.wear_ratio = fget()? as f32,
                "serve.commit_queue_depth" => self.serve.commit_queue_depth = iget()?,
                "serve.kernel" => {
                    self.serve.kernel =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "serve.precision" => {
                    self.serve.precision =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "net.listen" => {
                    self.net.listen =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "net.queue_depth" => self.net.queue_depth = iget()?,
                "net.checkpoint_dir" => {
                    self.net.checkpoint_dir =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "net.checkpoint_every" => self.net.checkpoint_every = iget()? as u64,
                "net.client_admin" => {
                    self.net.client_admin = v.as_bool().context("net.client_admin: bool")?;
                }
                "net.tick_ms" => self.net.tick_ms = iget()? as u64,
                "net.outbox_depth" => self.net.outbox_depth = iget()?,
                "net.snapshot_full_every" => self.net.snapshot_full_every = iget()? as u64,
                "net.fsync_policy" => {
                    self.net.fsync_policy =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "router.shards" => self.router.shards = iget()?,
                "router.shard_addrs" => {
                    // comma-separated list (the TOML subset has no arrays)
                    let raw = v.as_str().with_context(|| format!("{k}: expected string"))?;
                    self.router.shard_addrs = raw
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect();
                }
                "router.checkpoint_root" => {
                    self.router.checkpoint_root =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "router.max_parked" => self.router.max_parked = iget()?,
                "obs.mode" => {
                    self.obs.mode =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "obs.sample_every" => self.obs.sample_every = iget()? as u64,
                "obs.flight_capacity" => self.obs.flight_capacity = iget()?,
                "obs.snapshot_path" => {
                    self.obs.snapshot_path =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "obs.snapshot_every" => self.obs.snapshot_every = iget()? as u64,
                "scenario.phases" => {
                    self.scenario.phases =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "scenario.flash_mult" => self.scenario.flash_mult = iget()?,
                "scenario.lull_div" => self.scenario.lull_div = iget()?,
                "scenario.shifts" => {
                    self.scenario.shifts =
                        v.as_str().with_context(|| format!("{k}: expected string"))?.to_string();
                }
                "scenario.slow_frac" => self.scenario.slow_frac = fget()? as f32,
                "scenario.reconnect_frac" => self.scenario.reconnect_frac = fget()? as f32,
                "scenario.abandon_frac" => self.scenario.abandon_frac = fget()? as f32,
                "scenario.tenant_classes" => self.scenario.tenant_classes = iget()?,
                "scenario.recovery_threshold" => {
                    self.scenario.recovery_threshold = fget()? as f32;
                }
                "scenario.recovery_window" => self.scenario.recovery_window = iget()?,
                other => anyhow::bail!("unknown config key `{other}`"),
            }
        }
        self.validate()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let map = parse_toml(&text)?;
        let mut cfg = RunConfig::default();
        cfg.apply(&map)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!((0.0..=1.0).contains(&self.lam), "lam must be in [0,1]");
        anyhow::ensure!((0.0..=1.0).contains(&self.beta), "beta must be in [0,1]");
        anyhow::ensure!(self.lr > 0.0, "lr must be positive");
        anyhow::ensure!((0.0..=1.0).contains(&self.replay_mix), "replay.mix in [0,1]");
        anyhow::ensure!(self.num_tasks >= 1, "need at least one task");
        anyhow::ensure!(self.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(!self.backend.is_empty(), "backend name must be non-empty");
        self.serve.validate()?;
        self.net.validate()?;
        self.router.validate()?;
        self.obs.validate()?;
        self.scenario.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_toml_overrides() {
        let map = parse_toml(
            "lr = 0.1\nseed = 7\nnum_tasks = 3\n[replay]\nper_task = 312\nmix = 0.25\nenabled = false\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.lr, 0.1);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.num_tasks, 3);
        assert_eq!(cfg.replay_per_task, 312);
        assert_eq!(cfg.replay_mix, 0.25);
        assert!(!cfg.replay);
    }

    #[test]
    fn backend_and_workers_from_toml() {
        let map = parse_toml("backend = \"crossbar\"\nworkers = 4\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.backend, "crossbar");
        assert_eq!(cfg.workers, 4);
        let bad = parse_toml("workers = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
    }

    #[test]
    fn unknown_key_is_error() {
        let map = parse_toml("learning_rate = 0.1\n").unwrap();
        assert!(RunConfig::default().apply(&map).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let map = parse_toml("lam = 1.5\n").unwrap();
        assert!(RunConfig::default().apply(&map).is_err());
        let map = parse_toml("lr = -0.1\n").unwrap();
        assert!(RunConfig::default().apply(&map).is_err());
    }

    #[test]
    fn serve_keys_from_toml() {
        let map = parse_toml(
            "[serve]\nmax_batch = 16\nmax_wait = 2\ncapacity = 64\nttl = 100\nupdate_every = 8\nreplay_cap = 32\nreplay_mix = 0.25\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.serve.max_batch, 16);
        assert_eq!(cfg.serve.max_wait, 2);
        assert_eq!(cfg.serve.capacity, 64);
        assert_eq!(cfg.serve.ttl, 100);
        assert_eq!(cfg.serve.update_every, 8);
        assert_eq!(cfg.serve.replay_cap, 32);
        assert_eq!(cfg.serve.replay_mix, 0.25);
    }

    #[test]
    fn serve_capacity_below_batch_rejected() {
        let map = parse_toml("[serve]\nmax_batch = 64\ncapacity = 8\n").unwrap();
        assert!(RunConfig::default().apply(&map).is_err());
        let bad_mix = parse_toml("[serve]\nreplay_mix = 0.95\n").unwrap();
        assert!(RunConfig::default().apply(&bad_mix).is_err());
    }

    #[test]
    fn net_keys_from_toml() {
        let map = parse_toml(
            "[net]\nlisten = \"127.0.0.1:7432\"\nqueue_depth = 64\ncheckpoint_dir = \"ckpt\"\ncheckpoint_every = 500\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.net.listen, "127.0.0.1:7432");
        assert_eq!(cfg.net.queue_depth, 64);
        assert_eq!(cfg.net.checkpoint_dir, "ckpt");
        assert_eq!(cfg.net.checkpoint_every, 500);
        let bad = parse_toml("[net]\nqueue_depth = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
    }

    #[test]
    fn client_admin_off_requires_server_ticks() {
        let bad = parse_toml("[net]\nclient_admin = false\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err(), "no clock source must be rejected");
        let ok = parse_toml("[net]\nclient_admin = false\ntick_ms = 20\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&ok).unwrap();
        assert!(!cfg.net.client_admin);
        assert_eq!(cfg.net.tick_ms, 20);
    }

    #[test]
    fn async_serve_and_snapshot_keys_from_toml() {
        let map = parse_toml(
            "[serve]\ncommit_queue_depth = 2\n[net]\noutbox_depth = 16\nsnapshot_full_every = 4\nfsync_policy = \"full\"\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.serve.commit_queue_depth, 2);
        assert_eq!(cfg.net.outbox_depth, 16);
        assert_eq!(cfg.net.snapshot_full_every, 4);
        assert_eq!(cfg.net.fsync().unwrap(), FsyncPolicy::FullOnly);
        // invalid values are rejected at validation time
        let bad = parse_toml("[serve]\ncommit_queue_depth = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
        let bad = parse_toml("[net]\noutbox_depth = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
        let bad = parse_toml("[net]\nsnapshot_full_every = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
        let bad = parse_toml("[net]\nfsync_policy = \"sometimes\"\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
        // defaults parse every policy value
        for (s, want) in [
            ("always", FsyncPolicy::Always),
            ("full", FsyncPolicy::FullOnly),
            ("never", FsyncPolicy::Never),
        ] {
            assert_eq!(FsyncPolicy::parse(s).unwrap(), want);
        }
    }

    #[test]
    fn router_keys_from_toml() {
        let map = parse_toml(
            "[router]\nshards = 4\ncheckpoint_root = \"ckpt/router\"\nmax_parked = 128\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.router.shards, 4);
        assert_eq!(cfg.router.checkpoint_root, "ckpt/router");
        assert_eq!(cfg.router.max_parked, 128);
        assert!(cfg.router.shard_addrs.is_empty());
        assert_eq!(cfg.router.fleet_size(), 4);
        // comma-separated remote addresses; the list length wins
        let map = parse_toml(
            "[router]\nshards = 2\nshard_addrs = \"127.0.0.1:7501, 127.0.0.1:7502\"\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(
            cfg.router.shard_addrs,
            vec!["127.0.0.1:7501".to_string(), "127.0.0.1:7502".to_string()]
        );
        assert_eq!(cfg.router.fleet_size(), 2);
    }

    #[test]
    fn router_validation_rejects_bad_configs() {
        let bad = parse_toml("[router]\nshards = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err(), "zero shards must be rejected");
        let bad = parse_toml("[router]\nmax_parked = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err(), "zero park capacity must be rejected");
        // a checkpoint root combined with remote shards is a config error:
        // remote shards own their durability
        let bad = parse_toml(
            "[router]\nshard_addrs = \"127.0.0.1:7501\"\ncheckpoint_root = \"ckpt\"\n",
        )
        .unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
        // blank entries in the address list are rejected (a trailing comma
        // is tolerated by the split filter)
        let mut cfg = RunConfig::default();
        cfg.router.shard_addrs = vec!["127.0.0.1:7501".into(), "  ".into()];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn serve_kernel_key_from_toml() {
        let map = parse_toml("[serve]\nkernel = \"scalar\"\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.serve.kernel, "scalar");
        for ok in ["auto", "simd"] {
            let map = parse_toml(&format!("[serve]\nkernel = \"{ok}\"\n")).unwrap();
            RunConfig::default().apply(&map).unwrap();
        }
        let bad = parse_toml("[serve]\nkernel = \"avx512\"\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err(), "unknown kernel names are rejected");
    }

    #[test]
    fn serve_precision_key_from_toml() {
        let map = parse_toml("[serve]\nprecision = \"int8\"\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.serve.precision, "int8");
        let map = parse_toml("[serve]\nprecision = \"f32\"\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.serve.precision, "f32");
        let bad = parse_toml("[serve]\nprecision = \"fp16\"\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err(), "unknown precisions are rejected");
    }

    #[test]
    fn wear_ratio_validation() {
        let ok = parse_toml("[serve]\nwear_ratio = 2.5\n").unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&ok).unwrap();
        assert_eq!(cfg.serve.wear_ratio, 2.5);
        let off = parse_toml("[serve]\nwear_ratio = 0\n").unwrap();
        RunConfig::default().apply(&off).unwrap();
        // ratios in (0, 1) would ration *under*-stressed columns — rejected
        let bad = parse_toml("[serve]\nwear_ratio = 0.5\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
    }

    #[test]
    fn obs_keys_from_toml() {
        let map = parse_toml(
            "[obs]\nmode = \"sampled\"\nsample_every = 8\nflight_capacity = 64\nsnapshot_path = \"metrics.prom\"\nsnapshot_every = 100\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.obs.mode, "sampled");
        assert_eq!(cfg.obs.sample_every, 8);
        assert_eq!(cfg.obs.flight_capacity, 64);
        assert_eq!(cfg.obs.snapshot_path, "metrics.prom");
        assert_eq!(cfg.obs.snapshot_every, 100);
        let bad = parse_toml("[obs]\nmode = \"loud\"\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err(), "unknown modes are rejected");
        let bad = parse_toml("[obs]\nsample_every = 0\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
        // a snapshot cadence with nowhere to write is a config error
        let bad = parse_toml("[obs]\nsnapshot_every = 10\n").unwrap();
        assert!(RunConfig::default().apply(&bad).is_err());
    }

    #[test]
    fn scenario_keys_from_toml() {
        let map = parse_toml(
            "[scenario]\nphases = \"steady:20,flash:10,lull:5,churn:15\"\nflash_mult = 3\nlull_div = 2\nshifts = \"40:1,80:0\"\nslow_frac = 0.25\nreconnect_frac = 0.25\nabandon_frac = 0.125\ntenant_classes = 4\nrecovery_threshold = 0.8\nrecovery_window = 48\n",
        )
        .unwrap();
        let mut cfg = RunConfig::default();
        cfg.apply(&map).unwrap();
        assert_eq!(cfg.scenario.phases, "steady:20,flash:10,lull:5,churn:15");
        assert_eq!(cfg.scenario.flash_mult, 3);
        assert_eq!(cfg.scenario.lull_div, 2);
        assert_eq!(cfg.scenario.shifts, "40:1,80:0");
        assert_eq!(cfg.scenario.slow_frac, 0.25);
        assert_eq!(cfg.scenario.reconnect_frac, 0.25);
        assert_eq!(cfg.scenario.abandon_frac, 0.125);
        assert_eq!(cfg.scenario.tenant_classes, 4);
        assert_eq!(cfg.scenario.recovery_threshold, 0.8);
        assert_eq!(cfg.scenario.recovery_window, 48);
        assert!(cfg.scenario.enabled());
        assert!(!ScenarioConfig::default().enabled());
    }

    #[test]
    fn scenario_validation_rejects_bad_configs() {
        for bad in [
            "[scenario]\nphases = \"sleepy:10\"\n",
            "[scenario]\nphases = \"flash\"\n",
            "[scenario]\nphases = \"flash:0\"\n",
            "[scenario]\nshifts = \"40\"\n",
            "[scenario]\nshifts = \"40:1,30:2\"\n",
            "[scenario]\nshifts = \"40:x\"\n",
            "[scenario]\nflash_mult = 0\n",
            "[scenario]\nlull_div = 0\n",
            "[scenario]\nslow_frac = 1.5\n",
            "[scenario]\nslow_frac = 0.6\nreconnect_frac = 0.6\n",
            "[scenario]\nrecovery_threshold = 0\n",
            "[scenario]\nrecovery_window = 0\n",
        ] {
            let map = parse_toml(bad).unwrap();
            assert!(RunConfig::default().apply(&map).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn load_from_file() {
        let p = std::env::temp_dir().join(format!("m2ru_runcfg_{}.toml", std::process::id()));
        std::fs::write(&p, "lr = 0.33\nepochs = 4\n").unwrap();
        let cfg = RunConfig::load(&p).unwrap();
        assert_eq!(cfg.lr, 0.33);
        assert_eq!(cfg.epochs, 4);
    }
}
