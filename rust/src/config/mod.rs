//! Configuration system: network configs, the artifact manifest contract,
//! and a TOML-subset parser for run configuration files.
//!
//! The offline build has no serde; `toml_lite` is a small hand-rolled
//! parser covering the subset this project uses (tables, string / number /
//! boolean scalars, comments) with proper error reporting.

mod manifest;
mod netcfg;
mod runcfg;
mod toml_lite;

pub use manifest::{Manifest, ManifestArtifact};
pub use netcfg::NetConfig;
pub use runcfg::{
    FsyncPolicy, ObsConfig, RouterConfig, RunConfig, ScenarioConfig, ServeConfig, TransportConfig,
};
pub use toml_lite::{parse_toml, TomlError, TomlValue};
