//! TOML-subset parser (no serde in the offline environment).
//!
//! Supported: `[table]` headers, `key = value` with string, integer,
//! float, boolean scalars, `#` comments, blank lines. Keys inside a table
//! are flattened to `"table.key"`. Errors carry line numbers.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, message: impl Into<String>) -> TomlError {
    TomlError { line, message: message.into() }
}

fn parse_scalar(raw: &str, line: usize) -> Result<TomlValue, TomlError> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(err(line, "missing value"));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(line, "embedded quote in string"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if raw.contains('.') || raw.contains('e') || raw.contains('E') {
        if let Ok(f) = raw.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    Err(err(line, format!("unparseable value `{raw}`")))
}

/// Parse a TOML-subset document into flattened `table.key` → value.
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>, TomlError> {
    let mut out = BTreeMap::new();
    let mut prefix = String::new();
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        // strip comments (not inside strings — our strings forbid '#')
        let line = match raw_line.find('#') {
            Some(pos) if !raw_line[..pos].contains('"') => &raw_line[..pos],
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err(line_no, "unclosed table header"))?;
            let name = name.trim();
            if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '.')
            {
                return Err(err(line_no, format!("bad table name `{name}`")));
            }
            prefix = format!("{name}.");
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err(line_no, "expected `key = value`"))?;
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || c == '_') {
            return Err(err(line_no, format!("bad key `{key}`")));
        }
        let value = parse_scalar(&line[eq + 1..], line_no)?;
        let full = format!("{prefix}{key}");
        if out.insert(full.clone(), value).is_some() {
            return Err(err(line_no, format!("duplicate key `{full}`")));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = r#"
# run configuration
seed = 42
lr = 0.05
name = "pmnist"
verbose = true

[replay]
per_task = 1875
enabled = false
"#;
        let m = parse_toml(doc).unwrap();
        assert_eq!(m["seed"], TomlValue::Int(42));
        assert_eq!(m["lr"], TomlValue::Float(0.05));
        assert_eq!(m["name"], TomlValue::Str("pmnist".into()));
        assert_eq!(m["verbose"], TomlValue::Bool(true));
        assert_eq!(m["replay.per_task"], TomlValue::Int(1875));
        assert_eq!(m["replay.enabled"], TomlValue::Bool(false));
    }

    #[test]
    fn accessors() {
        assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
        assert_eq!(TomlValue::Str("x".into()).as_str(), Some("x"));
        assert_eq!(TomlValue::Bool(true).as_bool(), Some(true));
        assert_eq!(TomlValue::Float(0.5).as_int(), None);
    }

    #[test]
    fn error_reports_line_numbers() {
        let e = parse_toml("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_toml("a = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = parse_toml("a = 1\na = 2\n").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let m = parse_toml("# only comments\n\n  \n").unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn scientific_notation_floats() {
        let m = parse_toml("endurance = 1e9\n").unwrap();
        assert_eq!(m["endurance"].as_float(), Some(1e9));
    }

    #[test]
    fn bad_table_rejected() {
        assert!(parse_toml("[ta ble]\n").is_err());
        assert!(parse_toml("[open\n").is_err());
    }
}
