//! Network configuration — the rust mirror of `python/compile/configs.py`.
//!
//! Shapes must agree with the lowered artifacts; `Manifest::check` verifies
//! the contract at load time and refuses to run against stale artifacts.

/// One MiRU network instantiation (shapes are lowering-time static).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetConfig {
    pub name: &'static str,
    pub nx: usize,
    pub nh: usize,
    pub ny: usize,
    pub nt: usize,
    pub b_train: usize,
    pub b_eval: usize,
    pub nb: u32,
    pub adc_bits: u32,
    pub keep_frac: f32,
}

impl NetConfig {
    pub const SMALL: NetConfig = NetConfig {
        name: "small",
        nx: 8,
        nh: 16,
        ny: 4,
        nt: 5,
        b_train: 8,
        b_eval: 16,
        nb: 8,
        adc_bits: 8,
        keep_frac: 0.53,
    };
    pub const PMNIST100: NetConfig = NetConfig {
        name: "pmnist100",
        nx: 28,
        nh: 100,
        ny: 10,
        nt: 28,
        b_train: 32,
        b_eval: 200,
        nb: 8,
        adc_bits: 8,
        keep_frac: 0.53,
    };
    pub const PMNIST256: NetConfig =
        NetConfig { name: "pmnist256", nh: 256, ..NetConfig::PMNIST100 };
    pub const CIFAR100: NetConfig = NetConfig {
        name: "cifar100",
        nx: 32,
        nh: 100,
        ny: 2,
        nt: 16,
        b_train: 32,
        b_eval: 200,
        nb: 8,
        adc_bits: 8,
        keep_frac: 0.53,
    };
    pub const CIFAR256: NetConfig = NetConfig { name: "cifar256", nh: 256, ..NetConfig::CIFAR100 };

    pub const ALL: [NetConfig; 5] = [
        NetConfig::SMALL,
        NetConfig::PMNIST100,
        NetConfig::PMNIST256,
        NetConfig::CIFAR100,
        NetConfig::CIFAR256,
    ];

    pub fn by_name(name: &str) -> Option<NetConfig> {
        NetConfig::ALL.into_iter().find(|c| c.name == name)
    }

    /// Total parameter count (matches `model.param_count`).
    pub fn param_count(&self) -> usize {
        self.nx * self.nh + self.nh * self.nh + self.nh + self.nh * self.ny + self.ny
    }

    /// Configs that ship a dense (no-ζ) DFA train artifact.
    pub fn has_dense_train(&self) -> bool {
        matches!(self.name, "small" | "pmnist100")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(NetConfig::by_name("pmnist256").unwrap().nh, 256);
        assert!(NetConfig::by_name("nope").is_none());
    }

    #[test]
    fn param_count_pmnist100() {
        assert_eq!(NetConfig::PMNIST100.param_count(), 2800 + 10_000 + 100 + 1000 + 10);
    }

    #[test]
    fn geometry_matches_python_configs() {
        // keep in lock-step with python/compile/configs.py
        let c = NetConfig::CIFAR100;
        assert_eq!((c.nx, c.nt, c.ny), (32, 16, 2));
        assert_eq!(c.nx * c.nt, 512);
        assert_eq!(NetConfig::SMALL.b_train, 8);
    }

    #[test]
    fn dense_train_flags() {
        assert!(NetConfig::SMALL.has_dense_train());
        assert!(NetConfig::PMNIST100.has_dense_train());
        assert!(!NetConfig::PMNIST256.has_dense_train());
    }
}
