//! Random-number substrates.
//!
//! The paper's hardware uses two distinct generators and the distinction is
//! load-bearing (§IV-A1): the reservoir sampler needs *decorrelated,
//! uniform, unbiased* indices — a 32-bit **xorshift** — while the stochastic
//! quantizer only needs cheap uniform bits — an **LFSR**. Both are
//! implemented exactly as the circuits would be, plus a [`SplitMix64`]
//! seeder and Gaussian sampling used by the software-side substrates
//! (data generation, weight init, device variability).

mod lfsr;
mod xorshift;

pub use lfsr::Lfsr16;
pub use xorshift::Xorshift32;

/// SplitMix64: seed expander (Steele et al.). Used to derive uncorrelated
/// seeds for the many per-subsystem RNG instances from one CLI seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Non-zero 32-bit seed (xorshift/LFSR must never be seeded with 0).
    pub fn next_seed32(&mut self) -> u32 {
        loop {
            let s = (self.next_u64() >> 32) as u32;
            if s != 0 {
                return s;
            }
        }
    }

    /// Current generator state. `SplitMix64::new(state)` reconstructs the
    /// generator exactly — the checkpoint/restore hook.
    pub fn state(&self) -> u64 {
        self.state
    }
}

/// Uniform f32 in [0, 1) from any u32 source (24-bit mantissa path,
/// matching what a hardware comparator against an LFSR word sees).
pub fn u32_to_unit_f32(x: u32) -> f32 {
    (x >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Software-side Gaussian sampler (Box–Muller over a SplitMix64 stream).
/// Used for weight init, synthetic data and device variability — never in
/// the modeled hardware datapath.
#[derive(Clone, Debug)]
pub struct GaussianRng {
    src: SplitMix64,
    spare: Option<f32>,
}

impl GaussianRng {
    pub fn new(seed: u64) -> Self {
        Self { src: SplitMix64::new(seed), spare: None }
    }

    /// Serializable generator state: the SplitMix64 word plus the cached
    /// Box–Muller spare (checkpoint/restore hook).
    pub fn state(&self) -> (u64, Option<f32>) {
        (self.src.state(), self.spare)
    }

    /// Reconstruct a generator mid-stream from [`GaussianRng::state`].
    pub fn from_state(state: u64, spare: Option<f32>) -> Self {
        Self { src: SplitMix64::new(state), spare }
    }

    pub fn uniform(&mut self) -> f32 {
        u32_to_unit_f32((self.src.next_u64() >> 32) as u32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f32::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.src.next_u64() % n as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic_and_nondegenerate() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
        assert!(xs.windows(2).all(|w| w[0] != w[1]));
    }

    #[test]
    fn seed32_never_zero() {
        let mut s = SplitMix64::new(0);
        for _ in 0..1000 {
            assert_ne!(s.next_seed32(), 0);
        }
    }

    #[test]
    fn unit_f32_in_range() {
        for x in [0u32, 1, u32::MAX, 0xDEAD_BEEF] {
            let f = u32_to_unit_f32(x);
            assert!((0.0..1.0).contains(&f), "{f}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut g = GaussianRng::new(7);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_a_bijection() {
        let mut g = GaussianRng::new(3);
        let p = g.permutation(784);
        let mut seen = vec![false; 784];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn uniform_in_bounds() {
        let mut g = GaussianRng::new(11);
        for _ in 0..1000 {
            let v = g.uniform_in(-0.5, 2.0);
            assert!((-0.5..2.0).contains(&v));
        }
    }
}
