//! 32-bit xorshift — the reservoir sampler's random index source (§IV-A1).
//!
//! The paper selects xorshift over an LFSR *specifically* because the
//! sampler's uniformity guarantee (every stream element equally likely to
//! be retained) requires decorrelated, unbiased indices. This is
//! Marsaglia's (13, 17, 5) triple — the exact "32-bit xorshift circuit"
//! of Fig. 1 — with period 2^32 − 1 over non-zero states.

/// Marsaglia xorshift32. `state` must be non-zero (zero is a fixed point).
#[derive(Clone, Debug)]
pub struct Xorshift32 {
    state: u32,
}

impl Xorshift32 {
    /// Create from a non-zero seed. A zero seed is remapped (hardware
    /// reset value): the register is never all-zeros in the circuit.
    pub fn new(seed: u32) -> Self {
        Self { state: if seed == 0 { 0x1u32 } else { seed } }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.state = x;
        x
    }

    /// The modulus unit of Fig. 1: fold the 32-bit word into `1..=i`.
    ///
    /// The hardware computes `(x mod i) + 1`; the tiny modulo bias
    /// (≤ i/2^32) is part of the modeled circuit and is what the
    /// reservoir-uniformity property test bounds.
    #[inline]
    pub fn next_index(&mut self, i: u32) -> u32 {
        debug_assert!(i > 0);
        (self.next_u32() % i) + 1
    }

    pub fn state(&self) -> u32 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Xorshift32::new(0);
        assert_ne!(r.next_u32(), 0);
    }

    #[test]
    fn known_sequence_from_seed_1() {
        // First outputs of Marsaglia (13,17,5) from state 1.
        let mut r = Xorshift32::new(1);
        assert_eq!(r.next_u32(), 270_369);
        assert_eq!(r.next_u32(), 67_634_689);
    }

    #[test]
    fn never_hits_zero() {
        let mut r = Xorshift32::new(0xDEAD_BEEF);
        for _ in 0..100_000 {
            assert_ne!(r.next_u32(), 0);
        }
    }

    #[test]
    fn index_in_range() {
        let mut r = Xorshift32::new(42);
        for i in 1..200u32 {
            for _ in 0..20 {
                let j = r.next_index(i);
                assert!((1..=i).contains(&j), "j={j} i={i}");
            }
        }
    }

    #[test]
    fn indices_are_roughly_uniform() {
        // chi-square-ish sanity over 1..=16 — the property the paper buys
        // by choosing xorshift over an LFSR.
        let mut r = Xorshift32::new(7);
        let mut counts = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            counts[(r.next_index(16) - 1) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        for (k, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bucket {k}: {c} vs {expect}");
        }
    }

    #[test]
    fn long_period_no_short_cycle() {
        let mut r = Xorshift32::new(123);
        let start = r.state();
        for _ in 0..1_000_000 {
            r.next_u32();
            assert_ne!(r.state(), start);
        }
    }
}
