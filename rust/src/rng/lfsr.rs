//! 16-bit LFSR — the stochastic quantizer's uniform source (§IV-A2).
//!
//! A Fibonacci LFSR with the maximal-length polynomial
//! x^16 + x^15 + x^13 + x^4 + 1 (taps 16, 15, 13, 4), period 2^16 − 1.
//! The quantizer compares the register word against the fractional part of
//! the scaled pixel (Eqs. 4–6); an LFSR is fine *here* because each draw
//! only gates one rounding decision — the bias the paper worries about for
//! the reservoir sampler does not apply.

/// Maximal-length 16-bit Fibonacci LFSR.
#[derive(Clone, Debug)]
pub struct Lfsr16 {
    state: u16,
}

impl Lfsr16 {
    pub fn new(seed: u16) -> Self {
        Self { state: if seed == 0 { 0xACE1 } else { seed } }
    }

    /// One shift: feedback = bit16 ^ bit15 ^ bit13 ^ bit4 (1-indexed from
    /// the output end, the classic 0xB400 Fibonacci form).
    #[inline]
    pub fn step(&mut self) -> u16 {
        let lsb = self.state & 1;
        self.state >>= 1;
        if lsb != 0 {
            self.state ^= 0xB400;
        }
        self.state
    }

    /// A fresh 16-bit word (16 shifts in hardware; one step here since the
    /// register is full-width readable).
    #[inline]
    pub fn next_u16(&mut self) -> u16 {
        self.step()
    }

    /// Uniform in [0,1) with 16-bit resolution — the comparator reference.
    #[inline]
    pub fn next_unit(&mut self) -> f32 {
        f32::from(self.next_u16()) * (1.0 / 65536.0)
    }

    pub fn state(&self) -> u16 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_seed_remapped() {
        let mut l = Lfsr16::new(0);
        assert_ne!(l.state(), 0);
        l.step();
        assert_ne!(l.state(), 0);
    }

    #[test]
    fn maximal_period() {
        // 0xB400 is a maximal polynomial: period must be 2^16 - 1.
        let mut l = Lfsr16::new(1);
        let start = l.state();
        let mut n = 0u32;
        loop {
            l.step();
            n += 1;
            if l.state() == start {
                break;
            }
            assert!(n < 70_000, "no cycle found");
        }
        assert_eq!(n, 65_535);
    }

    #[test]
    fn unit_outputs_in_range_and_spread() {
        let mut l = Lfsr16::new(0x1234);
        let xs: Vec<f32> = (0..10_000).map(|_| l.next_unit()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn never_zero_state() {
        let mut l = Lfsr16::new(0xBEEF);
        for _ in 0..65_536 {
            l.step();
            assert_ne!(l.state(), 0);
        }
    }
}
