//! `m2ru` — leader binary of the M2RU reproduction.
//!
//! Subcommands:
//!   info                         runtime + artifact + hw-model summary
//!   train        [flags]         one continual-learning run
//!   serve        [flags]         streaming session server (synthetic open loop, or
//!                                `--listen ADDR` for the TCP frontend with durable sessions)
//!   loadgen      [flags]         closed-loop load generator against the same server
//!   router       [flags]         multi-shard session router front door (in-process
//!                                shard threads, or remote `serve --listen` shards)
//!   connect      [flags]         closed-loop TCP load generator against `serve --listen`
//!   experiment <id> [flags]      regenerate a paper figure/table
//!   help
//!
//! Run `m2ru help` for flags. Only `experiment` (and `--backend artifact`)
//! needs artifacts (`make artifacts`); everything else runs offline.

use anyhow::{bail, Context, Result};

use m2ru::backend::{BackendCtx, BackendRegistry};
use m2ru::cli::Args;
use m2ru::config::{Manifest, NetConfig, RunConfig};
use m2ru::coordinator::{
    ContinualTrainer, Engine, HardwareEngine, ParallelEngine, RustAdamEngine, RustDfaEngine,
    XlaAdamEngine, XlaDfaEngine,
};
use m2ru::device::DeviceParams;
use m2ru::experiments::{
    run_ablation_replay, run_ablation_sampler, run_ablation_zeta, run_fault, run_fig4, run_fig5a,
    run_fig5b, run_fig5c, run_fig5d, run_headline, run_table1, Fig4Options, Fig5bOptions,
};
use m2ru::net::{
    run_connect, ConnectOptions, NetClient, NetServeOptions, NetServer, RouterServeOptions,
    RouterServer,
};
use m2ru::runtime::{ModelBundle, Runtime};
use m2ru::serve::{run_serve, ServeOptions};

const HELP: &str = "\
m2ru — Memristive Minion Recurrent Unit (full-system reproduction)

USAGE: m2ru [--artifacts DIR] [--results DIR] <subcommand> [flags]

SUBCOMMANDS
  info                      platform, manifest and hw-model summary
  backends                  list the registered compute backends
  train                     one continual-learning run
      --net NAME            network config (small|pmnist100|pmnist256|cifar100|cifar256)
      --backend NAME        dense|crossbar|artifact (BackendRegistry)  [dense]
      --workers N           worker threads for the serving engine      [1]
      --engine NAME         legacy engine path: adam|dfa|hw|rust-dfa|rust-adam
                            (overrides --backend; dfa/adam/hw need artifacts)
      --dataset NAME        pmnist|cifarfeat (must match --net geometry)
      --config FILE         TOML run configuration
      --tasks N --train-per-task N --test-per-task N --epochs N
      --replay BOOL --replay-per-task N --seed N --lr F --lam F --beta F
  serve                     streaming session server on synthetic traffic (open loop)
      --net NAME            network config                               [pmnist100]
      --backend NAME        dense|crossbar (artifact graphs are lowered
                            whole-sequence and cannot serve streams)     [dense]
      --workers N           worker threads for batched step dispatch     [1]
      --requests N          requests to complete                         [2000]
      --sessions K          simulated users                              [128]
      --arrivals N          requests admitted per tick                   [max-batch]
      --max-batch N --max-wait T   batcher policy (T in ticks)           [32 / 4]
      --capacity N --ttl T  session slots / idle-tick expiry (0=never)   [1024 / 0]
      --update-every N      labeled steps per online DFA commit (0=off)  [64]
      --replay-cap N --replay-mix F   online replay reservoir / mix      [256 / 0.5]
      --wear-ratio F        ration commit writes to columns above F x
                            mean device wear (0=off; crossbar only)      [4.0]
      --commit-queue-depth N  bounded serve->committer job queue (async
                            weight commits + snapshot writes)            [4]
      --kernel NAME         compute kernel: auto|scalar|simd (bitwise-
                            identical; overrides M2RU_KERNEL env)       [auto]
      --precision NAME      serving precision: f32|int8 (int8 serves from
                            pre-quantized i8 weight planes; overrides
                            M2RU_PRECISION env)                          [f32]
      --listen ADDR         serve real clients over TCP instead of the
                            synthetic driver (host:port; port 0 = auto).
                            Prints `listening on ADDR`, runs until a
                            client sends Shutdown (see `connect`)
      --checkpoint-dir DIR  durable sessions: restore snapshot chain on
                            boot, write on shutdown (and every
                            --checkpoint-every T ticks); kill/restart
                            resumes every session
      --snapshot-full-every N  every Nth snapshot is a full rewrite, the
                            rest are incremental deltas (1 = always full) [8]
      --fsync-policy P      always|full|never — which snapshot files are
                            fsynced before they count as durable        [always]
      --queue-depth N       bounded reader->serve queue (back-pressure)   [256]
      --outbox-depth N      per-connection response outbox; a slow client
                            fills its own and is dropped                  [64]
      --obs MODE            observability: on|off|sampled — timing plane
                            only, the deterministic serve signature is
                            bitwise-identical in every mode               [on]
      --obs-sample N        with --obs sampled, time 1-in-N batches       [16]
      --obs-flight-cap N    flight-recorder ring capacity (events)        [256]
      --obs-snapshot PATH   periodically write the Prometheus exposition
                            to PATH (and flight events to PATH.jsonl)
      --obs-snapshot-every T  snapshot period in ticks (0 = never)        [0]
      scenario simulation (traffic storms + domain shifts; DESIGN.md 16):
      --scenario-phases L   arrival-curve phases cycled per wave, e.g.
                            steady:20,flash:5,lull:10,churn:5 (one wave =
                            one logical tick)
      --scenario-flash-mult N / --scenario-lull-div N   flash multiplies
                            the base arrivals, lull divides them     [4 / 4]
      --scenario-shifts L   domain-shift schedule wave:task, e.g.
                            40:1,80:0 (task 0 = identity; reusing a task
                            id revisits that exact permuted domain)
      --scenario-slow-frac F / --scenario-reconnect-frac F /
      --scenario-abandon-frac F   client-behavior mix (fractions of the
                            user population; the rest behave normally) [0]
      --scenario-tenant-classes N  eviction-fairness classes (uid % N;
                            0 disables the evictions_by_class report)  [0]
      --scenario-recovery-threshold F / --scenario-recovery-window W
                            a shift counts recovered when windowed
                            accuracy over the last W labeled steps
                            re-crosses F x pre-shift accuracy    [0.9 / 32]
      --config FILE --seed N --lr F --lam F --beta F
  loadgen                   closed-loop load generator (same flags as serve)
      --concurrency C       outstanding-request target                   [4*max-batch]
  router                    multi-shard session router: one TCP front door
                            partitioning sessions (session_id % N) across N
                            independent serve shards (DESIGN.md 11)
      --shards N            in-process shard threads, each a full serve
                            stack (engine, learner, commit pipeline)      [1]
      --shard-addrs LIST    comma-separated host:port of running
                            `m2ru serve --listen` shard processes
                            (overrides --shards; the router speaks the
                            wire protocol to them)
      --checkpoint-root DIR durable in-process shards: shard k restores
                            from and snapshots into DIR/shard-k/
      --listen ADDR         front-door address (port 0 = auto)  [127.0.0.1:0]
      plus the serve policy/transport flags above (--max-batch,
      --update-every, --checkpoint-every, --queue-depth, ...)
      admin plane (acts on a RUNNING router and exits; DESIGN.md 14):
      --addr HOST:PORT      front door of the running router (required)
      --drain K             quiesce shard K, migrate its sessions to the
                            surviving shards, checkpoint and retire it
      --rebalance M         recut the session space across shards 0..M
                            (bumps the routing epoch, migrates the moved
                            sessions live; clients never see an error)
                            with neither flag, prints the current epoch
  connect                   closed-loop TCP load generator against `serve --listen`
      --addr HOST:PORT      server address (required)
      --net NAME            network shapes (must match the server)       [pmnist100]
      --requests N --sessions K --arrivals N --seed N   workload (same
                            schedule as the in-process driver: identical
                            seed/policy => bit-identical logits)
      --skip N              fast-forward the workload N requests (resume
                            against a server restored from a checkpoint)
      --scenario-* ...      drive the scenario workload over the wire
                            (same flags as serve; launch the server with
                            the same schedule so its shift report and the
                            client's traffic shaping line up)
      --keep-alive          do not send Shutdown when done
      --metrics             fetch and print the server's MetricsDump
                            (Prometheus text; a router answers with
                            per-shard sections plus a fleet rollup)
  experiment ID             fig4|fig5a|fig5b|fig5c|fig5d|table1|headline|all
                            |ablation-replay|ablation-zeta|ablation-sampler|fault
      fig4:  --dataset pmnist|cifarfeat  --nh 100|256  --engines adam,dfa,hw
      plus the train flags above for workload scaling
  help
";

fn apply_run_flags(args: &mut Args, run: &mut RunConfig) -> Result<()> {
    if let Some(path) = args.get_opt("config") {
        *run = RunConfig::load(&path)?;
    }
    run.num_tasks = args.get_parse("tasks", run.num_tasks)?;
    run.train_per_task = args.get_parse("train-per-task", run.train_per_task)?;
    run.test_per_task = args.get_parse("test-per-task", run.test_per_task)?;
    run.epochs = args.get_parse("epochs", run.epochs)?;
    run.replay_per_task = args.get_parse("replay-per-task", run.replay_per_task)?;
    run.seed = args.get_parse("seed", run.seed)?;
    run.lr = args.get_parse("lr", run.lr)?;
    run.lam = args.get_parse("lam", run.lam)?;
    run.beta = args.get_parse("beta", run.beta)?;
    if let Some(r) = args.get_opt("replay") {
        run.replay = r.parse().context("--replay expects true/false")?;
    }
    run.validate()
}

fn cmd_info(rt: &Runtime, manifest: Option<&Manifest>) -> Result<()> {
    println!("platform: {}", rt.platform());
    println!("kernel: {}", m2ru::linalg::kernels::active_name());
    println!("precision: {}", m2ru::linalg::kernels::precision_name());
    println!("cpu features: {}", m2ru::linalg::kernels::cpu_features());
    match manifest {
        Some(manifest) => {
            println!("artifacts: {} ({} configs, {} executables)", manifest.dir.display(),
                     manifest.configs.len(), manifest.artifacts.len());
            for (name, _) in &manifest.configs {
                let arts = manifest.artifacts_for(name);
                println!("  {name}: {} artifacts", arts.len());
            }
        }
        // fresh clone: no artifacts is a normal state, not a failure —
        // everything except `--backend artifact` and the XLA experiment
        // paths works without them
        None => println!(
            "artifacts: none (run `make artifacts` to enable the artifact backend \
             and XLA experiments)"
        ),
    }
    let report = run_headline()?;
    drop(report);

    // serve-path observability probe: a tiny crossbar serve run whose
    // wear/lifespan/commit-pipeline lines come from the metrics
    // registry — the same series a live server exposes via MetricsDump
    let net = NetConfig::by_name("small").context("built-in net `small` missing")?;
    let mut run = RunConfig::default();
    run.backend = "crossbar".to_string();
    run.serve.update_every = 16;
    let mut opts = ServeOptions::new(net, run);
    opts.requests = 256;
    opts.sessions = 16;
    let rep = run_serve(&opts)?;
    println!("serve observability probe (crossbar, {} requests):", opts.requests);
    for line in &rep.obs_lines {
        println!("  {line}");
    }
    for line in rep.lines() {
        if line.contains("lifespan") {
            println!("  {line}");
        }
    }
    Ok(())
}

fn cmd_train(artifacts: &str, args: &mut Args) -> Result<()> {
    let net = args.get("net", "pmnist100");
    let engine_flag = args.get_opt("engine");
    let cfg = NetConfig::by_name(&net).with_context(|| format!("unknown net `{net}`"))?;
    let default_ds = if net.starts_with("cifar") { "cifarfeat" } else { "pmnist" };
    let dataset = args.get("dataset", default_ds);
    let levels_flag = args.get_parse("levels", DeviceParams::default().levels)?;
    let mut run = RunConfig::default();
    apply_run_flags(args, &mut run)?;
    if let Some(b) = args.get_opt("backend") {
        run.backend = b;
    }
    run.workers = args.get_parse("workers", run.workers)?;
    run.validate()?;
    args.finish()?;
    if engine_flag.is_some() && run.workers > 1 {
        eprintln!(
            "note: --workers applies to the backend serving path; \
             legacy --engine runs single-threaded"
        );
    }

    let stream = match dataset.as_str() {
        "pmnist" => {
            anyhow::ensure!(cfg.nx == 28, "net `{net}` does not match pmnist geometry");
            m2ru::data::permuted_task_stream(run.num_tasks, run.train_per_task, run.test_per_task, run.seed)
        }
        "cifarfeat" => {
            anyhow::ensure!(cfg.nx == 32, "net `{net}` does not match cifarfeat geometry");
            m2ru::data::feature_task_stream(run.num_tasks, run.train_per_task, run.test_per_task, 0.8, run.seed)
        }
        other => bail!("unknown dataset `{other}`"),
    };

    let mut trainer = ContinualTrainer::new(&stream, run.clone(), cfg.b_train, cfg.b_eval);

    let run_engine = |trainer: &mut ContinualTrainer, eng: &mut dyn Engine| -> Result<()> {
        for t in 0..run.num_tasks.min(stream.num_tasks()) {
            let res = trainer.run_task(eng, t)?;
            println!(
                "task {}: loss={:.4} acc/task={:?} MA={:.3}",
                t + 1,
                res.mean_loss,
                res.acc_per_task.iter().map(|a| (a * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
                res.mean_acc
            );
        }
        Ok(())
    };

    match engine_flag.as_deref() {
        // The serving path: backend selected through the registry, batches
        // sharded across workers by the parallel engine. Needs no XLA or
        // artifacts unless `--backend artifact` is chosen.
        None => {
            println!(
                "training backend `{}` ({} worker{}) on {dataset} with net {net} ({} tasks)",
                run.backend,
                run.workers,
                if run.workers == 1 { "" } else { "s" },
                run.num_tasks
            );
            let mut ctx = BackendCtx::from_run(cfg, &run);
            ctx.device = DeviceParams { levels: levels_flag, ..DeviceParams::default() };
            ctx.artifacts_dir = artifacts.to_string();
            let backend = BackendRegistry::with_defaults().create(&run.backend, &ctx)?;
            let mut e = ParallelEngine::new(backend, run.workers);
            run_engine(&mut trainer, &mut e)?;
            for line in e.stats() {
                println!("{line}");
            }
        }
        Some("rust-dfa") => {
            let mut e = RustDfaEngine::new(
                cfg.nx, cfg.nh, cfg.ny, run.lam, run.beta, run.lr, Some(cfg.keep_frac), run.seed,
            );
            println!("training `rust-dfa` on {dataset} with net {net} ({} tasks)", run.num_tasks);
            run_engine(&mut trainer, &mut e)?;
        }
        Some("rust-adam") => {
            let mut e =
                RustAdamEngine::new(cfg.nx, cfg.nh, cfg.ny, run.lam, run.beta, run.lr * 0.05, run.seed);
            println!("training `rust-adam` on {dataset} with net {net} ({} tasks)", run.num_tasks);
            run_engine(&mut trainer, &mut e)?;
        }
        Some("dfa") => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(artifacts)?;
            let bundle = ModelBundle::load(&rt, &manifest, cfg)?;
            let mut e = XlaDfaEngine::new(&bundle, run.lam, run.beta, run.lr, run.seed);
            println!("training `dfa` on {dataset} with net {net} ({} tasks)", run.num_tasks);
            run_engine(&mut trainer, &mut e)?;
        }
        Some("adam") => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(artifacts)?;
            let bundle = ModelBundle::load(&rt, &manifest, cfg)?;
            let mut e = XlaAdamEngine::new(&bundle, run.lam, run.beta, run.lr * 0.05, run.seed);
            println!("training `adam` on {dataset} with net {net} ({} tasks)", run.num_tasks);
            run_engine(&mut trainer, &mut e)?;
        }
        Some("hw") => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(artifacts)?;
            let bundle = ModelBundle::load(&rt, &manifest, cfg)?;
            let device = DeviceParams { levels: levels_flag, ..DeviceParams::default() };
            let mut e = HardwareEngine::new(&bundle, run.lam, run.beta, run.lr, device, run.seed);
            println!("training `hw` on {dataset} with net {net} ({} tasks)", run.num_tasks);
            run_engine(&mut trainer, &mut e)?;
            println!(
                "device writes: total={} mean/step={:.1}",
                e.programmer.total.writes,
                e.programmer.writes_per_step()
            );
        }
        Some(other) => bail!("unknown engine `{other}`"),
    }
    println!("final MA={:.3} forgetting={:.3}", trainer.matrix.mean_final(), trainer.matrix.forgetting());
    Ok(())
}

/// The `[scenario]` flag surface, shared by `serve`, `loadgen`, `router`
/// (via the run config) and `connect` (via its own workload config).
fn apply_scenario_flags(args: &mut Args, sc: &mut m2ru::config::ScenarioConfig) -> Result<()> {
    if let Some(p) = args.get_opt("scenario-phases") {
        sc.phases = p;
    }
    sc.flash_mult = args.get_parse("scenario-flash-mult", sc.flash_mult)?;
    sc.lull_div = args.get_parse("scenario-lull-div", sc.lull_div)?;
    if let Some(s) = args.get_opt("scenario-shifts") {
        sc.shifts = s;
    }
    sc.slow_frac = args.get_parse("scenario-slow-frac", sc.slow_frac)?;
    sc.reconnect_frac = args.get_parse("scenario-reconnect-frac", sc.reconnect_frac)?;
    sc.abandon_frac = args.get_parse("scenario-abandon-frac", sc.abandon_frac)?;
    sc.tenant_classes = args.get_parse("scenario-tenant-classes", sc.tenant_classes)?;
    sc.recovery_threshold = args.get_parse("scenario-recovery-threshold", sc.recovery_threshold)?;
    sc.recovery_window = args.get_parse("scenario-recovery-window", sc.recovery_window)?;
    Ok(())
}

/// The `[serve]` policy + `[net]` transport flag surface shared by
/// `serve`, `loadgen` and `router`.
fn apply_serve_net_flags(args: &mut Args, run: &mut RunConfig) -> Result<()> {
    if let Some(b) = args.get_opt("backend") {
        run.backend = b;
    }
    run.workers = args.get_parse("workers", run.workers)?;
    run.serve.max_batch = args.get_parse("max-batch", run.serve.max_batch)?;
    run.serve.max_wait = args.get_parse("max-wait", run.serve.max_wait)?;
    run.serve.capacity = args.get_parse("capacity", run.serve.capacity)?;
    run.serve.ttl = args.get_parse("ttl", run.serve.ttl)?;
    run.serve.update_every = args.get_parse("update-every", run.serve.update_every)?;
    run.serve.replay_cap = args.get_parse("replay-cap", run.serve.replay_cap)?;
    run.serve.replay_mix = args.get_parse("replay-mix", run.serve.replay_mix)?;
    run.serve.wear_ratio = args.get_parse("wear-ratio", run.serve.wear_ratio)?;
    run.serve.commit_queue_depth =
        args.get_parse("commit-queue-depth", run.serve.commit_queue_depth)?;
    if let Some(kernel) = args.get_opt("kernel") {
        run.serve.kernel = kernel;
    }
    if let Some(precision) = args.get_opt("precision") {
        run.serve.precision = precision;
    }
    if let Some(listen) = args.get_opt("listen") {
        run.net.listen = listen;
    }
    run.net.checkpoint_every = args.get_parse("checkpoint-every", run.net.checkpoint_every)?;
    run.net.snapshot_full_every =
        args.get_parse("snapshot-full-every", run.net.snapshot_full_every)?;
    if let Some(policy) = args.get_opt("fsync-policy") {
        run.net.fsync_policy = policy;
    }
    run.net.queue_depth = args.get_parse("queue-depth", run.net.queue_depth)?;
    run.net.outbox_depth = args.get_parse("outbox-depth", run.net.outbox_depth)?;
    if let Some(mode) = args.get_opt("obs") {
        run.obs.mode = mode;
    }
    run.obs.sample_every = args.get_parse("obs-sample", run.obs.sample_every)?;
    run.obs.flight_capacity = args.get_parse("obs-flight-cap", run.obs.flight_capacity)?;
    if let Some(path) = args.get_opt("obs-snapshot") {
        run.obs.snapshot_path = path;
    }
    run.obs.snapshot_every = args.get_parse("obs-snapshot-every", run.obs.snapshot_every)?;
    apply_scenario_flags(args, &mut run.scenario)?;
    Ok(())
}

/// `m2ru serve` (open loop), `m2ru serve --listen` (TCP frontend) and
/// `m2ru loadgen` (closed loop): drive the streaming session server and
/// print the throughput/latency/batching/eviction report.
fn cmd_serve(args: &mut Args, closed_loop: bool) -> Result<()> {
    let net_name = args.get("net", "pmnist100");
    let net = NetConfig::by_name(&net_name).with_context(|| format!("unknown net `{net_name}`"))?;
    let mut run = RunConfig::default();
    apply_run_flags(args, &mut run)?;
    apply_serve_net_flags(args, &mut run)?;
    if let Some(dir) = args.get_opt("checkpoint-dir") {
        run.net.checkpoint_dir = dir;
    }
    run.validate()?;
    if !run.serve.kernel.is_empty() {
        m2ru::linalg::kernels::force(&run.serve.kernel)?;
    }
    if !run.serve.precision.is_empty() {
        m2ru::linalg::kernels::force_precision(&run.serve.precision)?;
    }
    println!("kernel: {}", m2ru::linalg::kernels::active_name());
    println!("precision: {}", m2ru::linalg::kernels::precision_name());

    // transport-backed event loop: serve real clients over TCP
    if !closed_loop && !run.net.listen.is_empty() {
        // accepted for flag-compatibility with the synthetic driver, but
        // real clients decide the workload over TCP
        let _ = args.get_parse("requests", 0u64)?;
        let _ = args.get_parse("sessions", 0usize)?;
        let _ = args.get_parse("arrivals", 0usize)?;
        args.finish()?;
        let server = NetServer::bind(NetServeOptions::new(net, run.clone(), run.net.listen.clone()))?;
        println!("listening on {}", server.local_addr()?);
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        let rep = server.run()?;
        println!("connections: {}", rep.connections);
        if rep.restored_sessions > 0 {
            println!("restored sessions: {}", rep.restored_sessions);
        }
        for line in rep.report.lines() {
            println!("{line}");
        }
        if let Some(path) = rep.checkpoint_path {
            println!("checkpoint: {}", path.display());
        }
        return Ok(());
    }

    let mut opts = ServeOptions::new(net, run);
    opts.requests = args.get_parse("requests", opts.requests)?;
    opts.sessions = args.get_parse("sessions", opts.sessions)?;
    opts.arrivals = args.get_parse("arrivals", opts.arrivals)?;
    if closed_loop {
        opts.concurrency = args.get_parse("concurrency", 4 * opts.run.serve.max_batch)?;
        // 0 is the driver's open-loop sentinel — an explicit 0 here would
        // silently measure the wrong thing
        anyhow::ensure!(opts.concurrency >= 1, "--concurrency must be >= 1 for loadgen");
    }
    args.finish()?;
    println!(
        "{}: backend `{}` ({} worker{}), {} requests over {} sessions",
        if closed_loop { "loadgen" } else { "serve" },
        opts.run.backend,
        opts.run.workers,
        if opts.run.workers == 1 { "" } else { "s" },
        opts.requests,
        opts.sessions
    );
    let report = run_serve(&opts)?;
    for line in report.lines() {
        println!("{line}");
    }
    Ok(())
}

/// `m2ru router`: the multi-shard session router front door
/// (DESIGN.md §11) — in-process shard threads by default, remote
/// `m2ru serve --listen` shards with `--shard-addrs`.
fn cmd_router(args: &mut Args) -> Result<()> {
    // admin plane: `--addr` points at a *running* router's front door;
    // `--drain K` / `--rebalance M` reshard it live and exit, neither
    // flag queries the current routing epoch (DESIGN.md §14)
    if let Some(addr) = args.get_opt("addr") {
        let drain = args.get_opt("drain");
        let rebalance = args.get_opt("rebalance");
        args.finish()?;
        let mut client = NetClient::connect(&addr)?;
        match (drain, rebalance) {
            (Some(_), Some(_)) => bail!("--drain and --rebalance are mutually exclusive"),
            (Some(k), None) => {
                let k: u32 = k.parse().context("--drain expects a shard index")?;
                let (epoch, shards) = client.drain(k)?;
                println!("drained shard {k}: epoch={epoch} shards={shards}");
            }
            (None, Some(m)) => {
                let m: u32 = m.parse().context("--rebalance expects a shard count")?;
                let (epoch, shards) = client.rebalance(m)?;
                println!("rebalanced to {shards} shard(s): epoch={epoch}");
            }
            (None, None) => {
                let (epoch, shards) = client.epoch()?;
                println!("epoch={epoch} shards={shards}");
            }
        }
        return Ok(());
    }

    let net_name = args.get("net", "pmnist100");
    let net = NetConfig::by_name(&net_name).with_context(|| format!("unknown net `{net_name}`"))?;
    let mut run = RunConfig::default();
    apply_run_flags(args, &mut run)?;
    apply_serve_net_flags(args, &mut run)?;
    run.router.shards = args.get_parse("shards", run.router.shards)?;
    if let Some(addrs) = args.get_opt("shard-addrs") {
        run.router.shard_addrs =
            addrs.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    }
    if let Some(root) = args.get_opt("checkpoint-root") {
        run.router.checkpoint_root = root;
    }
    if run.net.listen.is_empty() {
        run.net.listen = "127.0.0.1:0".to_string();
    }
    run.validate()?;
    args.finish()?;
    if !run.serve.kernel.is_empty() {
        m2ru::linalg::kernels::force(&run.serve.kernel)?;
    }
    if !run.serve.precision.is_empty() {
        m2ru::linalg::kernels::force_precision(&run.serve.precision)?;
    }
    println!("kernel: {}", m2ru::linalg::kernels::active_name());
    println!("precision: {}", m2ru::linalg::kernels::precision_name());

    let remote = !run.router.shard_addrs.is_empty();
    let server = RouterServer::bind(RouterServeOptions { net, run: run.clone() })?;
    println!("listening on {}", server.local_addr()?);
    println!(
        "routing across {} {} shard(s)",
        run.router.fleet_size(),
        if remote { "remote" } else { "in-process" }
    );
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    let rep = server.run()?;
    println!("connections: {}", rep.connections);
    if rep.restored_sessions > 0 {
        println!("restored sessions: {}", rep.restored_sessions);
    }
    println!("routed: {} request(s) across {} shard(s)", rep.routed, rep.shards);
    println!("routing epoch: {} (sessions migrated: {})", rep.epoch, rep.migrated);
    println!(
        "outbox: drops_full={} drops_timeout={} drops_writer_failed={}",
        rep.outbox_drops.full, rep.outbox_drops.timeout, rep.outbox_drops.writer_failed
    );
    for (k, routed) in rep.shard_routed.iter().enumerate() {
        if rep.remote {
            println!("shard {k}: routed={routed} served_total={}", rep.shard_totals[k]);
        } else {
            println!("shard {k}: routed={routed}");
        }
    }
    for (k, report) in &rep.shard_reports {
        for line in report.lines() {
            println!("shard {k}: {line}");
        }
    }
    Ok(())
}

/// `m2ru connect`: closed-loop TCP load generator against a
/// `m2ru serve --listen` server.
fn cmd_connect(args: &mut Args) -> Result<()> {
    let addr = args.get_opt("addr").context("--addr HOST:PORT is required")?;
    let net_name = args.get("net", "pmnist100");
    let net = NetConfig::by_name(&net_name).with_context(|| format!("unknown net `{net_name}`"))?;
    let mut opts = ConnectOptions::new(addr, net);
    opts.requests = args.get_parse("requests", opts.requests)?;
    opts.sessions = args.get_parse("sessions", opts.sessions)?;
    opts.arrivals = args.get_parse("arrivals", opts.arrivals)?;
    opts.seed = args.get_parse("seed", opts.seed)?;
    opts.skip = args.get_parse("skip", opts.skip)?;
    opts.shutdown = !args.get_bool("keep-alive")?;
    opts.metrics = args.get_bool("metrics")?;
    // the client-side half of a scenario run: the server gets the same
    // schedule via the serve-side flags, the client shapes the traffic
    apply_scenario_flags(args, &mut opts.scenario)?;
    opts.scenario.validate()?;
    args.finish()?;
    println!(
        "connect: {} requests over {} sessions to {} (arrivals {}, seed {})",
        opts.requests, opts.sessions, opts.addr, opts.arrivals, opts.seed
    );
    let rep = run_connect(&opts)?;
    println!(
        "connect: completed {} requests in {:.3} s ({:.0} req/s), {} labeled",
        rep.completed.len(),
        rep.wall.as_secs_f64(),
        rep.throughput(),
        rep.labeled
    );
    println!("per-session signature: {:016x}", rep.session_signature());
    println!("server stats:");
    for line in rep.stats_text.lines() {
        println!("  {line}");
    }
    if let Some(text) = &rep.metrics_text {
        println!("server metrics:");
        for line in text.lines() {
            println!("  {line}");
        }
    }
    if let Some(text) = &rep.events_text {
        println!("server flight events:");
        for line in text.lines() {
            println!("  {line}");
        }
    }
    if let Some(total) = rep.server_total {
        println!("shutdown: server acknowledged {total} total requests");
    }
    Ok(())
}

fn cmd_experiment(rt: &Runtime, manifest: &Manifest, args: &mut Args, results: &str) -> Result<()> {
    let id = args.positional(0).context("experiment id required (fig4|fig5a|fig5b|fig5c|fig5d|table1|headline|all)")?.to_string();
    let mut reports = Vec::new();
    let quick = args.get_bool("quick")?;

    let fig4_opts = |args: &mut Args, dataset: String, nh: usize| -> Result<Fig4Options> {
        let mut o = Fig4Options { dataset, nh, ..Fig4Options::default() };
        if quick {
            o.run.num_tasks = 2;
            o.run.train_per_task = 200;
            o.run.test_per_task = 100;
            o.run.epochs = 1;
            o.run.replay_per_task = 100;
        }
        apply_run_flags(args, &mut o.run)?;
        let engines = args.get("engines", "adam,dfa,hw");
        o.engines = engines.split(',').map(str::to_string).collect();
        Ok(o)
    };

    match id.as_str() {
        "fig4" => {
            let dataset = args.get("dataset", "pmnist");
            let nh = args.get_parse("nh", 100usize)?;
            let opts = fig4_opts(args, dataset, nh)?;
            args.finish()?;
            let (rep, _) = run_fig4(rt, manifest, &opts)?;
            reports.push(rep);
        }
        "fig5a" => {
            let n = args.get_parse("samples", 40usize)?;
            let seed = args.get_parse("seed", 0u64)?;
            args.finish()?;
            reports.push(run_fig5a(n, seed)?);
        }
        "fig5b" => {
            let mut opts = Fig5bOptions::default();
            if quick {
                opts.run.train_per_task = 160;
                opts.run.test_per_task = 60;
            }
            apply_run_flags(args, &mut opts.run)?;
            args.finish()?;
            reports.push(run_fig5b(rt, manifest, &opts)?);
        }
        "fig5c" => {
            args.finish()?;
            reports.push(run_fig5c()?);
        }
        "fig5d" => {
            args.finish()?;
            reports.push(run_fig5d()?);
        }
        "table1" => {
            args.finish()?;
            reports.push(run_table1()?);
        }
        "headline" => {
            args.finish()?;
            reports.push(run_headline()?);
        }
        "ablation-replay" | "ablation-zeta" => {
            let mut run = RunConfig::default();
            if quick {
                run.num_tasks = 2;
                run.train_per_task = 300;
                run.test_per_task = 100;
                run.epochs = 3;
                run.replay_per_task = 150;
            }
            apply_run_flags(args, &mut run)?;
            args.finish()?;
            reports.push(if id == "ablation-replay" {
                run_ablation_replay(rt, manifest, &run)?
            } else {
                run_ablation_zeta(rt, manifest, &run)?
            });
        }
        "ablation-sampler" => {
            args.finish()?;
            reports.push(run_ablation_sampler()?);
        }
        "fault" => {
            let mut run = RunConfig {
                num_tasks: 1,
                train_per_task: 600,
                test_per_task: 150,
                epochs: 5,
                ..RunConfig::default()
            };
            apply_run_flags(args, &mut run)?;
            args.finish()?;
            reports.push(run_fault(rt, manifest, &run)?);
        }
        "all" => {
            // analytical ones always; workload ones in quick mode
            reports.push(run_fig5c()?);
            reports.push(run_fig5d()?);
            reports.push(run_table1()?);
            reports.push(run_headline()?);
            reports.push(run_fig5a(30, 0)?);
            let mut o5b = Fig5bOptions::default();
            o5b.run.train_per_task = 160;
            o5b.run.test_per_task = 60;
            reports.push(run_fig5b(rt, manifest, &o5b)?);
            for (ds, nh) in [("pmnist", 100), ("pmnist", 256), ("cifarfeat", 100), ("cifarfeat", 256)]
            {
                let opts = fig4_opts(args, ds.to_string(), nh)?;
                let (rep, _) = run_fig4(rt, manifest, &opts)?;
                reports.push(rep);
            }
            args.finish()?;
        }
        other => bail!("unknown experiment `{other}`"),
    }
    // quick (scaled-down) runs must never clobber archived full results
    let dir = if quick { format!("{results}/quick") } else { results.to_string() };
    for rep in &reports {
        let path = rep.save(&dir)?;
        eprintln!("[saved {}]", path.display());
    }
    Ok(())
}

fn main() -> Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let artifacts = args.get("artifacts", "artifacts");
    let results = args.get("results", "results");

    match args.subcommand()? {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "info" => {
            args.finish()?;
            let rt = Runtime::cpu()?;
            // a missing artifacts directory must not make `info` unusable
            // on a fresh clone — degrade to a "no artifacts" summary. A
            // *present but broken* manifest still surfaces its error.
            if std::path::Path::new(&artifacts).join("manifest.txt").exists() {
                let manifest = Manifest::load(&artifacts)?;
                cmd_info(&rt, Some(&manifest))
            } else {
                cmd_info(&rt, None)
            }
        }
        "backends" => {
            args.finish()?;
            for name in BackendRegistry::with_defaults().names() {
                println!("{name}");
            }
            Ok(())
        }
        "train" => cmd_train(&artifacts, &mut args),
        "serve" => cmd_serve(&mut args, false),
        "loadgen" => cmd_serve(&mut args, true),
        "router" => cmd_router(&mut args),
        "connect" => cmd_connect(&mut args),
        "experiment" => {
            let rt = Runtime::cpu()?;
            let manifest = Manifest::load(&artifacts)?;
            cmd_experiment(&rt, &manifest, &mut args, &results)
        }
        other => bail!("unknown subcommand `{other}` (try `m2ru help`)"),
    }
}
