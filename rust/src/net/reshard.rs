//! Epoch-versioned routing for the elastic shard fleet (DESIGN.md §14).
//!
//! PR 5's router froze the partition at boot: `shard(session) =
//! session_id % N`, with N fixed for the life of the process. This
//! module makes the partition a *versioned value* instead of a constant:
//! a [`RoutingEpoch`] pairs a monotonically increasing epoch number with
//! an explicit logical-slot → physical-shard map, so the fleet can grow,
//! shrink and drain shards while every participant agrees — per epoch —
//! on exactly one deterministic routing function.
//!
//! ## The routing function, per epoch
//!
//! ```text
//! slot(session)  = session_id % slots        (slots = logical width)
//! shard(session) = map[slot(session)]        (map: slot → physical id)
//! ```
//!
//! At boot the map is the identity over N slots, which reproduces PR 5's
//! `session_id % N` bit-for-bit — epoch 0 *is* the old router. A
//! rebalance to M slots bumps the epoch and swaps the map; the moved
//! set between two epochs is pure arithmetic over the session ids
//! (computable by any participant, no routing table exchange), and for
//! identity maps it collapses to the classic `sid % N != sid % M`.
//!
//! ## Why sessions between *surviving* shards move too
//!
//! Draining shard k of N is a rebalance onto the N−1 surviving
//! physicals: the modulus shrinks, so some sessions hosted on shards
//! that are not being drained also change route. That is inherent to
//! modular rehashing and deliberate — the moved set stays a pure
//! function of (old epoch, new epoch, session id), which is what keeps
//! the cutover deterministic and testable. [`RoutingEpoch::moved`]
//! computes exactly that set.
//!
//! ## Parked steps
//!
//! While a session's state is in flight between shards, its steps must
//! be neither dropped (zero client-visible errors) nor reordered (the
//! per-session stream is the determinism unit). [`StepPark`] is the
//! router-side holding pen: strict FIFO per session, bounded in total,
//! drained in arrival order at cutover commit.

use std::collections::{HashMap, VecDeque};

use anyhow::{ensure, Result};

/// The routing function of PR 5 and of every identity-mapped epoch:
/// pure modular arithmetic over the keyed session id (uniform by
/// construction, so shards stay balanced).
pub fn shard_of(session: u64, shards: usize) -> usize {
    (session % shards.max(1) as u64) as usize
}

/// Does `session` change shard under a pure N→M resize (identity maps
/// on both sides)? The exhaustive small-domain law in the tests below
/// pins this to "moves exactly the intended set" for every N,M ≤ 6.
pub fn moves(session: u64, n: usize, m: usize) -> bool {
    shard_of(session, n) != shard_of(session, m)
}

/// One epoch of the fleet's routing table: a version number plus the
/// logical-slot → physical-shard map in force for that version.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoutingEpoch {
    epoch: u64,
    /// `map[slot]` = physical shard id serving that logical slot.
    /// Never empty.
    map: Vec<u32>,
}

impl RoutingEpoch {
    /// Epoch 0: the identity map over `shards` physicals — bitwise the
    /// PR 5 router (`session_id % N`).
    pub fn identity(shards: usize) -> RoutingEpoch {
        let n = shards.max(1);
        RoutingEpoch { epoch: 0, map: (0..n as u32).collect() }
    }

    /// The epoch number (bumped by every rebalance/drain).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Logical width: the modulus of the routing function.
    pub fn slots(&self) -> usize {
        self.map.len()
    }

    /// The slot → physical map.
    pub fn map(&self) -> &[u32] {
        &self.map
    }

    /// The physical shard serving `session` under this epoch.
    pub fn route(&self, session: u64) -> usize {
        self.map[shard_of(session, self.map.len())] as usize
    }

    /// The successor epoch routing over `map` (slot j → physical
    /// `map[j]`). Rejects an empty map and duplicate physicals (two
    /// slots may not share a shard — the moved-set math assumes the
    /// map is injective, and nothing in the fleet wants oversubscribed
    /// physicals).
    pub fn rebalanced(&self, map: Vec<u32>) -> Result<RoutingEpoch> {
        ensure!(!map.is_empty(), "a routing epoch needs at least one shard");
        let mut seen = map.clone();
        seen.sort_unstable();
        ensure!(
            seen.windows(2).all(|w| w[0] != w[1]),
            "routing map assigns one physical shard to two slots"
        );
        Ok(RoutingEpoch { epoch: self.epoch + 1, map })
    }

    /// The successor epoch with physical shard `k` removed (the drain
    /// cutover target): the surviving physicals keep their relative
    /// order, the modulus shrinks by one.
    pub fn drained(&self, k: u32) -> Result<RoutingEpoch> {
        ensure!(self.map.contains(&k), "shard {k} is not in the current routing map");
        ensure!(self.map.len() > 1, "cannot drain the last shard");
        let map: Vec<u32> = self.map.iter().copied().filter(|&p| p != k).collect();
        self.rebalanced(map)
    }

    /// The sessions whose route changes from `self` to `next`, as
    /// `(session, from_physical, to_physical)` in the iteration order
    /// given. This is the migration work list of a cutover.
    pub fn moved<I: IntoIterator<Item = u64>>(
        &self,
        next: &RoutingEpoch,
        sessions: I,
    ) -> Vec<(u64, usize, usize)> {
        sessions
            .into_iter()
            .filter_map(|sid| {
                let from = self.route(sid);
                let to = next.route(sid);
                (from != to).then_some((sid, from, to))
            })
            .collect()
    }
}

/// One step held back because its session is mid-migration.
#[derive(Clone, Debug, PartialEq)]
pub struct ParkedStep {
    pub session: u64,
    pub label: Option<u32>,
    pub x: Vec<f32>,
    /// The client connection that sent it (logits route back here).
    pub conn: u64,
}

/// The router-side holding pen for steps that arrive while their
/// session's state is in flight between shards: strict FIFO per
/// session, bounded in total (a stuck migration must not buffer
/// unboundedly), drained in arrival order at cutover commit.
#[derive(Debug, Default)]
pub struct StepPark {
    /// Total parked steps across every session, bounding memory.
    total: usize,
    /// Per-session FIFO queues (order within a session is sacred).
    queues: HashMap<u64, VecDeque<ParkedStep>>,
}

impl StepPark {
    pub fn new() -> StepPark {
        StepPark::default()
    }

    /// Mark `session` as migrating: from now until [`StepPark::unpark`],
    /// [`StepPark::is_parked`] reports true even with no steps queued.
    pub fn begin(&mut self, session: u64) {
        self.queues.entry(session).or_default();
    }

    /// Is this session currently being held?
    pub fn is_parked(&self, session: u64) -> bool {
        self.queues.contains_key(&session)
    }

    /// Sessions currently held.
    pub fn sessions(&self) -> impl Iterator<Item = u64> + '_ {
        self.queues.keys().copied()
    }

    /// Steps currently held across all sessions.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0 && self.queues.is_empty()
    }

    /// Hold one step. Errors when the pen is full (`cap` total steps) —
    /// the caller treats that like a full outbox and severs the sender
    /// rather than buffering without bound. The session must have been
    /// [`StepPark::begin`]-marked.
    pub fn park(&mut self, step: ParkedStep, cap: usize) -> Result<()> {
        ensure!(self.total < cap, "step park is full ({cap} steps) — migration is stuck");
        let q = self
            .queues
            .get_mut(&step.session)
            .ok_or_else(|| anyhow::anyhow!("parking a step for a session not migrating"))?;
        q.push_back(step);
        self.total += 1;
        Ok(())
    }

    /// Release a session at cutover commit: returns its held steps in
    /// arrival order and stops holding future ones.
    pub fn unpark(&mut self, session: u64) -> VecDeque<ParkedStep> {
        let q = self.queues.remove(&session).unwrap_or_default();
        self.total -= q.len();
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_zero_is_the_pr5_router() {
        for n in 1..=6usize {
            let e = RoutingEpoch::identity(n);
            assert_eq!(e.epoch(), 0);
            for sid in 0..1000u64 {
                assert_eq!(e.route(sid), shard_of(sid, n));
            }
        }
    }

    /// The satellite law: over an exhaustive small domain, `shard_of`
    /// under an N→M resize moves exactly the sessions with
    /// `sid % N != sid % M` — no more, no fewer — for every pair
    /// N,M ≤ 6. The domain covers every residue class of every
    /// modulus pair (lcm(1..6) = 60 ≪ 5040).
    #[test]
    fn exhaustive_moved_set_on_every_n_to_m_pair() {
        let sessions: Vec<u64> = (0..5040).collect();
        for n in 1..=6usize {
            for m in 1..=6usize {
                let a = RoutingEpoch::identity(n);
                let b = a.rebalanced((0..m as u32).collect()).unwrap();
                assert_eq!(b.epoch(), 1);
                let moved = a.moved(&b, sessions.iter().copied());
                let expect: Vec<(u64, usize, usize)> = sessions
                    .iter()
                    .copied()
                    .filter(|&sid| moves(sid, n, m))
                    .map(|sid| (sid, shard_of(sid, n), shard_of(sid, m)))
                    .collect();
                assert_eq!(moved, expect, "moved set mismatch for {n}→{m}");
                if n == m {
                    assert!(moved.is_empty(), "{n}→{n} must move nothing");
                }
            }
        }
    }

    #[test]
    fn drain_removes_exactly_one_physical_and_bumps_the_epoch() {
        let e = RoutingEpoch::identity(3);
        let d = e.drained(1).unwrap();
        assert_eq!(d.epoch(), 1);
        assert_eq!(d.map(), &[0, 2]);
        // every session routes to a surviving shard
        for sid in 0..100u64 {
            assert_ne!(d.route(sid), 1);
        }
        // all of shard 1's sessions are in the moved set
        let moved = e.moved(&d, 0..100u64);
        for sid in 0..100u64 {
            if e.route(sid) == 1 {
                assert!(moved.iter().any(|&(s, from, _)| s == sid && from == 1));
            }
        }
        assert!(e.drained(7).is_err(), "draining an absent shard must fail");
        let one = RoutingEpoch::identity(1);
        assert!(one.drained(0).is_err(), "draining the last shard must fail");
    }

    #[test]
    fn rebalance_rejects_degenerate_maps() {
        let e = RoutingEpoch::identity(2);
        assert!(e.rebalanced(vec![]).is_err());
        assert!(e.rebalanced(vec![0, 0]).is_err());
        assert!(e.rebalanced(vec![0, 2, 1]).is_ok());
    }

    #[test]
    fn step_park_is_fifo_per_session_and_bounded() {
        let mut park = StepPark::new();
        park.begin(7);
        park.begin(9);
        assert!(park.is_parked(7) && park.is_parked(9));
        assert!(!park.is_parked(8));
        for i in 0..3u32 {
            park.park(
                ParkedStep { session: 7, label: Some(i), x: vec![i as f32], conn: 1 },
                10,
            )
            .unwrap();
        }
        park.park(ParkedStep { session: 9, label: None, x: vec![9.0], conn: 2 }, 10).unwrap();
        assert_eq!(park.len(), 4);
        // cap enforcement
        assert!(park
            .park(ParkedStep { session: 9, label: None, x: vec![], conn: 2 }, 4)
            .is_err());
        // parking an unmarked session is an error
        assert!(park
            .park(ParkedStep { session: 8, label: None, x: vec![], conn: 3 }, 10)
            .is_err());
        let drained = park.unpark(7);
        let labels: Vec<Option<u32>> = drained.iter().map(|s| s.label).collect();
        assert_eq!(labels, vec![Some(0), Some(1), Some(2)], "FIFO order violated");
        assert!(!park.is_parked(7));
        assert_eq!(park.len(), 1);
        park.unpark(9);
        assert!(park.is_empty());
    }
}
