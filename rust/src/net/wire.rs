//! Wire codec: the length-prefixed binary protocol of the TCP serving
//! frontend (DESIGN.md §9).
//!
//! ## Frame layout (all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       4     magic    "M2RU"
//! 4       2     version  2
//! 6       1     kind     message discriminant (1..=12)
//! 7       1     flags    FLAG_TICK | FLAG_FLUSH
//! 8       4     len      payload byte count (<= MAX_PAYLOAD)
//! 12      len   payload  per-kind layout below
//! ```
//!
//! Per-kind payloads: `Hello{user u64, epoch u64}`, `Step{session u64,
//! n u32, n×f32}`, `StepLabeled{session u64, label u32, n u32, n×f32}`,
//! `Ack{value u64, epoch u64}`, `Logits{session u64, pred u32, n u32,
//! n×f32}`, `Stats{utf-8 bytes}` (the header's payload length delimits
//! the text), `Shutdown{}` (empty), `Nop{}` (empty), `MetricsDump{utf-8
//! bytes}` (same text layout as `Stats`), `Migrate{session u64, n u32,
//! n bytes}` (an opaque migration parcel, DESIGN.md §14), `Drain{shard
//! u32}`, `Epoch{epoch u64, shards u32}`.
//!
//! Version 2 extends version 1 with the **routing epoch** (DESIGN.md
//! §14): every `Hello` carries the client's last-known epoch (0 when
//! unknown) and every `Ack` carries the responder's current epoch, so
//! both ends of a handshake agree on which `shard_of` mapping is in
//! force. `Migrate`/`Drain`/`Epoch` are the resharding control plane;
//! plain servers treat `Drain`/`Epoch` from clients as violations.
//!
//! Flags drive the server's deterministic logical clock: `FLAG_TICK`
//! marks the end of an admission wave (dispatch per the max-batch/
//! max-wait policy, then advance the tick — exactly one driver loop
//! iteration), `FLAG_FLUSH` forces the end-of-traffic tail flush. A
//! client that pipelines waves with these flags reproduces the
//! in-process driver's batch boundaries bit-for-bit.
//!
//! Malformed input — bad magic, unknown version or kind, oversized or
//! truncated payloads, trailing bytes — decodes to an error, never a
//! panic; the server drops the offending connection.

use std::io::Read;

use anyhow::{bail, ensure, Result};

use crate::codec::{LeReader, LeWriter};

/// `"M2RU"`.
pub const MAGIC: u32 = u32::from_le_bytes(*b"M2RU");
pub const VERSION: u16 = 2;
pub const HEADER_LEN: usize = 12;
/// Upper bound on one frame's payload; larger length fields are rejected
/// before any allocation happens. Sized so one `Migrate` frame holds a
/// whole session parcel (history ring + pending window) with room to
/// spare.
pub const MAX_PAYLOAD: u32 = 1 << 24;

/// End of an admission wave: dispatch ready batches, advance the tick.
pub const FLAG_TICK: u8 = 0b01;
/// Traffic source exhausted: flush queued requests past the wait policy.
pub const FLAG_FLUSH: u8 = 0b10;

/// One protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Client handshake; the server replies `Ack{session id, epoch}` for
    /// the given user key (a keyed hash under the server's per-boot
    /// secret) and binds that session to this connection — only the
    /// binding connection may step it. `epoch` is the client's last-known
    /// routing epoch (0 when unknown; plain servers ignore it).
    Hello { user: u64, epoch: u64 },
    /// One unlabeled timestep of `session`'s stream.
    Step { session: u64, x: Vec<f32> },
    /// One labeled timestep (feeds the online learner when dispatched).
    StepLabeled { session: u64, label: u32, x: Vec<f32> },
    /// Generic acknowledgement carrying one value plus the responder's
    /// current routing epoch (0 from a plain single-shard server).
    Ack { value: u64, epoch: u64 },
    /// Served logits for one completed step.
    Logits { session: u64, pred: u32, logits: Vec<f32> },
    /// Stats request (client → server, empty text) and response
    /// (server → client, the serve report).
    Stats { text: String },
    /// Drain everything, checkpoint, and stop the server.
    Shutdown,
    /// A frame whose only job is its TICK/FLUSH flags: the shard router
    /// marks every wave boundary on every shard with one of these, so a
    /// shard that received no steps this wave still advances its clock in
    /// lock-step (batch wait policy, TTL expiry, checkpoint cadence).
    /// Servers process the flags and send no response.
    Nop,
    /// Observability exposition (DESIGN.md §13). Request (client →
    /// server): `text` is the selector — `""`/`"prom"` for the
    /// Prometheus exposition, `"events"` for the flight-recorder JSONL.
    /// Response (server → client): the rendered dump. `Stats` (kind 6)
    /// stays for compatibility with pre-§13 clients; this frame carries
    /// the full registry instead of the human report.
    MetricsDump { text: String },
    /// Resharding control plane (DESIGN.md §14): one session's sealed
    /// migration parcel. Router → shard with an **empty** payload:
    /// extract `session` (the shard removes it and replies `Migrate`
    /// with the parcel bytes). Router → shard with a **non-empty**
    /// payload: inject the parcel (the shard installs it and replies
    /// `Migrate` with an empty payload). The parcel bytes are opaque at
    /// this layer — sealed and versioned by `serve::migrate`.
    Migrate { session: u64, payload: Vec<u8> },
    /// Admin → router: quiesce shard `shard`, migrate its sessions out,
    /// checkpoint and retire it. The router replies `Epoch{new epoch,
    /// new width}` after cutover. A violation on a plain server.
    Drain { shard: u32 },
    /// Routing-epoch control. Admin → router: `shards == 0` queries the
    /// current epoch, `shards == M` requests an N→M rebalance. Router →
    /// admin / router → shard: announces the (possibly bumped) epoch and
    /// the shard count it maps over.
    Epoch { epoch: u64, shards: u32 },
}

impl Message {
    /// Wire discriminant.
    pub fn kind(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::Step { .. } => 2,
            Message::StepLabeled { .. } => 3,
            Message::Ack { .. } => 4,
            Message::Logits { .. } => 5,
            Message::Stats { .. } => 6,
            Message::Shutdown => 7,
            Message::Nop => 8,
            Message::MetricsDump { .. } => 9,
            Message::Migrate { .. } => 10,
            Message::Drain { .. } => 11,
            Message::Epoch { .. } => 12,
        }
    }
}

/// One decoded frame: header flags + message.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub flags: u8,
    pub msg: Message,
}

// ---------------------------------------------------------------- encoding

fn encode_payload(msg: &Message) -> Vec<u8> {
    let mut p = LeWriter::new();
    match msg {
        Message::Hello { user, epoch } => {
            p.u64(*user);
            p.u64(*epoch);
        }
        Message::Step { session, x } => {
            p.u64(*session);
            p.f32s(x);
        }
        Message::StepLabeled { session, label, x } => {
            p.u64(*session);
            p.u32(*label);
            p.f32s(x);
        }
        Message::Ack { value, epoch } => {
            p.u64(*value);
            p.u64(*epoch);
        }
        Message::Logits { session, pred, logits } => {
            p.u64(*session);
            p.u32(*pred);
            p.f32s(logits);
        }
        Message::Stats { text } | Message::MetricsDump { text } => p.raw(text.as_bytes()),
        Message::Shutdown | Message::Nop => {}
        Message::Migrate { session, payload } => {
            p.u64(*session);
            p.bytes(payload);
        }
        Message::Drain { shard } => p.u32(*shard),
        Message::Epoch { epoch, shards } => {
            p.u64(*epoch);
            p.u32(*shards);
        }
    }
    p.into_vec()
}

/// Encode one frame (header + payload) to bytes.
pub fn encode_frame(flags: u8, msg: &Message) -> Vec<u8> {
    let payload = encode_payload(msg);
    debug_assert!(payload.len() <= MAX_PAYLOAD as usize, "payload exceeds protocol bound");
    let mut out = LeWriter::from_vec(Vec::with_capacity(HEADER_LEN + payload.len()));
    out.u32(MAGIC);
    out.u16(VERSION);
    out.u8(msg.kind());
    out.u8(flags);
    out.u32(payload.len() as u32);
    out.raw(&payload);
    out.into_vec()
}

// ---------------------------------------------------------------- decoding

// Decoding runs on the shared bounds-checked cursor ([`crate::codec`]) —
// the same truncation semantics as the snapshot/delta formats, so a
// bounds-handling fix cannot diverge between the two layers.

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Message> {
    let mut c = LeReader::new(payload);
    let msg = match kind {
        1 => Message::Hello { user: c.u64()?, epoch: c.u64()? },
        2 => Message::Step { session: c.u64()?, x: c.f32s()? },
        3 => Message::StepLabeled { session: c.u64()?, label: c.u32()?, x: c.f32s()? },
        4 => Message::Ack { value: c.u64()?, epoch: c.u64()? },
        5 => Message::Logits { session: c.u64()?, pred: c.u32()?, logits: c.f32s()? },
        6 => {
            // the frame header's length delimits the text — no inner count
            let bytes = c.take(c.remaining())?.to_vec();
            let text = String::from_utf8(bytes).map_err(|_| anyhow::anyhow!("stats text not utf-8"))?;
            Message::Stats { text }
        }
        7 => Message::Shutdown,
        8 => Message::Nop,
        9 => {
            let bytes = c.take(c.remaining())?.to_vec();
            let text = String::from_utf8(bytes)
                .map_err(|_| anyhow::anyhow!("metrics text not utf-8"))?;
            Message::MetricsDump { text }
        }
        10 => Message::Migrate { session: c.u64()?, payload: c.byte_vec()? },
        11 => Message::Drain { shard: c.u32()? },
        12 => Message::Epoch { epoch: c.u64()?, shards: c.u32()? },
        other => bail!("unknown message kind {other}"),
    };
    c.done()?;
    Ok(msg)
}

/// Parse the 12-byte header; returns `(kind, flags, payload_len)`.
fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(u8, u8, usize)> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    ensure!(magic == MAGIC, "bad magic {magic:#010x} (expected {MAGIC:#010x})");
    let version = u16::from_le_bytes([h[4], h[5]]);
    ensure!(version == VERSION, "unsupported protocol version {version}");
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    ensure!(len <= MAX_PAYLOAD, "oversized payload ({len} > {MAX_PAYLOAD} bytes)");
    Ok((h[6], h[7], len as usize))
}

/// Decode one frame from a byte slice; returns the frame and the bytes
/// consumed. Errors (never panics) on truncation, bad magic/version,
/// oversized length, unknown kind, or trailing payload bytes.
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize)> {
    ensure!(buf.len() >= HEADER_LEN, "truncated header ({} of {HEADER_LEN} bytes)", buf.len());
    let mut h = [0u8; HEADER_LEN];
    h.copy_from_slice(&buf[..HEADER_LEN]);
    let (kind, flags, len) = decode_header(&h)?;
    ensure!(
        buf.len() >= HEADER_LEN + len,
        "truncated payload ({} of {} frame bytes)",
        buf.len(),
        HEADER_LEN + len
    );
    let msg = decode_payload(kind, &buf[HEADER_LEN..HEADER_LEN + len])?;
    Ok((Frame { flags, msg }, HEADER_LEN + len))
}

/// Fill `buf` from the reader. `Ok(false)` on clean EOF before the first
/// byte (a frame boundary); an EOF mid-buffer is an error (truncated
/// frame).
fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                bail!("connection closed mid-frame ({filled} of {} bytes)", buf.len());
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// Read one frame from a stream. `Ok(None)` on clean EOF at a frame
/// boundary; errors on malformed frames or mid-frame disconnects.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    if !read_full(r, &mut header)? {
        return Ok(None);
    }
    let (kind, flags, len) = decode_header(&header)?;
    let mut payload = vec![0u8; len];
    if len > 0 && !read_full(r, &mut payload)? {
        bail!("connection closed before payload");
    }
    let msg = decode_payload(kind, &payload)?;
    Ok(Some(Frame { flags, msg }))
}

/// Write one frame to a stream.
pub fn write_frame<Wr: std::io::Write>(w: &mut Wr, flags: u8, msg: &Message) -> Result<()> {
    let buf = encode_frame(flags, msg);
    w.write_all(&buf)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(flags: u8, msg: Message) {
        let buf = encode_frame(flags, &msg);
        let (frame, consumed) = decode_frame(&buf).unwrap();
        assert_eq!(consumed, buf.len());
        assert_eq!(frame.flags, flags);
        assert_eq!(frame.msg, msg);
        // stream path agrees with the slice path
        let mut cursor = &buf[..];
        let streamed = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(streamed.msg, frame.msg);
    }

    #[test]
    fn every_message_kind_roundtrips() {
        roundtrip(0, Message::Hello { user: 0xDEAD_BEEF, epoch: 0 });
        roundtrip(0, Message::Hello { user: 1, epoch: u64::MAX });
        roundtrip(FLAG_TICK, Message::Step { session: 7, x: vec![0.5, -0.25, 1.0] });
        roundtrip(
            FLAG_TICK | FLAG_FLUSH,
            Message::StepLabeled { session: 9, label: 3, x: vec![-1.0, 0.0] },
        );
        roundtrip(0, Message::Ack { value: 42, epoch: 3 });
        roundtrip(0, Message::Logits { session: 1, pred: 2, logits: vec![0.1, 0.9, -3.5] });
        roundtrip(0, Message::Stats { text: "req=10 batches=2".to_string() });
        roundtrip(FLAG_FLUSH, Message::Shutdown);
        roundtrip(FLAG_TICK, Message::Nop);
        roundtrip(FLAG_TICK | FLAG_FLUSH, Message::Nop);
        roundtrip(0, Message::MetricsDump { text: "events".to_string() });
        roundtrip(0, Message::MetricsDump { text: "# TYPE m2ru_requests_total counter\n".into() });
        roundtrip(0, Message::Migrate { session: 11, payload: vec![0xDE, 0xAD, 0x00, 0x7F] });
        roundtrip(0, Message::Drain { shard: 2 });
        roundtrip(0, Message::Epoch { epoch: 5, shards: 3 });
        roundtrip(0, Message::Epoch { epoch: 7, shards: 0 });
    }

    #[test]
    fn empty_vectors_and_strings_roundtrip() {
        roundtrip(0, Message::Step { session: 0, x: vec![] });
        roundtrip(0, Message::Stats { text: String::new() });
        roundtrip(0, Message::Migrate { session: 4, payload: vec![] });
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = encode_frame(0, &Message::Shutdown);
        buf[0] ^= 0xFF;
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("bad magic"));
    }

    #[test]
    fn bad_version_rejected() {
        let mut buf = encode_frame(0, &Message::Shutdown);
        buf[4] = 99;
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut buf = encode_frame(0, &Message::Shutdown);
        buf[6] = 200;
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("unknown message kind"));
    }

    #[test]
    fn truncated_frames_rejected_without_panic() {
        let frames = [
            encode_frame(0, &Message::Step { session: 3, x: vec![1.0, 2.0] }),
            encode_frame(0, &Message::Hello { user: 9, epoch: 4 }),
            encode_frame(0, &Message::Migrate { session: 8, payload: vec![1, 2, 3, 4, 5] }),
            encode_frame(0, &Message::Drain { shard: 1 }),
            encode_frame(0, &Message::Epoch { epoch: 2, shards: 3 }),
        ];
        for buf in &frames {
            for cut in 0..buf.len() {
                assert!(decode_frame(&buf[..cut]).is_err(), "cut at {cut} must error");
            }
        }
    }

    #[test]
    fn oversized_length_field_rejected_before_allocation() {
        let mut buf = encode_frame(0, &Message::Shutdown);
        buf[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("oversized"));
        // stream path too
        let mut cursor = &buf[..];
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn trailing_payload_bytes_rejected() {
        // declare a 17-byte payload for an Ack (16 bytes used)
        let mut buf = encode_frame(0, &Message::Ack { value: 5, epoch: 1 });
        buf[8..12].copy_from_slice(&17u32.to_le_bytes());
        buf.push(0xAB);
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("trailing"));
    }

    #[test]
    fn float_count_beyond_payload_rejected() {
        // Step with a declared float count far past the payload end
        let mut p = Vec::new();
        p.extend_from_slice(&7u64.to_le_bytes());
        p.extend_from_slice(&1000u32.to_le_bytes()); // claims 1000 floats, provides none
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.push(2);
        buf.push(0);
        buf.extend_from_slice(&(p.len() as u32).to_le_bytes());
        buf.extend_from_slice(&p);
        assert!(decode_frame(&buf).unwrap_err().to_string().contains("truncated"));
    }

    #[test]
    fn clean_eof_at_boundary_is_none() {
        let empty: &[u8] = &[];
        let mut r = empty;
        assert!(read_frame(&mut r).unwrap().is_none());
        // EOF mid-header is an error, not None
        let partial = encode_frame(0, &Message::Shutdown);
        let mut r = &partial[..5];
        assert!(read_frame(&mut r).is_err());
    }
}
