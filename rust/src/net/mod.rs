//! Network transport: the TCP serving frontend of the streaming session
//! server (DESIGN.md §9) — where the server meets the outside world.
//!
//! * [`wire`] — the versioned length-prefixed binary protocol
//!   (Hello/Step/StepLabeled/Ack/Logits/Stats/Shutdown/MetricsDump
//!   frames, explicit little-endian layout, malformed-frame rejection
//!   without panics).
//! * [`NetServer`] — `std::net::TcpListener` accept loop, one reader
//!   thread per connection, a bounded `std::sync::mpsc` channel into the
//!   single deterministic serve thread driving
//!   [`crate::serve::ServeCore`], and checkpoint/restore wiring
//!   (`m2ru serve --listen ADDR --checkpoint-dir DIR`).
//! * [`NetClient`] / [`run_connect`] — the protocol client and the
//!   closed-loop load generator (`m2ru connect`), which replays the
//!   synthetic driver's admission schedule over loopback with
//!   bit-identical results.
//! * [`RouterServer`] / [`RouterCore`] — the multi-shard session router
//!   (`m2ru router`, DESIGN.md §11): one front door partitioning
//!   established session ids (`session_id % N`) across N independent
//!   [`crate::serve::ServeCore`] shards — in-process shard threads or
//!   remote `m2ru serve --listen` processes — each with its own engine,
//!   learner, commit pipeline and checkpoint chain (`shard-<k>/`).
//! * [`reshard`] — epoch-versioned routing (DESIGN.md §14): the
//!   [`RoutingEpoch`] map the router routes by, the moved-set math of
//!   an N→M rebalance or a `--drain`, and the [`StepPark`] holding pen
//!   that keeps mid-migration steps ordered and un-dropped.
//!
//! No dependencies beyond `std`: the frame codec, threading and
//! durability are all plain `std::net` + `std::sync`.

mod client;
mod conn;
pub mod reshard;
mod router;
mod server;
pub mod wire;

pub use client::{run_connect, ConnectOptions, ConnectReport, NetClient};
pub use reshard::{moves, shard_of, ParkedStep, RoutingEpoch, StepPark};
pub use router::{run_router, RouterCore, RouterReport, RouterServeOptions, RouterServer};
pub use server::{run_net_serve, snapshot_path, NetServeOptions, NetServeReport, NetServer};
pub use wire::{
    decode_frame, encode_frame, read_frame, write_frame, Frame, Message, FLAG_FLUSH, FLAG_TICK,
    HEADER_LEN, MAGIC, MAX_PAYLOAD, VERSION,
};
