//! Shared TCP accept path: the connection plumbing both serving
//! frontends — the single-process server ([`super::server::NetServer`])
//! and the multi-shard router ([`super::router::RouterServer`]) — are
//! built on.
//!
//! One acceptor thread owns the listen socket; every accepted connection
//! gets a reader thread (decoding frames into the frontend's bounded
//! event queue) and a writer thread (draining a bounded response outbox
//! onto the socket). The frontend's serve thread is the only consumer of
//! the event queue and the only producer into the outboxes, so all
//! serving state stays single-threaded.
//!
//! The event queue is generic: each frontend wraps [`ConnEvent`] into
//! its own event enum (`From<ConnEvent>`), letting the router add shard
//! events to the same queue without duplicating the accept path.
//!
//! [`ConnTable`] keeps live connections, their session bindings and the
//! writer-outbox drop counters ([`OutboxDrops`]) consistent as one unit:
//! every path that loses a connection — clean disconnect, protocol
//! violation, a full outbox, a write timeout or a dead peer — also
//! releases the sessions it had bound.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::obs::{Counter, FlightRecorder};
use crate::serve::{CompletedStep, OutboxDrops};

use super::wire::{self, Frame, Message};

/// Writer-outbox flow counters shared between the serve thread and every
/// writer thread: frames enqueued into outboxes vs frames actually
/// written to sockets. Their difference is the instantaneous fleet-wide
/// outbox occupancy (the `m2ru_outbox_occupancy` gauge). Plain relaxed
/// atomics — timing plane only, never consulted by dispatch.
#[derive(Clone, Default)]
pub(crate) struct OutboxFlow {
    pub(crate) enqueued: Counter,
    pub(crate) written: Counter,
}

impl OutboxFlow {
    /// Frames currently sitting in writer outboxes (fleet-wide).
    pub(crate) fn occupancy(&self) -> u64 {
        self.enqueued.get().saturating_sub(self.written.get())
    }
}

/// Events the accept path feeds the frontend's serve thread.
pub(crate) enum ConnEvent {
    Connected {
        conn: u64,
        /// Control handle on the socket (shutdown on drop/violation).
        ctl: TcpStream,
        /// Bounded outbox feeding the connection's writer thread.
        outbox: SyncSender<Vec<u8>>,
        /// The writer thread, joined at teardown.
        writer: JoinHandle<()>,
    },
    Frame {
        conn: u64,
        frame: Frame,
    },
    Disconnected {
        conn: u64,
    },
    Malformed {
        conn: u64,
        error: String,
    },
    /// The connection's writer thread hit a socket write error (dead or
    /// stalled peer): the connection must be *severed*, not just
    /// forgotten — its reader may still be alive on the open socket.
    /// `timeout` distinguishes the write-timeout backstop from an
    /// outright failed write (the drop counters report them separately).
    WriterFailed {
        conn: u64,
        timeout: bool,
    },
}

/// The per-connection writer thread: drain the bounded outbox onto the
/// socket. Exits when the outbox closes (connection forgotten/dropped)
/// or a write fails (dead or timed-out peer — reported so the serve
/// thread severs the connection and releases its session bindings).
fn writer_loop<E: From<ConnEvent> + Send + 'static>(
    conn: u64,
    mut sock: TcpStream,
    outbox: Receiver<Vec<u8>>,
    tx: SyncSender<E>,
    flow: OutboxFlow,
) {
    use std::io::Write as _;
    for buf in outbox {
        if let Err(e) = sock.write_all(&buf) {
            let timeout = matches!(
                e.kind(),
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
            );
            // best-effort: at teardown the serve thread is gone
            let _ = tx.send(ConnEvent::WriterFailed { conn, timeout }.into());
            return;
        }
        flow.written.inc();
    }
}

/// Accept connections until stopped; one reader thread and one writer
/// thread (with a bounded `outbox_depth`-frame outbox) per connection.
/// Connection ids count up from 1 (the router's shard peers live in a
/// separate index space, so the two can never collide).
pub(crate) fn spawn_acceptor<E: From<ConnEvent> + Send + 'static>(
    listener: TcpListener,
    tx: SyncSender<E>,
    stop: Arc<AtomicBool>,
    outbox_depth: usize,
    flow: OutboxFlow,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut next_conn: u64 = 1;
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let _ = stream.set_nodelay(true);
            let conn = next_conn;
            next_conn += 1;
            let (ctl, wsock) = match (stream.try_clone(), stream.try_clone()) {
                (Ok(a), Ok(b)) => (a, b),
                _ => continue,
            };
            // backstop only: the serve thread never writes, but the
            // writer thread must not hang forever on a half-dead peer —
            // after the timeout its write errors and the connection dies
            let _ = wsock.set_write_timeout(Some(std::time::Duration::from_secs(10)));
            let (obx_tx, obx_rx) = sync_channel::<Vec<u8>>(outbox_depth);
            let writer_tx = tx.clone();
            let writer_flow = flow.clone();
            let writer = std::thread::spawn(move || {
                writer_loop::<E>(conn, wsock, obx_rx, writer_tx, writer_flow)
            });
            if tx.send(ConnEvent::Connected { conn, ctl, outbox: obx_tx, writer }.into()).is_err()
            {
                return;
            }
            let reader_tx = tx.clone();
            let mut reader = stream;
            std::thread::spawn(move || loop {
                match wire::read_frame(&mut reader) {
                    Ok(Some(frame)) => {
                        if reader_tx.send(ConnEvent::Frame { conn, frame }.into()).is_err() {
                            return;
                        }
                    }
                    Ok(None) => {
                        let _ = reader_tx.send(ConnEvent::Disconnected { conn }.into());
                        return;
                    }
                    Err(e) => {
                        let _ = reader_tx
                            .send(ConnEvent::Malformed { conn, error: e.to_string() }.into());
                        return;
                    }
                }
            });
        }
    })
}

/// One live connection's serve-side handle: the control socket (for
/// shutdowns), the bounded outbox into its writer thread, and the
/// writer's join handle.
struct ConnEntry {
    ctl: TcpStream,
    outbox: SyncSender<Vec<u8>>,
    writer: JoinHandle<()>,
}

/// Live connections and their session bindings, kept consistent as one
/// unit: every path that loses a connection — clean disconnect, protocol
/// violation, a full outbox or a dead peer — also releases the sessions
/// it had bound, so a reconnecting user can always re-`Hello` their
/// session. Outbox-related drops are counted by reason in
/// [`ConnTable::drops`].
pub(crate) struct ConnTable {
    conns: HashMap<u64, ConnEntry>,
    /// session id → owning connection.
    owner: HashMap<u64, u64>,
    /// connection → bindings held (bounds `owner` under a Hello flood).
    owned: HashMap<u64, usize>,
    /// Writer threads of departed connections. NEVER joined inline — a
    /// dying writer may be blocked reporting its own death into the full
    /// event queue, which only the serve thread drains; joining here
    /// would deadlock. Reaped in `close_all` after the event channel is
    /// gone.
    reap: Vec<JoinHandle<()>>,
    /// Writer-outbox drops by reason (surfaced through `ServeReport`).
    pub(crate) drops: OutboxDrops,
    /// Outbox flow counters (shared with the writer threads via
    /// [`spawn_acceptor`]); `send` counts the enqueue side.
    pub(crate) flow: OutboxFlow,
    /// Optional flight recorder: severed connections are recorded with
    /// their reason. Timing plane only.
    pub(crate) recorder: Option<Arc<FlightRecorder>>,
    /// Logical tick stamped onto recorded events (the frontend updates
    /// it as its clock advances; observability bookkeeping only).
    pub(crate) obs_tick: u64,
}

impl ConnTable {
    pub(crate) fn new() -> ConnTable {
        ConnTable {
            conns: HashMap::new(),
            owner: HashMap::new(),
            owned: HashMap::new(),
            reap: Vec::new(),
            drops: OutboxDrops::default(),
            flow: OutboxFlow::default(),
            recorder: None,
            obs_tick: 0,
        }
    }

    pub(crate) fn connected(
        &mut self,
        conn: u64,
        ctl: TcpStream,
        outbox: SyncSender<Vec<u8>>,
        writer: JoinHandle<()>,
    ) {
        self.conns.insert(conn, ConnEntry { ctl, outbox, writer });
    }

    /// Release a cleanly-disconnected connection's bookkeeping. The
    /// outbox sender drops, so the writer flushes what is queued and
    /// exits; the socket itself stays open until the writer is done.
    pub(crate) fn forget(&mut self, conn: u64) {
        if let Some(e) = self.conns.remove(&conn) {
            self.reap.push(e.writer);
        }
        if self.owned.remove(&conn).is_some() {
            self.owner.retain(|_, c| *c != conn);
        }
    }

    /// Sever a protocol-violating (or stalled/dead) connection: log,
    /// shut the socket down (which also unblocks its writer), and
    /// release every session bound to it.
    pub(crate) fn drop_conn(&mut self, conn: u64, reason: &str) {
        eprintln!("net: dropping connection {conn}: {reason}");
        if let Some(rec) = &self.recorder {
            rec.record(
                self.obs_tick,
                "conn_severed",
                vec![("conn", format!("{conn}")), ("reason", reason.to_string())],
            );
        }
        if let Some(e) = self.conns.remove(&conn) {
            let _ = e.ctl.shutdown(std::net::Shutdown::Both);
            self.reap.push(e.writer);
        }
        if self.owned.remove(&conn).is_some() {
            self.owner.retain(|_, c| *c != conn);
        }
    }

    /// A writer thread reported a failed or timed-out write. Counted and
    /// severed only if the connection is still live — the write that
    /// failed may belong to a connection already dropped for an earlier
    /// reason, which must not be double-counted.
    pub(crate) fn writer_failed(&mut self, conn: u64, timeout: bool) {
        if !self.conns.contains_key(&conn) {
            return;
        }
        if timeout {
            self.drops.timeout += 1;
            self.drop_conn(conn, "response write timed out (stalled peer)");
        } else {
            self.drops.writer_failed += 1;
            self.drop_conn(conn, "response write failed (dead peer)");
        }
    }

    /// Did `conn` establish `session` with a `Hello`?
    pub(crate) fn owns(&self, conn: u64, session: u64) -> bool {
        self.owner.get(&session) == Some(&conn)
    }

    /// The connection currently holding `session`, if any.
    pub(crate) fn owner_of(&self, session: u64) -> Option<u64> {
        self.owner.get(&session).copied()
    }

    /// Bind `sid` to `conn` per the trust rules: idempotent for the
    /// holder, rejected while another *live* connection holds it, taken
    /// over from a connection known to be gone, and capped per
    /// connection so `owner` cannot grow without bound.
    pub(crate) fn bind(&mut self, conn: u64, sid: u64, cap: usize) -> Result<(), String> {
        match self.owner.get(&sid).copied() {
            Some(c) if c == conn => Ok(()),
            Some(c) if self.conns.contains_key(&c) => {
                Err("Hello for a session bound to another live connection".to_string())
            }
            stale => {
                if let Some(c) = stale {
                    // the previous holder is gone; release its slot
                    if let Some(n) = self.owned.get_mut(&c) {
                        *n = n.saturating_sub(1);
                    }
                }
                let n = self.owned.entry(conn).or_insert(0);
                if *n >= cap {
                    return Err(format!("connection exceeded {cap} session bindings"));
                }
                *n += 1;
                self.owner.insert(sid, conn);
                Ok(())
            }
        }
    }

    /// Non-blocking frame dispatch into the connection's writer outbox.
    /// A full outbox means the peer is slow (its writer is stuck on a
    /// full socket) — that connection alone is dropped; the serve thread
    /// never waits on anyone's socket.
    pub(crate) fn send(&mut self, conn: u64, msg: &Message) {
        let Some(e) = self.conns.get(&conn) else { return };
        let buf = wire::encode_frame(0, msg);
        match e.outbox.try_send(buf) {
            Ok(()) => self.flow.enqueued.inc(),
            Err(TrySendError::Full(_)) => {
                self.drops.full += 1;
                self.drop_conn(conn, "response outbox full (slow client)");
            }
            Err(TrySendError::Disconnected(_)) => {
                self.drops.writer_failed += 1;
                self.drop_conn(conn, "writer thread gone");
            }
        }
    }

    /// Return each completed step's logits to the connection it arrived
    /// on (consumes the steps — the logits rows move into the frames).
    pub(crate) fn route_logits(&mut self, done: Vec<CompletedStep>) {
        for step in done {
            let msg = Message::Logits {
                session: step.session,
                pred: step.pred as u32,
                logits: step.logits,
            };
            self.send(step.tag, &msg);
        }
    }

    /// Teardown: let every live connection's writer flush its queued
    /// frames (the shutdown Ack, final logits) by closing the outbox and
    /// joining it *before* the socket is shut down — a blocked writer is
    /// bounded by its socket write timeout. Only called after the serve
    /// thread has dropped the event receiver, so no writer can block
    /// reporting its own death.
    pub(crate) fn close_all(&mut self) {
        for (_, e) in self.conns.drain() {
            drop(e.outbox);
            let _ = e.writer.join();
            let _ = e.ctl.shutdown(std::net::Shutdown::Both);
        }
        // writers of already-severed connections (their sockets are shut;
        // they exit as soon as their pending write fails)
        for h in self.reap.drain(..) {
            let _ = h.join();
        }
    }
}

/// Why a Step/StepLabeled frame is a protocol violation, if it is one:
/// wrong input width, a label outside the class range (it would index the
/// one-hot/loss rows out of bounds), or a session this connection never
/// established with `Hello`.
pub(crate) fn step_violation(
    owns: bool,
    got: usize,
    nx: usize,
    label: Option<u32>,
    ny: usize,
) -> Option<String> {
    if got != nx {
        return Some(format!("step of width {got} (net expects {nx})"));
    }
    if let Some(l) = label {
        if l as usize >= ny {
            return Some(format!("label {l} out of range (net has {ny} classes)"));
        }
    }
    if !owns {
        return Some("step for a session this connection did not establish".to_string());
    }
    None
}

/// Wake a listener blocked in `accept` with a throwaway connection (the
/// teardown path). When bound to an unspecified address (0.0.0.0 / ::),
/// connect via loopback instead. Returns whether the wake connected — if
/// it did not, the caller must NOT join the acceptor: shutdown (and the
/// final checkpoint) must not hang on a blocked accept; the acceptor
/// dies with the process.
pub(crate) fn wake_acceptor(listener: &TcpListener) -> bool {
    match listener.local_addr() {
        Ok(mut addr) => {
            if addr.ip().is_unspecified() {
                let ip = match addr.ip() {
                    std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                    std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
                };
                addr.set_ip(ip);
            }
            TcpStream::connect(addr).is_ok()
        }
        Err(_) => false,
    }
}
