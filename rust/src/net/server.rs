//! TCP serving frontend: a multi-client accept loop feeding one
//! deterministic serve thread over a bounded channel (DESIGN.md §9–§10).
//!
//! ## Threading
//!
//! ```text
//! acceptor thread ──spawns──> reader thread (per connection)
//!                                   │  wire::read_frame
//!                                   ▼
//!                  std::sync::mpsc::sync_channel (bounded: back-pressure)
//!                                   │
//!                                   ▼
//!                   serve thread: ServeCore (store/batcher/learner)
//!                    │ commits+snapshots       │ encoded frames
//!                    ▼                         ▼
//!            committer thread        per-connection writer threads
//!            (owns the weights)      (bounded outbox each)
//! ```
//!
//! The accept path (acceptor, reader/writer threads, connection table)
//! is shared with the multi-shard router — see [`super::conn`].
//!
//! Readers block when the serve loop falls behind (`net.queue_depth`
//! frames in flight), which propagates back-pressure to clients through
//! TCP flow control instead of buffering unboundedly.
//!
//! The serve thread never touches a socket, the weights, or the disk:
//! responses are queued (non-blocking) to one **writer thread per
//! connection** with a bounded outbox of `net.outbox_depth` frames — a
//! stalled or dead peer fills its own outbox and is dropped, without
//! adding a microsecond to any other client's latency — while weight
//! commits and durable snapshots run on the committer thread inside
//! [`ServeCore`] (see `serve::commit`). Connections severed this way are
//! counted by reason in `ServeReport::outbox_drops` (full outbox, write
//! timeout, failed write), so load tests assert slow-client isolation on
//! counters instead of scraping stderr.
//!
//! ## Determinism
//!
//! The serve thread is the only thread touching serving state, and it
//! advances the logical clock exactly when a frame carries `FLAG_TICK` —
//! so a single client replaying the synthetic driver's admission
//! schedule (one wave per tick, `FLAG_TICK` on the wave's last frame,
//! `FLAG_FLUSH` on the run's last frame) reproduces the in-process
//! driver's batches, commits and logits bit-for-bit. The loopback test
//! in `tests/net_roundtrip.rs` asserts exactly that.
//!
//! ## Durability
//!
//! With a checkpoint directory configured, the server restores the last
//! snapshot on boot (corrupt snapshots warn and boot fresh), snapshots
//! every `net.checkpoint_every` ticks, and always snapshots on shutdown —
//! a kill/restart resumes every live session's hidden state bitwise.
//!
//! ## Trust model
//!
//! Session ids are a keyed hash of the user key under a random per-boot
//! secret (persisted in checkpoints, so restored sessions keep their
//! ids; see [`session_id_keyed`] for what the keying does and does not
//! guarantee). The enforcement boundary is *connection binding*: a
//! session belongs to the connection that established it with `Hello`,
//! and `Step` frames for a session this connection never established,
//! an out-of-range label, or a `Hello` for a session bound to another
//! *live* connection are protocol violations that drop the offending
//! connection without touching serving state. Every path that loses a
//! connection — clean EOF, violation, failed write to a dead peer —
//! releases its bindings, so a session whose holder is known to be gone
//! can be re-established by a fresh `Hello`; and each connection may
//! hold at most `serve.capacity` bindings, so the binding table stays
//! bounded under a Hello flood.
//!
//! Client administration — `Shutdown` frames, `Migrate` session
//! transfers and the TICK/FLUSH clock flags — is on by default, which
//! suits the loopback harness, closed-loop benches and router-owned
//! shards where the single client *is* the operator. For a server
//! exposed to untrusted clients, set `net.client_admin = false` and a
//! `net.tick_ms` period: client flags are then ignored, `Shutdown` and
//! `Migrate` become protocol violations, and a server-side timer drives
//! the logical clock (batching, TTL expiry, checkpoint cadence)
//! instead.
//!
//! A plain server is not a router: its routing epoch is always 0 (it
//! echoes that in every `Ack` and ignores the client's `Hello` epoch),
//! and the router-plane `Drain`/`Epoch` frames are protocol violations
//! here. `Migrate` is the shard half of a live migration (DESIGN.md
//! §14): an empty payload asks this server to *extract* the session
//! into a sealed parcel (replied in a `Migrate` frame — empty when the
//! session is not resident), a non-empty payload *injects* a parcel
//! under the frame's session id (confirmed with an empty `Migrate`).

use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{NetConfig, RunConfig};
use crate::serve::{
    extract_parcel, inject_parcel, session_id_keyed, try_restore, CompletedStep, RestoreOutcome,
    ServeCore, ServeReport, SnapshotPolicy,
};

use super::conn::{self, ConnEvent, ConnTable, OutboxFlow};
use super::wire::{Frame, Message, FLAG_FLUSH, FLAG_TICK};

/// One network serve run, fully specified.
#[derive(Clone, Debug)]
pub struct NetServeOptions {
    /// Network shapes (must match what clients stream).
    pub net: NetConfig,
    /// Backend, workers, seed, `[serve]` policy and `[net]` transport
    /// policy — including `net.listen`, the single source of truth for
    /// the listen address (`host:port`; port 0 picks a free port).
    pub run: RunConfig,
}

impl NetServeOptions {
    /// Build options, overriding `run.net.listen` with `listen`.
    pub fn new(net: NetConfig, run: RunConfig, listen: impl Into<String>) -> NetServeOptions {
        let mut run = run;
        run.net.listen = listen.into();
        NetServeOptions { net, run }
    }
}

/// Outcome of a network serve run (after a client sent `Shutdown`).
pub struct NetServeReport {
    /// The usual serve report (metrics include any restored history;
    /// `outbox_drops` carries the slow-client severing counters).
    pub report: ServeReport,
    /// Connections accepted over the run.
    pub connections: u64,
    /// Where the final snapshot landed (durability enabled only).
    pub checkpoint_path: Option<PathBuf>,
    /// Sessions restored from a snapshot at boot.
    pub restored_sessions: usize,
}

/// Events the connection threads (and the optional ticker) feed the
/// serve thread.
enum Event {
    Conn(ConnEvent),
    /// Server-driven clock pulse (`net.tick_ms` mode).
    Tick,
}

impl From<ConnEvent> for Event {
    fn from(e: ConnEvent) -> Event {
        Event::Conn(e)
    }
}

/// A random 64-bit per-boot key for the session-id space, drawn from the
/// standard library's hash seeding (OS entropy, no new dependencies).
pub(crate) fn random_boot_secret() -> u64 {
    use std::hash::{BuildHasher, Hasher};
    let a = std::collections::hash_map::RandomState::new().build_hasher().finish();
    let b = std::collections::hash_map::RandomState::new().build_hasher().finish();
    a ^ b.rotate_left(32)
}

/// A bound TCP serving frontend. `bind` then `run`; `local_addr` exposes
/// the picked port so tests and scripts can use `--listen 127.0.0.1:0`.
pub struct NetServer {
    listener: TcpListener,
    opts: NetServeOptions,
}

impl NetServer {
    pub fn bind(opts: NetServeOptions) -> Result<NetServer> {
        opts.run.validate()?;
        let listener = TcpListener::bind(&opts.run.net.listen)
            .with_context(|| format!("binding {}", opts.run.net.listen))?;
        Ok(NetServer { listener, opts })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `Shutdown`. Blocking; spawn a thread to
    /// run it in the background.
    pub fn run(self) -> Result<NetServeReport> {
        let NetServer { listener, opts } = self;
        let mut core = ServeCore::new(opts.net, &opts.run)?;

        // durable boot: restore the last snapshot if one exists
        let ckpt_dir: Option<PathBuf> = if opts.run.net.checkpoint_dir.is_empty() {
            None
        } else {
            Some(PathBuf::from(&opts.run.net.checkpoint_dir))
        };
        let mut restored_sessions = 0;
        let mut restored = false;
        if let Some(dir) = &ckpt_dir {
            match try_restore(&mut core, dir)? {
                RestoreOutcome::Restored { sessions, tick, deltas } => {
                    restored_sessions = sessions;
                    restored = true;
                    eprintln!(
                        "restored {sessions} session(s) at tick {tick} ({deltas} delta snapshot(s) applied) from {}",
                        dir.display()
                    );
                }
                RestoreOutcome::Corrupt { error } => {
                    eprintln!("warning: ignoring corrupt checkpoint ({error}); booting fresh");
                }
                RestoreOutcome::Fresh => {}
            }
        }
        // fresh boots key the session-id space with a random secret so
        // clients cannot compute each other's session ids; a restore
        // keeps the checkpointed key so existing session ids stay valid
        if !restored {
            core.set_session_secret(random_boot_secret());
        }

        // observability: writer-outbox flow counters shared with the
        // writer threads, plus the panic-time flight-recorder dump.
        // Timing plane only — none of it is consulted by dispatch.
        let flow = if core.obs().enabled() {
            let reg = &core.obs().registry;
            crate::obs::install_panic_dump(&core.obs().recorder);
            OutboxFlow {
                enqueued: reg.counter(
                    "m2ru_outbox_frames_enqueued_total",
                    "frames enqueued into per-connection writer outboxes",
                ),
                written: reg.counter(
                    "m2ru_outbox_frames_written_total",
                    "frames written to client sockets by writer threads",
                ),
            }
        } else {
            OutboxFlow::default()
        };

        // acceptor + per-connection readers feed one bounded channel
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Event>(opts.run.net.queue_depth.max(1));
        let acceptor = conn::spawn_acceptor::<Event>(
            listener.try_clone()?,
            tx.clone(),
            stop.clone(),
            opts.run.net.outbox_depth.max(1),
            flow.clone(),
        );
        if opts.run.net.tick_ms > 0 {
            // wall-clock tick source (required when client_admin is off);
            // dies on its own once the receiver is gone — never joined
            let period = std::time::Duration::from_millis(opts.run.net.tick_ms);
            let tick_tx = tx.clone();
            let tick_stop = stop.clone();
            std::thread::spawn(move || loop {
                std::thread::sleep(period);
                if tick_stop.load(Ordering::SeqCst) || tick_tx.send(Event::Tick).is_err() {
                    return;
                }
            });
        }
        drop(tx);

        // ---- the serve thread (this thread) -----------------------------
        let start = Instant::now();
        let mut table = ConnTable::new();
        table.flow = flow;
        table.recorder = core.obs().enabled().then(|| core.obs().recorder.clone());
        let mut total_conns: u64 = 0;
        let nx = opts.net.nx;
        let ny = opts.net.ny;
        let client_admin = opts.run.net.client_admin;
        // a connection may hold at most one store's worth of session
        // bindings — bounds the owner map under a Hello flood
        let bind_cap = opts.run.serve.capacity;
        let checkpoint_every = opts.run.net.checkpoint_every;
        let policy = SnapshotPolicy::from_net(&opts.run.net)?;
        let serve_result = (|| -> Result<()> {
            while let Ok(ev) = rx.recv() {
                let ev = match ev {
                    Event::Tick => {
                        // wall-clock pulse: one driver-loop iteration
                        let done = core.drain_ready()?;
                        table.route_logits(done);
                        core.advance_tick();
                        table.obs_tick = core.tick();
                        if checkpoint_every > 0 && core.tick() % checkpoint_every == 0 {
                            if let Some(dir) = &ckpt_dir {
                                core.snapshot_async(dir, &policy)?;
                            }
                        }
                        continue;
                    }
                    Event::Conn(ev) => ev,
                };
                match ev {
                    ConnEvent::Connected { conn, ctl, outbox, writer } => {
                        table.connected(conn, ctl, outbox, writer);
                        total_conns += 1;
                    }
                    ConnEvent::Disconnected { conn } => {
                        table.forget(conn);
                    }
                    ConnEvent::WriterFailed { conn, timeout } => {
                        table.writer_failed(conn, timeout);
                    }
                    ConnEvent::Malformed { conn, error } => {
                        table.drop_conn(conn, &error);
                    }
                    ConnEvent::Frame { conn, frame } => {
                        let Frame { flags, msg } = frame;
                        // without client administration, clients cannot
                        // drive the clock (the ticker does)
                        let flags = if client_admin { flags } else { 0 };
                        // 1. steps enqueue before their flags act. A
                        //    protocol-violating frame drops its own
                        //    connection but its flags still drive the
                        //    clock below — one client's bad frame must
                        //    not stall other clients' queued requests.
                        let mut shutdown = false;
                        match msg {
                            Message::Step { session, x } => {
                                if let Some(reason) = conn::step_violation(
                                    table.owns(conn, session),
                                    x.len(),
                                    nx,
                                    None,
                                    ny,
                                ) {
                                    table.drop_conn(conn, &reason);
                                } else {
                                    core.submit(session, x, None, conn);
                                }
                            }
                            Message::StepLabeled { session, label, x } => {
                                if let Some(reason) = conn::step_violation(
                                    table.owns(conn, session),
                                    x.len(),
                                    nx,
                                    Some(label),
                                    ny,
                                ) {
                                    table.drop_conn(conn, &reason);
                                } else {
                                    core.submit(session, x, Some(label as usize), conn);
                                }
                            }
                            // a plain server has no routing epochs: the
                            // client's proposed epoch is ignored and the
                            // ack reports epoch 0
                            Message::Hello { user, epoch: _ } => {
                                let sid = session_id_keyed(user, core.session_secret());
                                match table.bind(conn, sid, bind_cap) {
                                    Ok(()) => {
                                        // scenario runs: the tenant class is
                                        // a pure function of the user key
                                        // (reconnector uids stride by a
                                        // multiple of the class count), so
                                        // the server recovers it at Hello
                                        // with no wire change
                                        let classes = core.tenant_classes() as u64;
                                        if classes > 0 {
                                            core.register_session_class(
                                                sid,
                                                (user % classes) as usize,
                                            );
                                        }
                                        table.send(conn, &Message::Ack { value: sid, epoch: 0 });
                                    }
                                    Err(reason) => table.drop_conn(conn, &reason),
                                }
                            }
                            Message::Migrate { session, payload } => {
                                if !client_admin {
                                    table.drop_conn(
                                        conn,
                                        "Migrate from a client (net.client_admin is off)",
                                    );
                                } else if payload.is_empty() {
                                    // extract: ship the session out as a
                                    // sealed parcel (empty = not resident)
                                    match extract_parcel(&mut core, session) {
                                        Ok(parcel) => table.send(
                                            conn,
                                            &Message::Migrate {
                                                session,
                                                payload: parcel.unwrap_or_default(),
                                            },
                                        ),
                                        // steps still queued for the
                                        // session: the requester failed to
                                        // quiesce — a protocol violation,
                                        // not a server fault
                                        Err(e) => table.drop_conn(conn, &e.to_string()),
                                    }
                                } else {
                                    // inject: install the parcel under
                                    // *this* server's session id; a parcel
                                    // that fails its checksum/shape checks
                                    // installs nothing
                                    match inject_parcel(&mut core, session, &payload) {
                                        Ok(_slot) => table.send(
                                            conn,
                                            &Message::Migrate { session, payload: Vec::new() },
                                        ),
                                        Err(e) => table.drop_conn(conn, &e.to_string()),
                                    }
                                }
                            }
                            Message::Drain { .. } | Message::Epoch { .. } => {
                                table.drop_conn(
                                    conn,
                                    "router-plane frame (Drain/Epoch) sent to a plain server",
                                );
                            }
                            Message::Stats { .. } => {
                                let sessions = core.store().len();
                                let mut rep = core.report(sessions)?;
                                rep.outbox_drops = table.drops.clone();
                                // deterministic key=value lines (stable
                                // order, machine-parseable); human-format
                                // `lines()` stays on the CLI exit path
                                let text = rep.kv_lines().join("\n");
                                table.send(conn, &Message::Stats { text });
                            }
                            Message::MetricsDump { text: selector } => {
                                if core.obs().enabled() {
                                    let reg = core.obs().registry.clone();
                                    reg.gauge(
                                        "m2ru_outbox_occupancy",
                                        "frames currently queued in writer outboxes",
                                    )
                                    .set(table.flow.occupancy() as f64);
                                    let d = &table.drops;
                                    for (name, v) in [
                                        ("m2ru_outbox_drops_full_total", d.full),
                                        ("m2ru_outbox_drops_timeout_total", d.timeout),
                                        ("m2ru_outbox_drops_writer_failed_total", d.writer_failed),
                                    ] {
                                        reg.counter(name, "connections severed by outbox reason")
                                            .set(v);
                                    }
                                }
                                let text = core.metrics_text(&selector)?;
                                table.send(conn, &Message::MetricsDump { text });
                            }
                            Message::Shutdown => {
                                if client_admin {
                                    shutdown = true;
                                } else {
                                    table.drop_conn(
                                        conn,
                                        "Shutdown from a client (net.client_admin is off)",
                                    );
                                }
                            }
                            // a clock carrier: nothing to do beyond the
                            // flag handling below
                            Message::Nop => {}
                            Message::Ack { .. } | Message::Logits { .. } => {
                                table.drop_conn(conn, "client sent a server-only message");
                            }
                        }
                        // 2. flags drive the deterministic clock, exactly
                        //    one driver-loop iteration per FLAG_TICK wave
                        let mut done: Vec<CompletedStep> = Vec::new();
                        if flags & FLAG_TICK != 0 {
                            done.extend(core.drain_ready()?);
                        }
                        if shutdown || flags & FLAG_FLUSH != 0 {
                            done.extend(core.flush_all()?);
                        }
                        table.route_logits(done);
                        if flags & FLAG_TICK != 0 {
                            core.advance_tick();
                            table.obs_tick = core.tick();
                            if checkpoint_every > 0 && core.tick() % checkpoint_every == 0 {
                                if let Some(dir) = &ckpt_dir {
                                    core.snapshot_async(dir, &policy)?;
                                }
                            }
                        }
                        if shutdown {
                            table.send(
                                conn,
                                &Message::Ack { value: core.metrics().requests, epoch: 0 },
                            );
                            return Ok(());
                        }
                    }
                }
            }
            Ok(())
        })();

        // ---- teardown ---------------------------------------------------
        stop.store(true, Ordering::SeqCst);
        // drop the receiver FIRST: any acceptor/reader blocked in send()
        // on the full bounded channel errors out immediately instead of
        // deadlocking the acceptor join below
        drop(rx);
        if conn::wake_acceptor(&listener) {
            let _ = acceptor.join();
        }
        // closing the write halves unblocks client readers (and joins
        // every per-connection writer thread)
        table.close_all();
        serve_result?;

        core.set_wall(start.elapsed());
        core.drain_engine();
        // queue the final snapshot, then stop the committer — `finish`
        // completes every queued job (commits and snapshot writes) and
        // surfaces any write failure before we report success
        let checkpoint_path = match &ckpt_dir {
            Some(dir) => {
                let planned = core.snapshot_async(dir, &policy)?;
                core.finish()?;
                Some(planned)
            }
            None => {
                core.finish()?;
                None
            }
        };
        let sessions = core.store().len();
        let mut report = core.report(sessions)?;
        report.outbox_drops = table.drops.clone();
        Ok(NetServeReport { report, connections: total_conns, checkpoint_path, restored_sessions })
    }
}

/// Convenience wrapper: bind, print nothing, serve until shutdown.
pub fn run_net_serve(opts: &NetServeOptions) -> Result<NetServeReport> {
    NetServer::bind(opts.clone())?.run()
}

// Integration coverage lives in `tests/net_roundtrip.rs` (loopback
// equivalence against the in-process driver, restart resumption, codec
// fuzz cases); unit tests here would need real sockets too and would
// duplicate that.

/// The snapshot a checkpoint directory holds — see
/// [`crate::serve::checkpoint`] for the format.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(crate::serve::SNAPSHOT_FILE)
}
