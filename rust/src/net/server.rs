//! TCP serving frontend: a multi-client accept loop feeding one
//! deterministic serve thread over a bounded channel (DESIGN.md §9).
//!
//! ## Threading
//!
//! ```text
//! acceptor thread ──spawns──> reader thread (per connection)
//!                                   │  wire::read_frame
//!                                   ▼
//!                  std::sync::mpsc::sync_channel (bounded: back-pressure)
//!                                   │
//!                                   ▼
//!                   serve thread: ServeCore (store/batcher/learner)
//!                                   │  writes Logits/Ack/Stats frames
//!                                   ▼
//!                    per-connection cloned TcpStream writers
//! ```
//!
//! Readers block when the serve loop falls behind (`net.queue_depth`
//! frames in flight), which propagates back-pressure to clients through
//! TCP flow control instead of buffering unboundedly.
//!
//! ## Determinism
//!
//! The serve thread is the only thread touching serving state, and it
//! advances the logical clock exactly when a frame carries `FLAG_TICK` —
//! so a single client replaying the synthetic driver's admission
//! schedule (one wave per tick, `FLAG_TICK` on the wave's last frame,
//! `FLAG_FLUSH` on the run's last frame) reproduces the in-process
//! driver's batches, commits and logits bit-for-bit. The loopback test
//! in `tests/net_roundtrip.rs` asserts exactly that.
//!
//! ## Durability
//!
//! With a checkpoint directory configured, the server restores the last
//! snapshot on boot (corrupt snapshots warn and boot fresh), snapshots
//! every `net.checkpoint_every` ticks, and always snapshots on shutdown —
//! a kill/restart resumes every live session's hidden state bitwise.

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{NetConfig, RunConfig};
use crate::serve::{
    save_checkpoint, session_id_for_user, try_restore, CompletedStep, RestoreOutcome, ServeCore,
    ServeReport,
};

use super::wire::{self, Frame, Message, FLAG_FLUSH, FLAG_TICK};

/// One network serve run, fully specified.
#[derive(Clone, Debug)]
pub struct NetServeOptions {
    /// Network shapes (must match what clients stream).
    pub net: NetConfig,
    /// Backend, workers, seed, `[serve]` policy and `[net]` transport
    /// policy (queue depth, checkpointing).
    pub run: RunConfig,
    /// Listen address (`host:port`; port 0 picks a free port).
    pub listen: String,
}

impl NetServeOptions {
    pub fn new(net: NetConfig, run: RunConfig, listen: impl Into<String>) -> NetServeOptions {
        NetServeOptions { net, run, listen: listen.into() }
    }
}

/// Outcome of a network serve run (after a client sent `Shutdown`).
pub struct NetServeReport {
    /// The usual serve report (metrics include any restored history).
    pub report: ServeReport,
    /// Connections accepted over the run.
    pub connections: u64,
    /// Where the final snapshot landed (durability enabled only).
    pub checkpoint_path: Option<PathBuf>,
    /// Sessions restored from a snapshot at boot.
    pub restored_sessions: usize,
}

/// Events the connection threads feed the serve thread.
enum Event {
    Connected { conn: u64, writer: TcpStream },
    Frame { conn: u64, frame: Frame },
    Disconnected { conn: u64 },
    Malformed { conn: u64, error: String },
}

/// A bound TCP serving frontend. `bind` then `run`; `local_addr` exposes
/// the picked port so tests and scripts can use `--listen 127.0.0.1:0`.
pub struct NetServer {
    listener: TcpListener,
    opts: NetServeOptions,
}

impl NetServer {
    pub fn bind(opts: NetServeOptions) -> Result<NetServer> {
        opts.run.validate()?;
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding {}", opts.listen))?;
        Ok(NetServer { listener, opts })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `Shutdown`. Blocking; spawn a thread to
    /// run it in the background.
    pub fn run(self) -> Result<NetServeReport> {
        let NetServer { listener, opts } = self;
        let mut core = ServeCore::new(opts.net, &opts.run)?;

        // durable boot: restore the last snapshot if one exists
        let ckpt_dir: Option<PathBuf> = if opts.run.net.checkpoint_dir.is_empty() {
            None
        } else {
            Some(PathBuf::from(&opts.run.net.checkpoint_dir))
        };
        let mut restored_sessions = 0;
        if let Some(dir) = &ckpt_dir {
            match try_restore(&mut core, dir)? {
                RestoreOutcome::Restored { sessions, tick } => {
                    restored_sessions = sessions;
                    eprintln!("restored {sessions} session(s) at tick {tick} from {}", dir.display());
                }
                RestoreOutcome::Corrupt { error } => {
                    eprintln!("warning: ignoring corrupt checkpoint ({error}); booting fresh");
                }
                RestoreOutcome::Fresh => {}
            }
        }

        // acceptor + per-connection readers feed one bounded channel
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Event>(opts.run.net.queue_depth.max(1));
        let acceptor = spawn_acceptor(listener.try_clone()?, tx.clone(), stop.clone());
        drop(tx);

        // ---- the serve thread (this thread) -----------------------------
        let start = Instant::now();
        let mut conns: HashMap<u64, TcpStream> = HashMap::new();
        let mut total_conns: u64 = 0;
        let nx = opts.net.nx;
        let checkpoint_every = opts.run.net.checkpoint_every;
        let serve_result = (|| -> Result<()> {
            while let Ok(ev) = rx.recv() {
                match ev {
                    Event::Connected { conn, writer } => {
                        conns.insert(conn, writer);
                        total_conns += 1;
                    }
                    Event::Disconnected { conn } => {
                        conns.remove(&conn);
                    }
                    Event::Malformed { conn, error } => {
                        eprintln!("net: dropping connection {conn}: {error}");
                        if let Some(s) = conns.remove(&conn) {
                            let _ = s.shutdown(std::net::Shutdown::Both);
                        }
                    }
                    Event::Frame { conn, frame } => {
                        let Frame { flags, msg } = frame;
                        // 1. steps enqueue before their flags act. A
                        //    protocol-violating frame drops its own
                        //    connection but its flags still drive the
                        //    clock below — one client's bad frame must
                        //    not stall other clients' queued requests.
                        let mut shutdown = false;
                        match msg {
                            Message::Step { session, x } => {
                                if x.len() != nx {
                                    drop_protocol_violation(&mut conns, conn, x.len(), nx);
                                } else {
                                    core.submit(session, x, None, conn);
                                }
                            }
                            Message::StepLabeled { session, label, x } => {
                                if x.len() != nx {
                                    drop_protocol_violation(&mut conns, conn, x.len(), nx);
                                } else {
                                    core.submit(session, x, Some(label as usize), conn);
                                }
                            }
                            Message::Hello { user } => {
                                let sid = session_id_for_user(user);
                                send_to(&mut conns, conn, &Message::Ack { value: sid });
                            }
                            Message::Stats { .. } => {
                                let text =
                                    core.report(core.store().len()).lines().join("\n");
                                send_to(&mut conns, conn, &Message::Stats { text });
                            }
                            Message::Shutdown => shutdown = true,
                            Message::Ack { .. } | Message::Logits { .. } => {
                                eprintln!(
                                    "net: client {conn} sent a server-only message; dropping it"
                                );
                                if let Some(s) = conns.remove(&conn) {
                                    let _ = s.shutdown(std::net::Shutdown::Both);
                                }
                            }
                        }
                        // 2. flags drive the deterministic clock, exactly
                        //    one driver-loop iteration per FLAG_TICK wave
                        let mut done: Vec<CompletedStep> = Vec::new();
                        if flags & FLAG_TICK != 0 {
                            done.extend(core.drain_ready()?);
                        }
                        if shutdown || flags & FLAG_FLUSH != 0 {
                            done.extend(core.flush_all()?);
                        }
                        route_logits(&mut conns, done);
                        if flags & FLAG_TICK != 0 {
                            core.advance_tick();
                            if checkpoint_every > 0 && core.tick() % checkpoint_every == 0 {
                                if let Some(dir) = &ckpt_dir {
                                    save_checkpoint(&core, dir)?;
                                }
                            }
                        }
                        if shutdown {
                            send_to(
                                &mut conns,
                                conn,
                                &Message::Ack { value: core.metrics().requests },
                            );
                            return Ok(());
                        }
                    }
                }
            }
            Ok(())
        })();

        // ---- teardown ---------------------------------------------------
        stop.store(true, Ordering::SeqCst);
        // drop the receiver FIRST: any acceptor/reader blocked in send()
        // on the full bounded channel errors out immediately instead of
        // deadlocking the acceptor join below
        drop(rx);
        // wake the blocking accept with a throwaway connection; when
        // bound to an unspecified address (0.0.0.0 / ::), connect via
        // loopback instead. If the wake fails, do NOT join — shutdown
        // (and the final checkpoint) must not hang on a blocked accept;
        // the acceptor dies with the process.
        let woke = match listener.local_addr() {
            Ok(mut addr) => {
                if addr.ip().is_unspecified() {
                    let ip = match addr.ip() {
                        std::net::IpAddr::V4(_) => {
                            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                        }
                        std::net::IpAddr::V6(_) => {
                            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                        }
                    };
                    addr.set_ip(ip);
                }
                TcpStream::connect(addr).is_ok()
            }
            Err(_) => false,
        };
        if woke {
            let _ = acceptor.join();
        }
        // closing the write halves unblocks client readers
        for (_, s) in conns.drain() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        serve_result?;

        core.set_wall(start.elapsed());
        core.drain_engine();
        let checkpoint_path = match &ckpt_dir {
            Some(dir) => Some(save_checkpoint(&core, dir)?),
            None => None,
        };
        let report = core.report(core.store().len());
        Ok(NetServeReport { report, connections: total_conns, checkpoint_path, restored_sessions })
    }
}

/// Accept connections until stopped; one reader thread per connection.
fn spawn_acceptor(
    listener: TcpListener,
    tx: SyncSender<Event>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut next_conn: u64 = 1;
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let _ = stream.set_nodelay(true);
            let conn = next_conn;
            next_conn += 1;
            let writer = match stream.try_clone() {
                Ok(w) => w,
                Err(_) => continue,
            };
            // bounded writes: a client that stops reading its socket must
            // not freeze the single serve thread — after the timeout the
            // write errors and the connection is dropped
            let _ = writer.set_write_timeout(Some(std::time::Duration::from_secs(10)));
            if tx.send(Event::Connected { conn, writer }).is_err() {
                return;
            }
            let reader_tx = tx.clone();
            let mut reader = stream;
            std::thread::spawn(move || loop {
                match wire::read_frame(&mut reader) {
                    Ok(Some(frame)) => {
                        if reader_tx.send(Event::Frame { conn, frame }).is_err() {
                            return;
                        }
                    }
                    Ok(None) => {
                        let _ = reader_tx.send(Event::Disconnected { conn });
                        return;
                    }
                    Err(e) => {
                        let _ = reader_tx.send(Event::Malformed { conn, error: e.to_string() });
                        return;
                    }
                }
            });
        }
    })
}

/// Return each completed step's logits to the connection it arrived on
/// (consumes the steps — the logits rows move into the frames).
fn route_logits(conns: &mut HashMap<u64, TcpStream>, done: Vec<CompletedStep>) {
    for step in done {
        let msg = Message::Logits {
            session: step.session,
            pred: step.pred as u32,
            logits: step.logits,
        };
        send_to(conns, step.tag, &msg);
    }
}

/// Best-effort frame write; a dead peer just drops out of the conn map
/// (its reader thread reports the disconnect separately).
fn send_to(conns: &mut HashMap<u64, TcpStream>, conn: u64, msg: &Message) {
    if let Some(s) = conns.get_mut(&conn) {
        let buf = wire::encode_frame(0, msg);
        if s.write_all(&buf).is_err() {
            conns.remove(&conn);
        }
    }
}

fn drop_protocol_violation(conns: &mut HashMap<u64, TcpStream>, conn: u64, got: usize, want: usize) {
    eprintln!("net: connection {conn} sent a step of width {got} (net expects {want}); dropping");
    if let Some(s) = conns.remove(&conn) {
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
}

/// Convenience wrapper: bind, print nothing, serve until shutdown.
pub fn run_net_serve(opts: &NetServeOptions) -> Result<NetServeReport> {
    NetServer::bind(opts.clone())?.run()
}

// Integration coverage lives in `tests/net_roundtrip.rs` (loopback
// equivalence against the in-process driver, restart resumption, codec
// fuzz cases); unit tests here would need real sockets too and would
// duplicate that.

/// The snapshot a checkpoint directory holds — see
/// [`crate::serve::checkpoint`] for the format.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(crate::serve::SNAPSHOT_FILE)
}
