//! TCP serving frontend: a multi-client accept loop feeding one
//! deterministic serve thread over a bounded channel (DESIGN.md §9–§10).
//!
//! ## Threading
//!
//! ```text
//! acceptor thread ──spawns──> reader thread (per connection)
//!                                   │  wire::read_frame
//!                                   ▼
//!                  std::sync::mpsc::sync_channel (bounded: back-pressure)
//!                                   │
//!                                   ▼
//!                   serve thread: ServeCore (store/batcher/learner)
//!                    │ commits+snapshots       │ encoded frames
//!                    ▼                         ▼
//!            committer thread        per-connection writer threads
//!            (owns the weights)      (bounded outbox each)
//! ```
//!
//! Readers block when the serve loop falls behind (`net.queue_depth`
//! frames in flight), which propagates back-pressure to clients through
//! TCP flow control instead of buffering unboundedly.
//!
//! The serve thread never touches a socket, the weights, or the disk:
//! responses are queued (non-blocking) to one **writer thread per
//! connection** with a bounded outbox of `net.outbox_depth` frames — a
//! stalled or dead peer fills its own outbox and is dropped, without
//! adding a microsecond to any other client's latency — while weight
//! commits and durable snapshots run on the committer thread inside
//! [`ServeCore`] (see `serve::commit`).
//!
//! ## Determinism
//!
//! The serve thread is the only thread touching serving state, and it
//! advances the logical clock exactly when a frame carries `FLAG_TICK` —
//! so a single client replaying the synthetic driver's admission
//! schedule (one wave per tick, `FLAG_TICK` on the wave's last frame,
//! `FLAG_FLUSH` on the run's last frame) reproduces the in-process
//! driver's batches, commits and logits bit-for-bit. The loopback test
//! in `tests/net_roundtrip.rs` asserts exactly that.
//!
//! ## Durability
//!
//! With a checkpoint directory configured, the server restores the last
//! snapshot on boot (corrupt snapshots warn and boot fresh), snapshots
//! every `net.checkpoint_every` ticks, and always snapshots on shutdown —
//! a kill/restart resumes every live session's hidden state bitwise.
//!
//! ## Trust model
//!
//! Session ids are a keyed hash of the user key under a random per-boot
//! secret (persisted in checkpoints, so restored sessions keep their
//! ids; see [`session_id_keyed`] for what the keying does and does not
//! guarantee). The enforcement boundary is *connection binding*: a
//! session belongs to the connection that established it with `Hello`,
//! and `Step` frames for a session this connection never established,
//! an out-of-range label, or a `Hello` for a session bound to another
//! *live* connection are protocol violations that drop the offending
//! connection without touching serving state. Every path that loses a
//! connection — clean EOF, violation, failed write to a dead peer —
//! releases its bindings, so a session whose holder is known to be gone
//! can be re-established by a fresh `Hello`; and each connection may
//! hold at most `serve.capacity` bindings, so the binding table stays
//! bounded under a Hello flood.
//!
//! Client administration — `Shutdown` frames and the TICK/FLUSH clock
//! flags — is on by default, which suits the loopback harness and
//! closed-loop benches where the single client *is* the operator. For a
//! server exposed to untrusted clients, set `net.client_admin = false`
//! and a `net.tick_ms` period: client flags are then ignored, `Shutdown`
//! becomes a protocol violation, and a server-side timer drives the
//! logical clock (batching, TTL expiry, checkpoint cadence) instead.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{NetConfig, RunConfig};
use crate::serve::{
    session_id_keyed, try_restore, CompletedStep, RestoreOutcome, ServeCore, ServeReport,
    SnapshotPolicy,
};

use super::wire::{self, Frame, Message, FLAG_FLUSH, FLAG_TICK};

/// One network serve run, fully specified.
#[derive(Clone, Debug)]
pub struct NetServeOptions {
    /// Network shapes (must match what clients stream).
    pub net: NetConfig,
    /// Backend, workers, seed, `[serve]` policy and `[net]` transport
    /// policy — including `net.listen`, the single source of truth for
    /// the listen address (`host:port`; port 0 picks a free port).
    pub run: RunConfig,
}

impl NetServeOptions {
    /// Build options, overriding `run.net.listen` with `listen`.
    pub fn new(net: NetConfig, run: RunConfig, listen: impl Into<String>) -> NetServeOptions {
        let mut run = run;
        run.net.listen = listen.into();
        NetServeOptions { net, run }
    }
}

/// Outcome of a network serve run (after a client sent `Shutdown`).
pub struct NetServeReport {
    /// The usual serve report (metrics include any restored history).
    pub report: ServeReport,
    /// Connections accepted over the run.
    pub connections: u64,
    /// Where the final snapshot landed (durability enabled only).
    pub checkpoint_path: Option<PathBuf>,
    /// Sessions restored from a snapshot at boot.
    pub restored_sessions: usize,
}

/// Events the connection threads (and the optional ticker) feed the
/// serve thread.
enum Event {
    Connected {
        conn: u64,
        /// Control handle on the socket (shutdown on drop/violation).
        ctl: TcpStream,
        /// Bounded outbox feeding the connection's writer thread.
        outbox: SyncSender<Vec<u8>>,
        /// The writer thread, joined at teardown.
        writer: JoinHandle<()>,
    },
    Frame { conn: u64, frame: Frame },
    Disconnected { conn: u64 },
    Malformed { conn: u64, error: String },
    /// The connection's writer thread hit a socket write error (dead or
    /// stalled peer): the connection must be *severed*, not just
    /// forgotten — its reader may still be alive on the open socket.
    WriterFailed { conn: u64 },
    /// Server-driven clock pulse (`net.tick_ms` mode).
    Tick,
}

/// A random 64-bit per-boot key for the session-id space, drawn from the
/// standard library's hash seeding (OS entropy, no new dependencies).
fn random_boot_secret() -> u64 {
    use std::hash::{BuildHasher, Hasher};
    let a = std::collections::hash_map::RandomState::new().build_hasher().finish();
    let b = std::collections::hash_map::RandomState::new().build_hasher().finish();
    a ^ b.rotate_left(32)
}

/// A bound TCP serving frontend. `bind` then `run`; `local_addr` exposes
/// the picked port so tests and scripts can use `--listen 127.0.0.1:0`.
pub struct NetServer {
    listener: TcpListener,
    opts: NetServeOptions,
}

impl NetServer {
    pub fn bind(opts: NetServeOptions) -> Result<NetServer> {
        opts.run.validate()?;
        let listener = TcpListener::bind(&opts.run.net.listen)
            .with_context(|| format!("binding {}", opts.run.net.listen))?;
        Ok(NetServer { listener, opts })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `Shutdown`. Blocking; spawn a thread to
    /// run it in the background.
    pub fn run(self) -> Result<NetServeReport> {
        let NetServer { listener, opts } = self;
        let mut core = ServeCore::new(opts.net, &opts.run)?;

        // durable boot: restore the last snapshot if one exists
        let ckpt_dir: Option<PathBuf> = if opts.run.net.checkpoint_dir.is_empty() {
            None
        } else {
            Some(PathBuf::from(&opts.run.net.checkpoint_dir))
        };
        let mut restored_sessions = 0;
        let mut restored = false;
        if let Some(dir) = &ckpt_dir {
            match try_restore(&mut core, dir)? {
                RestoreOutcome::Restored { sessions, tick, deltas } => {
                    restored_sessions = sessions;
                    restored = true;
                    eprintln!(
                        "restored {sessions} session(s) at tick {tick} ({deltas} delta snapshot(s) applied) from {}",
                        dir.display()
                    );
                }
                RestoreOutcome::Corrupt { error } => {
                    eprintln!("warning: ignoring corrupt checkpoint ({error}); booting fresh");
                }
                RestoreOutcome::Fresh => {}
            }
        }
        // fresh boots key the session-id space with a random secret so
        // clients cannot compute each other's session ids; a restore
        // keeps the checkpointed key so existing session ids stay valid
        if !restored {
            core.set_session_secret(random_boot_secret());
        }

        // acceptor + per-connection readers feed one bounded channel
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<Event>(opts.run.net.queue_depth.max(1));
        let acceptor = spawn_acceptor(
            listener.try_clone()?,
            tx.clone(),
            stop.clone(),
            opts.run.net.outbox_depth.max(1),
        );
        if opts.run.net.tick_ms > 0 {
            // wall-clock tick source (required when client_admin is off);
            // dies on its own once the receiver is gone — never joined
            let period = std::time::Duration::from_millis(opts.run.net.tick_ms);
            let tick_tx = tx.clone();
            let tick_stop = stop.clone();
            std::thread::spawn(move || loop {
                std::thread::sleep(period);
                if tick_stop.load(Ordering::SeqCst) || tick_tx.send(Event::Tick).is_err() {
                    return;
                }
            });
        }
        drop(tx);

        // ---- the serve thread (this thread) -----------------------------
        let start = Instant::now();
        let mut table = ConnTable::new();
        let mut total_conns: u64 = 0;
        let nx = opts.net.nx;
        let ny = opts.net.ny;
        let client_admin = opts.run.net.client_admin;
        // a connection may hold at most one store's worth of session
        // bindings — bounds the owner map under a Hello flood
        let bind_cap = opts.run.serve.capacity;
        let checkpoint_every = opts.run.net.checkpoint_every;
        let policy = SnapshotPolicy::from_net(&opts.run.net)?;
        let serve_result = (|| -> Result<()> {
            while let Ok(ev) = rx.recv() {
                match ev {
                    Event::Connected { conn, ctl, outbox, writer } => {
                        table.connected(conn, ctl, outbox, writer);
                        total_conns += 1;
                    }
                    Event::Disconnected { conn } => {
                        table.forget(conn);
                    }
                    Event::WriterFailed { conn } => {
                        table.drop_conn(conn, "response write failed (dead or stalled peer)");
                    }
                    Event::Malformed { conn, error } => {
                        table.drop_conn(conn, &error);
                    }
                    Event::Tick => {
                        // wall-clock pulse: one driver-loop iteration
                        let done = core.drain_ready()?;
                        table.route_logits(done);
                        core.advance_tick();
                        if checkpoint_every > 0 && core.tick() % checkpoint_every == 0 {
                            if let Some(dir) = &ckpt_dir {
                                core.snapshot_async(dir, &policy)?;
                            }
                        }
                    }
                    Event::Frame { conn, frame } => {
                        let Frame { flags, msg } = frame;
                        // without client administration, clients cannot
                        // drive the clock (the ticker does)
                        let flags = if client_admin { flags } else { 0 };
                        // 1. steps enqueue before their flags act. A
                        //    protocol-violating frame drops its own
                        //    connection but its flags still drive the
                        //    clock below — one client's bad frame must
                        //    not stall other clients' queued requests.
                        let mut shutdown = false;
                        match msg {
                            Message::Step { session, x } => {
                                if let Some(reason) = step_violation(
                                    table.owns(conn, session),
                                    x.len(),
                                    nx,
                                    None,
                                    ny,
                                ) {
                                    table.drop_conn(conn, &reason);
                                } else {
                                    core.submit(session, x, None, conn);
                                }
                            }
                            Message::StepLabeled { session, label, x } => {
                                if let Some(reason) = step_violation(
                                    table.owns(conn, session),
                                    x.len(),
                                    nx,
                                    Some(label),
                                    ny,
                                ) {
                                    table.drop_conn(conn, &reason);
                                } else {
                                    core.submit(session, x, Some(label as usize), conn);
                                }
                            }
                            Message::Hello { user } => {
                                let sid = session_id_keyed(user, core.session_secret());
                                match table.bind(conn, sid, bind_cap) {
                                    Ok(()) => {
                                        table.send(conn, &Message::Ack { value: sid });
                                    }
                                    Err(reason) => table.drop_conn(conn, &reason),
                                }
                            }
                            Message::Stats { .. } => {
                                let sessions = core.store().len();
                                let text = core.report(sessions)?.lines().join("\n");
                                table.send(conn, &Message::Stats { text });
                            }
                            Message::Shutdown => {
                                if client_admin {
                                    shutdown = true;
                                } else {
                                    table.drop_conn(
                                        conn,
                                        "Shutdown from a client (net.client_admin is off)",
                                    );
                                }
                            }
                            Message::Ack { .. } | Message::Logits { .. } => {
                                table.drop_conn(conn, "client sent a server-only message");
                            }
                        }
                        // 2. flags drive the deterministic clock, exactly
                        //    one driver-loop iteration per FLAG_TICK wave
                        let mut done: Vec<CompletedStep> = Vec::new();
                        if flags & FLAG_TICK != 0 {
                            done.extend(core.drain_ready()?);
                        }
                        if shutdown || flags & FLAG_FLUSH != 0 {
                            done.extend(core.flush_all()?);
                        }
                        table.route_logits(done);
                        if flags & FLAG_TICK != 0 {
                            core.advance_tick();
                            if checkpoint_every > 0 && core.tick() % checkpoint_every == 0 {
                                if let Some(dir) = &ckpt_dir {
                                    core.snapshot_async(dir, &policy)?;
                                }
                            }
                        }
                        if shutdown {
                            table.send(conn, &Message::Ack { value: core.metrics().requests });
                            return Ok(());
                        }
                    }
                }
            }
            Ok(())
        })();

        // ---- teardown ---------------------------------------------------
        stop.store(true, Ordering::SeqCst);
        // drop the receiver FIRST: any acceptor/reader blocked in send()
        // on the full bounded channel errors out immediately instead of
        // deadlocking the acceptor join below
        drop(rx);
        // wake the blocking accept with a throwaway connection; when
        // bound to an unspecified address (0.0.0.0 / ::), connect via
        // loopback instead. If the wake fails, do NOT join — shutdown
        // (and the final checkpoint) must not hang on a blocked accept;
        // the acceptor dies with the process.
        let woke = match listener.local_addr() {
            Ok(mut addr) => {
                if addr.ip().is_unspecified() {
                    let ip = match addr.ip() {
                        std::net::IpAddr::V4(_) => {
                            std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                        }
                        std::net::IpAddr::V6(_) => {
                            std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                        }
                    };
                    addr.set_ip(ip);
                }
                TcpStream::connect(addr).is_ok()
            }
            Err(_) => false,
        };
        if woke {
            let _ = acceptor.join();
        }
        // closing the write halves unblocks client readers (and joins
        // every per-connection writer thread)
        table.close_all();
        serve_result?;

        core.set_wall(start.elapsed());
        core.drain_engine();
        // queue the final snapshot, then stop the committer — `finish`
        // completes every queued job (commits and snapshot writes) and
        // surfaces any write failure before we report success
        let checkpoint_path = match &ckpt_dir {
            Some(dir) => {
                let planned = core.snapshot_async(dir, &policy)?;
                core.finish()?;
                Some(planned)
            }
            None => {
                core.finish()?;
                None
            }
        };
        let sessions = core.store().len();
        let report = core.report(sessions)?;
        Ok(NetServeReport { report, connections: total_conns, checkpoint_path, restored_sessions })
    }
}

/// The per-connection writer thread: drain the bounded outbox onto the
/// socket. Exits when the outbox closes (connection forgotten/dropped)
/// or a write fails (dead peer — reported so the serve thread releases
/// the connection's session bindings).
fn writer_loop(conn: u64, mut sock: TcpStream, outbox: Receiver<Vec<u8>>, tx: SyncSender<Event>) {
    use std::io::Write as _;
    for buf in outbox {
        if sock.write_all(&buf).is_err() {
            // best-effort: at teardown the serve thread is gone
            let _ = tx.send(Event::WriterFailed { conn });
            return;
        }
    }
}

/// Accept connections until stopped; one reader thread and one writer
/// thread (with a bounded `outbox_depth`-frame outbox) per connection.
fn spawn_acceptor(
    listener: TcpListener,
    tx: SyncSender<Event>,
    stop: Arc<AtomicBool>,
    outbox_depth: usize,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut next_conn: u64 = 1;
        for stream in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let _ = stream.set_nodelay(true);
            let conn = next_conn;
            next_conn += 1;
            let (ctl, wsock) = match (stream.try_clone(), stream.try_clone()) {
                (Ok(a), Ok(b)) => (a, b),
                _ => continue,
            };
            // backstop only: the serve thread never writes, but the
            // writer thread must not hang forever on a half-dead peer —
            // after the timeout its write errors and the connection dies
            let _ = wsock.set_write_timeout(Some(std::time::Duration::from_secs(10)));
            let (obx_tx, obx_rx) = sync_channel::<Vec<u8>>(outbox_depth);
            let writer_tx = tx.clone();
            let writer =
                std::thread::spawn(move || writer_loop(conn, wsock, obx_rx, writer_tx));
            if tx.send(Event::Connected { conn, ctl, outbox: obx_tx, writer }).is_err() {
                return;
            }
            let reader_tx = tx.clone();
            let mut reader = stream;
            std::thread::spawn(move || loop {
                match wire::read_frame(&mut reader) {
                    Ok(Some(frame)) => {
                        if reader_tx.send(Event::Frame { conn, frame }).is_err() {
                            return;
                        }
                    }
                    Ok(None) => {
                        let _ = reader_tx.send(Event::Disconnected { conn });
                        return;
                    }
                    Err(e) => {
                        let _ = reader_tx.send(Event::Malformed { conn, error: e.to_string() });
                        return;
                    }
                }
            });
        }
    })
}

/// One live connection's serve-side handle: the control socket (for
/// shutdowns), the bounded outbox into its writer thread, and the
/// writer's join handle.
struct ConnEntry {
    ctl: TcpStream,
    outbox: SyncSender<Vec<u8>>,
    writer: JoinHandle<()>,
}

/// Live connections and their session bindings, kept consistent as one
/// unit: every path that loses a connection — clean disconnect, protocol
/// violation, a full outbox or a dead peer — also releases the sessions
/// it had bound, so a reconnecting user can always re-`Hello` their
/// session.
struct ConnTable {
    conns: HashMap<u64, ConnEntry>,
    /// session id → owning connection.
    owner: HashMap<u64, u64>,
    /// connection → bindings held (bounds `owner` under a Hello flood).
    owned: HashMap<u64, usize>,
    /// Writer threads of departed connections. NEVER joined inline — a
    /// dying writer may be blocked reporting its own death into the full
    /// event queue, which only the serve thread drains; joining here
    /// would deadlock. Reaped in `close_all` after the event channel is
    /// gone.
    reap: Vec<JoinHandle<()>>,
}

impl ConnTable {
    fn new() -> ConnTable {
        ConnTable {
            conns: HashMap::new(),
            owner: HashMap::new(),
            owned: HashMap::new(),
            reap: Vec::new(),
        }
    }

    fn connected(&mut self, conn: u64, ctl: TcpStream, outbox: SyncSender<Vec<u8>>, writer: JoinHandle<()>) {
        self.conns.insert(conn, ConnEntry { ctl, outbox, writer });
    }

    /// Release a cleanly-disconnected connection's bookkeeping. The
    /// outbox sender drops, so the writer flushes what is queued and
    /// exits; the socket itself stays open until the writer is done.
    fn forget(&mut self, conn: u64) {
        if let Some(e) = self.conns.remove(&conn) {
            self.reap.push(e.writer);
        }
        if self.owned.remove(&conn).is_some() {
            self.owner.retain(|_, c| *c != conn);
        }
    }

    /// Sever a protocol-violating (or stalled/dead) connection: log,
    /// shut the socket down (which also unblocks its writer), and
    /// release every session bound to it.
    fn drop_conn(&mut self, conn: u64, reason: &str) {
        eprintln!("net: dropping connection {conn}: {reason}");
        if let Some(e) = self.conns.remove(&conn) {
            let _ = e.ctl.shutdown(std::net::Shutdown::Both);
            self.reap.push(e.writer);
        }
        if self.owned.remove(&conn).is_some() {
            self.owner.retain(|_, c| *c != conn);
        }
    }

    /// Did `conn` establish `session` with a `Hello`?
    fn owns(&self, conn: u64, session: u64) -> bool {
        self.owner.get(&session) == Some(&conn)
    }

    /// Bind `sid` to `conn` per the trust rules: idempotent for the
    /// holder, rejected while another *live* connection holds it, taken
    /// over from a connection known to be gone, and capped per
    /// connection so `owner` cannot grow without bound.
    fn bind(&mut self, conn: u64, sid: u64, cap: usize) -> Result<(), String> {
        match self.owner.get(&sid).copied() {
            Some(c) if c == conn => Ok(()),
            Some(c) if self.conns.contains_key(&c) => {
                Err("Hello for a session bound to another live connection".to_string())
            }
            stale => {
                if let Some(c) = stale {
                    // the previous holder is gone; release its slot
                    if let Some(n) = self.owned.get_mut(&c) {
                        *n = n.saturating_sub(1);
                    }
                }
                let n = self.owned.entry(conn).or_insert(0);
                if *n >= cap {
                    return Err(format!("connection exceeded {cap} session bindings"));
                }
                *n += 1;
                self.owner.insert(sid, conn);
                Ok(())
            }
        }
    }

    /// Non-blocking frame dispatch into the connection's writer outbox.
    /// A full outbox means the peer is slow (its writer is stuck on a
    /// full socket) — that connection alone is dropped; the serve thread
    /// never waits on anyone's socket.
    fn send(&mut self, conn: u64, msg: &Message) {
        let Some(e) = self.conns.get(&conn) else { return };
        let buf = wire::encode_frame(0, msg);
        match e.outbox.try_send(buf) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.drop_conn(conn, "response outbox full (slow client)");
            }
            Err(TrySendError::Disconnected(_)) => {
                self.drop_conn(conn, "writer thread gone");
            }
        }
    }

    /// Return each completed step's logits to the connection it arrived
    /// on (consumes the steps — the logits rows move into the frames).
    fn route_logits(&mut self, done: Vec<CompletedStep>) {
        for step in done {
            let msg = Message::Logits {
                session: step.session,
                pred: step.pred as u32,
                logits: step.logits,
            };
            self.send(step.tag, &msg);
        }
    }

    /// Teardown: let every live connection's writer flush its queued
    /// frames (the shutdown Ack, final logits) by closing the outbox and
    /// joining it *before* the socket is shut down — a blocked writer is
    /// bounded by its socket write timeout. Only called after the serve
    /// thread has dropped the event receiver, so no writer can block
    /// reporting its own death.
    fn close_all(&mut self) {
        for (_, e) in self.conns.drain() {
            drop(e.outbox);
            let _ = e.writer.join();
            let _ = e.ctl.shutdown(std::net::Shutdown::Both);
        }
        // writers of already-severed connections (their sockets are shut;
        // they exit as soon as their pending write fails)
        for h in self.reap.drain(..) {
            let _ = h.join();
        }
    }
}

/// Why a Step/StepLabeled frame is a protocol violation, if it is one:
/// wrong input width, a label outside the class range (it would index the
/// one-hot/loss rows out of bounds), or a session this connection never
/// established with `Hello`.
fn step_violation(
    owns: bool,
    got: usize,
    nx: usize,
    label: Option<u32>,
    ny: usize,
) -> Option<String> {
    if got != nx {
        return Some(format!("step of width {got} (net expects {nx})"));
    }
    if let Some(l) = label {
        if l as usize >= ny {
            return Some(format!("label {l} out of range (net has {ny} classes)"));
        }
    }
    if !owns {
        return Some("step for a session this connection did not establish".to_string());
    }
    None
}

/// Convenience wrapper: bind, print nothing, serve until shutdown.
pub fn run_net_serve(opts: &NetServeOptions) -> Result<NetServeReport> {
    NetServer::bind(opts.clone())?.run()
}

// Integration coverage lives in `tests/net_roundtrip.rs` (loopback
// equivalence against the in-process driver, restart resumption, codec
// fuzz cases); unit tests here would need real sockets too and would
// duplicate that.

/// The snapshot a checkpoint directory holds — see
/// [`crate::serve::checkpoint`] for the format.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(crate::serve::SNAPSHOT_FILE)
}
