//! `NetClient` — the Rust client of the TCP serving protocol — and the
//! closed-loop load generator behind `m2ru connect`.
//!
//! The client splits its socket: the calling thread writes frames, a
//! reader thread drains responses into a channel. Keeping the responses
//! drained matters beyond convenience: the server hands each
//! connection's responses to a writer thread with a *bounded* outbox
//! (`net.outbox_depth`), and a client that stops reading eventually
//! jams that writer and is dropped as a slow consumer — by design, so
//! one stalled peer cannot delay anyone else. A `NetClient` that keeps
//! its reader alive is never that peer, and pipelined waves stay
//! deadlock-free.
//!
//! [`run_connect`] replays the synthetic driver's admission schedule
//! over the wire: `arrivals` steps per wave, `FLAG_TICK` on each wave's
//! last frame, `FLAG_FLUSH` on the run's last frame. Against a loopback
//! server with the same seed and policy this produces bit-identical
//! logits to `m2ru serve`'s in-process run — asserted by
//! `tests/net_roundtrip.rs`.

use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{NetConfig, ScenarioConfig};
use crate::serve::SyntheticWorkload;

use super::wire::{self, Frame, Message, FLAG_FLUSH, FLAG_TICK};

/// A connected protocol client.
pub struct NetClient {
    writer: TcpStream,
    rx: Receiver<Frame>,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl NetClient {
    /// Connect and start the response-reader thread.
    pub fn connect(addr: &str) -> Result<NetClient> {
        let writer = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let _ = writer.set_nodelay(true);
        let mut read_half = writer.try_clone().context("cloning socket for the reader")?;
        let (tx, rx) = channel::<Frame>();
        let reader = std::thread::spawn(move || loop {
            match wire::read_frame(&mut read_half) {
                Ok(Some(frame)) => {
                    if tx.send(frame).is_err() {
                        return;
                    }
                }
                // clean EOF or any read error: the connection is done
                _ => return,
            }
        });
        Ok(NetClient { writer, rx, reader: Some(reader) })
    }

    /// Send one frame.
    pub fn send(&mut self, flags: u8, msg: &Message) -> Result<()> {
        wire::write_frame(&mut self.writer, flags, msg)
    }

    /// Block for the next response message.
    pub fn recv(&mut self) -> Result<Message> {
        match self.rx.recv() {
            Ok(frame) => Ok(frame.msg),
            Err(_) => bail!("server closed the connection"),
        }
    }

    /// Non-blocking response poll.
    pub fn try_recv(&mut self) -> Option<Message> {
        self.rx.try_recv().ok().map(|f| f.msg)
    }

    /// Handshake: register `user` and return its server-side session id.
    /// The session is *bound* to this connection — stepping a session id
    /// that this connection never established is a protocol violation
    /// the server answers by dropping the connection.
    pub fn hello(&mut self, user: u64) -> Result<u64> {
        Ok(self.hello_epoch(user)?.0)
    }

    /// Handshake returning `(session_id, routing_epoch)`: a plain server
    /// always reports epoch 0; a router reports the fleet's current
    /// routing epoch, which bumps on every rebalance or drain.
    pub fn hello_epoch(&mut self, user: u64) -> Result<(u64, u64)> {
        self.send(0, &Message::Hello { user, epoch: 0 })?;
        match self.recv()? {
            Message::Ack { value, epoch } => Ok((value, epoch)),
            other => bail!("expected Ack to Hello, got {other:?}"),
        }
    }

    /// Admin query: the router's current routing epoch and logical shard
    /// width (`Epoch` with `shards = 0` changes nothing). Plain servers
    /// treat this frame as a protocol violation.
    pub fn epoch(&mut self) -> Result<(u64, u32)> {
        self.send(0, &Message::Epoch { epoch: 0, shards: 0 })?;
        match self.recv()? {
            Message::Epoch { epoch, shards } => Ok((epoch, shards)),
            other => bail!("expected Epoch, got {other:?}"),
        }
    }

    /// Admin: rebalance the router's fleet to `m` shards (N→M cutover —
    /// bump the epoch, migrate the moved set, replay parked steps).
    /// Blocks until the cutover commits; returns the new
    /// `(epoch, shards)`.
    pub fn rebalance(&mut self, m: u32) -> Result<(u64, u32)> {
        anyhow::ensure!(m >= 1, "cannot rebalance to zero shards");
        self.send(0, &Message::Epoch { epoch: 0, shards: m })?;
        match self.recv()? {
            Message::Epoch { epoch, shards } => Ok((epoch, shards)),
            other => bail!("expected Epoch ack to rebalance, got {other:?}"),
        }
    }

    /// Admin: drain shard `k` — quiesce it, migrate every session off,
    /// checkpoint and retire it, with zero client-visible errors. Blocks
    /// until the drain completes; returns the new `(epoch, shards)`.
    pub fn drain(&mut self, k: u32) -> Result<(u64, u32)> {
        self.send(0, &Message::Drain { shard: k })?;
        match self.recv()? {
            Message::Epoch { epoch, shards } => Ok((epoch, shards)),
            other => bail!("expected Epoch ack to drain, got {other:?}"),
        }
    }

    /// Synchronous single step: send one (optionally labeled) timestep
    /// and wait for its logits. The session id must come from a prior
    /// [`NetClient::hello`] on this connection. Flags force immediate
    /// dispatch, so this is the low-latency interactive path (one tick
    /// per request).
    pub fn step(&mut self, session: u64, x: Vec<f32>, label: Option<u32>) -> Result<(u32, Vec<f32>)> {
        let msg = match label {
            Some(l) => Message::StepLabeled { session, label: l, x },
            None => Message::Step { session, x },
        };
        self.send(FLAG_TICK | FLAG_FLUSH, &msg)?;
        match self.recv()? {
            Message::Logits { pred, logits, .. } => Ok((pred, logits)),
            other => bail!("expected Logits, got {other:?}"),
        }
    }

    /// Fetch the server's serve-report text (deterministic `key=value`
    /// lines, stable order).
    pub fn stats(&mut self) -> Result<String> {
        self.send(0, &Message::Stats { text: String::new() })?;
        match self.recv()? {
            Message::Stats { text } => Ok(text),
            other => bail!("expected Stats, got {other:?}"),
        }
    }

    /// Fetch the server's metrics exposition. `selector` is `""`/`"prom"`
    /// for Prometheus text or `"events"` for the flight-recorder JSONL;
    /// a router answers with per-shard sections plus a fleet rollup.
    pub fn metrics(&mut self, selector: &str) -> Result<String> {
        self.send(0, &Message::MetricsDump { text: String::from(selector) })?;
        match self.recv()? {
            Message::MetricsDump { text } => Ok(text),
            other => bail!("expected MetricsDump, got {other:?}"),
        }
    }

    /// Ask the server to drain, checkpoint and exit; returns its total
    /// served request count.
    pub fn shutdown_server(&mut self) -> Result<u64> {
        self.send(0, &Message::Shutdown)?;
        match self.recv()? {
            Message::Ack { value, .. } => Ok(value),
            other => bail!("expected Ack to Shutdown, got {other:?}"),
        }
    }
}

impl Drop for NetClient {
    fn drop(&mut self) {
        // unblock and reap the reader thread
        let _ = self.writer.shutdown(std::net::Shutdown::Both);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// One `m2ru connect` run, fully specified.
#[derive(Clone, Debug)]
pub struct ConnectOptions {
    /// Server address (`host:port`).
    pub addr: String,
    /// Network shapes — must match the server's `--net`.
    pub net: NetConfig,
    /// Requests to stream.
    pub requests: u64,
    /// Simulated users.
    pub sessions: usize,
    /// Requests per wave (one wave = one server tick).
    pub arrivals: usize,
    /// Workload seed; with the server's seed and policy equal to an
    /// `m2ru serve` run, logits are bit-identical to the in-process
    /// driver.
    pub seed: u64,
    /// Fast-forward the workload past this many requests first (resume
    /// traffic against a server restarted from a checkpoint).
    pub skip: u64,
    /// Send `Shutdown` when done (the server drains, checkpoints, exits).
    pub shutdown: bool,
    /// Fetch a `MetricsDump` (Prometheus text) after the run.
    pub metrics: bool,
    /// Scenario config for the client-side workload (default disabled).
    /// Wave sizes then follow the arrival curve (`arrivals` is the
    /// steady-phase base), behaviors/shifts apply, and reconnector churn
    /// handshakes new sessions mid-run. Launch the server with the same
    /// schedule so its shift tracker lines up with this traffic.
    pub scenario: ScenarioConfig,
}

impl ConnectOptions {
    pub fn new(addr: impl Into<String>, net: NetConfig) -> ConnectOptions {
        ConnectOptions {
            addr: addr.into(),
            net,
            requests: 2000,
            sessions: 128,
            arrivals: 32,
            seed: 42,
            skip: 0,
            shutdown: true,
            metrics: false,
            scenario: ScenarioConfig::default(),
        }
    }
}

/// Outcome of a `m2ru connect` run.
pub struct ConnectReport {
    /// Server-issued session id per simulated user (index = user key):
    /// ids are keyed by the server's per-boot secret, so they are only
    /// knowable through the `Hello` handshake.
    pub session_ids: Vec<u64>,
    /// `(session, prediction, logits)` per response, in completion order.
    pub completed: Vec<(u64, u32, Vec<f32>)>,
    /// Labeled requests issued (scored server-side).
    pub labeled: u64,
    /// Wall-clock time from first wave to last response.
    pub wall: Duration,
    /// The server's serve report, fetched after the run.
    pub stats_text: String,
    /// The server's metrics exposition (only when `metrics` was
    /// requested; a router answers with the fleet aggregation).
    pub metrics_text: Option<String>,
    /// The server's flight-recorder dump as JSONL (only when `metrics`
    /// was requested).
    pub events_text: Option<String>,
    /// The server's total served count from the shutdown Ack (only when
    /// `shutdown` was requested).
    pub server_total: Option<u64>,
}

impl ConnectReport {
    pub fn throughput(&self) -> f64 {
        self.completed.len() as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Client-side **per-session signature**: every response's prediction
    /// and logits bits are folded FNV-style into its session's running
    /// hash (order-sensitive *within* a session), and the per-session
    /// hashes combine by wrapping addition (order-insensitive *across*
    /// sessions). Session ids themselves are excluded from the fold —
    /// they are keyed per deployment — so the same per-session response
    /// streams yield the same signature no matter how many shards served
    /// them or which server issued the ids. This is what the router CI
    /// smoke compares between a sharded and an unsharded run.
    pub fn session_signature(&self) -> u64 {
        const FNV: u64 = 0x0000_0100_0000_01B3;
        let mut per: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
        for (session, pred, logits) in &self.completed {
            let h = per.entry(*session).or_insert(0xCBF2_9CE4_8422_2325);
            *h = (*h ^ (u64::from(*pred) + 1)).wrapping_mul(FNV);
            for v in logits {
                *h = (*h ^ u64::from(v.to_bits())).wrapping_mul(FNV);
            }
        }
        per.values()
            .fold(0u64, |acc, h| acc.wrapping_add(*h))
            .wrapping_add(per.len() as u64)
    }
}

/// Closed-loop load generator: replay the synthetic workload over TCP in
/// driver-equivalent waves and collect every response.
pub fn run_connect(opts: &ConnectOptions) -> Result<ConnectReport> {
    anyhow::ensure!(opts.requests >= 1, "need at least one request");
    anyhow::ensure!(opts.sessions >= 1, "need at least one session");
    anyhow::ensure!(opts.arrivals >= 1, "need at least one request per wave");
    let mut client = NetClient::connect(&opts.addr)?;
    // handshake every simulated user up front: validates protocol/version
    // compatibility and collects the server-issued (secret-keyed) session
    // ids this connection is bound to
    let mut session_ids = Vec::with_capacity(opts.sessions);
    for user in 0..opts.sessions as u64 {
        session_ids.push(client.hello(user)?);
    }
    // reconnector uids are generation-bumped past the base population and
    // appear mid-run (churn waves); each is handshaked on first sight —
    // exactly what a reconnecting client does — and cached here
    let mut extra_ids: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();

    let mut workload = SyntheticWorkload::with_scenario(
        &opts.net,
        opts.sessions,
        opts.seed,
        &opts.scenario,
        opts.arrivals,
    )?;
    workload.skip(opts.skip);

    let mut completed: Vec<(u64, u32, Vec<f32>)> = Vec::with_capacity(opts.requests as usize);
    let mut labeled: u64 = 0;
    let collect = |completed: &mut Vec<(u64, u32, Vec<f32>)>, msg: Message| -> Result<()> {
        match msg {
            Message::Logits { session, pred, logits } => {
                completed.push((session, pred, logits));
                Ok(())
            }
            other => bail!("expected Logits during the run, got {other:?}"),
        }
    };

    let start = Instant::now();
    let mut issued: u64 = 0;
    while issued < opts.requests {
        // scenario runs size each wave from the arrival curve; plain
        // runs keep the flat rate. Either way one wave = one server tick.
        let quota = workload.wave_quota().unwrap_or(opts.arrivals) as u64;
        let wave = quota.min(opts.requests - issued) as usize;
        for i in 0..wave {
            let (user, x, label) = workload.next();
            let session = if (user as usize) < session_ids.len() {
                session_ids[user as usize]
            } else {
                match extra_ids.get(&user) {
                    Some(&sid) => sid,
                    None => {
                        // the Ack may arrive behind pipelined Logits from
                        // earlier waves on the shared channel — keep
                        // collecting those while waiting for it
                        client.send(0, &Message::Hello { user, epoch: 0 })?;
                        let sid = loop {
                            match client.recv()? {
                                Message::Ack { value, .. } => break value,
                                other => collect(&mut completed, other)?,
                            }
                        };
                        extra_ids.insert(user, sid);
                        sid
                    }
                }
            };
            if label.is_some() {
                labeled += 1;
            }
            let last_of_wave = i + 1 == wave;
            let last_of_run = issued + 1 == opts.requests;
            let mut flags = 0u8;
            if last_of_wave {
                flags |= FLAG_TICK;
            }
            if last_of_run {
                // the driver's end-of-traffic tail flush, same tick
                flags |= FLAG_FLUSH;
            }
            let msg = match label {
                Some(l) => Message::StepLabeled { session, label: l as u32, x },
                None => Message::Step { session, x },
            };
            client.send(flags, &msg)?;
            issued += 1;
        }
        // opportunistically drain responses to bound in-flight buffering
        while let Some(msg) = client.try_recv() {
            collect(&mut completed, msg)?;
        }
    }
    while (completed.len() as u64) < opts.requests {
        let msg = client.recv()?;
        collect(&mut completed, msg)?;
    }
    let wall = start.elapsed();

    let stats_text = client.stats()?;
    let metrics_text = if opts.metrics { Some(client.metrics("")?) } else { None };
    let events_text = if opts.metrics { Some(client.metrics("events")?) } else { None };
    let server_total = if opts.shutdown { Some(client.shutdown_server()?) } else { None };
    Ok(ConnectReport {
        session_ids,
        completed,
        labeled,
        wall,
        stats_text,
        metrics_text,
        events_text,
        server_total,
    })
}
