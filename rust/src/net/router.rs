//! Multi-shard session router (DESIGN.md §11): one TCP front door that
//! partitions established sessions across N independent serve shards.
//!
//! One `m2ru serve` process cannot serve millions of users: its serve
//! thread, committer thread and session store are a single vertical
//! slice. ReckOn and Chameleon scale on-chip learning by replicating
//! small autonomous learning cores rather than growing one; the serving
//! analogue is replicating the whole [`ServeCore`] stack — session
//! store, batcher, online learner, commit pipeline, checkpoint chain —
//! and routing each session to exactly one replica.
//!
//! ## Shard id math
//!
//! Session ids are a keyed SplitMix64 hash of the user key
//! ([`session_id_keyed`]) — uniformly spread by construction — so the
//! routing function is pure modular arithmetic over the id the router
//! itself issued at `Hello`:
//!
//! ```text
//! shard(session) = session_id % N
//! ```
//!
//! Every request of a session lands on the same shard, each shard owns
//! a disjoint id subset, and the partition is deterministic given the
//! (checkpoint-persisted) session secret.
//!
//! Since DESIGN.md §14 the partition is *versioned*: a [`RoutingEpoch`]
//! pairs the modulus with an epoch number, `Hello` acks carry the
//! current epoch, and an admin can rebalance the fleet N→M
//! (`Epoch{shards: M}`) or drain one shard (`Drain{shard: k}`) at
//! runtime. A cutover quiesces the fleet at a wave boundary, ships each
//! moved session between shards as a sealed migration parcel
//! ([`crate::serve::migrate`]), bumps the epoch, and replays any steps
//! that arrived mid-flight in their original per-session order
//! ([`StepPark`]) — zero client-visible errors, zero reordering. Epoch
//! 0 with the identity map *is* the PR 5 router, bit for bit.
//!
//! ## Determinism contract
//!
//! A shard is driven exactly like the single-process server drives its
//! core: submit the wave's requests, dispatch per the max-batch/max-wait
//! policy, advance the logical clock once per wave — every shard ticks
//! on every router wave (shards with no traffic that wave tick too, via
//! a `Nop` clock-carrier frame in remote mode). Consequently a shard is
//! **bitwise-identical to a dedicated single-process server** fed that
//! shard's request subset on the same wave schedule: per-session hidden
//! states, batching, online commits and logits all match. With online
//! learning disabled (weights frozen at boot), per-session logits are
//! additionally independent of the partition entirely, so 1-, 2- and
//! 4-shard deployments produce bitwise-identical per-session logits to
//! one unsharded process. `tests/router_shard.rs` asserts both claims,
//! in-process and over loopback TCP, including a mid-run shard
//! kill/restart from the shard's own delta snapshot chain.
//!
//! ## Failure model: one shard down ≠ service down
//!
//! Each shard checkpoints into its own directory (`<root>/shard-<k>/`)
//! and restores from its own chain, so shard lifecycles are independent.
//! A remote shard that dies takes down only its own sessions: steps
//! routed to it sever the *requesting* connection ("shard unavailable")
//! while every other shard keeps serving; when the shard comes back the
//! router reconnects on demand and re-`Hello`es the sessions it had
//! mapped there (the shard's restored secret keeps their ids valid). A
//! router restart restores every shard from its chain and adopts the
//! persisted session secret, so client-visible session ids survive.
//!
//! ## Two shard substrates
//!
//! * **In-process** (`--shards N`): N shard threads, each owning a full
//!   `ServeCore` (its own `ParallelEngine`, `OnlineLearner`, committer
//!   thread and checkpoint chain), driven over unbounded command
//!   channels — the router thread never blocks on a shard, shards block
//!   on the shared reply queue only when the router is draining it.
//! * **Remote** (`--shard-addrs a:p,b:p`): each shard is a separate
//!   `m2ru serve --listen` process; the router speaks the existing wire
//!   protocol to it (forwarded `Hello`/`Step`/`StepLabeled`, `Nop` clock
//!   pulses, fanned-out `Stats` and `Shutdown`), mapping its own session
//!   ids to each shard's `Hello`-issued ids.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{NetConfig, RunConfig};
use crate::serve::{
    extract_parcel, inject_parcel, session_id_keyed, try_restore, CompletedStep, OutboxDrops,
    RestoreOutcome, ServeCore, ServeReport, SnapshotPolicy, DEFAULT_SESSION_SECRET,
};

use super::conn::{self, ConnEvent, ConnTable, OutboxFlow};
use super::reshard::{ParkedStep, RoutingEpoch, StepPark};
use super::server::random_boot_secret;
use super::wire::{self, Frame, Message, FLAG_FLUSH, FLAG_TICK};

// ------------------------------------------------------- in-process shards

/// Commands the router sends a shard thread (strict FIFO per shard — the
/// determinism contract depends on it).
enum ShardCmd {
    /// One routed request at the current tick.
    Submit { session: u64, x: Vec<f32>, label: Option<usize>, tag: u64 },
    /// Tag a session's tenant class for scenario eviction accounting
    /// (fire-and-forget, reporting-plane only — dispatch ignores it).
    Class { session: u64, class: usize },
    /// End of an admission wave: dispatch per policy (`tick`), force the
    /// tail flush (`flush`), reply with the completed steps, then
    /// advance the clock and run the checkpoint cadence (`tick` only).
    Wave { tick: bool, flush: bool },
    /// Assemble this shard's serve report (syncs in-flight commits).
    Report,
    /// Render this shard's metrics exposition (`""`/`"prom"` →
    /// Prometheus text, `"events"` → flight-recorder JSONL).
    Metrics { selector: String },
    /// List the resident session ids (ascending) — the reshard cutover's
    /// migration work list.
    Sessions,
    /// Carve one session out as a sealed migration parcel (`None` when
    /// it is not resident). The caller quiesces the fleet first.
    Extract { session: u64 },
    /// Install a migration parcel under the local id `session`.
    Inject { session: u64, parcel: Vec<u8> },
    /// Flush, checkpoint (if durable), stop the committer and reply with
    /// the final report.
    Stop,
}

/// Shard thread replies, delivered over one shared unbounded channel.
enum ShardReply {
    Wave { shard: usize, steps: Vec<CompletedStep> },
    Report { shard: usize, report: Box<ServeReport> },
    Metrics { shard: usize, text: String },
    Sessions { shard: usize, ids: Vec<u64> },
    Parcel { shard: usize, parcel: Result<Option<Vec<u8>>, String> },
    Injected { shard: usize, result: Result<usize, String> },
    Stopped { shard: usize, result: Result<(Vec<CompletedStep>, Box<ServeReport>), String> },
}

/// One in-process shard: the command sender and the thread to reap.
struct ShardHandle {
    cmds: Sender<ShardCmd>,
    thread: JoinHandle<()>,
}

/// The shard thread body: drive one [`ServeCore`] exactly the way the
/// single-process frontends do (submit → drain per tick), so the shard
/// is bitwise-identical to a dedicated unsharded server fed the same
/// request subset on the same wave schedule.
fn shard_loop(
    shard: usize,
    mut core: ServeCore,
    dir: Option<PathBuf>,
    policy: SnapshotPolicy,
    checkpoint_every: u64,
    cmds: Receiver<ShardCmd>,
    replies: Sender<ShardReply>,
) {
    let fail = |e: anyhow::Error, replies: &Sender<ShardReply>| {
        let _ = replies.send(ShardReply::Stopped { shard, result: Err(e.to_string()) });
    };
    for cmd in cmds {
        match cmd {
            ShardCmd::Submit { session, x, label, tag } => core.submit(session, x, label, tag),
            ShardCmd::Class { session, class } => core.register_session_class(session, class),
            ShardCmd::Wave { tick, flush } => {
                let res = (|| -> Result<Vec<CompletedStep>> {
                    let mut steps = if tick { core.drain_ready()? } else { Vec::new() };
                    if flush {
                        steps.extend(core.flush_all()?);
                    }
                    Ok(steps)
                })();
                match res {
                    Ok(steps) => {
                        if replies.send(ShardReply::Wave { shard, steps }).is_err() {
                            return;
                        }
                    }
                    Err(e) => return fail(e, &replies),
                }
                if tick {
                    core.advance_tick();
                    if checkpoint_every > 0 && core.tick() % checkpoint_every == 0 {
                        if let Some(d) = &dir {
                            if let Err(e) = core.snapshot_async(d, &policy) {
                                return fail(e, &replies);
                            }
                        }
                    }
                }
            }
            ShardCmd::Report => {
                let sessions = core.store().len();
                match core.report(sessions) {
                    Ok(report) => {
                        if replies
                            .send(ShardReply::Report { shard, report: Box::new(report) })
                            .is_err()
                        {
                            return;
                        }
                    }
                    Err(e) => return fail(e, &replies),
                }
            }
            ShardCmd::Metrics { selector } => match core.metrics_text(&selector) {
                Ok(text) => {
                    if replies.send(ShardReply::Metrics { shard, text }).is_err() {
                        return;
                    }
                }
                Err(e) => return fail(e, &replies),
            },
            ShardCmd::Sessions => {
                let ids = core.store().ids();
                if replies.send(ShardReply::Sessions { shard, ids }).is_err() {
                    return;
                }
            }
            ShardCmd::Extract { session } => {
                let parcel = extract_parcel(&mut core, session).map_err(|e| e.to_string());
                if replies.send(ShardReply::Parcel { shard, parcel }).is_err() {
                    return;
                }
            }
            ShardCmd::Inject { session, parcel } => {
                let result = inject_parcel(&mut core, session, &parcel).map_err(|e| e.to_string());
                if replies.send(ShardReply::Injected { shard, result }).is_err() {
                    return;
                }
            }
            ShardCmd::Stop => {
                let result = (|| -> Result<(Vec<CompletedStep>, Box<ServeReport>)> {
                    // mirror the single-process shutdown path: flush the
                    // tail, then queue the final snapshot and complete
                    // every committer job before reporting success
                    let tail = core.flush_all()?;
                    core.drain_engine();
                    if let Some(d) = &dir {
                        core.snapshot_async(d, &policy)?;
                    }
                    core.finish()?;
                    let sessions = core.store().len();
                    let report = core.report(sessions)?;
                    Ok((tail, Box::new(report)))
                })()
                .map_err(|e| e.to_string());
                let _ = replies.send(ShardReply::Stopped { shard, result });
                return;
            }
        }
    }
    // command channel closed without Stop (router tearing down): stop the
    // committer quietly; there is nobody left to report to
    let _ = core.finish();
}

/// The in-process shard fleet behind one routing surface — the engine of
/// `m2ru router --shards N`, and the direct-drive API the equivalence
/// tests and benches use (no sockets).
///
/// Every method runs on the caller's thread; shards run concurrently but
/// each one observes a strict FIFO command stream, so results are
/// deterministic per shard. [`RouterCore::wave`] is a barrier: it
/// returns once every live shard has dispatched the wave.
pub struct RouterCore {
    net: NetConfig,
    run: RunConfig,
    shards: Vec<Option<ShardHandle>>,
    replies_tx: Sender<ShardReply>,
    replies: Receiver<ShardReply>,
    policy: SnapshotPolicy,
    root: Option<PathBuf>,
    secret: u64,
    restored: bool,
    restored_sessions: usize,
    routed: u64,
    shard_routed: Vec<u64>,
    /// The routing epoch in force: bumped by every rebalance/drain.
    /// Epoch 0 is the identity map over the boot fleet (the PR 5
    /// router, bit for bit).
    epoch: RoutingEpoch,
    /// Sessions migrated between shards over this router's lifetime.
    migrated: u64,
}

impl RouterCore {
    /// Build (and durably restore) `run.router.shards` in-process shards
    /// under the default session secret (tests and benches — the same
    /// public id space as [`crate::serve::session_id_for_user`]).
    pub fn new(net: NetConfig, run: &RunConfig) -> Result<RouterCore> {
        RouterCore::with_secret(net, run, None)
    }

    /// Build the shard fleet. `fresh_secret` keys the session-id space
    /// on a fresh boot (the TCP front door passes a random per-boot
    /// secret); a restore adopts the checkpointed secret instead, so
    /// client-visible session ids survive a router restart.
    pub fn with_secret(net: NetConfig, run: &RunConfig, fresh_secret: Option<u64>) -> Result<RouterCore> {
        run.validate()?;
        ensure!(
            run.router.shard_addrs.is_empty(),
            "RouterCore drives in-process shards; remote shard addresses are the TCP router's job"
        );
        let n = run.router.shards;
        let root = if run.router.checkpoint_root.is_empty() {
            None
        } else {
            Some(PathBuf::from(&run.router.checkpoint_root))
        };
        let policy = SnapshotPolicy::from_net(&run.net)?;
        let (replies_tx, replies) = channel::<ShardReply>();
        let mut me = RouterCore {
            net,
            run: run.clone(),
            shards: Vec::with_capacity(n),
            replies_tx,
            replies,
            policy,
            root,
            secret: DEFAULT_SESSION_SECRET,
            restored: false,
            restored_sessions: 0,
            routed: 0,
            shard_routed: vec![0; n],
            epoch: RoutingEpoch::identity(n),
            migrated: 0,
        };
        // restore every shard before any thread starts, so the adopted
        // session secret is known (and consistent) up front
        let mut cores = Vec::with_capacity(n);
        let mut restored_secret: Option<u64> = None;
        for k in 0..n {
            let mut core = ServeCore::new(net, run)?;
            if let Some(dir) = me.shard_dir(k) {
                match try_restore(&mut core, &dir)? {
                    RestoreOutcome::Restored { sessions, tick, deltas } => {
                        me.restored = true;
                        me.restored_sessions += sessions;
                        eprintln!(
                            "router: shard {k}: restored {sessions} session(s) at tick {tick} ({deltas} delta snapshot(s) applied)"
                        );
                        match restored_secret {
                            None => restored_secret = Some(core.session_secret()),
                            Some(s) => ensure!(
                                s == core.session_secret(),
                                "shard {k} checkpoint carries a different session secret — \
                                 the shard directories under {} are not one deployment's chain",
                                me.root.as_ref().expect("restore implies a root").display()
                            ),
                        }
                    }
                    RestoreOutcome::Corrupt { error } => {
                        eprintln!(
                            "warning: shard {k}: ignoring corrupt checkpoint ({error}); booting fresh"
                        );
                    }
                    RestoreOutcome::Fresh => {}
                }
            }
            cores.push(core);
        }
        me.secret = match restored_secret {
            Some(s) => s,
            None => fresh_secret.unwrap_or(DEFAULT_SESSION_SECRET),
        };
        for (k, mut core) in cores.into_iter().enumerate() {
            // one id space across the fleet: shards never *compute* ids
            // (the router does), but each shard persists the secret in
            // its checkpoints so a restart re-adopts it
            core.set_session_secret(me.secret);
            let handle = me.spawn_shard(k, core);
            me.shards.push(Some(handle));
        }
        Ok(me)
    }

    fn shard_dir(&self, k: usize) -> Option<PathBuf> {
        self.root.as_ref().map(|r| r.join(format!("shard-{k}")))
    }

    fn spawn_shard(&self, k: usize, core: ServeCore) -> ShardHandle {
        let (ctx, crx) = channel::<ShardCmd>();
        let replies = self.replies_tx.clone();
        let dir = self.shard_dir(k);
        let policy = self.policy.clone();
        let every = self.run.net.checkpoint_every;
        let thread = std::thread::Builder::new()
            .name(format!("m2ru-shard-{k}"))
            .spawn(move || shard_loop(k, core, dir, policy, every, crx, replies))
            .expect("spawning shard thread");
        ShardHandle { cmds: ctx, thread }
    }

    fn reap(&mut self, k: usize) {
        if let Some(h) = self.shards[k].take() {
            drop(h.cmds);
            let _ = h.thread.join();
        }
    }

    /// Number of shards in the fleet.
    pub fn shards(&self) -> usize {
        self.shard_routed.len()
    }

    /// The key of the fleet's session-id space.
    pub fn secret(&self) -> u64 {
        self.secret
    }

    /// Whether any shard restored from its checkpoint chain at boot.
    pub fn restored(&self) -> bool {
        self.restored
    }

    /// Sessions restored across all shards at boot.
    pub fn restored_sessions(&self) -> usize {
        self.restored_sessions
    }

    /// Requests routed so far (total and per shard).
    pub fn routed(&self) -> u64 {
        self.routed
    }

    pub fn shard_routed(&self) -> &[u64] {
        &self.shard_routed
    }

    /// The session id the router issues for `user` (and routes by).
    pub fn session_id(&self, user: u64) -> u64 {
        session_id_keyed(user, self.secret)
    }

    /// Which shard serves `session` under the current routing epoch.
    pub fn shard_of(&self, session: u64) -> usize {
        self.epoch.route(session)
    }

    /// The routing epoch in force.
    pub fn epoch(&self) -> &RoutingEpoch {
        &self.epoch
    }

    /// Sessions migrated between shards over this router's lifetime.
    pub fn migrated(&self) -> u64 {
        self.migrated
    }

    /// Route one request to its session's shard. Never blocks: shard
    /// command queues are unbounded (back-pressure reaches clients
    /// through the frontend's bounded event queue instead, and a shard
    /// blocks only on the shared reply queue the router drains).
    pub fn submit(&mut self, session: u64, x: Vec<f32>, label: Option<usize>, tag: u64) -> Result<()> {
        let k = self.shard_of(session);
        let h = self.shards[k].as_ref().with_context(|| format!("shard {k} is down"))?;
        h.cmds
            .send(ShardCmd::Submit { session, x, label, tag })
            .map_err(|_| anyhow!("shard {k} is down"))?;
        self.routed += 1;
        self.shard_routed[k] += 1;
        Ok(())
    }

    /// Tag `session`'s tenant class on its owning shard so scenario
    /// eviction-fairness accounting attributes its eviction there
    /// (reporting plane only; a class tag does not survive a later
    /// migration — by design, migrations are voluntary, not evictions).
    pub fn register_session_class(&mut self, session: u64, class: usize) -> Result<()> {
        let k = self.shard_of(session);
        let h = self.shards[k].as_ref().with_context(|| format!("shard {k} is down"))?;
        h.cmds
            .send(ShardCmd::Class { session, class })
            .map_err(|_| anyhow!("shard {k} is down"))?;
        Ok(())
    }

    /// End the admission wave on **every** shard in lock-step: dispatch
    /// per the batch policy (`tick`), force the end-of-traffic tail
    /// flush (`flush`), and advance each shard's clock (`tick`). Returns
    /// the completed steps of all shards (per-shard order preserved;
    /// cross-shard interleaving is arrival order).
    pub fn wave(&mut self, tick: bool, flush: bool) -> Result<Vec<CompletedStep>> {
        let mut expected = 0usize;
        for (k, h) in self.shards.iter().enumerate() {
            if let Some(h) = h {
                h.cmds
                    .send(ShardCmd::Wave { tick, flush })
                    .map_err(|_| anyhow!("shard {k} is down"))?;
                expected += 1;
            }
        }
        let mut out = Vec::new();
        while expected > 0 {
            match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                ShardReply::Wave { steps, .. } => {
                    out.extend(steps);
                    expected -= 1;
                }
                ShardReply::Stopped { shard, result } => {
                    self.reap(shard);
                    match result {
                        Err(e) => bail!("shard {shard} failed: {e}"),
                        Ok(_) => bail!("shard {shard} stopped unexpectedly"),
                    }
                }
                _ => {}
            }
        }
        Ok(out)
    }

    /// Collect every live shard's metrics exposition, in shard order.
    /// `None` marks a down shard. Timing plane only: the dump syncs no
    /// shard clocks and perturbs no dispatch decisions.
    pub fn metrics(&mut self, selector: &str) -> Result<Vec<Option<String>>> {
        let n = self.shards();
        let mut out: Vec<Option<String>> = vec![None; n];
        let mut expected = 0usize;
        for h in self.shards.iter().flatten() {
            if h.cmds.send(ShardCmd::Metrics { selector: String::from(selector) }).is_ok() {
                expected += 1;
            }
        }
        while expected > 0 {
            match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                ShardReply::Metrics { shard, text } => {
                    out[shard] = Some(text);
                    expected -= 1;
                }
                ShardReply::Stopped { shard, result } => {
                    self.reap(shard);
                    match result {
                        Err(e) => bail!("shard {shard} failed: {e}"),
                        Ok(_) => bail!("shard {shard} stopped unexpectedly"),
                    }
                }
                _ => {}
            }
        }
        Ok(out)
    }

    /// Collect every live shard's serve report (syncs their commit
    /// pipelines), in shard order.
    pub fn reports(&mut self) -> Result<Vec<(usize, ServeReport)>> {
        let mut expected = 0usize;
        for h in self.shards.iter().flatten() {
            if h.cmds.send(ShardCmd::Report).is_ok() {
                expected += 1;
            }
        }
        let mut out: Vec<(usize, ServeReport)> = Vec::with_capacity(expected);
        while out.len() < expected {
            match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                ShardReply::Report { shard, report } => out.push((shard, *report)),
                ShardReply::Stopped { shard, result } => {
                    self.reap(shard);
                    match result {
                        Err(e) => bail!("shard {shard} failed: {e}"),
                        Ok(_) => bail!("shard {shard} stopped unexpectedly"),
                    }
                }
                _ => {}
            }
        }
        out.sort_by_key(|(k, _)| *k);
        Ok(out)
    }

    /// Kill shard `k` (flush, checkpoint into its own chain, stop its
    /// committer) and immediately rebuild it from that chain — the
    /// single-shard crash/recovery path the router harness exercises
    /// mid-run. Returns the stopped life's report plus any steps its
    /// final flush completed (empty when the caller flushed first).
    pub fn restart_shard(&mut self, k: usize) -> Result<(ServeReport, Vec<CompletedStep>)> {
        let dir = self
            .shard_dir(k)
            .context("restarting a shard requires router.checkpoint_root")?;
        let h = self.shards[k].take().with_context(|| format!("shard {k} is already down"))?;
        h.cmds.send(ShardCmd::Stop).map_err(|_| anyhow!("shard {k} is down"))?;
        let (report, tail) = loop {
            match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                ShardReply::Stopped { shard, result } if shard == k => match result {
                    Ok((tail, rep)) => break (*rep, tail),
                    Err(e) => {
                        let _ = h.thread.join();
                        bail!("shard {k} failed to stop cleanly: {e}");
                    }
                },
                // no other shard has outstanding commands during a
                // restart; anything else here is a stray late reply
                _ => {}
            }
        };
        let _ = h.thread.join();
        let mut core = ServeCore::new(self.net, &self.run)?;
        match try_restore(&mut core, &dir)? {
            RestoreOutcome::Restored { .. } => {}
            RestoreOutcome::Fresh => {
                bail!("no snapshot to restart shard {k} from in {}", dir.display())
            }
            RestoreOutcome::Corrupt { error } => {
                bail!("shard {k} snapshot chain is corrupt: {error}")
            }
        }
        ensure!(
            core.session_secret() == self.secret,
            "restarted shard {k} restored a different session secret"
        );
        let handle = self.spawn_shard(k, core);
        self.shards[k] = Some(handle);
        Ok((report, tail))
    }

    /// Rebalance the fleet onto `m` shards (DESIGN.md §14): quiesce at a
    /// wave boundary (flush every queued step — clocks do not advance),
    /// spawn or revive physical shards `0..m` as needed, migrate every
    /// resident session whose route changes under the identity map over
    /// `m`, bump the epoch, and retire any physical shard the new map no
    /// longer uses. Returns `(new epoch, sessions migrated, steps the
    /// quiescing flush completed)` — the caller routes those steps to
    /// their clients before acknowledging the cutover.
    pub fn rebalance(&mut self, m: usize) -> Result<(u64, usize, Vec<CompletedStep>)> {
        ensure!(m >= 1, "cannot rebalance to zero shards");
        for k in 0..m.max(self.shards.len()) {
            if k >= self.shards.len() {
                self.shards.push(None);
                self.shard_routed.push(0);
            }
            if k < m && self.shards[k].is_none() {
                self.revive_shard(k)?;
            }
        }
        let next = self.epoch.rebalanced((0..m as u32).collect())?;
        let mut steps = self.wave(false, true)?;
        let migrated = self.cutover(next)?;
        for k in m..self.shards.len() {
            if self.shards[k].is_some() {
                steps.extend(self.retire(k)?);
            }
        }
        Ok((self.epoch.epoch(), migrated, steps))
    }

    /// Drain physical shard `k`: quiesce the fleet, migrate every moved
    /// session onto the survivors (the modulus shrinks, so sessions
    /// between surviving shards move too — see [`RoutingEpoch::drained`]),
    /// bump the epoch, then checkpoint and retire the shard. Same return
    /// contract as [`RouterCore::rebalance`].
    pub fn drain(&mut self, k: usize) -> Result<(u64, usize, Vec<CompletedStep>)> {
        ensure!(
            k < self.shards.len() && self.shards[k].is_some(),
            "shard {k} is not live"
        );
        let next = self.epoch.drained(k as u32)?;
        let mut steps = self.wave(false, true)?;
        let migrated = self.cutover(next)?;
        steps.extend(self.retire(k)?);
        Ok((self.epoch.epoch(), migrated, steps))
    }

    /// Move the fleet from the current epoch to `next`: list the
    /// resident sessions of every currently-mapped shard, compute the
    /// moved set ([`RoutingEpoch::moved`]), and ship each moved session
    /// as a sealed migration parcel, in ascending session-id order. The
    /// caller has already quiesced (no shard holds queued steps), so
    /// every extract either succeeds or reports the session gone
    /// (evicted between listing and extract — nothing to move).
    fn cutover(&mut self, next: RoutingEpoch) -> Result<usize> {
        let physicals: Vec<usize> = self.epoch.map().iter().map(|&p| p as usize).collect();
        let mut resident: Vec<u64> = Vec::new();
        let mut expected = 0usize;
        for &k in &physicals {
            let h = self.shards[k].as_ref().with_context(|| format!("shard {k} is down"))?;
            h.cmds.send(ShardCmd::Sessions).map_err(|_| anyhow!("shard {k} is down"))?;
            expected += 1;
        }
        while expected > 0 {
            match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                ShardReply::Sessions { ids, .. } => {
                    resident.extend(ids);
                    expected -= 1;
                }
                ShardReply::Stopped { shard, result } => {
                    self.reap(shard);
                    match result {
                        Err(e) => bail!("shard {shard} failed: {e}"),
                        Ok(_) => bail!("shard {shard} stopped unexpectedly"),
                    }
                }
                _ => {}
            }
        }
        resident.sort_unstable();
        let moved = self.epoch.moved(&next, resident.iter().copied());
        for &(sid, from, to) in &moved {
            let h = self.shards[from].as_ref().with_context(|| format!("shard {from} is down"))?;
            h.cmds
                .send(ShardCmd::Extract { session: sid })
                .map_err(|_| anyhow!("shard {from} is down"))?;
            let parcel = loop {
                match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                    ShardReply::Parcel { parcel, .. } => {
                        break parcel.map_err(|e| anyhow!("shard {from}: {e}"))?;
                    }
                    ShardReply::Stopped { shard, result } => {
                        self.reap(shard);
                        match result {
                            Err(e) => bail!("shard {shard} failed: {e}"),
                            Ok(_) => bail!("shard {shard} stopped unexpectedly"),
                        }
                    }
                    _ => {}
                }
            };
            let Some(parcel) = parcel else { continue };
            let h = self.shards[to].as_ref().with_context(|| format!("shard {to} is down"))?;
            h.cmds
                .send(ShardCmd::Inject { session: sid, parcel })
                .map_err(|_| anyhow!("shard {to} is down"))?;
            loop {
                match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                    ShardReply::Injected { result, .. } => {
                        result.map_err(|e| anyhow!("shard {to}: {e}"))?;
                        break;
                    }
                    ShardReply::Stopped { shard, result } => {
                        self.reap(shard);
                        match result {
                            Err(e) => bail!("shard {shard} failed: {e}"),
                            Ok(_) => bail!("shard {shard} stopped unexpectedly"),
                        }
                    }
                    _ => {}
                }
            }
            self.migrated += 1;
        }
        self.epoch = next;
        Ok(moved.len())
    }

    /// Stop shard `k` for good (flush — a no-op post-quiesce —
    /// checkpoint into its chain, stop its committer) and leave its slot
    /// empty. Returns any steps its final flush completed.
    fn retire(&mut self, k: usize) -> Result<Vec<CompletedStep>> {
        let h = self.shards[k].take().with_context(|| format!("shard {k} is already down"))?;
        h.cmds.send(ShardCmd::Stop).map_err(|_| anyhow!("shard {k} is down"))?;
        let tail = loop {
            match self.replies.recv().map_err(|_| anyhow!("every shard is gone"))? {
                ShardReply::Stopped { shard, result } if shard == k => match result {
                    Ok((tail, _report)) => break tail,
                    Err(e) => {
                        let _ = h.thread.join();
                        bail!("shard {k} failed to retire cleanly: {e}");
                    }
                },
                _ => {}
            }
        };
        let _ = h.thread.join();
        Ok(tail)
    }

    /// Bring an empty physical slot back to life: restore from the
    /// shard's own checkpoint chain when one exists (a previously
    /// drained shard re-adopts its weights and learner state; its
    /// sessions migrated out before the retiring checkpoint), fresh
    /// otherwise, always under the fleet's session secret.
    fn revive_shard(&mut self, k: usize) -> Result<()> {
        let mut core = ServeCore::new(self.net, &self.run)?;
        if let Some(dir) = self.shard_dir(k) {
            if let RestoreOutcome::Restored { .. } = try_restore(&mut core, &dir)? {
                ensure!(
                    core.session_secret() == self.secret,
                    "revived shard {k} restored a different session secret"
                );
            }
        }
        core.set_session_secret(self.secret);
        let handle = self.spawn_shard(k, core);
        self.shards[k] = Some(handle);
        Ok(())
    }

    /// Stop every shard (flush, checkpoint, stop committers) and collect
    /// their final reports in shard order, plus any steps the final
    /// flushes completed.
    pub fn finish(&mut self) -> Result<(Vec<(usize, ServeReport)>, Vec<CompletedStep>)> {
        let mut expected = 0usize;
        for h in self.shards.iter().flatten() {
            if h.cmds.send(ShardCmd::Stop).is_ok() {
                expected += 1;
            }
        }
        let mut reports: Vec<(usize, ServeReport)> = Vec::with_capacity(expected);
        let mut tail: Vec<CompletedStep> = Vec::new();
        let mut first_err: Option<String> = None;
        while expected > 0 {
            match self.replies.recv() {
                Ok(ShardReply::Stopped { shard, result }) => {
                    expected -= 1;
                    match result {
                        Ok((steps, rep)) => {
                            tail.extend(steps);
                            reports.push((shard, *rep));
                        }
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(format!("shard {shard}: {e}"));
                            }
                        }
                    }
                }
                Ok(ShardReply::Wave { steps, .. }) => tail.extend(steps),
                Ok(_) => {}
                Err(_) => break,
            }
        }
        for slot in self.shards.iter_mut() {
            if let Some(h) = slot.take() {
                drop(h.cmds);
                let _ = h.thread.join();
            }
        }
        if let Some(e) = first_err {
            bail!("{e}");
        }
        reports.sort_by_key(|(k, _)| *k);
        Ok((reports, tail))
    }
}

impl Drop for RouterCore {
    fn drop(&mut self) {
        // closing the command channels ends every shard loop; join so no
        // shard outlives its router (panics cannot propagate from Drop)
        for slot in self.shards.iter_mut() {
            if let Some(h) = slot.take() {
                drop(h.cmds);
                let _ = h.thread.join();
            }
        }
    }
}

// --------------------------------------------------------- remote shards

/// How long the router keeps retrying a shard connection before calling
/// the shard unavailable (a restarting shard restores its chain within
/// this window in the harness and CI).
const CONNECT_RETRIES: usize = 40;
const CONNECT_DELAY_MS: u64 = 250;

/// One remote shard: a `m2ru serve --listen` process the router speaks
/// the wire protocol to, plus the session-id translation tables.
struct RemoteShard {
    addr: String,
    sock: Option<TcpStream>,
    /// Bumped per (re)connect; stale `ShardDown` events from a previous
    /// connection's reader are ignored by generation.
    gen: u64,
    /// router session id → shard-issued session id.
    sids: HashMap<u64, u64>,
    /// shard-issued session id → router session id.
    rev: HashMap<u64, u64>,
    /// router session id → user key (for re-`Hello` after a reconnect).
    users: HashMap<u64, u64>,
    /// Hellos awaiting the shard's `Ack`, FIFO. `None` connections are
    /// reconnect re-hellos (no client is waiting on them).
    pending_hellos: VecDeque<(Option<u64>, u64, u64)>,
}

impl RemoteShard {
    /// Abandon every in-flight hello (connection died or is being
    /// replaced): the acks will never come, and leaving entries behind
    /// would desynchronize the FIFO ack matching on the next connection
    /// — acks would pop the wrong entry and corrupt the sid translation
    /// tables. Returns the client connections that were waiting, so the
    /// caller can sever them (their `Hello` can never be answered).
    fn abandon_hellos(&mut self) -> Vec<u64> {
        let mut orphaned = Vec::new();
        while let Some((waiter, _, _)) = self.pending_hellos.pop_front() {
            if let Some(waiter) = waiter {
                orphaned.push(waiter);
            }
        }
        orphaned
    }
}

impl RemoteShard {
    fn new(addr: String) -> RemoteShard {
        RemoteShard {
            addr,
            sock: None,
            gen: 0,
            sids: HashMap::new(),
            rev: HashMap::new(),
            users: HashMap::new(),
            pending_hellos: VecDeque::new(),
        }
    }
}

/// The remote-shard fleet: connection management, re-hello on reconnect,
/// and frame forwarding.
struct Remote {
    shards: Vec<RemoteShard>,
    tx: SyncSender<REvent>,
    stop: Arc<AtomicBool>,
    /// Client connections whose in-flight `Hello` was abandoned by a
    /// shard-connection loss; the router loop severs them after each
    /// event (their handshake can never complete).
    orphaned: Vec<u64>,
    /// Flight-recorder hook: shard (re)connects are recorded here;
    /// shard deaths are recorded by the router loop on `ShardDown`.
    recorder: Option<Arc<crate::obs::FlightRecorder>>,
}

impl Remote {
    /// Connect shard `k` if it is not connected, retrying up to
    /// `retries` attempts, then re-`Hello` every session mapped to it
    /// (the shard's binding table died with the old connection; its
    /// restored secret keeps the shard-side ids identical). Any hello
    /// still pending from the dead connection is abandoned first — its
    /// ack will never come, and a stale entry would desynchronize the
    /// FIFO ack matching on the fresh connection.
    fn ensure_connected(&mut self, k: usize, retries: usize) -> Result<()> {
        if self.shards[k].sock.is_some() {
            return Ok(());
        }
        let addr = self.shards[k].addr.clone();
        let mut sock = None;
        for attempt in 0..retries {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match TcpStream::connect(&addr) {
                Ok(s) => {
                    sock = Some(s);
                    break;
                }
                Err(_) if attempt + 1 < retries => {
                    std::thread::sleep(std::time::Duration::from_millis(CONNECT_DELAY_MS))
                }
                Err(_) => {}
            }
        }
        let Some(sock) = sock else { bail!("shard {k} unreachable at {addr}") };
        let _ = sock.set_nodelay(true);
        let mut rsock = sock.try_clone().context("cloning shard socket for the reader")?;
        let stale = self.shards[k].abandon_hellos();
        self.orphaned.extend(stale);
        self.shards[k].gen += 1;
        let gen = self.shards[k].gen;
        let tx = self.tx.clone();
        std::thread::spawn(move || loop {
            match wire::read_frame(&mut rsock) {
                Ok(Some(frame)) => {
                    if tx.send(REvent::ShardFrame { shard: k, frame }).is_err() {
                        return;
                    }
                }
                // clean EOF or any read error: this connection is done
                _ => {
                    let _ = tx.send(REvent::ShardDown { shard: k, gen });
                    return;
                }
            }
        });
        self.shards[k].sock = Some(sock);
        if let Some(rec) = &self.recorder {
            rec.record(
                0,
                "shard_connect",
                vec![("shard", format!("{k}")), ("addr", addr.clone())],
            );
        }
        let rehello: Vec<(u64, u64)> =
            self.shards[k].users.iter().map(|(sid, user)| (*sid, *user)).collect();
        for (sid, user) in rehello {
            self.write(k, 0, &Message::Hello { user, epoch: 0 })?;
            self.shards[k].pending_hellos.push_back((None, user, sid));
        }
        Ok(())
    }

    /// Write one frame to shard `k`'s live connection; a failed write
    /// marks the shard down.
    fn write(&mut self, k: usize, flags: u8, msg: &Message) -> Result<()> {
        use std::io::Write as _;
        let Some(sock) = self.shards[k].sock.as_mut() else { bail!("shard {k} is down") };
        let buf = wire::encode_frame(flags, msg);
        if let Err(e) = sock.write_all(&buf) {
            self.shards[k].sock = None;
            bail!("shard {k} write failed: {e}");
        }
        Ok(())
    }

    /// Forward a session-bearing frame (Step/Hello/Shutdown),
    /// reconnecting with the full retry window — a shard mid-restart is
    /// worth waiting for when a specific session needs it. One write
    /// retry covers a connection that died quietly since the last write.
    fn forward(&mut self, k: usize, flags: u8, msg: &Message) -> Result<()> {
        self.ensure_connected(k, CONNECT_RETRIES)?;
        if self.write(k, flags, msg).is_err() {
            self.ensure_connected(k, CONNECT_RETRIES)?;
            self.write(k, flags, msg)?;
        }
        Ok(())
    }

    /// Forward a fleet-wide pulse (Nop clock carrier, Stats fan-out)
    /// with a single fast connect attempt: these frames target *every*
    /// shard on the shared router thread, so a down shard must cost one
    /// failed connect, not the full retry window — otherwise one dead
    /// shard stalls every healthy shard's clients for seconds per wave
    /// (the §11 failure model forbids exactly that). A shard that
    /// reconnects this way still re-helloes before anything else.
    fn pulse(&mut self, k: usize, flags: u8, msg: &Message) -> Result<()> {
        self.ensure_connected(k, 1)?;
        if self.write(k, flags, msg).is_err() {
            self.ensure_connected(k, 1)?;
            self.write(k, flags, msg)?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------ TCP router

/// One router run, fully specified. `run.router` picks the shard
/// substrate (`shards` in-process threads, or `shard_addrs` remote
/// processes) and the per-shard checkpoint root; `run.net.listen` is the
/// front-door address.
#[derive(Clone, Debug)]
pub struct RouterServeOptions {
    pub net: NetConfig,
    pub run: RunConfig,
}

/// Outcome of a router run (after a client sent `Shutdown`).
pub struct RouterReport {
    /// Shards in the fleet.
    pub shards: usize,
    /// Whether the fleet was remote (`--shard-addrs`).
    pub remote: bool,
    /// Client connections accepted over the run.
    pub connections: u64,
    /// Requests routed (total and per shard).
    pub routed: u64,
    pub shard_routed: Vec<u64>,
    /// Final per-shard serve reports (in-process fleets only).
    pub shard_reports: Vec<(usize, ServeReport)>,
    /// Per-shard served totals from the shutdown acks (remote fleets
    /// only; 0 for shards that were unreachable at shutdown).
    pub shard_totals: Vec<u64>,
    /// Sessions restored across all shards at boot (in-process only;
    /// remote shards restore in their own processes).
    pub restored_sessions: usize,
    /// Client writer-outbox drops by reason.
    pub outbox_drops: OutboxDrops,
    /// The routing epoch in force at shutdown (0 = never resharded).
    pub epoch: u64,
    /// Sessions migrated between shards by rebalances/drains.
    pub migrated: u64,
}

/// Events the router's serve thread consumes: the shared accept path's
/// connection events, frames from remote shards, shard-connection
/// deaths, and the optional server-driven clock.
enum REvent {
    Conn(ConnEvent),
    ShardFrame { shard: usize, frame: Frame },
    ShardDown { shard: usize, gen: u64 },
    Tick,
}

impl From<ConnEvent> for REvent {
    fn from(e: ConnEvent) -> REvent {
        REvent::Conn(e)
    }
}

/// One in-flight `Stats` aggregation over a remote fleet.
struct StatsAgg {
    waiters: Vec<u64>,
    texts: Vec<Option<String>>,
}

/// One in-flight reshard operation over a *remote* fleet (in-process
/// fleets cut over synchronously inside [`RouterCore`]). The new epoch
/// is adopted the moment the operation starts: steps for moved sessions
/// are parked until their state lands on the target, so no step ever
/// chases the old route. Event handlers only record shard replies
/// (`Await* → Need*`); every wire action happens in the pump at the
/// bottom of the router loop, which walks the queue one session at a
/// time.
struct ReshardOp {
    /// The admin connection awaiting the `Epoch` acknowledgement.
    admin: u64,
    /// Sessions still to migrate: `(router sid, from, to)`, in the
    /// deterministic moved-set order.
    queue: VecDeque<(u64, usize, usize)>,
    phase: MigPhase,
    /// `Some(k)` for a drain: shut shard `k` down after the cutover
    /// (it checkpoints on the way out). Taken when the retire starts.
    retire: Option<usize>,
    /// The drained shard, kept for completion bookkeeping (`retire` is
    /// consumed when the shutdown goes out).
    drained: Option<usize>,
    /// Sessions migrated by this operation.
    migrated: u64,
    /// Wall-clock start, for the drain-duration histogram.
    started: std::time::Instant,
}

/// Where the in-flight migration of one session stands. `Await*` states
/// wait on a shard frame; `Need*` states wait on the pump to act.
enum MigPhase {
    /// Between sessions: the pump pops the next queue entry.
    Idle,
    /// Extract request sent; waiting for the source's `Migrate` reply
    /// (the parcel, or empty when the session was not resident).
    AwaitParcel { rsid: u64, from: usize, to: usize },
    /// Parcel in hand; the pump must `Hello` the target to map the
    /// session there.
    NeedHello { rsid: u64, to: usize, user: u64, parcel: Vec<u8> },
    /// Hello sent; waiting for the target's ack to land the mapping.
    AwaitHello { rsid: u64, to: usize, parcel: Vec<u8> },
    /// Mapping landed; the pump must send the inject (or skip straight
    /// to commit when the parcel is empty).
    NeedInject { rsid: u64, to: usize, parcel: Vec<u8> },
    /// Inject sent; waiting for the target's empty `Migrate` confirm.
    AwaitInject { rsid: u64, to: usize },
    /// Confirmed: the pump unparks the session's held steps and
    /// forwards them to the target in arrival order.
    NeedCommit { rsid: u64, to: usize },
    /// Drain only: `Shutdown` sent to the retired shard; waiting for
    /// its final ack.
    AwaitRetire { shard: usize },
    /// The whole operation is finished; the pump acks the admin.
    Done,
}

/// Open a reshard operation over a remote fleet: compute the moved set
/// (every session mapped — or with a `Hello` in flight — on any shard
/// whose route changes under `next`), park them all, and adopt the new
/// epoch immediately so no step ever chases the old route. The returned
/// op's queue is drained by the pump in the router loop.
fn start_reshard(
    admin: u64,
    repoch: &mut RoutingEpoch,
    next: RoutingEpoch,
    retire: Option<usize>,
    remote: &Remote,
    park: &mut StepPark,
    obs: &crate::obs::Obs,
) -> ReshardOp {
    let mut mapped: Vec<u64> = Vec::new();
    for sh in &remote.shards {
        mapped.extend(sh.sids.keys().copied());
        mapped.extend(sh.pending_hellos.iter().map(|(_, _, rsid)| *rsid));
    }
    mapped.sort_unstable();
    mapped.dedup();
    let moved = repoch.moved(&next, mapped);
    for &(rsid, _, _) in &moved {
        park.begin(rsid);
    }
    obs.event(
        0,
        "epoch_bump",
        vec![
            ("epoch", format!("{}", next.epoch())),
            ("shards", format!("{}", next.slots())),
            ("moved", format!("{}", moved.len())),
            ("op", if retire.is_some() { "drain" } else { "rebalance" }.to_string()),
        ],
    );
    *repoch = next;
    ReshardOp {
        admin,
        queue: moved.into_iter().collect(),
        phase: MigPhase::Idle,
        retire,
        drained: retire,
        migrated: 0,
        started: std::time::Instant::now(),
    }
}

/// One in-flight `MetricsDump` aggregation over a remote fleet.
/// Concurrent dumps coalesce onto the first request's selector.
struct MetricsAgg {
    selector: String,
    waiters: Vec<u64>,
    texts: Vec<Option<String>>,
}

/// A bound multi-shard router front door. `bind` then `run`;
/// `local_addr` exposes the picked port for `--listen 127.0.0.1:0`.
pub struct RouterServer {
    listener: TcpListener,
    opts: RouterServeOptions,
}

enum Mode {
    Local(RouterCore),
    Remote(Remote),
}

impl RouterServer {
    pub fn bind(opts: RouterServeOptions) -> Result<RouterServer> {
        opts.run.validate()?;
        let listener = TcpListener::bind(&opts.run.net.listen)
            .with_context(|| format!("binding {}", opts.run.net.listen))?;
        Ok(RouterServer { listener, opts })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Route until a client sends `Shutdown`. Blocking; spawn a thread
    /// to run it in the background.
    pub fn run(self) -> Result<RouterReport> {
        let RouterServer { listener, opts } = self;
        let remote_mode = !opts.run.router.shard_addrs.is_empty();

        // router-level observability: the router owns its own registry
        // and flight recorder (each shard owns its own; a `MetricsDump`
        // fans out and aggregates them). Timing plane only.
        let obs = crate::obs::Obs::from_cfg(&opts.run.obs)?;
        let flow = if obs.enabled() {
            crate::obs::install_panic_dump(&obs.recorder);
            OutboxFlow {
                enqueued: obs.registry.counter(
                    "m2ru_outbox_frames_enqueued_total",
                    "frames enqueued into per-connection writer outboxes",
                ),
                written: obs.registry.counter(
                    "m2ru_outbox_frames_written_total",
                    "frames written to client sockets by writer threads",
                ),
            }
        } else {
            OutboxFlow::default()
        };

        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel::<REvent>(opts.run.net.queue_depth.max(1));
        let acceptor = conn::spawn_acceptor::<REvent>(
            listener.try_clone()?,
            tx.clone(),
            stop.clone(),
            opts.run.net.outbox_depth.max(1),
            flow.clone(),
        );
        if opts.run.net.tick_ms > 0 {
            let period = std::time::Duration::from_millis(opts.run.net.tick_ms);
            let tick_tx = tx.clone();
            let tick_stop = stop.clone();
            std::thread::spawn(move || loop {
                std::thread::sleep(period);
                if tick_stop.load(Ordering::SeqCst) || tick_tx.send(REvent::Tick).is_err() {
                    return;
                }
            });
        }

        let (mut mode, secret, restored_sessions, mut n) = if remote_mode {
            let shards: Vec<RemoteShard> =
                opts.run.router.shard_addrs.iter().map(|a| RemoteShard::new(a.clone())).collect();
            let n = shards.len();
            let remote = Remote {
                shards,
                tx: tx.clone(),
                stop: stop.clone(),
                orphaned: Vec::new(),
                recorder: obs.enabled().then(|| obs.recorder.clone()),
            };
            (Mode::Remote(remote), random_boot_secret(), 0usize, n)
        } else {
            let core =
                RouterCore::with_secret(opts.net, &opts.run, Some(random_boot_secret()))?;
            let n = core.shards();
            let secret = core.secret();
            let restored = core.restored_sessions();
            (Mode::Local(core), secret, restored, n)
        };
        drop(tx);

        // ---- the router thread (this thread) ----------------------------
        let mut table = ConnTable::new();
        table.flow = flow;
        table.recorder = obs.enabled().then(|| obs.recorder.clone());
        let mut total_conns: u64 = 0;
        let mut routed: u64 = 0;
        let mut shard_routed: Vec<u64> = vec![0; n];
        let mut shard_totals: Vec<u64> = vec![0; n];
        let mut shard_reports: Vec<(usize, ServeReport)> = Vec::new();
        let mut stats: Option<StatsAgg> = None;
        let mut mdump: Option<MetricsAgg> = None;
        // Some while a Shutdown fans out to a remote fleet: (admin conn,
        // per-shard acked flags)
        let mut shutdown_await: Option<(u64, Vec<bool>)> = None;
        let nx = opts.net.nx;
        let ny = opts.net.ny;
        let client_admin = opts.run.net.client_admin;
        let bind_cap = opts.run.serve.capacity;
        // scenario runs: class-of-user for eviction-fairness accounting
        // (0 when scenarios are off — the register call is gated on it)
        let scenario_classes = opts.run.scenario.tenant_classes as u64;
        // resharding state (DESIGN.md §14). `repoch` is the remote
        // fleet's routing epoch (an in-process fleet keeps its epoch
        // inside RouterCore); `active` marks remote physicals not yet
        // drained; `park` holds steps whose session is mid-migration.
        let mut repoch = RoutingEpoch::identity(n);
        let mut active: Vec<bool> = vec![true; n];
        let mut park = StepPark::new();
        let mut reshard: Option<ReshardOp> = None;
        let park_cap = opts.run.router.max_parked.max(1);
        let mut migrated_total: u64 = 0;
        if obs.enabled() {
            obs.registry
                .gauge("m2ru_routing_epoch", "routing epoch in force (bumps per cutover)")
                .set(0.0);
        }

        let serve_result = (|| -> Result<()> {
            while let Ok(ev) = rx.recv() {
                match ev {
                    REvent::Tick => match &mut mode {
                        Mode::Local(core) => {
                            let steps = core.wave(true, false)?;
                            table.route_logits(steps);
                        }
                        Mode::Remote(remote) => {
                            for k in 0..n {
                                if !active[k] {
                                    continue;
                                }
                                if let Err(e) = remote.pulse(k, FLAG_TICK, &Message::Nop) {
                                    eprintln!("router: shard {k} missed a clock pulse: {e}");
                                }
                            }
                        }
                    },
                    REvent::Conn(ConnEvent::Connected { conn, ctl, outbox, writer }) => {
                        table.connected(conn, ctl, outbox, writer);
                        total_conns += 1;
                    }
                    REvent::Conn(ConnEvent::Disconnected { conn }) => table.forget(conn),
                    REvent::Conn(ConnEvent::WriterFailed { conn, timeout }) => {
                        table.writer_failed(conn, timeout)
                    }
                    REvent::Conn(ConnEvent::Malformed { conn, error }) => {
                        table.drop_conn(conn, &error)
                    }
                    REvent::Conn(ConnEvent::Frame { conn, frame }) => {
                        let Frame { flags, msg } = frame;
                        let flags = if client_admin { flags } else { 0 };
                        let mut shutdown_req = false;
                        match msg {
                            Message::Step { .. } | Message::StepLabeled { .. } => {
                                let (session, label, x) = match msg {
                                    Message::Step { session, x } => (session, None, x),
                                    Message::StepLabeled { session, label, x } => {
                                        (session, Some(label), x)
                                    }
                                    _ => unreachable!("outer arm matched a step"),
                                };
                                if let Some(reason) = conn::step_violation(
                                    table.owns(conn, session),
                                    x.len(),
                                    nx,
                                    label,
                                    ny,
                                ) {
                                    table.drop_conn(conn, &reason);
                                } else {
                                    match &mut mode {
                                        Mode::Local(core) => {
                                            let k = core.shard_of(session);
                                            core.submit(
                                                session,
                                                x,
                                                label.map(|l| l as usize),
                                                conn,
                                            )?;
                                            routed += 1;
                                            shard_routed[k] += 1;
                                        }
                                        Mode::Remote(remote) => {
                                            let k = repoch.route(session);
                                            if park.is_parked(session) {
                                                // state in flight between shards:
                                                // hold the step, replay at commit
                                                let held = ParkedStep {
                                                    session,
                                                    label,
                                                    x,
                                                    conn,
                                                };
                                                if let Err(e) = park.park(held, park_cap) {
                                                    table.drop_conn(conn, &e.to_string());
                                                }
                                            } else {
                                            let ssid = remote.shards[k].sids.get(&session).copied();
                                            match ssid {
                                                None => table.drop_conn(
                                                    conn,
                                                    "step for a session the shard has not acknowledged",
                                                ),
                                                Some(ssid) => {
                                                    let fwd = match label {
                                                        Some(l) => Message::StepLabeled {
                                                            session: ssid,
                                                            label: l,
                                                            x,
                                                        },
                                                        None => Message::Step { session: ssid, x },
                                                    };
                                                    match remote.forward(k, 0, &fwd) {
                                                        Ok(()) => {
                                                            routed += 1;
                                                            shard_routed[k] += 1;
                                                        }
                                                        Err(e) => table.drop_conn(
                                                            conn,
                                                            &format!("shard {k} unavailable: {e}"),
                                                        ),
                                                    }
                                                }
                                            }
                                            }
                                        }
                                    }
                                }
                            }
                            Message::Hello { user, epoch: _ } => {
                                let sid = session_id_keyed(user, secret);
                                match &mut mode {
                                    Mode::Local(core) => match table.bind(conn, sid, bind_cap) {
                                        Ok(()) => {
                                            if scenario_classes > 0 {
                                                // tenant class is a pure
                                                // function of the user key —
                                                // tag the owning shard
                                                core.register_session_class(
                                                    sid,
                                                    (user % scenario_classes) as usize,
                                                )?;
                                            }
                                            table.send(
                                                conn,
                                                &Message::Ack {
                                                    value: sid,
                                                    epoch: core.epoch().epoch(),
                                                },
                                            )
                                        }
                                        Err(reason) => table.drop_conn(conn, &reason),
                                    },
                                    Mode::Remote(remote) => {
                                        let k = repoch.route(sid);
                                        if remote.shards[k].sids.contains_key(&sid)
                                            || park.is_parked(sid)
                                        {
                                            // already mapped there (an earlier
                                            // connection's Hello), or its state is
                                            // mid-flight *to* k and the migration
                                            // will land the mapping: bind locally,
                                            // no round-trip
                                            match table.bind(conn, sid, bind_cap) {
                                                Ok(()) => table.send(
                                                    conn,
                                                    &Message::Ack {
                                                        value: sid,
                                                        epoch: repoch.epoch(),
                                                    },
                                                ),
                                                Err(reason) => table.drop_conn(conn, &reason),
                                            }
                                        } else {
                                            match remote
                                                .forward(k, 0, &Message::Hello { user, epoch: 0 })
                                            {
                                                Ok(()) => remote.shards[k]
                                                    .pending_hellos
                                                    .push_back((Some(conn), user, sid)),
                                                Err(e) => table.drop_conn(
                                                    conn,
                                                    &format!("shard {k} unavailable: {e}"),
                                                ),
                                            }
                                        }
                                    }
                                }
                            }
                            Message::Stats { .. } => match &mut mode {
                                Mode::Local(core) => {
                                    // blocking collect: the router thread is the
                                    // reply channel's only consumer
                                    let reports = core.reports()?;
                                    let text = local_stats_text(
                                        routed,
                                        &shard_routed,
                                        core.epoch().epoch(),
                                        &reports,
                                        &table.drops,
                                    );
                                    table.send(conn, &Message::Stats { text });
                                }
                                Mode::Remote(remote) => match &mut stats {
                                    Some(agg) => agg.waiters.push(conn),
                                    None => {
                                        let mut agg = StatsAgg {
                                            waiters: vec![conn],
                                            texts: vec![None; n],
                                        };
                                        for k in 0..n {
                                            if !active[k] {
                                                agg.texts[k] =
                                                    Some("unreachable (retired)".to_string());
                                                continue;
                                            }
                                            if let Err(e) = remote.pulse(
                                                k,
                                                0,
                                                &Message::Stats { text: String::new() },
                                            ) {
                                                agg.texts[k] =
                                                    Some(format!("unreachable ({e})"));
                                            }
                                        }
                                        stats = Some(agg);
                                    }
                                },
                            },
                            Message::MetricsDump { text: selector } => match &mut mode {
                                Mode::Local(core) => {
                                    let texts = core.metrics(&selector)?;
                                    let router = router_metrics_text(
                                        &obs,
                                        &selector,
                                        routed,
                                        n,
                                        total_conns,
                                        core.epoch().epoch(),
                                        migrated_total,
                                        &table.flow,
                                        &table.drops,
                                    );
                                    let text = fleet_metrics_text(router, &texts, &selector);
                                    table.send(conn, &Message::MetricsDump { text });
                                }
                                Mode::Remote(remote) => match &mut mdump {
                                    Some(agg) => agg.waiters.push(conn),
                                    None => {
                                        let mut agg = MetricsAgg {
                                            selector: selector.clone(),
                                            waiters: vec![conn],
                                            texts: vec![None; n],
                                        };
                                        for k in 0..n {
                                            if !active[k] {
                                                agg.texts[k] =
                                                    Some(format!("# shard {k} retired\n"));
                                                continue;
                                            }
                                            if let Err(e) = remote.pulse(
                                                k,
                                                0,
                                                &Message::MetricsDump {
                                                    text: selector.clone(),
                                                },
                                            ) {
                                                agg.texts[k] = Some(format!(
                                                    "# shard {k} unreachable ({e})\n"
                                                ));
                                            }
                                        }
                                        mdump = Some(agg);
                                    }
                                },
                            },
                            Message::Epoch { epoch: _, shards: 0 } => {
                                // epoch query: read-only, ungated (like Stats)
                                let (e, w) = match &mode {
                                    Mode::Local(core) => {
                                        (core.epoch().epoch(), core.epoch().slots() as u32)
                                    }
                                    Mode::Remote(_) => (repoch.epoch(), repoch.slots() as u32),
                                };
                                table.send(conn, &Message::Epoch { epoch: e, shards: w });
                            }
                            Message::Epoch { epoch: _, shards: m } => {
                                if !client_admin {
                                    table.drop_conn(
                                        conn,
                                        "Epoch rebalance from a client (net.client_admin is off)",
                                    );
                                } else {
                                    match &mut mode {
                                        Mode::Local(core) => {
                                            match core.rebalance(m as usize) {
                                                Ok((e, migrated, steps)) => {
                                                    table.route_logits(steps);
                                                    if core.shards() > n {
                                                        n = core.shards();
                                                        shard_routed.resize(n, 0);
                                                        shard_totals.resize(n, 0);
                                                        active.resize(n, true);
                                                    }
                                                    migrated_total += migrated as u64;
                                                    obs.event(
                                                        0,
                                                        "epoch_bump",
                                                        vec![
                                                            ("epoch", format!("{e}")),
                                                            ("shards", format!("{m}")),
                                                            ("migrated", format!("{migrated}")),
                                                            ("op", "rebalance".to_string()),
                                                        ],
                                                    );
                                                    table.send(
                                                        conn,
                                                        &Message::Epoch {
                                                            epoch: e,
                                                            shards: core.epoch().slots() as u32,
                                                        },
                                                    );
                                                }
                                                Err(e) => table.drop_conn(
                                                    conn,
                                                    &format!("rebalance failed: {e}"),
                                                ),
                                            }
                                        }
                                        Mode::Remote(remote) => {
                                            let m = m as usize;
                                            if reshard.is_some() {
                                                table.drop_conn(
                                                    conn,
                                                    "a reshard operation is already in flight",
                                                );
                                            } else if m > n {
                                                table.drop_conn(
                                                    conn,
                                                    &format!(
                                                        "rebalance to {m} shards but only {n} configured (--shard-addrs)"
                                                    ),
                                                );
                                            } else if !(0..m).all(|k| active[k]) {
                                                table.drop_conn(
                                                    conn,
                                                    "rebalance map includes a drained shard",
                                                );
                                            } else {
                                                match repoch.rebalanced((0..m as u32).collect()) {
                                                    Ok(next) => {
                                                        reshard = Some(start_reshard(
                                                            conn, &mut repoch, next, None, remote,
                                                            &mut park, &obs,
                                                        ));
                                                    }
                                                    Err(e) => {
                                                        table.drop_conn(conn, &e.to_string())
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                            Message::Drain { shard } => {
                                if !client_admin {
                                    table.drop_conn(
                                        conn,
                                        "Drain from a client (net.client_admin is off)",
                                    );
                                } else {
                                    let k = shard as usize;
                                    match &mut mode {
                                        Mode::Local(core) => {
                                            let t0 = std::time::Instant::now();
                                            match core.drain(k) {
                                                Ok((e, migrated, steps)) => {
                                                    table.route_logits(steps);
                                                    migrated_total += migrated as u64;
                                                    if obs.enabled() {
                                                        obs.registry
                                                            .histogram(
                                                                "m2ru_drain_duration_ms",
                                                                "wall time of shard drains",
                                                            )
                                                            .observe(
                                                                t0.elapsed().as_millis() as u64
                                                            );
                                                    }
                                                    obs.event(
                                                        0,
                                                        "drain_complete",
                                                        vec![
                                                            ("shard", format!("{k}")),
                                                            ("epoch", format!("{e}")),
                                                            ("migrated", format!("{migrated}")),
                                                        ],
                                                    );
                                                    table.send(
                                                        conn,
                                                        &Message::Epoch {
                                                            epoch: e,
                                                            shards: core.epoch().slots() as u32,
                                                        },
                                                    );
                                                }
                                                Err(e) => table.drop_conn(
                                                    conn,
                                                    &format!("drain failed: {e}"),
                                                ),
                                            }
                                        }
                                        Mode::Remote(remote) => {
                                            if reshard.is_some() {
                                                table.drop_conn(
                                                    conn,
                                                    "a reshard operation is already in flight",
                                                );
                                            } else if k >= n || !active[k] {
                                                table.drop_conn(
                                                    conn,
                                                    &format!("shard {k} is not live"),
                                                );
                                            } else {
                                                match repoch.drained(shard) {
                                                    Ok(next) => {
                                                        reshard = Some(start_reshard(
                                                            conn,
                                                            &mut repoch,
                                                            next,
                                                            Some(k),
                                                            remote,
                                                            &mut park,
                                                            &obs,
                                                        ));
                                                    }
                                                    Err(e) => {
                                                        table.drop_conn(conn, &e.to_string())
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                            Message::Shutdown => {
                                if client_admin {
                                    shutdown_req = true;
                                } else {
                                    table.drop_conn(
                                        conn,
                                        "Shutdown from a client (net.client_admin is off)",
                                    );
                                }
                            }
                            Message::Nop => {}
                            Message::Ack { .. }
                            | Message::Logits { .. }
                            | Message::Migrate { .. } => {
                                table.drop_conn(conn, "client sent a server-only message");
                            }
                        }
                        // flags drive the fleet-wide clock: one wave on
                        // every shard per FLAG_TICK (Nop carries the
                        // pulse to remote shards with no steps this wave)
                        let tick = flags & FLAG_TICK != 0;
                        let flush = flags & FLAG_FLUSH != 0;
                        if tick || flush {
                            match &mut mode {
                                Mode::Local(core) => {
                                    let steps = core.wave(tick, flush)?;
                                    table.route_logits(steps);
                                }
                                Mode::Remote(remote) => {
                                    let mut f = 0u8;
                                    if tick {
                                        f |= FLAG_TICK;
                                    }
                                    if flush {
                                        f |= FLAG_FLUSH;
                                    }
                                    for k in 0..n {
                                        if !active[k] {
                                            continue;
                                        }
                                        if let Err(e) = remote.pulse(k, f, &Message::Nop) {
                                            eprintln!(
                                                "router: shard {k} missed a clock pulse: {e}"
                                            );
                                        }
                                    }
                                }
                            }
                        }
                        if shutdown_req {
                            match &mut mode {
                                Mode::Local(core) => {
                                    let epoch = core.epoch().epoch();
                                    let (reports, tail) = core.finish()?;
                                    table.route_logits(tail);
                                    shard_reports = reports;
                                    table.send(conn, &Message::Ack { value: routed, epoch });
                                    return Ok(());
                                }
                                Mode::Remote(remote) => {
                                    // fan the shutdown out; shards flush, send
                                    // their final logits, ack with their served
                                    // totals, and exit — ack the admin client
                                    // once every reachable shard has
                                    let mut acked = vec![true; n];
                                    for k in 0..n {
                                        if !active[k] {
                                            continue;
                                        }
                                        match remote.forward(k, 0, &Message::Shutdown) {
                                            Ok(()) => acked[k] = false,
                                            Err(e) => eprintln!(
                                                "router: shard {k} unreachable at shutdown: {e}"
                                            ),
                                        }
                                    }
                                    if acked.iter().all(|a| *a) {
                                        table.send(
                                            conn,
                                            &Message::Ack {
                                                value: routed,
                                                epoch: repoch.epoch(),
                                            },
                                        );
                                        return Ok(());
                                    }
                                    shutdown_await = Some((conn, acked));
                                }
                            }
                        }
                    }
                    REvent::ShardFrame { shard, frame } => {
                        let Mode::Remote(remote) = &mut mode else { continue };
                        match frame.msg {
                            Message::Ack { value, .. } => {
                                // the shard answers FIFO: hello acks first,
                                // then (only during a retire or teardown) the
                                // shutdown ack
                                if let Some((waiter, user, rsid)) =
                                    remote.shards[shard].pending_hellos.pop_front()
                                {
                                    remote.shards[shard].sids.insert(rsid, value);
                                    remote.shards[shard].rev.insert(value, rsid);
                                    remote.shards[shard].users.insert(rsid, user);
                                    if let Some(waiter) = waiter {
                                        match table.bind(waiter, rsid, bind_cap) {
                                            Ok(()) => table.send(
                                                waiter,
                                                &Message::Ack {
                                                    value: rsid,
                                                    epoch: repoch.epoch(),
                                                },
                                            ),
                                            Err(reason) => table.drop_conn(waiter, &reason),
                                        }
                                    }
                                    // a migration Hello: the mapping just
                                    // landed on the target — hand the parcel
                                    // back to the pump for the inject
                                    if let Some(op) = &mut reshard {
                                        let hit = matches!(
                                            &op.phase,
                                            MigPhase::AwaitHello { rsid: r, to, .. }
                                                if *r == rsid && *to == shard
                                        );
                                        if hit {
                                            let MigPhase::AwaitHello { rsid, to, parcel } =
                                                std::mem::replace(
                                                    &mut op.phase,
                                                    MigPhase::Idle,
                                                )
                                            else {
                                                unreachable!("checked above")
                                            };
                                            op.phase =
                                                MigPhase::NeedInject { rsid, to, parcel };
                                        }
                                    }
                                } else if matches!(
                                    &reshard,
                                    Some(op) if matches!(
                                        op.phase,
                                        MigPhase::AwaitRetire { shard: s } if s == shard
                                    )
                                ) {
                                    // the drained shard's final ack: it has
                                    // flushed, checkpointed and exited
                                    let op = reshard.as_mut().expect("checked above");
                                    shard_totals[shard] = value;
                                    op.phase = MigPhase::Done;
                                } else if let Some((admin, acked)) = &mut shutdown_await {
                                    if !acked[shard] {
                                        acked[shard] = true;
                                        shard_totals[shard] = value;
                                    }
                                    if acked.iter().all(|a| *a) {
                                        let admin = *admin;
                                        table.send(
                                            admin,
                                            &Message::Ack {
                                                value: routed,
                                                epoch: repoch.epoch(),
                                            },
                                        );
                                        return Ok(());
                                    }
                                }
                            }
                            Message::Logits { session, pred, logits } => {
                                if let Some(&rsid) = remote.shards[shard].rev.get(&session) {
                                    if let Some(waiter) = table.owner_of(rsid) {
                                        table.send(
                                            waiter,
                                            &Message::Logits { session: rsid, pred, logits },
                                        );
                                    }
                                }
                            }
                            Message::Stats { text } => {
                                if let Some(agg) = &mut stats {
                                    if agg.texts[shard].is_none() {
                                        agg.texts[shard] = Some(text);
                                    }
                                }
                            }
                            Message::MetricsDump { text } => {
                                if let Some(agg) = &mut mdump {
                                    if agg.texts[shard].is_none() {
                                        agg.texts[shard] = Some(text);
                                    }
                                }
                            }
                            Message::Migrate { session: _, payload } => {
                                // a migration reply: the source's parcel
                                // (extract) or the target's empty confirm
                                // (inject); which one is determined by the
                                // op's phase, not the payload
                                if let Some(op) = &mut reshard {
                                    match std::mem::replace(&mut op.phase, MigPhase::Idle) {
                                        MigPhase::AwaitParcel { rsid, from, to }
                                            if shard == from =>
                                        {
                                            // the session no longer lives on
                                            // the source: drop its translation
                                            // entries; the target Hello
                                            // re-creates them over there
                                            if let Some(ssid) =
                                                remote.shards[from].sids.remove(&rsid)
                                            {
                                                remote.shards[from].rev.remove(&ssid);
                                            }
                                            match remote.shards[from].users.remove(&rsid) {
                                                Some(user) => {
                                                    op.phase = MigPhase::NeedHello {
                                                        rsid,
                                                        to,
                                                        user,
                                                        parcel: payload,
                                                    };
                                                }
                                                None => {
                                                    for s in park.unpark(rsid) {
                                                        table.drop_conn(
                                                            s.conn,
                                                            "migration lost the session's user key",
                                                        );
                                                    }
                                                }
                                            }
                                        }
                                        MigPhase::AwaitInject { rsid, to } if shard == to => {
                                            op.phase = MigPhase::NeedCommit { rsid, to };
                                        }
                                        // stray migrate frame: put the phase
                                        // back and ignore it
                                        other => op.phase = other,
                                    }
                                }
                            }
                            // shards never originate anything else
                            _ => {}
                        }
                    }
                    REvent::ShardDown { shard, gen } => {
                        if let Mode::Remote(remote) = &mut mode {
                            if remote.shards[shard].gen == gen {
                                remote.shards[shard].sock = None;
                                obs.event(
                                    0,
                                    "shard_down",
                                    vec![
                                        ("shard", format!("{shard}")),
                                        ("addr", remote.shards[shard].addr.clone()),
                                    ],
                                );
                                // hellos in flight on the dead connection will
                                // never be acked; re-hello covers the mapped
                                // sessions after the next reconnect, so sever
                                // any client still waiting on a handshake
                                let orphaned = remote.shards[shard].abandon_hellos();
                                for waiter in orphaned {
                                    table.drop_conn(
                                        waiter,
                                        &format!("shard {shard} connection lost"),
                                    );
                                }
                                if let Some(agg) = &mut stats {
                                    if agg.texts[shard].is_none() {
                                        agg.texts[shard] =
                                            Some("unreachable (connection lost)".to_string());
                                    }
                                }
                                if let Some(agg) = &mut mdump {
                                    if agg.texts[shard].is_none() {
                                        agg.texts[shard] = Some(format!(
                                            "# shard {shard} unreachable (connection lost)\n"
                                        ));
                                    }
                                }
                                // a reshard op waiting on this shard can
                                // never hear back: skip the in-flight
                                // session (its parked steps can no longer be
                                // delivered in order), or treat a dying
                                // retiree as retired
                                if let Some(op) = &mut reshard {
                                    let stalled = match &op.phase {
                                        MigPhase::AwaitParcel { from, .. } => *from == shard,
                                        MigPhase::AwaitHello { to, .. }
                                        | MigPhase::AwaitInject { to, .. } => *to == shard,
                                        _ => false,
                                    };
                                    if stalled {
                                        if let MigPhase::AwaitParcel { rsid, .. }
                                        | MigPhase::AwaitHello { rsid, .. }
                                        | MigPhase::AwaitInject { rsid, .. } =
                                            std::mem::replace(&mut op.phase, MigPhase::Idle)
                                        {
                                            for s in park.unpark(rsid) {
                                                table.drop_conn(
                                                    s.conn,
                                                    &format!(
                                                        "shard {shard} connection lost mid-migration"
                                                    ),
                                                );
                                            }
                                        }
                                    }
                                    if matches!(
                                        op.phase,
                                        MigPhase::AwaitRetire { shard: s } if s == shard
                                    ) {
                                        // dead is as retired as it gets
                                        op.phase = MigPhase::Done;
                                    }
                                }
                                if let Some((admin, acked)) = &mut shutdown_await {
                                    if !acked[shard] {
                                        acked[shard] = true; // dead shard: nothing to wait for
                                    }
                                    if acked.iter().all(|a| *a) {
                                        let admin = *admin;
                                        table.send(
                                            admin,
                                            &Message::Ack {
                                                value: routed,
                                                epoch: repoch.epoch(),
                                            },
                                        );
                                        return Ok(());
                                    }
                                }
                            }
                        }
                    }
                }
                // hellos abandoned by a reconnect can never be answered:
                // sever their waiters (the client retries with a fresh
                // connection once the shard is reachable again)
                if let Mode::Remote(remote) = &mut mode {
                    let orphaned = std::mem::take(&mut remote.orphaned);
                    for waiter in orphaned {
                        table.drop_conn(waiter, "shard connection lost with a Hello in flight");
                    }
                }
                // the reshard pump: drive the in-flight migration state
                // machine (remote fleets only — in-process fleets cut
                // over synchronously above). Handlers parked shard
                // replies as Need* phases; every wire action happens
                // here. Await* phases stop the pump until the next
                // shard frame arrives.
                let mut reshard_done: Option<(u64, u64, Option<usize>, std::time::Instant)> =
                    None;
                if let (Some(op), Mode::Remote(remote)) = (&mut reshard, &mut mode) {
                    let mut spins = op.queue.len().max(1);
                    loop {
                        match std::mem::replace(&mut op.phase, MigPhase::Idle) {
                            MigPhase::Idle => {
                                let Some((rsid, from, to)) = op.queue.pop_front() else {
                                    if let Some(k) = op.retire.take() {
                                        match remote.forward(k, 0, &Message::Shutdown) {
                                            Ok(()) => {
                                                op.phase = MigPhase::AwaitRetire { shard: k };
                                                break;
                                            }
                                            Err(e) => {
                                                eprintln!(
                                                    "router: drained shard {k} unreachable at retire: {e}"
                                                );
                                                continue; // falls into Done
                                            }
                                        }
                                    }
                                    op.phase = MigPhase::Done;
                                    continue;
                                };
                                let Some(&ssid) = remote.shards[from].sids.get(&rsid) else {
                                    if remote.shards[from]
                                        .pending_hellos
                                        .iter()
                                        .any(|(_, _, r)| *r == rsid)
                                    {
                                        // its Hello is still in flight to the
                                        // source: retry after that ack lands
                                        op.queue.push_back((rsid, from, to));
                                        spins -= 1;
                                        if spins == 0 {
                                            break;
                                        }
                                        continue;
                                    }
                                    // never mapped and no hello pending:
                                    // nothing to move; held steps can no
                                    // longer be delivered in order
                                    for s in park.unpark(rsid) {
                                        table.drop_conn(
                                            s.conn,
                                            &format!(
                                                "session lost its source shard {from} mid-migration"
                                            ),
                                        );
                                    }
                                    continue;
                                };
                                obs.event(
                                    0,
                                    "migrate_start",
                                    vec![
                                        ("session", format!("{rsid:016x}")),
                                        ("from", format!("{from}")),
                                        ("to", format!("{to}")),
                                    ],
                                );
                                match remote.forward(
                                    from,
                                    0,
                                    &Message::Migrate { session: ssid, payload: Vec::new() },
                                ) {
                                    Ok(()) => {
                                        op.phase = MigPhase::AwaitParcel { rsid, from, to };
                                        break;
                                    }
                                    Err(e) => {
                                        for s in park.unpark(rsid) {
                                            table.drop_conn(
                                                s.conn,
                                                &format!(
                                                    "shard {from} unavailable during migration: {e}"
                                                ),
                                            );
                                        }
                                        continue;
                                    }
                                }
                            }
                            MigPhase::NeedHello { rsid, to, user, parcel } => {
                                match remote.forward(
                                    to,
                                    0,
                                    &Message::Hello { user, epoch: 0 },
                                ) {
                                    Ok(()) => {
                                        remote.shards[to]
                                            .pending_hellos
                                            .push_back((None, user, rsid));
                                        op.phase = MigPhase::AwaitHello { rsid, to, parcel };
                                        break;
                                    }
                                    Err(e) => {
                                        for s in park.unpark(rsid) {
                                            table.drop_conn(
                                                s.conn,
                                                &format!(
                                                    "shard {to} unavailable during migration: {e}"
                                                ),
                                            );
                                        }
                                        continue;
                                    }
                                }
                            }
                            MigPhase::NeedInject { rsid, to, parcel } => {
                                if parcel.is_empty() {
                                    // no resident state to ship: the Hello
                                    // alone re-homed the session
                                    op.phase = MigPhase::NeedCommit { rsid, to };
                                    continue;
                                }
                                let Some(&ssid) = remote.shards[to].sids.get(&rsid) else {
                                    for s in park.unpark(rsid) {
                                        table.drop_conn(
                                            s.conn,
                                            "migration target lost the session mapping",
                                        );
                                    }
                                    continue;
                                };
                                match remote.forward(
                                    to,
                                    0,
                                    &Message::Migrate { session: ssid, payload: parcel },
                                ) {
                                    Ok(()) => {
                                        op.phase = MigPhase::AwaitInject { rsid, to };
                                        break;
                                    }
                                    Err(e) => {
                                        for s in park.unpark(rsid) {
                                            table.drop_conn(
                                                s.conn,
                                                &format!(
                                                    "shard {to} unavailable during migration: {e}"
                                                ),
                                            );
                                        }
                                        continue;
                                    }
                                }
                            }
                            MigPhase::NeedCommit { rsid, to } => {
                                op.migrated += 1;
                                obs.event(
                                    0,
                                    "migrate_commit",
                                    vec![
                                        ("session", format!("{rsid:016x}")),
                                        ("to", format!("{to}")),
                                    ],
                                );
                                if let Some(&ssid) = remote.shards[to].sids.get(&rsid) {
                                    for s in park.unpark(rsid) {
                                        let fwd = match s.label {
                                            Some(l) => Message::StepLabeled {
                                                session: ssid,
                                                label: l,
                                                x: s.x,
                                            },
                                            None => Message::Step { session: ssid, x: s.x },
                                        };
                                        match remote.forward(to, 0, &fwd) {
                                            Ok(()) => {
                                                routed += 1;
                                                shard_routed[to] += 1;
                                            }
                                            Err(e) => table.drop_conn(
                                                s.conn,
                                                &format!("shard {to} unavailable: {e}"),
                                            ),
                                        }
                                    }
                                } else {
                                    for s in park.unpark(rsid) {
                                        table.drop_conn(
                                            s.conn,
                                            "migration target lost the session mapping",
                                        );
                                    }
                                }
                                continue;
                            }
                            p @ (MigPhase::AwaitParcel { .. }
                            | MigPhase::AwaitHello { .. }
                            | MigPhase::AwaitInject { .. }
                            | MigPhase::AwaitRetire { .. }) => {
                                op.phase = p;
                                break;
                            }
                            MigPhase::Done => {
                                op.phase = MigPhase::Done;
                                reshard_done =
                                    Some((op.admin, op.migrated, op.drained, op.started));
                                break;
                            }
                        }
                    }
                }
                if let Some((admin, migrated, drained, started)) = reshard_done {
                    reshard = None;
                    migrated_total += migrated;
                    if let Some(k) = drained {
                        active[k] = false;
                        if obs.enabled() {
                            obs.registry
                                .histogram(
                                    "m2ru_drain_duration_ms",
                                    "wall time of shard drains",
                                )
                                .observe(started.elapsed().as_millis() as u64);
                        }
                        obs.event(
                            0,
                            "drain_complete",
                            vec![
                                ("shard", format!("{k}")),
                                ("epoch", format!("{}", repoch.epoch())),
                                ("migrated", format!("{migrated}")),
                            ],
                        );
                    }
                    table.send(
                        admin,
                        &Message::Epoch {
                            epoch: repoch.epoch(),
                            shards: repoch.slots() as u32,
                        },
                    );
                }
                // a completed stats aggregation answers every waiter
                let complete =
                    stats.as_ref().map_or(false, |agg| agg.texts.iter().all(|t| t.is_some()));
                if complete {
                    let agg = stats.take().expect("checked above");
                    let text = remote_stats_text(
                        routed,
                        &shard_routed,
                        repoch.epoch(),
                        &agg.texts,
                        &table.drops,
                    );
                    for waiter in agg.waiters {
                        table.send(waiter, &Message::Stats { text: text.clone() });
                    }
                }
                // so does a completed metrics aggregation
                let mcomplete =
                    mdump.as_ref().map_or(false, |agg| agg.texts.iter().all(|t| t.is_some()));
                if mcomplete {
                    let MetricsAgg { selector, waiters, texts } =
                        mdump.take().expect("checked above");
                    let router = router_metrics_text(
                        &obs,
                        &selector,
                        routed,
                        n,
                        total_conns,
                        repoch.epoch(),
                        migrated_total,
                        &table.flow,
                        &table.drops,
                    );
                    let text = fleet_metrics_text(router, &texts, &selector);
                    for waiter in waiters {
                        table.send(waiter, &Message::MetricsDump { text: text.clone() });
                    }
                }
            }
            Ok(())
        })();

        // ---- teardown ---------------------------------------------------
        stop.store(true, Ordering::SeqCst);
        drop(rx);
        if conn::wake_acceptor(&listener) {
            let _ = acceptor.join();
        }
        table.close_all();
        serve_result?;

        // a local fleet that was not shut down through a client frame
        // (event channel closed) still stops cleanly and checkpoints
        if let Mode::Local(core) = &mut mode {
            if shard_reports.is_empty() {
                let (reports, _tail) = core.finish()?;
                shard_reports = reports;
            }
        }

        let epoch = match &mode {
            Mode::Local(core) => core.epoch().epoch(),
            Mode::Remote(_) => repoch.epoch(),
        };
        Ok(RouterReport {
            shards: n,
            remote: remote_mode,
            connections: total_conns,
            routed,
            shard_routed,
            shard_reports,
            shard_totals,
            restored_sessions,
            outbox_drops: table.drops.clone(),
            epoch,
            migrated: migrated_total,
        })
    }
}

/// The deterministic `key=value` header every router stats payload
/// starts with (stable order, machine-parseable — same contract as
/// [`ServeReport::kv_lines`]).
fn router_stats_header(
    mode: &str,
    shards: usize,
    routed: u64,
    epoch: u64,
    drops: &OutboxDrops,
) -> Vec<String> {
    vec![
        format!("router_mode={mode}"),
        format!("router_shards={shards}"),
        format!("router_routed={routed}"),
        format!("router_epoch={epoch}"),
        format!("router_outbox_drops_full={}", drops.full),
        format!("router_outbox_drops_timeout={}", drops.timeout),
        format!("router_outbox_drops_writer_failed={}", drops.writer_failed),
    ]
}

/// Aggregate stats text for an in-process fleet: the router header,
/// then each shard's `kv_lines` prefixed `shard<k>_`.
fn local_stats_text(
    routed: u64,
    shard_routed: &[u64],
    epoch: u64,
    reports: &[(usize, ServeReport)],
    drops: &OutboxDrops,
) -> String {
    let mut lines = router_stats_header("local", shard_routed.len(), routed, epoch, drops);
    for (k, rep) in reports {
        lines.push(format!("shard{k}_routed={}", shard_routed[*k]));
        for l in rep.kv_lines() {
            lines.push(format!("shard{k}_{l}"));
        }
    }
    lines.join("\n")
}

/// Aggregate stats text for a remote fleet: the router header, then
/// each shard's own stats payload (already `key=value` lines) prefixed
/// `shard<k>_`. Unreachable shards get `shard<k>_unreachable=1`.
fn remote_stats_text(
    routed: u64,
    shard_routed: &[u64],
    epoch: u64,
    texts: &[Option<String>],
    drops: &OutboxDrops,
) -> String {
    let mut lines = router_stats_header("remote", texts.len(), routed, epoch, drops);
    for (k, text) in texts.iter().enumerate() {
        lines.push(format!("shard{k}_routed={}", shard_routed[k]));
        match text {
            Some(t) if !t.starts_with("unreachable") => {
                for l in t.lines() {
                    lines.push(format!("shard{k}_{l}"));
                }
            }
            _ => lines.push(format!("shard{k}_unreachable=1")),
        }
    }
    lines.join("\n")
}

/// The router's own registry section of a fleet `MetricsDump`:
/// refreshes the router-plane mirrors, then renders. For the `events`
/// selector this is the router's flight-recorder JSONL instead.
fn router_metrics_text(
    obs: &crate::obs::Obs,
    selector: &str,
    routed: u64,
    shards: usize,
    conns: u64,
    epoch: u64,
    migrated: u64,
    flow: &OutboxFlow,
    drops: &OutboxDrops,
) -> String {
    if selector == "events" {
        return obs.recorder.dump_jsonl();
    }
    if !obs.enabled() {
        return "# observability disabled (obs.mode = \"off\")\n".to_string();
    }
    let reg = &obs.registry;
    reg.counter("m2ru_router_routed_total", "requests routed to shards").set(routed);
    reg.counter("m2ru_router_connections_total", "client connections accepted").set(conns);
    reg.gauge("m2ru_router_shards", "shards in the fleet").set(shards as f64);
    reg.gauge("m2ru_routing_epoch", "routing epoch in force (bumps per cutover)")
        .set(epoch as f64);
    reg.counter(
        "m2ru_sessions_migrated_total",
        "sessions migrated between shards by rebalances/drains",
    )
    .set(migrated);
    reg.gauge("m2ru_outbox_occupancy", "frames currently queued in writer outboxes")
        .set(flow.occupancy() as f64);
    for (name, v) in [
        ("m2ru_outbox_drops_full_total", drops.full),
        ("m2ru_outbox_drops_timeout_total", drops.timeout),
        ("m2ru_outbox_drops_writer_failed_total", drops.writer_failed),
    ] {
        reg.counter(name, "connections severed by outbox reason").set(v);
    }
    reg.counter(
        "m2ru_flight_events_dropped_total",
        "flight-recorder events evicted from the ring",
    )
    .set(obs.recorder.dropped());
    reg.render()
}

/// Assemble the fleet-wide `MetricsDump` response: the router's own
/// section, a fleet rollup (counters and histograms summed across
/// shards), then each shard's exposition relabeled `shard="<k>"`. For
/// the `events` selector: the router's JSONL followed by each reachable
/// shard's (unreachable markers are comment lines and are skipped, so
/// the dump stays line-by-line JSON-parseable).
fn fleet_metrics_text(router_text: String, texts: &[Option<String>], selector: &str) -> String {
    if selector == "events" {
        let mut out = router_text;
        for t in texts.iter().flatten() {
            if !t.starts_with('#') {
                out.push_str(t);
            }
        }
        return out;
    }
    let shard_texts: Vec<String> = texts
        .iter()
        .enumerate()
        .map(|(k, t)| t.clone().unwrap_or_else(|| format!("# shard {k} unreachable\n")))
        .collect();
    let mut out = String::from("# == router ==\n");
    out.push_str(&router_text);
    out.push_str("# == fleet (rollup of all shards) ==\n");
    out.push_str(&crate::obs::rollup(&shard_texts));
    for (k, t) in shard_texts.iter().enumerate() {
        out.push_str(&format!("# == shard {k} ==\n"));
        out.push_str(&crate::obs::relabel(t, "shard", &format!("{k}")));
    }
    out
}

/// Convenience wrapper: bind, route until shutdown.
pub fn run_router(opts: &RouterServeOptions) -> Result<RouterReport> {
    RouterServer::bind(opts.clone())?.run()
}
