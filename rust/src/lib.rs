//! # M2RU — Memristive Minion Recurrent Unit, full-system reproduction
//!
//! This crate is the Layer-3 runtime of a three-layer reproduction of
//! *"M2RU: Memristive Minion Recurrent Unit for On-Chip Continual Learning
//! at the Edge"* (Zyarah & Kudithipudi, 2025/2026):
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the weighted-bit-
//!   streaming crossbar VMM and fused MiRU cell, validated against pure-jnp
//!   oracles.
//! * **L2** — the JAX MiRU model and DFA/Adam training steps
//!   (`python/compile/model.py`), AOT-lowered once to HLO text.
//! * **L3** — this crate: the continual-learning coordinator. It owns the
//!   data-preparation unit (reservoir sampler → stochastic quantizer →
//!   replay buffer), the replay-mixed training loop, the memristor device
//!   and endurance models, the 65 nm @ 20 MHz architectural power/latency
//!   model, and the PJRT runtime that executes the AOT artifacts. Python
//!   is never on the request path.
//!
//! Module map (see `DESIGN.md` for the paper-subsystem ↔ module table):
//!
//! | module        | paper subsystem |
//! |---------------|-----------------|
//! | [`rng`]       | xorshift sampler core, LFSR of the stochastic quantizer |
//! | [`codec`]     | shared bounds-checked little-endian codec (wire frames + snapshots) |
//! | [`linalg`]    | dense matrix substrate (blocked matmul serving kernel) |
//! | [`nn`]        | MiRU Eqs. (1)–(3), DFA Algorithm 1, K-WTA ζ, Adam baseline |
//! | [`quant`]     | WBS input digitization, ADC model, replay quantizers |
//! | [`device`]    | memristor model, differential crossbar, endurance, Ziksa |
//! | [`backend`]   | pluggable compute substrates: dense CMOS baseline, crossbar datapath, AOT artifacts (Table I comparison) |
//! | [`hw_model`]  | §VI-C/D: latency, throughput, power, digital baseline |
//! | [`data`]      | synthetic permuted-MNIST / split-feature task streams |
//! | [`replay`]    | §IV-A data-preparation unit |
//! | [`runtime`]   | PJRT client; loads `artifacts/*.hlo.txt` |
//! | [`coordinator`]| trainer, batcher, parallel serving engine, tile scheduler, metrics |
//! | [`serve`]     | streaming session server: per-user state, dynamic batching, online learning, checkpoint/restore |
//! | [`net`]       | TCP serving frontend: wire protocol, accept loop, client + load generator, multi-shard session router |
//! | [`obs`]       | serve-path observability: atomic metrics registry, stage-span histograms, flight recorder |
//! | [`config`]    | network configs + run/backend selection + TOML-subset loader |
//! | [`cli`]       | argument parsing for the `m2ru` binary |
//! | [`experiments`]| regenerates every paper figure/table |
//! | [`proptest`]  | in-tree property-testing mini-framework |

pub mod backend;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod experiments;
pub mod hw_model;
pub mod linalg;
pub mod net;
pub mod nn;
pub mod obs;
pub mod proptest;
pub mod quant;
pub mod replay;
pub mod rng;
pub mod runtime;
pub mod serve;
