//! Shared little-endian byte codec: the one bounds-checked cursor pair
//! behind every binary format in the crate.
//!
//! Two independent hand-rolled copies of this logic used to live in
//! `net::wire` (the TCP frame payloads) and `serve::checkpoint` (the
//! snapshot files). Both formats need *identical* truncation semantics —
//! a hostile or truncated length field must error before it can reach
//! the allocator, and must never panic — and two copies of that rule can
//! drift apart. This module is the single implementation both layers
//! use, so a bounds-handling fix lands everywhere at once:
//!
//! * [`LeWriter`] — append-only little-endian byte sink.
//! * [`LeReader`] — bounds-checked cursor over a byte slice; every
//!   `take` is length-checked with subtraction (never multiplication, so
//!   nothing can overflow on 32-bit targets), counted vectors verify the
//!   declared element count against the remaining bytes *before*
//!   allocating, and [`LeReader::done`] rejects trailing bytes.
//!
//! All integers are little-endian, matching the wire protocol
//! (DESIGN.md §9) and the snapshot format (DESIGN.md §10).

use anyhow::{ensure, Result};

/// Append-only little-endian byte sink.
#[derive(Default)]
pub struct LeWriter {
    buf: Vec<u8>,
}

impl LeWriter {
    pub fn new() -> LeWriter {
        LeWriter { buf: Vec::new() }
    }

    /// Writer over an existing buffer (prefix already laid down).
    pub fn from_vec(buf: Vec<u8>) -> LeWriter {
        LeWriter { buf }
    }

    /// The encoded bytes.
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Raw bytes, no length prefix.
    pub fn raw(&mut self, vs: &[u8]) {
        self.buf.extend_from_slice(vs);
    }

    /// `u32` count followed by the f32 values.
    pub fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }

    /// `u32` count followed by the u64 values.
    pub fn u64s(&mut self, vs: &[u64]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.u64(v);
        }
    }

    /// `u32` count followed by the raw bytes.
    pub fn bytes(&mut self, vs: &[u8]) {
        self.u32(vs.len() as u32);
        self.buf.extend_from_slice(vs);
    }
}

/// Bounds-checked little-endian cursor over a byte slice. Malformed
/// input — truncation, counted vectors past the end, trailing bytes —
/// decodes to an error, never a panic or an unbounded allocation.
pub struct LeReader<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> LeReader<'a> {
    pub fn new(b: &'a [u8]) -> LeReader<'a> {
        LeReader { b, p: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.p
    }

    /// Take `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.b.len() - self.p >= n, "truncated at byte {}", self.p);
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let s = self.take(8)?;
        Ok(f64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    /// Counted f32 vector. The declared count is validated against the
    /// remaining bytes with a division (a `n * 4` product could wrap on
    /// 32-bit targets) before any allocation happens.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        ensure!((self.b.len() - self.p) / 4 >= n, "truncated at byte {}", self.p);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    /// Counted u64 vector, count validated before allocation.
    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        ensure!((self.b.len() - self.p) / 8 >= n, "truncated at byte {}", self.p);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    /// Counted raw byte vector.
    pub fn byte_vec(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Assert the whole input was consumed (no trailing bytes).
    pub fn done(&self) -> Result<()> {
        ensure!(self.p == self.b.len(), "{} trailing bytes", self.b.len() - self.p);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_primitive() {
        let mut w = LeWriter::new();
        w.u8(7);
        w.u16(0xBEEF);
        w.u32(0xDEAD_BEEF);
        w.u64(0x0123_4567_89AB_CDEF);
        w.f32(-1.5);
        w.f64(std::f64::consts::PI);
        w.f32s(&[0.25, -0.5]);
        w.u64s(&[1, 2, 3]);
        w.bytes(b"abc");
        let buf = w.into_vec();
        let mut r = LeReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.f32s().unwrap(), vec![0.25, -0.5]);
        assert_eq!(r.u64s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.byte_vec().unwrap(), b"abc".to_vec());
        r.done().unwrap();
    }

    #[test]
    fn truncation_errors_never_panic() {
        let mut w = LeWriter::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let buf = w.into_vec();
        for cut in 0..buf.len() {
            let mut r = LeReader::new(&buf[..cut]);
            assert!(r.f32s().is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn hostile_count_rejected_before_allocation() {
        // a count claiming 1 G floats over a 4-byte body must be rejected
        // by the remaining-bytes check, not by the allocator
        let mut w = LeWriter::new();
        w.u32(1 << 30);
        w.u32(0);
        let buf = w.into_vec();
        let mut r = LeReader::new(&buf);
        assert!(r.f32s().unwrap_err().to_string().contains("truncated"));
        let mut r2 = LeReader::new(&buf);
        assert!(r2.u64s().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = LeWriter::new();
        w.u32(5);
        w.u8(0);
        let buf = w.into_vec();
        let mut r = LeReader::new(&buf);
        r.u32().unwrap();
        assert!(r.done().unwrap_err().to_string().contains("trailing"));
        assert_eq!(r.remaining(), 1);
    }
}
