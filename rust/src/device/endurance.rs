//! Endurance / lifespan analysis (§VI-B, Fig. 5b).
//!
//! Training writes wear devices out. We collect per-device write counters
//! from the crossbars over a continual-learning run, build the CDF the
//! paper plots, project the distribution forward to the endurance limit
//! (the "overstressed" shaded region), and translate mean write pressure
//! into an expected lifespan in years at a given learning rate.

pub const SECONDS_PER_YEAR: f64 = 365.25 * 24.0 * 3600.0;

/// Summary of write activity over a training run of `updates` steps.
#[derive(Clone, Debug)]
pub struct EnduranceReport {
    /// Per-device writes accumulated during the measured run (sorted asc).
    pub sorted_writes: Vec<u64>,
    /// Number of parameter-update steps in the measured run.
    pub updates: u64,
    /// Mean writes per device over the run.
    pub mean_writes: f64,
    /// Total write operations.
    pub total_writes: u64,
}

impl EnduranceReport {
    pub fn from_counts(mut counts: Vec<u64>, updates: u64) -> Self {
        counts.sort_unstable();
        let total: u64 = counts.iter().sum();
        let mean = total as f64 / counts.len().max(1) as f64;
        Self { sorted_writes: counts, updates, mean_writes: mean, total_writes: total }
    }

    /// CDF sample points: (writes, fraction of devices ≤ writes).
    pub fn cdf(&self, points: usize) -> Vec<(u64, f64)> {
        let n = self.sorted_writes.len();
        if n == 0 {
            return Vec::new();
        }
        (1..=points)
            .map(|i| {
                let idx = (i * n / points).max(1) - 1;
                (self.sorted_writes[idx], (idx + 1) as f64 / n as f64)
            })
            .collect()
    }

    /// Project the measured distribution forward to a horizon of
    /// `horizon_updates` steps and return the fraction of devices whose
    /// projected writes exceed `endurance` — the paper's "overstressed"
    /// fraction (58.28% before sparsification at the plotted horizon).
    pub fn overstressed_fraction(&self, endurance: u64, horizon_updates: u64) -> f64 {
        if self.updates == 0 || self.sorted_writes.is_empty() {
            return 0.0;
        }
        let scale = horizon_updates as f64 / self.updates as f64;
        let over = self
            .sorted_writes
            .iter()
            .filter(|&&w| w as f64 * scale > endurance as f64)
            .count();
        over as f64 / self.sorted_writes.len() as f64
    }

    /// Mean writes per device per update step.
    pub fn writes_per_update(&self) -> f64 {
        if self.updates == 0 {
            0.0
        } else {
            self.mean_writes / self.updates as f64
        }
    }
}

/// Expected lifespan in years: a device endures `endurance` writes; the
/// mean write pressure is `writes_per_update` per step at `update_rate_hz`
/// steps per second (paper: 1 kHz ⇒ "learning at a rate of 1 ms").
pub fn lifespan_years(endurance: u64, writes_per_update: f64, update_rate_hz: f64) -> f64 {
    if writes_per_update <= 0.0 || update_rate_hz <= 0.0 {
        return f64::INFINITY;
    }
    endurance as f64 / (writes_per_update * update_rate_hz) / SECONDS_PER_YEAR
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_is_monotone_and_ends_at_one() {
        let rep = EnduranceReport::from_counts(vec![5, 1, 3, 3, 9, 2, 7, 4], 10);
        let cdf = rep.cdf(8);
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_and_total() {
        let rep = EnduranceReport::from_counts(vec![2, 4, 6], 3);
        assert_eq!(rep.total_writes, 12);
        assert!((rep.mean_writes - 4.0).abs() < 1e-12);
        assert!((rep.writes_per_update() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn overstressed_scales_with_horizon() {
        // half the devices write 2x as often
        let counts = vec![1u64; 50].into_iter().chain(vec![2u64; 50]).collect::<Vec<_>>();
        let rep = EnduranceReport::from_counts(counts, 1);
        // horizon such that only the heavy half crosses endurance 100:
        // heavy: 2*60 = 120 > 100; light: 60 < 100.
        let f = rep.overstressed_fraction(100, 60);
        assert!((f - 0.5).abs() < 1e-12);
        assert_eq!(rep.overstressed_fraction(100, 10), 0.0);
        assert_eq!(rep.overstressed_fraction(100, 1000), 1.0);
    }

    #[test]
    fn lifespan_matches_paper_arithmetic() {
        // Paper: ~6.9 years @ 1 ms updates, 1e9 endurance. Back out the
        // implied write pressure and confirm the inverse relation the
        // sparsification argument relies on (47% fewer writes → ~1.9x life).
        let implied = 1.0e9 / (6.9 * SECONDS_PER_YEAR) / 1000.0;
        let years = lifespan_years(1_000_000_000, implied, 1000.0);
        assert!((years - 6.9).abs() < 0.05, "{years}");
        let years_sparse = lifespan_years(1_000_000_000, implied * (8.5 / 16.0), 1000.0);
        assert!(years_sparse > 12.0 && years_sparse < 13.5, "{years_sparse}");
    }

    #[test]
    fn zero_pressure_is_infinite_life() {
        assert!(lifespan_years(1_000_000_000, 0.0, 1000.0).is_infinite());
    }
}
