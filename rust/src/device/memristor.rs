//! Behavioural memristor model (VTEAM-lite).
//!
//! We model what the architecture observes: a programmable conductance in
//! [1/R_off, 1/R_on], discretized to a finite number of programmable
//! levels, with cycle-to-cycle write noise, a fixed per-device
//! device-to-device deviation, and a finite write endurance after which
//! the device loses elasticity (freezes at its last conductance — the
//! paper's "loss of elasticity feature", §VI-B).

use crate::rng::GaussianRng;

/// Published device parameters (§V-B): TaOx-fitted VTEAM model.
#[derive(Clone, Copy, Debug)]
pub struct DeviceParams {
    /// Low-resistance state, Ω (R_on = 2 MΩ → g_max = 500 nS).
    pub r_on: f64,
    /// High-resistance state, Ω (R_off = 20 MΩ → g_min = 50 nS).
    pub r_off: f64,
    /// Programmable conductance levels between g_min and g_max.
    pub levels: u32,
    /// Cycle-to-cycle write variability (σ as a fraction of the target
    /// conductance step; paper: 10%).
    pub c2c_sigma: f64,
    /// Device-to-device variability (σ as a fraction of conductance).
    pub d2d_sigma: f64,
    /// Write endurance in cycles (paper sweep 1e6–1e12; 1e9 default).
    pub endurance: u64,
}

impl Default for DeviceParams {
    fn default() -> Self {
        Self {
            r_on: 2.0e6,
            r_off: 20.0e6,
            // 8-bit multilevel programming (Ziksa): coarser grids swallow
            // the ζ-sparsified DFA deltas and stall on-chip learning —
            // see EXPERIMENTS.md §Calibration.
            levels: 256,
            c2c_sigma: 0.10,
            d2d_sigma: 0.10,
            endurance: 1_000_000_000,
        }
    }
}

impl DeviceParams {
    pub fn g_min(&self) -> f64 {
        1.0 / self.r_off
    }
    pub fn g_max(&self) -> f64 {
        1.0 / self.r_on
    }
    /// Mid-window conductance — the fixed reference devices of Fig. 2.
    pub fn g_ref(&self) -> f64 {
        0.5 * (self.g_min() + self.g_max())
    }
    /// One programmable conductance step.
    pub fn g_step(&self) -> f64 {
        (self.g_max() - self.g_min()) / f64::from(self.levels - 1)
    }
    /// Snap a conductance to the nearest programmable level.
    pub fn quantize_g(&self, g: f64) -> f64 {
        let clamped = g.clamp(self.g_min(), self.g_max());
        let level = ((clamped - self.g_min()) / self.g_step()).round();
        self.g_min() + level * self.g_step()
    }
}

/// One tunable device in a crossbar.
#[derive(Clone, Debug)]
pub struct Memristor {
    /// Current (true) conductance, S.
    pub g: f64,
    /// Fixed multiplicative device-to-device deviation (≈ N(1, d2d_sigma)).
    pub d2d: f64,
    /// Accumulated write operations.
    pub writes: u64,
    /// Elasticity lost (writes exceeded endurance): further programming
    /// is a no-op, reads still work.
    pub frozen: bool,
}

impl Memristor {
    /// Fresh device at the reference conductance with sampled d2d factor.
    pub fn new(params: &DeviceParams, rng: &mut GaussianRng) -> Self {
        Self {
            g: params.g_ref(),
            d2d: (1.0 + params.d2d_sigma * f64::from(rng.normal())).max(0.5),
            writes: 0,
            frozen: false,
        }
    }

    /// Program toward `target` conductance. Counts one write cycle, snaps
    /// to the level grid and adds cycle-to-cycle noise. No-op (except for
    /// the attempt) once the device is frozen.
    pub fn program(&mut self, target: f64, params: &DeviceParams, rng: &mut GaussianRng) {
        if self.frozen {
            return;
        }
        self.writes += 1;
        if self.writes > params.endurance {
            self.frozen = true;
            return;
        }
        let ideal = params.quantize_g(target);
        let noise = params.g_step() * params.c2c_sigma * f64::from(rng.normal());
        self.g = (ideal + noise).clamp(params.g_min(), params.g_max());
    }

    /// Conductance as the read circuit sees it (d2d deviation applied).
    pub fn read(&self) -> f64 {
        self.g * self.d2d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_window() {
        let p = DeviceParams::default();
        assert!((p.g_min() - 5.0e-8).abs() < 1e-12);
        assert!((p.g_max() - 5.0e-7).abs() < 1e-12);
        assert!(p.g_ref() > p.g_min() && p.g_ref() < p.g_max());
    }

    #[test]
    fn quantize_snaps_to_grid_and_clamps() {
        let p = DeviceParams::default();
        let q = p.quantize_g(p.g_min() + 1.4 * p.g_step());
        assert!((q - (p.g_min() + p.g_step())).abs() < 1e-15);
        assert_eq!(p.quantize_g(1.0), p.g_max());
        assert_eq!(p.quantize_g(0.0), p.g_min());
    }

    #[test]
    fn program_counts_writes_and_stays_in_window() {
        let p = DeviceParams::default();
        let mut rng = GaussianRng::new(0);
        let mut m = Memristor::new(&p, &mut rng);
        for i in 0..1000 {
            let t = p.g_min() + (i as f64 / 999.0) * (p.g_max() - p.g_min());
            m.program(t, &p, &mut rng);
            assert!(m.g >= p.g_min() && m.g <= p.g_max());
        }
        assert_eq!(m.writes, 1000);
    }

    #[test]
    fn endurance_freezes_device() {
        let p = DeviceParams { endurance: 10, ..DeviceParams::default() };
        let mut rng = GaussianRng::new(1);
        let mut m = Memristor::new(&p, &mut rng);
        for _ in 0..20 {
            m.program(p.g_max(), &p, &mut rng);
        }
        assert!(m.frozen);
        let g_before = m.g;
        m.program(p.g_min(), &p, &mut rng);
        assert_eq!(m.g, g_before, "frozen device must not move");
        assert_eq!(m.writes, 11, "writes stop accumulating after freeze");
    }

    #[test]
    fn c2c_noise_is_bounded_relative_to_step() {
        let p = DeviceParams::default();
        let mut rng = GaussianRng::new(2);
        let mut m = Memristor::new(&p, &mut rng);
        let target = p.g_ref();
        let mut max_dev: f64 = 0.0;
        for _ in 0..500 {
            m.program(target, &p, &mut rng);
            max_dev = max_dev.max((m.g - p.quantize_g(target)).abs());
        }
        // 5 sigma of 10% of a step
        assert!(max_dev < 5.0 * p.c2c_sigma * p.g_step(), "{max_dev}");
    }

    #[test]
    fn d2d_is_fixed_per_device() {
        let p = DeviceParams::default();
        let mut rng = GaussianRng::new(3);
        let m = Memristor::new(&p, &mut rng);
        let r1 = m.read();
        let r2 = m.read();
        assert_eq!(r1, r2);
        assert!((m.read() / m.g - m.d2d).abs() < 1e-12);
    }
}
