//! Memristor device and crossbar substrate (§IV, §V-B device setup).
//!
//! The paper's evaluation used a Verilog-A VTEAM model fitted to TaOx
//! devices; this module is the behavioural equivalent with the same
//! published parameters: R_on = 2 MΩ, R_off = 20 MΩ, set/reset ≤ 1.2 V,
//! ±1 V threshold, 10% cycle-to-cycle and device-to-device variability,
//! endurance 10⁶–10¹² cycles (10⁹ default for the lifespan study).

mod crossbar;
mod endurance;
mod integrator;
mod memristor;
mod programming;
mod vteam;

pub use crossbar::DifferentialCrossbar;
pub use endurance::{lifespan_years, EnduranceReport, SECONDS_PER_YEAR};
pub use integrator::{IntegratorSpec, RetentionReport};
pub use memristor::{DeviceParams, Memristor};
pub use programming::{WriteEvent, ZiksaProgrammer};
pub use vteam::{VteamDevice, VteamParams};
