//! Differential memristive crossbar (Fig. 2-Left, Eq. 7).
//!
//! Each synaptic weight maps to one tunable device against a fixed
//! reference column initialized at the mid-window conductance; the bipolar
//! weight is the conductance difference. The crossbar exposes:
//!
//! * `program_weights` — full (re)programming, one write per device;
//! * `apply_deltas` — incremental training writes, one write per *changed*
//!   device (this is the endurance accounting hook for Fig. 5b);
//! * `read_weights` — the weights the analog VMM actually realizes, with
//!   conductance discretization and device variability folded in;
//! * `vmm` — the ideal analog dot product over the read weights (used by
//!   the Fig. 5a replay-error study).

use crate::linalg::Mat;
use crate::rng::GaussianRng;

use super::memristor::{DeviceParams, Memristor};

/// A rows×cols differential crossbar storing weights in [-w_max, +w_max].
#[derive(Clone, Debug)]
pub struct DifferentialCrossbar {
    pub rows: usize,
    pub cols: usize,
    pub params: DeviceParams,
    /// Weight magnitude that maps to the full conductance swing.
    pub w_max: f32,
    devices: Vec<Memristor>,
    rng: GaussianRng,
}

impl DifferentialCrossbar {
    pub fn new(rows: usize, cols: usize, w_max: f32, params: DeviceParams, seed: u64) -> Self {
        let mut rng = GaussianRng::new(seed);
        let devices = (0..rows * cols).map(|_| Memristor::new(&params, &mut rng)).collect();
        Self { rows, cols, params, w_max, devices, rng }
    }

    #[inline]
    fn idx(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        r * self.cols + c
    }

    /// Map a weight to a target conductance: g_ref + (w/w_max)·(swing/2).
    fn weight_to_g(&self, w: f32) -> f64 {
        let half_swing = 0.5 * (self.params.g_max() - self.params.g_min());
        self.params.g_ref() + f64::from((w / self.w_max).clamp(-1.0, 1.0)) * half_swing
    }

    /// Inverse map on the *read* conductance (reference column is ideal).
    fn g_to_weight(&self, g: f64) -> f32 {
        let half_swing = 0.5 * (self.params.g_max() - self.params.g_min());
        ((g - self.params.g_ref()) / half_swing) as f32 * self.w_max
    }

    /// Program every device to realize `w` (ex-situ load). One write each.
    pub fn program_weights(&mut self, w: &Mat) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        for r in 0..self.rows {
            for c in 0..self.cols {
                let target = self.weight_to_g(w.at(r, c));
                let i = self.idx(r, c);
                self.devices[i].program(target, &self.params.clone(), &mut self.rng);
            }
        }
    }

    /// In-situ training update: program only the devices whose delta is
    /// non-zero (the K-WTA-sparsified write set). Returns the number of
    /// write operations issued.
    pub fn apply_deltas(&mut self, delta: &Mat) -> u64 {
        assert_eq!((delta.rows, delta.cols), (self.rows, self.cols));
        let mut writes = 0;
        for r in 0..self.rows {
            for c in 0..self.cols {
                let d = delta.at(r, c);
                if d == 0.0 {
                    continue;
                }
                let i = self.idx(r, c);
                let current_w = self.g_to_weight(self.devices[i].g);
                let target = self.weight_to_g(current_w + d);
                self.devices[i].program(target, &self.params.clone(), &mut self.rng);
                writes += 1;
            }
        }
        writes
    }

    /// The weights the analog computation realizes right now:
    /// discretization and c2c noise are baked in by programming; the d2d
    /// deviation acts on the *differential* conductance (tunable and
    /// reference devices drift together to first order, so the net weight
    /// sees a ~10% relative error — the paper's variability bound).
    pub fn read_weights(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| {
            let dev = &self.devices[self.idx(r, c)];
            self.g_to_weight(dev.g) * dev.d2d as f32
        })
    }

    /// Ideal analog VMM over the realized weights: x[b,rows] → [b,cols].
    pub fn vmm(&self, x: &Mat) -> Mat {
        x.matmul(&self.read_weights())
    }

    /// Per-device write counters (row-major), for endurance analysis.
    pub fn write_counts(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.writes).collect()
    }

    /// Overwrite every device's write counter (row-major, checkpoint
    /// restore). Elasticity follows the endurance model: a restored
    /// counter beyond the endurance limit re-freezes its device, exactly
    /// as continued programming would have ([`Memristor::program`]
    /// freezes once `writes > endurance`).
    pub fn restore_write_counts(&mut self, counts: &[u64]) {
        assert_eq!(counts.len(), self.devices.len(), "wear record size mismatch");
        let endurance = self.params.endurance;
        for (d, &w) in self.devices.iter_mut().zip(counts) {
            d.writes = w;
            d.frozen = w > endurance;
        }
    }

    /// Cumulative writes per bitline column (summed over the column's
    /// devices) — the wear signal the serve-path write-rationing policy
    /// consults before each online commit.
    pub fn column_write_counts(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c] += self.devices[r * self.cols + c].writes;
            }
        }
        out
    }

    /// Fault injection: freeze a random fraction of devices at their
    /// current conductance (endurance exhaustion / stuck-at faults). The
    /// frozen devices still read, but no longer program — the §VI-B
    /// "loss of elasticity" failure mode, injected on demand for the
    /// fault-tolerance study.
    pub fn freeze_fraction(&mut self, frac: f64) -> usize {
        let n = self.devices.len();
        let target = ((frac.clamp(0.0, 1.0)) * n as f64).round() as usize;
        let mut order: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut order);
        let mut frozen = 0;
        for &i in &order {
            if frozen >= target {
                break;
            }
            if !self.devices[i].frozen {
                self.devices[i].frozen = true;
                frozen += 1;
            }
        }
        frozen
    }

    /// Fraction of devices that lost elasticity.
    pub fn frozen_fraction(&self) -> f64 {
        self.devices.iter().filter(|d| d.frozen).count() as f64 / self.devices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_xbar(seed: u64) -> DifferentialCrossbar {
        DifferentialCrossbar::new(8, 6, 1.0, DeviceParams::default(), seed)
    }

    #[test]
    fn program_then_read_roundtrips_within_quantization() {
        let mut xb = small_xbar(0);
        let w = Mat::from_fn(8, 6, |r, c| ((r * 6 + c) as f32 / 47.0) * 1.6 - 0.8);
        xb.program_weights(&w);
        let got = xb.read_weights();
        // error budget: 1 level of discretization + c2c + d2d (σ=10%,
        // allow ~3.5σ tails on the relative term)
        let lvl = 2.0 / 63.0; // one level in weight units (w_max=1)
        for (a, b) in got.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 0.5 * lvl + 0.35 * b.abs() + 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn weights_clamp_to_w_max() {
        let mut xb = small_xbar(1);
        let w = Mat::from_fn(8, 6, |_, _| 5.0);
        xb.program_weights(&w);
        let got = xb.read_weights();
        for &v in &got.data {
            assert!(v <= 1.0 * 1.5, "{v}"); // w_max + d2d headroom
        }
    }

    #[test]
    fn apply_deltas_counts_only_nonzero() {
        let mut xb = small_xbar(2);
        xb.program_weights(&Mat::zeros(8, 6));
        let mut delta = Mat::zeros(8, 6);
        *delta.at_mut(0, 0) = 0.1;
        *delta.at_mut(3, 4) = -0.2;
        let writes = xb.apply_deltas(&delta);
        assert_eq!(writes, 2);
        let counts = xb.write_counts();
        assert_eq!(counts.iter().filter(|&&c| c == 2).count(), 2);
        assert_eq!(counts.iter().filter(|&&c| c == 1).count(), 46);
    }

    #[test]
    fn deltas_move_weights_in_right_direction() {
        let mut xb = small_xbar(3);
        xb.program_weights(&Mat::zeros(8, 6));
        let before = xb.read_weights().at(2, 2);
        let mut delta = Mat::zeros(8, 6);
        *delta.at_mut(2, 2) = 0.4;
        xb.apply_deltas(&delta);
        let after = xb.read_weights().at(2, 2);
        assert!(after > before + 0.2, "{before} -> {after}");
    }

    #[test]
    fn vmm_matches_read_weights_matmul() {
        let mut xb = small_xbar(4);
        let w = Mat::from_fn(8, 6, |r, c| (r as f32 - c as f32) * 0.1);
        xb.program_weights(&w);
        let x = Mat::from_fn(3, 8, |r, c| (r + c) as f32 * 0.05);
        let got = xb.vmm(&x);
        let want = x.matmul(&xb.read_weights());
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = small_xbar(7);
        let mut b = small_xbar(7);
        let w = Mat::from_fn(8, 6, |r, _| r as f32 * 0.1 - 0.3);
        a.program_weights(&w);
        b.program_weights(&w);
        assert_eq!(a.read_weights().data, b.read_weights().data);
    }
}
