//! Ziksa-style write scheduling (§IV-B2, ref. [34]).
//!
//! The training module turns gradient deltas into device programming: the
//! write-control logic walks the sparsified delta matrices, schedules
//! set/reset pulses per device, and reports write events for endurance
//! accounting and energy estimation. We model the scheduler's observable
//! behaviour: pulse counts per update, per-crossbar write tallies, and the
//! write-energy hook consumed by `hw_model::power`.

use crate::linalg::Mat;

use super::crossbar::DifferentialCrossbar;

/// One crossbar update event (per train step, per crossbar).
#[derive(Clone, Copy, Debug, Default)]
pub struct WriteEvent {
    /// Devices programmed this step.
    pub writes: u64,
    /// Devices skipped because the delta was ζ-zeroed.
    pub skipped: u64,
    /// Sum of |Δw| actually applied (energy model input).
    pub delta_magnitude: f64,
}

/// Write controller wrapping the three weight crossbars of one MiRU layer
/// stack (W_h, U_h stacked on the hidden crossbar; W_o on the readout).
#[derive(Clone, Debug)]
pub struct ZiksaProgrammer {
    /// Cumulative events, for reporting.
    pub total: WriteEvent,
    /// Events of the last `apply` call.
    pub last: WriteEvent,
    /// Update steps issued.
    pub steps: u64,
}

impl Default for ZiksaProgrammer {
    fn default() -> Self {
        Self::new()
    }
}

impl ZiksaProgrammer {
    pub fn new() -> Self {
        Self { total: WriteEvent::default(), last: WriteEvent::default(), steps: 0 }
    }

    /// Apply one delta matrix to one crossbar, recording write pressure.
    pub fn apply(&mut self, xbar: &mut DifferentialCrossbar, delta: &Mat) -> WriteEvent {
        let writes = xbar.apply_deltas(delta);
        let nonzero_mag: f64 =
            delta.data.iter().filter(|&&d| d != 0.0).map(|&d| f64::from(d.abs())).sum();
        let ev = WriteEvent {
            writes,
            skipped: (delta.data.len() as u64).saturating_sub(writes),
            delta_magnitude: nonzero_mag,
        };
        self.last = ev;
        self.total.writes += ev.writes;
        self.total.skipped += ev.skipped;
        self.total.delta_magnitude += ev.delta_magnitude;
        self.steps += 1;
        ev
    }

    /// Mean writes per step so far.
    pub fn writes_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.total.writes as f64 / self.steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceParams;

    #[test]
    fn sparse_delta_reduces_writes() {
        let mut xb = DifferentialCrossbar::new(10, 10, 1.0, DeviceParams::default(), 0);
        xb.program_weights(&Mat::zeros(10, 10));
        let mut prog = ZiksaProgrammer::new();

        let dense = Mat::from_fn(10, 10, |_, _| 0.01);
        let ev_dense = prog.apply(&mut xb, &dense);
        assert_eq!(ev_dense.writes, 100);
        assert_eq!(ev_dense.skipped, 0);

        let mut sparse = Mat::zeros(10, 10);
        for i in 0..53 {
            sparse.data[i] = 0.01;
        }
        let ev_sparse = prog.apply(&mut xb, &sparse);
        assert_eq!(ev_sparse.writes, 53);
        assert_eq!(ev_sparse.skipped, 47);

        assert_eq!(prog.steps, 2);
        assert_eq!(prog.total.writes, 153);
        assert!((prog.writes_per_step() - 76.5).abs() < 1e-9);
    }

    #[test]
    fn delta_magnitude_accumulates_abs() {
        let mut xb = DifferentialCrossbar::new(2, 2, 1.0, DeviceParams::default(), 1);
        xb.program_weights(&Mat::zeros(2, 2));
        let mut prog = ZiksaProgrammer::new();
        let delta = Mat::from_vec(2, 2, vec![0.1, -0.2, 0.0, 0.3]);
        let ev = prog.apply(&mut xb, &delta);
        assert!((ev.delta_magnitude - 0.6).abs() < 1e-6);
        assert_eq!(ev.writes, 3);
    }
}
