//! VTEAM memristor dynamics (Kvatinsky et al. [38]), fitted to the TaOx
//! device of [39] — the model the paper simulates in Verilog-A.
//!
//! State variable w ∈ [0, 1] (normalized filament position):
//!
//!   dw/dt = k_off · (v/v_off − 1)^α    for v > v_off  (reset → R_off)
//!   dw/dt = −k_on · (v/v_on − 1)^α     for v < v_on   (set  → R_on)
//!   dw/dt = 0 otherwise                 (|v| below threshold)
//!
//! with conductance linear in the state: G(w) = g_max − w·(g_max − g_min).
//! The paper's constraints (§V-B): set/reset ≤ 1.2 V, threshold ±1 V —
//! reads at 0.1 V (WBS pulses) must never disturb the state, and a 1.2 V
//! Ziksa pulse train programs the device incrementally. `ZiksaProgrammer`
//! can drive this model as the pulse-level alternative to the behavioural
//! `Memristor::program` (same observable: conductance + write count).

/// VTEAM parameters, TaOx fit.
#[derive(Clone, Copy, Debug)]
pub struct VteamParams {
    /// Threshold voltages, V (paper: ±1.0).
    pub v_on: f64,
    pub v_off: f64,
    /// Rate constants, 1/s — fitted so one 1.2 V / 1 µs pulse moves the
    /// state by ≈1/64 of the window (≈64-pulse full traversal, multilevel).
    pub k_on: f64,
    pub k_off: f64,
    /// Nonlinearity exponent.
    pub alpha: f64,
    /// Conductance window (shared with `DeviceParams`).
    pub g_min: f64,
    pub g_max: f64,
}

impl Default for VteamParams {
    fn default() -> Self {
        Self {
            v_on: -1.0,
            v_off: 1.0,
            // (1.2/1.0 − 1)^1 = 0.2 ⇒ k·0.2·1µs = 1/64 ⇒ k = 78_125
            k_on: 78_125.0,
            k_off: 78_125.0,
            alpha: 1.0,
            g_min: 5.0e-8,
            g_max: 5.0e-7,
        }
    }
}

/// One VTEAM device.
#[derive(Clone, Debug)]
pub struct VteamDevice {
    /// Normalized state: 0 = fully ON (g_max), 1 = fully OFF (g_min).
    pub w: f64,
    pub params: VteamParams,
}

impl VteamDevice {
    pub fn at_state(w: f64, params: VteamParams) -> Self {
        Self { w: w.clamp(0.0, 1.0), params }
    }

    /// Current conductance.
    pub fn conductance(&self) -> f64 {
        self.params.g_max - self.w * (self.params.g_max - self.params.g_min)
    }

    /// Apply a voltage for `dt` seconds (explicit Euler — fine for the
    /// pulse widths used here).
    pub fn apply(&mut self, v: f64, dt: f64) {
        let p = &self.params;
        let dwdt = if v > p.v_off {
            p.k_off * (v / p.v_off - 1.0).powf(p.alpha)
        } else if v < p.v_on {
            -p.k_on * (v / p.v_on - 1.0).powf(p.alpha)
        } else {
            0.0
        };
        self.w = (self.w + dwdt * dt).clamp(0.0, 1.0);
    }

    /// One Ziksa programming pulse: ±1.2 V for 1 µs. `toward_off` raises
    /// resistance (reset), otherwise lowers it (set).
    pub fn ziksa_pulse(&mut self, toward_off: bool) {
        self.apply(if toward_off { 1.2 } else { -1.2 }, 1.0e-6);
    }

    /// Pulses needed to move from the current conductance to `target`
    /// (the write-energy / write-latency unit the scheduler bills).
    pub fn pulses_to(&self, target_g: f64) -> u32 {
        let span = self.params.g_max - self.params.g_min;
        let delta_w = ((self.conductance() - target_g) / span).abs();
        // one pulse ≈ 1/64 of the window (see k fit)
        (delta_w * 64.0).ceil() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_voltage_never_disturbs_state() {
        // WBS pulses are 0.1 V — far below the ±1 V threshold.
        let mut d = VteamDevice::at_state(0.5, VteamParams::default());
        for _ in 0..1_000_000 {
            d.apply(0.1, 50e-9);
            d.apply(-0.1, 50e-9);
        }
        assert_eq!(d.w, 0.5);
    }

    #[test]
    fn sub_threshold_exactly_at_1v_is_safe() {
        let mut d = VteamDevice::at_state(0.3, VteamParams::default());
        d.apply(1.0, 1.0);
        d.apply(-1.0, 1.0);
        assert_eq!(d.w, 0.3);
    }

    #[test]
    fn ziksa_pulse_moves_one_level() {
        let mut d = VteamDevice::at_state(0.5, VteamParams::default());
        d.ziksa_pulse(true);
        assert!((d.w - 0.5 - 1.0 / 64.0).abs() < 1e-9, "{}", d.w);
        d.ziksa_pulse(false);
        assert!((d.w - 0.5).abs() < 1e-9);
    }

    #[test]
    fn full_window_traversal_in_64_pulses() {
        let mut d = VteamDevice::at_state(0.0, VteamParams::default());
        for _ in 0..64 {
            d.ziksa_pulse(true);
        }
        assert!((d.w - 1.0).abs() < 1e-9);
        assert!((d.conductance() - d.params.g_min).abs() < 1e-15);
    }

    #[test]
    fn state_clamps_at_window_edges() {
        let mut d = VteamDevice::at_state(0.99, VteamParams::default());
        for _ in 0..10 {
            d.ziksa_pulse(true);
        }
        assert_eq!(d.w, 1.0);
    }

    #[test]
    fn conductance_is_linear_in_state() {
        let p = VteamParams::default();
        let g0 = VteamDevice::at_state(0.0, p).conductance();
        let g5 = VteamDevice::at_state(0.5, p).conductance();
        let g1 = VteamDevice::at_state(1.0, p).conductance();
        assert!((g0 - p.g_max).abs() < 1e-15);
        assert!((g1 - p.g_min).abs() < 1e-15);
        assert!((g5 - 0.5 * (p.g_max + p.g_min)).abs() < 1e-15);
    }

    #[test]
    fn pulses_to_target_counts_levels() {
        let p = VteamParams::default();
        let d = VteamDevice::at_state(0.0, p);
        let span = p.g_max - p.g_min;
        assert_eq!(d.pulses_to(p.g_max - 0.25 * span), 16);
        assert_eq!(d.pulses_to(p.g_max), 0);
        assert_eq!(d.pulses_to(p.g_min), 64);
    }
}
