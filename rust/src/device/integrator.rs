//! Integrator retention model — Eqs. (8)–(10) of the paper.
//!
//! During the shared-ADC scan the integrator must hold its charge. With
//! the hold switches (S_i, S_f) open, the droop is limited to the op-amp
//! input bias current and the capacitor dielectric leakage:
//!
//!   ΔV_l ≈ V_int · T_conv / (R_leakage · C_f)      (9)
//!   ΔV_b = I_b · T_conv / C_f                      (10)
//!
//! The paper's operating point: C_f = 2 pF, I_b < 50 pA, R_leak > 10 GΩ,
//! 1.28 GSps ADC (≈2 ns/channel), worst-case 200 ns scan ⇒ ΔV < 10.5 µV,
//! under 0.1 LSB. These equations gate the hw_model's shared-ADC policy.

/// Integrator + hold-switch circuit parameters.
#[derive(Clone, Copy, Debug)]
pub struct IntegratorSpec {
    /// Feedback capacitor, F.
    pub c_f: f64,
    /// Op-amp input bias current, A.
    pub i_bias: f64,
    /// Capacitor dielectric leakage resistance, Ω.
    pub r_leakage: f64,
    /// Stored full-scale voltage, V.
    pub v_int: f64,
}

impl Default for IntegratorSpec {
    fn default() -> Self {
        // §IV-B1 operating point. v_int = 0.55 V reproduces the paper's
        // "< 10.5 µV over 200 ns" total droop (5 µV bias + 5.5 µV leak),
        // consistent with the 1.2 V supply and sub-threshold bias headroom.
        Self { c_f: 2.0e-12, i_bias: 50.0e-12, r_leakage: 10.0e9, v_int: 0.55 }
    }
}

/// Droop analysis over one ADC scan window.
#[derive(Clone, Copy, Debug)]
pub struct RetentionReport {
    /// Leakage droop, Eq. (9), volts.
    pub dv_leakage: f64,
    /// Bias-current droop, Eq. (10), volts.
    pub dv_bias: f64,
    /// Total droop, volts.
    pub dv_total: f64,
    /// Droop in LSBs of an ADC with the given resolution over v_int.
    pub lsb_fraction: f64,
}

impl IntegratorSpec {
    /// Exponential droop without hold switches, Eq. (8): the case that
    /// forces either huge RC or many ADCs — the problem the switches solve.
    pub fn droop_no_switches(&self, t_conv: f64, r_feedback: f64) -> f64 {
        let tau = r_feedback * self.c_f;
        self.v_int * (1.0 - (-t_conv / tau).exp())
    }

    /// Hold-phase droop with switches open, Eqs. (9)+(10).
    pub fn retention(&self, t_conv: f64, adc_bits: u32) -> RetentionReport {
        let dv_leakage = self.v_int * t_conv / (self.r_leakage * self.c_f);
        let dv_bias = self.i_bias * t_conv / self.c_f;
        let dv_total = dv_leakage + dv_bias;
        let lsb = self.v_int / f64::from((1u64 << adc_bits) as u32);
        RetentionReport { dv_leakage, dv_bias, dv_total, lsb_fraction: dv_total / lsb }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_operating_point_under_tenth_lsb() {
        // worst case 200 ns scan, 8-bit ADC: ΔV ≈ 10.5 µV < 0.1 LSB? The
        // paper quotes < 10.5 µV and < 0.1 LSB over 200 ns.
        let spec = IntegratorSpec::default();
        let rep = spec.retention(200e-9, 8);
        assert!(rep.dv_total < 10.6e-6, "{:?}", rep);
        assert!(rep.lsb_fraction < 0.1, "{:?}", rep);
    }

    #[test]
    fn droop_components_match_hand_arithmetic() {
        let spec = IntegratorSpec::default();
        let rep = spec.retention(200e-9, 8);
        // ΔV_l = 0.55 * 200e-9 / (10e9 * 2e-12) = 5.5 µV
        assert!((rep.dv_leakage - 5.5e-6).abs() < 1e-9);
        // ΔV_b = 50e-12 * 200e-9 / 2e-12 = 5 µV
        assert!((rep.dv_bias - 5.0e-6).abs() < 1e-9);
    }

    #[test]
    fn no_switch_droop_is_much_worse() {
        let spec = IntegratorSpec::default();
        // R_feedback = 1 MΩ → τ = 2 µs; a 200 ns scan loses ~10% of V_int.
        let dv = spec.droop_no_switches(200e-9, 1.0e6);
        let with = spec.retention(200e-9, 8).dv_total;
        assert!(dv > 1000.0 * with, "dv {dv} vs {with}");
    }

    #[test]
    fn droop_scales_linearly_with_scan_time() {
        let spec = IntegratorSpec::default();
        let a = spec.retention(100e-9, 8).dv_total;
        let b = spec.retention(200e-9, 8).dv_total;
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
