//! One compiled HLO computation plus the host↔device literal plumbing.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::linalg::Mat;
use crate::nn::SeqBatch;

/// A compiled AOT artifact. All artifacts are lowered with
/// `return_tuple=True`, so every execution returns a tuple literal that we
/// immediately unpack.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub nargs: usize,
}

impl Executable {
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        name: &str,
        nargs: usize,
    ) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling artifact `{name}`"))?;
        Ok(Executable { exe, name: name.to_string(), nargs })
    }

    /// Execute with positional literal args; unpack the result tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        ensure!(
            args.len() == self.nargs,
            "artifact `{}` expects {} args, got {}",
            self.name,
            self.nargs,
            args.len()
        );
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing `{}`", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of `{}`", self.name))?;
        lit.to_tuple().with_context(|| format!("unpacking result tuple of `{}`", self.name))
    }
}

// ---- host <-> literal conversions ----------------------------------------

/// Rank-0 f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

/// Rank-1 f32 literal.
pub fn lit_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Rank-2 f32 literal from a row-major matrix.
pub fn lit_mat(m: &Mat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// Rank-3 f32 literal [b, nt, nx] from a sequence batch.
pub fn lit_seq(x: &SeqBatch) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&x.data).reshape(&[x.b as i64, x.nt as i64, x.nx as i64])?)
}

/// Read a rank-2 literal back into a matrix of known shape.
pub fn mat_from(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let data = lit.to_vec::<f32>()?;
    ensure!(data.len() == rows * cols, "literal size {} != {rows}x{cols}", data.len());
    Ok(Mat::from_vec(rows, cols, data))
}

/// Read a rank-1 literal back.
pub fn vec_from(lit: &xla::Literal, len: usize) -> Result<Vec<f32>> {
    let data = lit.to_vec::<f32>()?;
    ensure!(data.len() == len, "literal size {} != {len}", data.len());
    Ok(data)
}

/// Read a rank-0 literal back.
pub fn scalar_from(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}
