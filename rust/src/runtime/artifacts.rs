//! Typed façade over the per-config artifact set.
//!
//! `ModelBundle` owns the compiled executables of one network config and
//! exposes the exact L2 entry-point signatures (see `model.py` for the
//! parameter-order contract). All shape checking happens here, before
//! anything reaches PJRT.

use anyhow::{ensure, Context, Result};

use crate::config::{Manifest, NetConfig};
use crate::linalg::Mat;
use crate::nn::{AdamState, DfaDeltas, MiruParams, SeqBatch};

use super::executable::{
    lit_mat, lit_scalar, lit_seq, lit_vec, mat_from, scalar_from, vec_from, Executable,
};
use super::Runtime;

/// All compiled entry points for one `NetConfig`.
pub struct ModelBundle {
    pub cfg: NetConfig,
    forward: Executable,
    forward_hw: Executable,
    train_dfa: Executable,
    train_adam: Executable,
    train_dfa_dense: Option<Executable>,
}

impl ModelBundle {
    /// Compile every artifact of `cfg` listed in the manifest.
    pub fn load(rt: &Runtime, manifest: &Manifest, cfg: NetConfig) -> Result<ModelBundle> {
        ensure!(
            manifest.configs.contains_key(cfg.name),
            "config `{}` not present in artifact manifest — re-run `make artifacts`",
            cfg.name
        );
        let get = |stem: &str| -> Result<Executable> {
            let name = format!("{stem}_{}", cfg.name);
            let a = manifest
                .artifacts
                .get(&name)
                .with_context(|| format!("artifact `{name}` missing from manifest"))?;
            rt.load(&manifest.artifact_path(&name)?, &name, a.nargs)
        };
        let train_dfa_dense =
            if cfg.has_dense_train() { Some(get("train_dfa_dense")?) } else { None };
        Ok(ModelBundle {
            cfg,
            forward: get("forward")?,
            forward_hw: get("forward_hw")?,
            train_dfa: get("train_dfa")?,
            train_adam: get("train_adam")?,
            train_dfa_dense,
        })
    }

    fn check_params(&self, p: &MiruParams) -> Result<()> {
        ensure!(
            p.nx() == self.cfg.nx && p.nh() == self.cfg.nh && p.ny() == self.cfg.ny,
            "params {}x{}x{} do not match config `{}`",
            p.nx(),
            p.nh(),
            p.ny(),
            self.cfg.name
        );
        Ok(())
    }

    fn param_lits(&self, p: &MiruParams) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            lit_mat(&p.wh)?,
            lit_mat(&p.uh)?,
            lit_vec(&p.bh),
            lit_mat(&p.wo)?,
            lit_vec(&p.bo),
        ])
    }

    fn check_batch(&self, x: &SeqBatch, want_b: usize) -> Result<()> {
        ensure!(
            x.b == want_b && x.nt == self.cfg.nt && x.nx == self.cfg.nx,
            "batch [{},{},{}] does not match artifact shape [{},{},{}]",
            x.b,
            x.nt,
            x.nx,
            want_b,
            self.cfg.nt,
            self.cfg.nx
        );
        Ok(())
    }

    /// Software inference: logits [b_eval, ny].
    pub fn eval_logits(&self, p: &MiruParams, x: &SeqBatch, lam: f32, beta: f32) -> Result<Mat> {
        self.check_params(p)?;
        self.check_batch(x, self.cfg.b_eval)?;
        let mut args = self.param_lits(p)?;
        args.push(lit_scalar(lam));
        args.push(lit_scalar(beta));
        args.push(lit_seq(x)?);
        let out = self.forward.run(&args)?;
        mat_from(&out[0], self.cfg.b_eval, self.cfg.ny)
    }

    /// Mixed-signal inference through the WBS/ADC datapath. The params
    /// should be the *device-perturbed* weights from `device::crossbar`.
    pub fn eval_logits_hw(
        &self,
        p: &MiruParams,
        x: &SeqBatch,
        lam: f32,
        beta: f32,
        vscale_h: f32,
        vscale_o: f32,
    ) -> Result<Mat> {
        self.check_params(p)?;
        self.check_batch(x, self.cfg.b_eval)?;
        let mut args = self.param_lits(p)?;
        args.push(lit_scalar(lam));
        args.push(lit_scalar(beta));
        args.push(lit_scalar(vscale_h));
        args.push(lit_scalar(vscale_o));
        args.push(lit_seq(x)?);
        let out = self.forward_hw.run(&args)?;
        mat_from(&out[0], self.cfg.b_eval, self.cfg.ny)
    }

    fn run_dfa(
        &self,
        exe: &Executable,
        p: &MiruParams,
        x: &SeqBatch,
        lam: f32,
        beta: f32,
        lr: f32,
        psi: &Mat,
    ) -> Result<DfaDeltas> {
        self.check_params(p)?;
        self.check_batch(x, self.cfg.b_train)?;
        ensure!(
            psi.rows == self.cfg.ny && psi.cols == self.cfg.nh,
            "psi shape {}x{} != {}x{}",
            psi.rows,
            psi.cols,
            self.cfg.ny,
            self.cfg.nh
        );
        let mut args = self.param_lits(p)?;
        args.push(lit_scalar(lam));
        args.push(lit_scalar(beta));
        args.push(lit_scalar(lr));
        args.push(lit_mat(psi)?);
        args.push(lit_seq(x)?);
        args.push(lit_mat(&x.one_hot(self.cfg.ny))?);
        let out = exe.run(&args)?;
        Ok(DfaDeltas {
            d_wh: mat_from(&out[0], self.cfg.nx, self.cfg.nh)?,
            d_uh: mat_from(&out[1], self.cfg.nh, self.cfg.nh)?,
            d_bh: vec_from(&out[2], self.cfg.nh)?,
            d_wo: mat_from(&out[3], self.cfg.nh, self.cfg.ny)?,
            d_bo: vec_from(&out[4], self.cfg.ny)?,
            loss: scalar_from(&out[5])?,
        })
    }

    /// One DFA step with ζ-sparsified deltas (Algorithm 1).
    pub fn train_step_dfa(
        &self,
        p: &MiruParams,
        x: &SeqBatch,
        lam: f32,
        beta: f32,
        lr: f32,
        psi: &Mat,
    ) -> Result<DfaDeltas> {
        self.run_dfa(&self.train_dfa, p, x, lam, beta, lr, psi)
    }

    /// Dense (no-ζ) DFA step — Fig. 5(b) baseline; only selected configs.
    pub fn train_step_dfa_dense(
        &self,
        p: &MiruParams,
        x: &SeqBatch,
        lam: f32,
        beta: f32,
        lr: f32,
        psi: &Mat,
    ) -> Result<DfaDeltas> {
        let exe = self
            .train_dfa_dense
            .as_ref()
            .with_context(|| format!("config `{}` has no dense train artifact", self.cfg.name))?;
        self.run_dfa(exe, p, x, lam, beta, lr, psi)
    }

    /// One BPTT+Adam step; updates `p` and `st` in place, returns the loss.
    pub fn train_step_adam(
        &self,
        p: &mut MiruParams,
        st: &mut AdamState,
        x: &SeqBatch,
        lam: f32,
        beta: f32,
        lr: f32,
    ) -> Result<f32> {
        self.check_params(p)?;
        self.check_batch(x, self.cfg.b_train)?;
        ensure!(st.m.len() == self.cfg.param_count(), "adam state size mismatch");
        let mut args = self.param_lits(p)?;
        args.push(lit_vec(&st.m));
        args.push(lit_vec(&st.v));
        args.push(lit_scalar(st.t));
        args.push(lit_scalar(lam));
        args.push(lit_scalar(beta));
        args.push(lit_scalar(lr));
        args.push(lit_seq(x)?);
        args.push(lit_mat(&x.one_hot(self.cfg.ny))?);
        let out = self.train_adam.run(&args)?;
        p.wh = mat_from(&out[0], self.cfg.nx, self.cfg.nh)?;
        p.uh = mat_from(&out[1], self.cfg.nh, self.cfg.nh)?;
        p.bh = vec_from(&out[2], self.cfg.nh)?;
        p.wo = mat_from(&out[3], self.cfg.nh, self.cfg.ny)?;
        p.bo = vec_from(&out[4], self.cfg.ny)?;
        st.m = vec_from(&out[5], self.cfg.param_count())?;
        st.v = vec_from(&out[6], self.cfg.param_count())?;
        st.t = scalar_from(&out[7])?;
        scalar_from(&out[8])
    }
}
