//! PJRT runtime — loads the AOT artifacts and executes them on the hot
//! path. Python is build-time only; after `make artifacts` this module is
//! the only thing that touches the compute graphs.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file` → compile
//! on the CPU PJRT client): jax ≥ 0.5 emits serialized protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and `aot.py`).

mod artifacts;
mod executable;

pub use artifacts::ModelBundle;
pub use executable::Executable;

/// §Perf probe: build every host literal one DFA train step needs (params,
/// scalars, Ψ, batch, one-hot labels) without executing. Benchmarked to
/// bound the coordinator's share of the step.
pub fn host_overhead_probe(
    p: &crate::nn::MiruParams,
    psi: &crate::linalg::Mat,
    x: &crate::nn::SeqBatch,
) -> Result<usize> {
    use executable::{lit_mat, lit_scalar, lit_seq, lit_vec};
    let lits = [
        lit_mat(&p.wh)?,
        lit_mat(&p.uh)?,
        lit_vec(&p.bh),
        lit_mat(&p.wo)?,
        lit_vec(&p.bo),
        lit_scalar(0.9),
        lit_scalar(0.3),
        lit_scalar(0.3),
        lit_mat(psi)?,
        lit_seq(x)?,
        lit_mat(&x.one_hot(p.ny()))?,
    ];
    Ok(lits.len())
}

use anyhow::{Context, Result};

/// Shared PJRT CPU client. One per process; executables borrow it via the
/// xla crate's internal refcount.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, path: &std::path::Path, name: &str, nargs: usize) -> Result<Executable> {
        Executable::load(&self.client, path, name, nargs)
    }
}
