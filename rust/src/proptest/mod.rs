//! In-tree property-testing mini-framework.
//!
//! The offline environment has no `proptest` crate, so this module
//! provides the 20% that covers our needs: seeded generators, a runner
//! that executes N random cases, and greedy input shrinking on failure
//! (halving numeric values / truncating vectors) so failures are reported
//! at (near-)minimal inputs. Used by `rust/tests/proptests.rs` for the
//! coordinator invariants.

use crate::rng::GaussianRng;

/// A seeded test-case generator.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut GaussianRng) -> Self::Value;
    /// Candidate smaller versions of a failing value (greedy shrink).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult<V> {
    Pass { cases: usize },
    Fail { seed: u64, original: V, shrunk: V, message: String },
}

/// Run `prop` on `cases` random inputs from `gen`. On failure, shrink.
pub fn check<G, F>(seed: u64, cases: usize, gen: &G, prop: F) -> PropResult<G::Value>
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    let mut rng = GaussianRng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(message) = prop(&value) {
            // greedy shrink loop
            let original = value.clone();
            let mut current = value;
            let mut current_msg = message;
            'outer: loop {
                for cand in gen.shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            let _ = case;
            return PropResult::Fail { seed, original, shrunk: current, message: current_msg };
        }
    }
    PropResult::Pass { cases }
}

/// Assert a property holds; panics with the shrunk counterexample.
pub fn assert_prop<G, F>(seed: u64, cases: usize, gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    match check(seed, cases, gen, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { seed, original, shrunk, message } => {
            panic!(
                "property failed (seed {seed}): {message}\n  original: {original:?}\n  shrunk:   {shrunk:?}"
            );
        }
    }
}

// ---- stock generators -----------------------------------------------------

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut GaussianRng) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1); // last-resort linear walk toward the boundary
        }
        out.dedup();
        out
    }
}

/// Uniform f32 in [lo, hi).
pub struct F32In(pub f32, pub f32);

impl Gen for F32In {
    type Value = f32;
    fn generate(&self, rng: &mut GaussianRng) -> f32 {
        rng.uniform_in(self.0, self.1)
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mid = 0.5 * (self.0 + v);
        if (mid - v).abs() > 1e-6 {
            vec![self.0, mid]
        } else {
            vec![]
        }
    }
}

/// Vector of f32 with random length in [1, max_len].
pub struct VecF32 {
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut GaussianRng) -> Vec<f32> {
        let len = 1 + rng.below(self.max_len);
        (0..len).map(|_| rng.uniform_in(self.lo, self.hi)).collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // also try zeroing values
        if v.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Full-range u64 (four 16-bit draws — `GaussianRng` exposes no raw
/// word). Shrinks toward 0 by halving.
pub struct U64Any;

impl Gen for U64Any {
    type Value = u64;
    fn generate(&self, rng: &mut GaussianRng) -> u64 {
        let mut v = 0u64;
        for _ in 0..4 {
            v = (v << 16) | rng.below(1 << 16) as u64;
        }
        v
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > 0 {
            out.push(0);
            out.push(v >> 1);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Random byte vector with length in [0, max_len]. Shrinks by halving /
/// truncating / zeroing (the codec-fuzz workhorse).
pub struct ByteVec {
    pub max_len: usize,
}

impl Gen for ByteVec {
    type Value = Vec<u8>;
    fn generate(&self, rng: &mut GaussianRng) -> Vec<u8> {
        let len = rng.below(self.max_len + 1);
        (0..len).map(|_| rng.below(256) as u8).collect()
    }
    fn shrink(&self, v: &Vec<u8>) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(Vec::new());
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&b| b != 0) {
            out.push(vec![0; v.len()]);
        }
        out
    }
}

/// Vector of values from an element generator, length in [0, max_len].
/// Shrinks the vector (halve / drop-last) and then each element in
/// place — enough to land near-minimal counterexamples for sequence
/// laws (e.g. the codec roundtrip property).
pub struct VecOf<G> {
    pub elem: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut GaussianRng) -> Vec<G::Value> {
        let len = rng.below(self.max_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(Vec::new());
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        for (i, x) in v.iter().enumerate() {
            for sx in self.elem.shrink(x) {
                let mut w = v.clone();
                w[i] = sx;
                out.push(w);
            }
        }
        out
    }
}

/// A matmul problem shape: `a` is `m×k`, `b` is `k×n` (kernel-parity
/// test workhorse — see `tests/kernel_parity.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

/// Uniform [`MatShape`] with each dim in its inclusive range. Shrinks
/// one dimension at a time toward its lower bound (jump / halve /
/// decrement, like [`UsizeIn`]) so a failing kernel shape lands at a
/// near-minimal (m, k, n).
pub struct MatShapeGen {
    pub m: (usize, usize),
    pub k: (usize, usize),
    pub n: (usize, usize),
}

impl Gen for MatShapeGen {
    type Value = MatShape;
    fn generate(&self, rng: &mut GaussianRng) -> MatShape {
        MatShape {
            m: UsizeIn(self.m.0, self.m.1).generate(rng),
            k: UsizeIn(self.k.0, self.k.1).generate(rng),
            n: UsizeIn(self.n.0, self.n.1).generate(rng),
        }
    }
    fn shrink(&self, v: &MatShape) -> Vec<MatShape> {
        let mut out = Vec::new();
        for sm in UsizeIn(self.m.0, self.m.1).shrink(&v.m) {
            out.push(MatShape { m: sm, ..*v });
        }
        for sk in UsizeIn(self.k.0, self.k.1).shrink(&v.k) {
            out.push(MatShape { k: sk, ..*v });
        }
        for sn in UsizeIn(self.n.0, self.n.1).shrink(&v.n) {
            out.push(MatShape { n: sn, ..*v });
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut GaussianRng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        match check(0, 200, &UsizeIn(1, 100), |&n| {
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        }) {
            PropResult::Pass { cases } => assert_eq!(cases, 200),
            PropResult::Fail { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_toward_minimum() {
        // property: n < 50 — minimal counterexample is 50.
        match check(1, 500, &UsizeIn(1, 100), |&n| {
            if n < 50 {
                Ok(())
            } else {
                Err(format!("{n} >= 50"))
            }
        }) {
            PropResult::Pass { .. } => panic!("should fail"),
            PropResult::Fail { shrunk, .. } => {
                assert_eq!(shrunk, 50, "minimal counterexample");
            }
        }
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let g = VecF32 { max_len: 10, lo: -1.0, hi: 1.0 };
        let mut rng = GaussianRng::new(3);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((1..=10).contains(&v.len()));
            assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
        }
    }

    #[test]
    fn pair_shrinks_each_side() {
        let g = Pair(UsizeIn(0, 10), UsizeIn(0, 10));
        let shrinks = g.shrink(&(10, 10));
        assert!(shrinks.iter().any(|&(a, b)| a < 10 && b == 10));
        assert!(shrinks.iter().any(|&(a, b)| a == 10 && b < 10));
    }

    #[test]
    fn u64_any_covers_high_bits_and_shrinks_toward_zero() {
        let mut rng = GaussianRng::new(9);
        let mut any_high = false;
        for _ in 0..64 {
            if U64Any.generate(&mut rng) > u64::from(u32::MAX) {
                any_high = true;
            }
        }
        assert!(any_high, "the generator must reach beyond 32 bits");
        let shrinks = U64Any.shrink(&1024);
        assert!(shrinks.contains(&0) && shrinks.contains(&512) && shrinks.contains(&1023));
        assert!(U64Any.shrink(&0).is_empty());
    }

    #[test]
    fn byte_vec_respects_bounds_and_shrinks() {
        let g = ByteVec { max_len: 16 };
        let mut rng = GaussianRng::new(4);
        for _ in 0..100 {
            assert!(g.generate(&mut rng).len() <= 16);
        }
        let shrinks = g.shrink(&vec![1, 2, 3, 4]);
        assert!(shrinks.contains(&vec![]));
        assert!(shrinks.contains(&vec![1, 2]));
        assert!(shrinks.contains(&vec![0, 0, 0, 0]));
    }

    #[test]
    fn vec_of_shrinks_structure_and_elements() {
        let g = VecOf { elem: UsizeIn(0, 9), max_len: 8 };
        let mut rng = GaussianRng::new(5);
        for _ in 0..50 {
            let v = g.generate(&mut rng);
            assert!(v.len() <= 8 && v.iter().all(|&x| x <= 9));
        }
        let shrinks = g.shrink(&vec![9, 9]);
        assert!(shrinks.contains(&vec![]), "structural shrink");
        assert!(shrinks.contains(&vec![9]), "drop-last shrink");
        assert!(shrinks.iter().any(|v| v.len() == 2 && v[0] < 9), "element shrink");
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn assert_prop_panics_with_counterexample() {
        assert_prop(2, 100, &UsizeIn(0, 100), |&n| {
            if n < 10 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
