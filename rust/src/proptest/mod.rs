//! In-tree property-testing mini-framework.
//!
//! The offline environment has no `proptest` crate, so this module
//! provides the 20% that covers our needs: seeded generators, a runner
//! that executes N random cases, and greedy input shrinking on failure
//! (halving numeric values / truncating vectors) so failures are reported
//! at (near-)minimal inputs. Used by `rust/tests/proptests.rs` for the
//! coordinator invariants.

use crate::rng::GaussianRng;

/// A seeded test-case generator.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut GaussianRng) -> Self::Value;
    /// Candidate smaller versions of a failing value (greedy shrink).
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub enum PropResult<V> {
    Pass { cases: usize },
    Fail { seed: u64, original: V, shrunk: V, message: String },
}

/// Run `prop` on `cases` random inputs from `gen`. On failure, shrink.
pub fn check<G, F>(seed: u64, cases: usize, gen: &G, prop: F) -> PropResult<G::Value>
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    let mut rng = GaussianRng::new(seed);
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(message) = prop(&value) {
            // greedy shrink loop
            let original = value.clone();
            let mut current = value;
            let mut current_msg = message;
            'outer: loop {
                for cand in gen.shrink(&current) {
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            let _ = case;
            return PropResult::Fail { seed, original, shrunk: current, message: current_msg };
        }
    }
    PropResult::Pass { cases }
}

/// Assert a property holds; panics with the shrunk counterexample.
pub fn assert_prop<G, F>(seed: u64, cases: usize, gen: &G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> Result<(), String>,
{
    match check(seed, cases, gen, prop) {
        PropResult::Pass { .. } => {}
        PropResult::Fail { seed, original, shrunk, message } => {
            panic!(
                "property failed (seed {seed}): {message}\n  original: {original:?}\n  shrunk:   {shrunk:?}"
            );
        }
    }
}

// ---- stock generators -----------------------------------------------------

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut GaussianRng) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1); // last-resort linear walk toward the boundary
        }
        out.dedup();
        out
    }
}

/// Uniform f32 in [lo, hi).
pub struct F32In(pub f32, pub f32);

impl Gen for F32In {
    type Value = f32;
    fn generate(&self, rng: &mut GaussianRng) -> f32 {
        rng.uniform_in(self.0, self.1)
    }
    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mid = 0.5 * (self.0 + v);
        if (mid - v).abs() > 1e-6 {
            vec![self.0, mid]
        } else {
            vec![]
        }
    }
}

/// Vector of f32 with random length in [1, max_len].
pub struct VecF32 {
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut GaussianRng) -> Vec<f32> {
        let len = 1 + rng.below(self.max_len);
        (0..len).map(|_| rng.uniform_in(self.lo, self.hi)).collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        // also try zeroing values
        if v.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut GaussianRng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(a).into_iter().map(|a2| (a2, b.clone())).collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        match check(0, 200, &UsizeIn(1, 100), |&n| {
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        }) {
            PropResult::Pass { cases } => assert_eq!(cases, 200),
            PropResult::Fail { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_toward_minimum() {
        // property: n < 50 — minimal counterexample is 50.
        match check(1, 500, &UsizeIn(1, 100), |&n| {
            if n < 50 {
                Ok(())
            } else {
                Err(format!("{n} >= 50"))
            }
        }) {
            PropResult::Pass { .. } => panic!("should fail"),
            PropResult::Fail { shrunk, .. } => {
                assert_eq!(shrunk, 50, "minimal counterexample");
            }
        }
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let g = VecF32 { max_len: 10, lo: -1.0, hi: 1.0 };
        let mut rng = GaussianRng::new(3);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!((1..=10).contains(&v.len()));
            assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
        }
    }

    #[test]
    fn pair_shrinks_each_side() {
        let g = Pair(UsizeIn(0, 10), UsizeIn(0, 10));
        let shrinks = g.shrink(&(10, 10));
        assert!(shrinks.iter().any(|&(a, b)| a < 10 && b == 10));
        assert!(shrinks.iter().any(|&(a, b)| a == 10 && b < 10));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn assert_prop_panics_with_counterexample() {
        assert_prop(2, 100, &UsizeIn(0, 100), |&n| {
            if n < 10 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
