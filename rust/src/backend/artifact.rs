//! Artifact backend — the AOT-compiled XLA path through the PJRT runtime.
//!
//! Wraps [`crate::runtime::ModelBundle`]: forward and train steps execute
//! the HLO artifacts lowered by `python/compile/aot.py`. Construction
//! fails gracefully (factory returns an error) when the artifacts are
//! missing or when the build links the offline `xla` stub
//! (`vendor/xla-stub`) instead of a real PJRT client — the registry
//! surfaces that error to the CLI instead of crashing.
//!
//! The artifacts are lowered with static batch shapes, so this backend
//! reports [`ComputeBackend::prefers_whole_batch`] and the parallel
//! engine never row-shards it.

use anyhow::{anyhow, Context, Result};

use crate::config::Manifest;
use crate::linalg::Mat;
use crate::nn::{dfa_grads, make_psi, AdamState, DfaDeltas, MiruParams, SeqBatch};
use crate::runtime::{ModelBundle, Runtime};

use super::{BackendCtx, ComputeBackend, LayerSel, TrainHyper};

/// PJRT-executed backend over one config's artifact set.
pub struct ArtifactBackend {
    bundle: ModelBundle,
    params: MiruParams,
    psi: Mat,
    adam: AdamState,
    hyper: TrainHyper,
    /// Keeps the PJRT client alive for the executables.
    _rt: Runtime,
}

impl ArtifactBackend {
    pub fn new(ctx: &BackendCtx) -> Result<ArtifactBackend> {
        let rt = Runtime::cpu().context("creating PJRT client for the artifact backend")?;
        let manifest = Manifest::load(&ctx.artifacts_dir)
            .context("artifact backend needs `make artifacts`")?;
        let bundle = ModelBundle::load(&rt, &manifest, ctx.net)?;
        let c = ctx.net;
        let params = MiruParams::init(c.nx, c.nh, c.ny, ctx.seed);
        let n = params.count();
        Ok(ArtifactBackend {
            bundle,
            params,
            psi: make_psi(c.ny, c.nh, ctx.seed ^ 0xD0F4),
            adam: AdamState::new(n),
            hyper: TrainHyper {
                lam: ctx.lam,
                beta: ctx.beta,
                lr: ctx.lr,
                keep_frac: ctx.keep_frac,
            },
            _rt: rt,
        })
    }

    /// Registry factory.
    pub fn factory(ctx: &BackendCtx) -> Result<Box<dyn ComputeBackend>> {
        Ok(Box::new(ArtifactBackend::new(ctx)?))
    }
}

impl ComputeBackend for ArtifactBackend {
    fn name(&self) -> &'static str {
        "artifact"
    }

    fn hyper(&self) -> TrainHyper {
        self.hyper
    }

    fn effective_params(&self) -> MiruParams {
        self.params.clone()
    }

    fn forward(&self, x: &SeqBatch) -> Result<Mat> {
        // shape checking (b == b_eval) happens inside the bundle
        self.bundle.eval_logits(&self.params, x, self.hyper.lam, self.hyper.beta)
    }

    fn vmm(&self, x: &Mat, layer: LayerSel) -> Result<Mat> {
        // no standalone VMM artifact is lowered; the software semantics of
        // the artifact graphs are the exact product, computed host-side
        match layer {
            LayerSel::Hidden => {
                anyhow::ensure!(
                    x.cols == self.params.nx() + self.params.nh(),
                    "hidden vmm drive width {}",
                    x.cols
                );
                Ok(x.matmul(&Mat::vcat(&self.params.wh, &self.params.uh)))
            }
            LayerSel::Readout => {
                anyhow::ensure!(x.cols == self.params.nh(), "readout vmm drive width {}", x.cols);
                Ok(x.matmul(&self.params.wo))
            }
        }
    }

    fn dfa_raw_grads_from(&self, p: &MiruParams, x: &SeqBatch) -> Result<DfaDeltas> {
        // dense unit-lr deltas; host math accepts any shard shape (the
        // dense train artifact is only lowered for selected configs and
        // only at b_train)
        Ok(dfa_grads(p, x, self.hyper.lam, self.hyper.beta, 1.0, &self.psi, None))
    }

    fn apply_update(&mut self, d: &DfaDeltas) -> Result<()> {
        self.params.apply(d);
        Ok(())
    }

    fn train_dfa(&mut self, x: &SeqBatch) -> Result<f32> {
        // fused in-graph step: forward, DFA, ζ and lr all inside the artifact
        let d = self.bundle.train_step_dfa(
            &self.params,
            x,
            self.hyper.lam,
            self.hyper.beta,
            self.hyper.lr,
            &self.psi,
        )?;
        self.params.apply(&d);
        Ok(d.loss)
    }

    fn train_adam(&mut self, x: &SeqBatch) -> Result<f32> {
        self.bundle.train_step_adam(
            &mut self.params,
            &mut self.adam,
            x,
            self.hyper.lam,
            self.hyper.beta,
            self.hyper.lr,
        )
    }

    fn fork(&self) -> Result<Box<dyn ComputeBackend>> {
        Err(anyhow!(
            "artifact backend holds compiled executables and cannot fork; \
             run with --workers 1"
        ))
    }

    fn prefers_whole_batch(&self) -> bool {
        true
    }
}
