//! Dense digital backend — the CMOS baseline substrate.
//!
//! Exact f32 math over [`crate::linalg::Mat`] (the blocked matmul is the
//! hot path): the same network the Table-I digital comparator models.
//! This is the default serving backend and the numerical reference the
//! crossbar backend is parity-tested against.

use anyhow::{ensure, Result};

use crate::linalg::Mat;
use crate::nn::{bptt_grads, dfa_grads, make_psi, AdamState, DfaDeltas, MiruParams, SeqBatch};

use super::{BackendCtx, ComputeBackend, LayerSel, TrainHyper};

/// Weights live in plain matrices; updates are exact adds.
#[derive(Clone)]
pub struct DenseBackend {
    params: MiruParams,
    psi: Mat,
    adam: AdamState,
    hyper: TrainHyper,
}

impl DenseBackend {
    pub fn new(ctx: &BackendCtx) -> DenseBackend {
        let c = ctx.net;
        let params = MiruParams::init(c.nx, c.nh, c.ny, ctx.seed);
        let n = params.count();
        DenseBackend {
            params,
            psi: make_psi(c.ny, c.nh, ctx.seed ^ 0xD0F4),
            adam: AdamState::new(n),
            hyper: TrainHyper {
                lam: ctx.lam,
                beta: ctx.beta,
                lr: ctx.lr,
                keep_frac: ctx.keep_frac,
            },
        }
    }

    /// Registry factory.
    pub fn factory(ctx: &BackendCtx) -> Result<Box<dyn ComputeBackend>> {
        Ok(Box::new(DenseBackend::new(ctx)))
    }
}

impl ComputeBackend for DenseBackend {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn hyper(&self) -> TrainHyper {
        self.hyper
    }

    fn effective_params(&self) -> MiruParams {
        self.params.clone()
    }

    fn forward(&self, x: &SeqBatch) -> Result<Mat> {
        ensure!(x.nx == self.params.nx(), "batch nx {} != net nx {}", x.nx, self.params.nx());
        Ok(self.params.forward(x, self.hyper.lam, self.hyper.beta))
    }

    fn vmm(&self, x: &Mat, layer: LayerSel) -> Result<Mat> {
        match layer {
            LayerSel::Hidden => {
                let nin = self.params.nx() + self.params.nh();
                ensure!(x.cols == nin, "hidden vmm drive width {} != {nin}", x.cols);
                Ok(x.matmul(&Mat::vcat(&self.params.wh, &self.params.uh)))
            }
            LayerSel::Readout => {
                ensure!(x.cols == self.params.nh(), "readout vmm drive width {}", x.cols);
                Ok(x.matmul(&self.params.wo))
            }
        }
    }

    fn step_hidden(&self, h: &Mat, x: &Mat) -> Result<Mat> {
        self.step_hidden_from(&self.params, h, x)
    }

    fn readout(&self, h: &Mat) -> Result<Mat> {
        self.readout_from(&self.params, h)
    }

    fn step_hidden_from(&self, p: &MiruParams, h: &Mat, x: &Mat) -> Result<Mat> {
        ensure!(x.cols == p.nx(), "step nx {} != net nx {}", x.cols, p.nx());
        ensure!(h.cols == p.nh(), "step nh {} != net nh {}", h.cols, p.nh());
        ensure!(h.rows == x.rows, "state rows {} != input rows {}", h.rows, x.rows);
        Ok(p.step(h, x, self.hyper.lam, self.hyper.beta).1)
    }

    fn readout_from(&self, p: &MiruParams, h: &Mat) -> Result<Mat> {
        ensure!(h.cols == p.nh(), "readout nh {} != net nh {}", h.cols, p.nh());
        let mut logits = h.matmul(&p.wo);
        logits.add_row_bias(&p.bo);
        Ok(logits)
    }

    /// The int8 serving step: the same `[x | βh] @ [W_h; U_h]` drive as
    /// the f32 [`MiruParams::step`], but through the pre-quantized
    /// per-column planes and the i8×i8→i32 kernel, with one rescale per
    /// pre-activation. Bias add, tanh and the λ-interpolation stay f32.
    fn step_hidden_int8(
        &self,
        p: &MiruParams,
        q: &crate::quant::QuantizedParams,
        h: &Mat,
        x: &Mat,
    ) -> Result<Mat> {
        ensure!(x.cols == p.nx(), "step nx {} != net nx {}", x.cols, p.nx());
        ensure!(h.cols == p.nh(), "step nh {} != net nh {}", h.cols, p.nh());
        ensure!(h.rows == x.rows, "state rows {} != input rows {}", h.rows, x.rows);
        let (lam, beta) = (self.hyper.lam, self.hyper.beta);
        let mut bh_scaled = h.clone();
        bh_scaled.scale(beta);
        let drive = Mat::hcat(x, &bh_scaled);
        let mut pre = crate::quant::matmul_i8_rowquant(&drive, &q.hidden);
        pre.add_row_bias(&p.bh);
        let cand = pre.map(f32::tanh);
        let mut h_new = h.clone();
        h_new.scale(lam);
        h_new.add_scaled(&cand, 1.0 - lam);
        Ok(h_new)
    }

    fn readout_int8(
        &self,
        p: &MiruParams,
        q: &crate::quant::QuantizedParams,
        h: &Mat,
    ) -> Result<Mat> {
        ensure!(h.cols == p.nh(), "readout nh {} != net nh {}", h.cols, p.nh());
        let mut logits = crate::quant::matmul_i8_rowquant(h, &q.wo);
        logits.add_row_bias(&p.bo);
        Ok(logits)
    }

    fn dfa_raw_grads_from(&self, p: &MiruParams, x: &SeqBatch) -> Result<DfaDeltas> {
        Ok(dfa_grads(p, x, self.hyper.lam, self.hyper.beta, 1.0, &self.psi, None))
    }

    fn dfa_raw_grads(&self, x: &SeqBatch) -> Result<DfaDeltas> {
        // skip the effective_params clone of the default implementation
        Ok(dfa_grads(&self.params, x, self.hyper.lam, self.hyper.beta, 1.0, &self.psi, None))
    }

    fn apply_update(&mut self, d: &DfaDeltas) -> Result<()> {
        self.params.apply(d);
        Ok(())
    }

    fn train_adam(&mut self, x: &SeqBatch) -> Result<f32> {
        let (g, loss) = bptt_grads(&self.params, x, self.hyper.lam, self.hyper.beta);
        let upd = self.adam.step(&g, self.hyper.lr);
        self.params.apply_flat_update(&upd);
        Ok(loss)
    }

    fn fork(&self) -> Result<Box<dyn ComputeBackend>> {
        Ok(Box::new(self.clone()))
    }

    /// Digital weights restore bit-exactly: a checkpointed dense serve
    /// loop resumes with identical effective parameters.
    fn restore_params(&mut self, p: &MiruParams) -> Result<()> {
        ensure!(
            p.nx() == self.params.nx() && p.nh() == self.params.nh() && p.ny() == self.params.ny(),
            "checkpoint shapes ({}, {}, {}) do not match net ({}, {}, {})",
            p.nx(),
            p.nh(),
            p.ny(),
            self.params.nx(),
            self.params.nh(),
            self.params.ny()
        );
        self.params = p.clone();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::tests::toy_batch;
    use crate::config::NetConfig;
    use crate::linalg::argmax_rows;

    fn ctx() -> BackendCtx {
        BackendCtx {
            lam: 0.5,
            beta: 0.7,
            lr: 0.5,
            seed: 1,
            ..BackendCtx::new(NetConfig::SMALL)
        }
    }

    #[test]
    fn dfa_training_improves_accuracy() {
        let net = NetConfig::SMALL;
        let mut be = DenseBackend::new(&ctx());
        let test = toy_batch(&net, 64, 0);
        let acc = |be: &DenseBackend| {
            let preds = argmax_rows(&be.forward(&test).unwrap());
            preds.iter().zip(&test.labels).filter(|(a, b)| a == b).count() as f32 / 64.0
        };
        let before = acc(&be);
        for i in 0..50 {
            be.train_dfa(&toy_batch(&net, 8, 10 + i)).unwrap();
        }
        let after = acc(&be);
        assert!(after > before + 0.2, "before {before} after {after}");
    }

    #[test]
    fn fork_is_independent_and_identical() {
        let net = NetConfig::SMALL;
        let mut be = DenseBackend::new(&ctx());
        let x = toy_batch(&net, 16, 3);
        let fork = be.fork().unwrap();
        assert_eq!(fork.forward(&x).unwrap().data, be.forward(&x).unwrap().data);
        // training the original must not affect the fork
        let frozen = fork.forward(&x).unwrap();
        be.train_dfa(&toy_batch(&net, 8, 4)).unwrap();
        assert_eq!(fork.forward(&x).unwrap().data, frozen.data);
        assert_ne!(be.forward(&x).unwrap().data, frozen.data);
    }

    #[test]
    fn int8_step_and_readout_track_f32() {
        let be = DenseBackend::new(&ctx());
        let p = be.effective_params();
        let q = crate::quant::QuantizedParams::build(&p);
        let h = Mat::from_fn(9, p.nh(), |r, c| ((r * 3 + c) % 11) as f32 / 5.5 - 1.0);
        let x = Mat::from_fn(9, p.nx(), |r, c| ((r * 7 + c * 2) % 13) as f32 / 6.5 - 1.0);
        let hf = be.step_hidden_from(&p, &h, &x).unwrap();
        let hq = be.step_hidden_int8(&p, &q, &h, &x).unwrap();
        for (a, b) in hq.data.iter().zip(&hf.data) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        let lf = be.readout_from(&p, &hf).unwrap();
        let lq = be.readout_int8(&p, &q, &hf).unwrap();
        for (a, b) in lq.data.iter().zip(&lf.data) {
            assert!((a - b).abs() < 0.1 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn vmm_matches_manual_product() {
        let be = DenseBackend::new(&ctx());
        let p = be.effective_params();
        let nin = p.nx() + p.nh();
        let x = Mat::from_fn(3, nin, |r, c| ((r + c) % 5) as f32 * 0.1 - 0.2);
        let got = be.vmm(&x, LayerSel::Hidden).unwrap();
        let want = x.matmul(&Mat::vcat(&p.wh, &p.uh));
        assert_eq!(got.data, want.data);
        assert!(be.vmm(&x, LayerSel::Readout).is_err(), "wrong drive width must error");
    }
}
