//! Memristive crossbar backend — the M2RU substrate, entirely in rust.
//!
//! Weights live in two differential crossbars (hidden: `(nx+nh)×nh`
//! holding `[W_h; U_h]`; readout: `nh×ny` holding `W_o`); biases stay in
//! digital registers. The forward pass is the mixed-signal datapath of
//! §IV-B1 exactly as `model.forward_hw` lowers it: WBS `n_b`-bit input
//! digitization → analog VMM over the *effective* (discretized, noisy)
//! conductances → shared-ADC read-out with the adaptive full-scale shift
//! → digital tanh and interpolation. Training computes DFA deltas from
//! the effective weights and programs them through the write-counted
//! Ziksa scheduler, so endurance accounting comes for free.

use anyhow::{ensure, Result};

use crate::device::{DeviceParams, DifferentialCrossbar, ZiksaProgrammer};
use crate::linalg::bitplane::wbs_vmm;
use crate::linalg::{kernels, Mat};
use crate::nn::{bptt_grads, dfa_grads, make_psi, AdamState, DfaDeltas, MiruParams, SeqBatch};
use crate::quant::adc_quantize;

use super::{BackendCtx, ComputeBackend, LayerSel, TrainHyper};

/// Device-aware backend: every weight read goes through the crossbar
/// conductances, every weight write through Ziksa programming.
#[derive(Clone)]
pub struct CrossbarBackend {
    nx: usize,
    nh: usize,
    ny: usize,
    nb: u32,
    adc_bits: u32,
    hyper: TrainHyper,
    psi: Mat,
    /// biases stay digital (registers)
    bh: Vec<f32>,
    bo: Vec<f32>,
    xbar_hidden: DifferentialCrossbar,
    xbar_out: DifferentialCrossbar,
    programmer: ZiksaProgrammer,
    adam: AdamState,
}

/// ADC full-scale ranges for the current weights — the paper's "shift
/// operation controlling the dynamic range of the synaptic weights"
/// (§IV-B1): the integrator swing is bounded by the L1 norm of the
/// heaviest bitline, and the ADC range follows it so training growth
/// never clips the read-out (clipped logits collapse argmax).
fn l1max(m: &Mat) -> f32 {
    let mut best = 0.0f32;
    for c in 0..m.cols {
        let mut s = 0.0;
        for r in 0..m.rows {
            s += m.at(r, c).abs();
        }
        best = best.max(s);
    }
    best
}

/// Hidden-layer ADC full-scale: the drive is |x| ≤ 1 on nx lines and
/// |βh| ≤ β on nh lines; typical activity is far below the bound — a
/// third of the bound keeps the LSB fine while tanh saturation forgives
/// the rare clip. `g_hidden` is the stacked `[W_h; U_h]` crossbar readout.
fn vscale_hidden(g_hidden: &Mat) -> f32 {
    (0.3 * l1max(g_hidden)).max(1.0)
}

/// Readout ADC full-scale: logits must never clip (argmax!), use the
/// full bound.
fn vscale_readout(wo: &Mat) -> f32 {
    l1max(wo).max(1.0)
}

impl CrossbarBackend {
    pub fn new(ctx: &BackendCtx) -> CrossbarBackend {
        let c = ctx.net;
        let init = MiruParams::init(c.nx, c.nh, c.ny, ctx.seed);
        // w_max sized to the init distribution with training headroom
        let w_max = 1.0;
        let mut xbar_hidden =
            DifferentialCrossbar::new(c.nx + c.nh, c.nh, w_max, ctx.device, ctx.seed ^ 0xBAD1);
        let mut xbar_out =
            DifferentialCrossbar::new(c.nh, c.ny, w_max, ctx.device, ctx.seed ^ 0xBAD2);
        xbar_hidden.program_weights(&Mat::vcat(&init.wh, &init.uh));
        xbar_out.program_weights(&init.wo);
        let n = init.count();
        CrossbarBackend {
            nx: c.nx,
            nh: c.nh,
            ny: c.ny,
            nb: c.nb,
            adc_bits: c.adc_bits,
            hyper: TrainHyper {
                lam: ctx.lam,
                beta: ctx.beta,
                lr: ctx.lr,
                keep_frac: ctx.keep_frac,
            },
            psi: make_psi(c.ny, c.nh, ctx.seed ^ 0xD0F4),
            bh: init.bh,
            bo: init.bo,
            xbar_hidden,
            xbar_out,
            programmer: ZiksaProgrammer::new(),
            adam: AdamState::new(n),
        }
    }

    /// Registry factory.
    pub fn factory(ctx: &BackendCtx) -> Result<Box<dyn ComputeBackend>> {
        Ok(Box::new(CrossbarBackend::new(ctx)))
    }

    /// Device parameters the backend was built with (via the hidden
    /// crossbar — both crossbars share them).
    pub fn device(&self) -> DeviceParams {
        self.xbar_hidden.params
    }

    /// One mixed-signal recurrent step against an already-read hidden
    /// crossbar: the `[x | βh]` drive is WBS-digitized and bit-plane
    /// packed, streamed through the packed bit-serial MAC
    /// ([`wbs_vmm`] — the §IV-B1 datapath, 64 wordline bits per `u64`
    /// word) → shared ADC at `vscale_h` → digital
    /// bias/tanh/interpolation. Both [`ComputeBackend::forward`] and
    /// [`ComputeBackend::step_hidden`] route through here, so streaming
    /// and whole-sequence execution are bitwise-identical (crossbar
    /// reads are deterministic between programming events). The bias
    /// registers come in with the crossbar readout so a snapshot-driven
    /// step (`step_hidden_from` on another instance's snapshot — the
    /// async-commit serve path) uses the snapshot's biases, never this
    /// instance's possibly-stale ones.
    fn step_with(&self, g_hidden: &Mat, bh: &[f32], vscale_h: f32, h: &Mat, xt: &Mat) -> Mat {
        let (lam, beta) = (self.hyper.lam, self.hyper.beta);
        let mut bh_scaled = h.clone();
        bh_scaled.scale(beta);
        let drive = Mat::hcat(xt, &bh_scaled); // wordline voltages
        let mut acc = wbs_vmm(&drive, g_hidden, self.nb); // integrator voltages
        for v in &mut acc.data {
            *v = adc_quantize(*v, self.adc_bits, vscale_h);
        }
        acc.add_row_bias(bh);
        let cand = acc.map(f32::tanh);
        let mut h_new = h.clone();
        h_new.scale(lam);
        h_new.add_scaled(&cand, 1.0 - lam);
        h_new
    }

    /// Readout half of the datapath against an already-read output
    /// crossbar: digitized + packed hidden state → bit-serial VMM → ADC
    /// at `vscale_o` → digital bias add (bias registers passed in, as in
    /// `step_with`).
    fn readout_with(&self, wo: &Mat, bo: &[f32], vscale_o: f32, h: &Mat) -> Mat {
        let mut logits = wbs_vmm(h, wo, self.nb);
        for v in &mut logits.data {
            *v = adc_quantize(*v, self.adc_bits, vscale_o);
        }
        logits.add_row_bias(bo);
        logits
    }
}

impl ComputeBackend for CrossbarBackend {
    fn name(&self) -> &'static str {
        "crossbar"
    }

    fn hyper(&self) -> TrainHyper {
        self.hyper
    }

    fn effective_params(&self) -> MiruParams {
        let hidden = self.xbar_hidden.read_weights();
        let wh = Mat::from_fn(self.nx, self.nh, |r, col| hidden.at(r, col));
        let uh = Mat::from_fn(self.nh, self.nh, |r, col| hidden.at(self.nx + r, col));
        MiruParams {
            wh,
            uh,
            bh: self.bh.clone(),
            wo: self.xbar_out.read_weights(),
            bo: self.bo.clone(),
        }
    }

    /// The mixed-signal forward of `model.forward_hw`, in rust.
    fn forward(&self, x: &SeqBatch) -> Result<Mat> {
        ensure!(x.nx == self.nx, "batch nx {} != net nx {}", x.nx, self.nx);
        // read each crossbar once; the hidden readout is already the
        // stacked [W_h; U_h] layout the datapath drives
        let g_hidden = self.xbar_hidden.read_weights();
        let wo = self.xbar_out.read_weights();
        let vscale_h = vscale_hidden(&g_hidden);
        let vscale_o = vscale_readout(&wo);
        let mut h = Mat::zeros(x.b, self.nh);
        for t in 0..x.nt {
            h = self.step_with(&g_hidden, &self.bh, vscale_h, &h, &x.step(t));
        }
        Ok(self.readout_with(&wo, &self.bo, vscale_o, &h))
    }

    fn step_hidden(&self, h: &Mat, x: &Mat) -> Result<Mat> {
        ensure!(x.cols == self.nx, "step nx {} != net nx {}", x.cols, self.nx);
        ensure!(h.cols == self.nh, "step nh {} != net nh {}", h.cols, self.nh);
        ensure!(h.rows == x.rows, "state rows {} != input rows {}", h.rows, x.rows);
        let g_hidden = self.xbar_hidden.read_weights();
        let vscale_h = vscale_hidden(&g_hidden);
        Ok(self.step_with(&g_hidden, &self.bh, vscale_h, h, x))
    }

    fn readout(&self, h: &Mat) -> Result<Mat> {
        ensure!(h.cols == self.nh, "readout nh {} != net nh {}", h.cols, self.nh);
        let wo = self.xbar_out.read_weights();
        let vscale_o = vscale_readout(&wo);
        Ok(self.readout_with(&wo, &self.bo, vscale_o, h))
    }

    /// Snapshot variant: `p` is the `effective_params` readout, so
    /// re-stacking `[W_h; U_h]` reproduces the hidden crossbar's read
    /// bit-for-bit without touching the devices again — one read per
    /// dispatched serve batch instead of one per worker shard.
    fn step_hidden_from(&self, p: &MiruParams, h: &Mat, x: &Mat) -> Result<Mat> {
        ensure!(x.cols == self.nx, "step nx {} != net nx {}", x.cols, self.nx);
        ensure!(h.cols == self.nh, "step nh {} != net nh {}", h.cols, self.nh);
        ensure!(h.rows == x.rows, "state rows {} != input rows {}", h.rows, x.rows);
        let g_hidden = Mat::vcat(&p.wh, &p.uh);
        let vscale_h = vscale_hidden(&g_hidden);
        Ok(self.step_with(&g_hidden, &p.bh, vscale_h, h, x))
    }

    fn readout_from(&self, p: &MiruParams, h: &Mat) -> Result<Mat> {
        ensure!(h.cols == self.nh, "readout nh {} != net nh {}", h.cols, self.nh);
        let vscale_o = vscale_readout(&p.wo);
        Ok(self.readout_with(&p.wo, &p.bo, vscale_o, h))
    }

    /// Integrator voltages of one crossbar (pre-ADC): the WBS-digitized
    /// drive streamed bit-serially over the effective conductances — the
    /// packed-MAC `wbs_vmm` primitive.
    fn vmm(&self, x: &Mat, layer: LayerSel) -> Result<Mat> {
        let (xbar, want) = match layer {
            LayerSel::Hidden => (&self.xbar_hidden, self.nx + self.nh),
            LayerSel::Readout => (&self.xbar_out, self.nh),
        };
        ensure!(x.cols == want, "{layer:?} vmm drive width {} != {want}", x.cols);
        Ok(wbs_vmm(x, &xbar.read_weights(), self.nb))
    }

    /// The int8 serving step: WBS-digitized drive → packed bit-plane MAC
    /// with i32 accumulation over the pre-quantized column planes
    /// ([`crate::linalg::bitplane::wbs_mac_packed_i32`]) → shared ADC →
    /// digital bias/tanh/interpolation. The ADC full-scales derive from
    /// the L1 norms the committer stored alongside the planes, so the
    /// dispatch path never re-reads the f32 weights.
    fn step_hidden_int8(
        &self,
        p: &MiruParams,
        q: &crate::quant::QuantizedParams,
        h: &Mat,
        x: &Mat,
    ) -> Result<Mat> {
        ensure!(x.cols == self.nx, "step nx {} != net nx {}", x.cols, self.nx);
        ensure!(h.cols == self.nh, "step nh {} != net nh {}", h.cols, self.nh);
        ensure!(h.rows == x.rows, "state rows {} != input rows {}", h.rows, x.rows);
        let (lam, beta) = (self.hyper.lam, self.hyper.beta);
        let vscale_h = (0.3 * q.hidden_l1max).max(1.0); // as `vscale_hidden`
        let mut bh_scaled = h.clone();
        bh_scaled.scale(beta);
        let drive = Mat::hcat(x, &bh_scaled);
        let mut acc = Mat::zeros(drive.rows, q.hidden.cols);
        for r in 0..drive.rows {
            let bp = crate::linalg::bitplane::BitPlanes::pack(drive.row(r), self.nb);
            acc.row_mut(r)
                .copy_from_slice(&crate::linalg::bitplane::wbs_mac_packed_i32(&bp, &q.hidden));
        }
        for v in &mut acc.data {
            *v = adc_quantize(*v, self.adc_bits, vscale_h);
        }
        acc.add_row_bias(&p.bh);
        let cand = acc.map(f32::tanh);
        let mut h_new = h.clone();
        h_new.scale(lam);
        h_new.add_scaled(&cand, 1.0 - lam);
        Ok(h_new)
    }

    fn readout_int8(
        &self,
        p: &MiruParams,
        q: &crate::quant::QuantizedParams,
        h: &Mat,
    ) -> Result<Mat> {
        ensure!(h.cols == self.nh, "readout nh {} != net nh {}", h.cols, self.nh);
        let vscale_o = q.wo_l1max.max(1.0); // as `vscale_readout`
        let mut logits = Mat::zeros(h.rows, q.wo.cols);
        for r in 0..h.rows {
            let bp = crate::linalg::bitplane::BitPlanes::pack(h.row(r), self.nb);
            logits
                .row_mut(r)
                .copy_from_slice(&crate::linalg::bitplane::wbs_mac_packed_i32(&bp, &q.wo));
        }
        for v in &mut logits.data {
            *v = adc_quantize(*v, self.adc_bits, vscale_o);
        }
        logits.add_row_bias(&p.bo);
        Ok(logits)
    }

    fn dfa_raw_grads_from(&self, p: &MiruParams, x: &SeqBatch) -> Result<DfaDeltas> {
        // DFA deltas from the weights the devices actually realize (`p`
        // should come from `effective_params`)
        Ok(dfa_grads(p, x, self.hyper.lam, self.hyper.beta, 1.0, &self.psi, None))
    }

    fn apply_update(&mut self, d: &DfaDeltas) -> Result<()> {
        // program the crossbars (write-counted, quantized, noisy)
        let hidden_delta = Mat::vcat(&d.d_wh, &d.d_uh);
        self.programmer.apply(&mut self.xbar_hidden, &hidden_delta);
        self.programmer.apply(&mut self.xbar_out, &d.d_wo);
        // biases update digitally
        for (b, &v) in self.bh.iter_mut().zip(&d.d_bh) {
            *b += v;
        }
        for (b, &v) in self.bo.iter_mut().zip(&d.d_bo) {
            *b += v;
        }
        Ok(())
    }

    fn train_adam(&mut self, x: &SeqBatch) -> Result<f32> {
        let eff = self.effective_params();
        let (g, loss) = bptt_grads(&eff, x, self.hyper.lam, self.hyper.beta);
        let upd = self.adam.step(&g, self.hyper.lr);
        // the update vector is *subtracted* from the flattened params —
        // negate it into programming deltas (artifact order)
        let (nx, nh, ny) = (self.nx, self.nh, self.ny);
        let (wh_n, uh_n, wo_n) = (nx * nh, nh * nh, nh * ny);
        let mut off = 0;
        let mut take = |n: usize| {
            let s: Vec<f32> = upd[off..off + n].iter().map(|v| -v).collect();
            off += n;
            s
        };
        let d = DfaDeltas {
            d_wh: Mat::from_vec(nx, nh, take(wh_n)),
            d_uh: Mat::from_vec(nh, nh, take(uh_n)),
            d_bh: take(nh),
            d_wo: Mat::from_vec(nh, ny, take(wo_n)),
            d_bo: take(ny),
            loss,
        };
        self.apply_update(&d)?;
        Ok(loss)
    }

    fn fork(&self) -> Result<Box<dyn ComputeBackend>> {
        Ok(Box::new(self.clone()))
    }

    /// Reprogram every device to realize the checkpointed weights (the
    /// ex-situ reload path). Conductance discretization and write noise
    /// apply — exactly what reloading a physical chip costs — so the
    /// restored *effective* weights track the snapshot within device
    /// tolerances rather than bit-exactly. Biases restore exactly (they
    /// live in digital registers).
    fn restore_params(&mut self, p: &MiruParams) -> Result<()> {
        ensure!(
            p.nx() == self.nx && p.nh() == self.nh && p.ny() == self.ny,
            "checkpoint shapes ({}, {}, {}) do not match net ({}, {}, {})",
            p.nx(),
            p.nh(),
            p.ny(),
            self.nx,
            self.nh,
            self.ny
        );
        self.xbar_hidden.program_weights(&Mat::vcat(&p.wh, &p.uh));
        self.xbar_out.program_weights(&p.wo);
        self.bh = p.bh.clone();
        self.bo = p.bo.clone();
        Ok(())
    }

    fn column_write_counts(&self) -> Option<super::ColumnWear> {
        Some(super::ColumnWear {
            hidden: self.xbar_hidden.column_write_counts(),
            readout: self.xbar_out.column_write_counts(),
        })
    }

    fn wear_state(&self) -> Option<super::WearState> {
        Some(super::WearState {
            hidden: self.xbar_hidden.write_counts(),
            readout: self.xbar_out.write_counts(),
            steps: self.programmer.steps,
            writes: self.programmer.total.writes,
            skipped: self.programmer.total.skipped,
            delta_magnitude: self.programmer.total.delta_magnitude,
        })
    }

    /// Overwrite per-device write counters and the Ziksa totals with the
    /// checkpointed values. The `restore_params` reload that precedes
    /// this call issued its own programming pulses; those are discarded
    /// here on purpose — the restored run continues with exactly the
    /// wear the snapshotted run had accumulated, so rationing and the
    /// lifespan projection are kill/restart-invariant.
    fn restore_wear(&mut self, w: &super::WearState) -> Result<()> {
        ensure!(
            w.hidden.len() == self.xbar_hidden.rows * self.xbar_hidden.cols
                && w.readout.len() == self.xbar_out.rows * self.xbar_out.cols,
            "wear record sizes ({}, {}) do not match crossbars ({}, {})",
            w.hidden.len(),
            w.readout.len(),
            self.xbar_hidden.rows * self.xbar_hidden.cols,
            self.xbar_out.rows * self.xbar_out.cols
        );
        self.xbar_hidden.restore_write_counts(&w.hidden);
        self.xbar_out.restore_write_counts(&w.readout);
        self.programmer.steps = w.steps;
        self.programmer.total.writes = w.writes;
        self.programmer.total.skipped = w.skipped;
        self.programmer.total.delta_magnitude = w.delta_magnitude;
        Ok(())
    }

    /// Mean per-device writes per committed update, projected through the
    /// endurance model at the paper's 1 kHz ("learning at a rate of 1 ms")
    /// commit cadence. Infinite before the first training commit.
    fn projected_lifespan_years(&self) -> Option<f64> {
        let n_dev = (self.xbar_hidden.rows * self.xbar_hidden.cols
            + self.xbar_out.rows * self.xbar_out.cols) as f64;
        // the Ziksa programmer is invoked once per crossbar per train
        // step, so commits = steps / 2
        let commits = (self.programmer.steps / 2).max(1) as f64;
        let writes_per_device_per_commit = self.programmer.total.writes as f64 / n_dev / commits;
        Some(crate::device::lifespan_years(
            self.device().endurance,
            writes_per_device_per_commit,
            1000.0,
        ))
    }

    fn stats(&self) -> Vec<String> {
        vec![
            format!(
                "wbs mac: packed bit-planes (nb={}, kernel={}, precision={})",
                self.nb,
                kernels::active_name(),
                kernels::precision_name()
            ),
            format!(
                "device writes: total={} mean/step={:.1} skipped={}",
                self.programmer.total.writes,
                self.programmer.writes_per_step(),
                self.programmer.total.skipped
            ),
            format!(
                "frozen devices: hidden {:.4} readout {:.4}",
                self.xbar_hidden.frozen_fraction(),
                self.xbar_out.frozen_fraction()
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::tests::toy_batch;
    use crate::config::NetConfig;
    use crate::linalg::argmax_rows;

    fn quiet_ctx(seed: u64) -> BackendCtx {
        // noise-free, fine-grained devices: isolates the WBS/ADC
        // quantization error from programming stochasticity
        BackendCtx {
            lam: 0.5,
            beta: 0.7,
            lr: 0.5,
            seed,
            device: DeviceParams {
                levels: 4096,
                c2c_sigma: 0.0,
                d2d_sigma: 0.0,
                ..DeviceParams::default()
            },
            ..BackendCtx::new(NetConfig::SMALL)
        }
    }

    #[test]
    fn forward_tracks_ideal_math_on_effective_weights() {
        let net = NetConfig::SMALL;
        let be = CrossbarBackend::new(&quiet_ctx(1));
        let x = toy_batch(&net, 16, 2);
        let got = be.forward(&x).unwrap();
        let eff = be.effective_params();
        let ideal = eff.forward(&x, 0.5, 0.7);
        for (a, b) in got.data.iter().zip(&ideal.data) {
            assert!((a - b).abs() < 0.2, "quantization error budget exceeded: {a} vs {b}");
        }
    }

    #[test]
    fn training_improves_accuracy_through_devices() {
        let net = NetConfig::SMALL;
        let mut be = CrossbarBackend::new(&quiet_ctx(1));
        let test = toy_batch(&net, 64, 0);
        let acc = |be: &CrossbarBackend| {
            let preds = argmax_rows(&be.forward(&test).unwrap());
            preds.iter().zip(&test.labels).filter(|(a, b)| a == b).count() as f32 / 64.0
        };
        let before = acc(&be);
        for i in 0..60 {
            be.train_dfa(&toy_batch(&net, 8, 10 + i)).unwrap();
        }
        let after = acc(&be);
        assert!(after > before + 0.15, "before {before} after {after}");
        assert!(be.programmer.total.writes > 0, "training must issue device writes");
    }

    #[test]
    fn zeta_sparsification_skips_writes() {
        let net = NetConfig::SMALL;
        let x = toy_batch(&net, 8, 3);
        let mut sparse = CrossbarBackend::new(&quiet_ctx(5));
        sparse.train_dfa(&x).unwrap();
        let mut dense = CrossbarBackend::new(&BackendCtx {
            keep_frac: None,
            ..quiet_ctx(5)
        });
        dense.train_dfa(&x).unwrap();
        assert!(
            sparse.programmer.total.writes < dense.programmer.total.writes,
            "ζ must reduce write pressure: {} vs {}",
            sparse.programmer.total.writes,
            dense.programmer.total.writes
        );
    }

    #[test]
    fn int8_step_and_readout_track_f32() {
        let be = CrossbarBackend::new(&quiet_ctx(11));
        let p = be.effective_params();
        let q = crate::quant::QuantizedParams::build(&p);
        let h = Mat::from_fn(6, be.nh, |r, c| ((r * 3 + c) % 11) as f32 / 5.5 - 1.0);
        let x = Mat::from_fn(6, be.nx, |r, c| ((r * 7 + c * 2) % 13) as f32 / 6.5 - 1.0);
        let hf = be.step_hidden_from(&p, &h, &x).unwrap();
        let hq = be.step_hidden_int8(&p, &q, &h, &x).unwrap();
        for (a, b) in hq.data.iter().zip(&hf.data) {
            // weight quantization on top of the WBS/ADC error budget
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
        let lf = be.readout_from(&p, &hf).unwrap();
        let lq = be.readout_int8(&p, &q, &hf).unwrap();
        for (a, b) in lq.data.iter().zip(&lf.data) {
            assert!((a - b).abs() < 0.15 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn vmm_is_the_bit_serial_wbs_mac() {
        // the backend VMM must be bit-identical to the per-bit reference
        // loop over the same effective weights (§IV-B1 semantics), and
        // value-close to digitize-then-matmul (same math, different f32
        // association across bit-planes)
        let be = CrossbarBackend::new(&quiet_ctx(7));
        let nin = be.nx + be.nh;
        let x = Mat::from_fn(2, nin, |r, c| ((r * nin + c) % 7) as f32 / 7.0 - 0.5);
        let got = be.vmm(&x, LayerSel::Hidden).unwrap();
        let g = be.xbar_hidden.read_weights();
        for r in 0..x.rows {
            let want = crate::linalg::bitplane::wbs_mac_bitloop(x.row(r), &g, be.nb);
            for (a, b) in got.row(r).iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {r}");
            }
        }
        let mut xq = x.clone();
        for v in &mut xq.data {
            *v = crate::quant::wbs_input_quantize(*v, be.nb);
        }
        let approx = xq.matmul(&g);
        for (a, b) in got.data.iter().zip(&approx.data) {
            assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "association drift too large: {a} vs {b}");
        }
        assert!(be.vmm(&x, LayerSel::Readout).is_err());
    }
}
