//! Runtime backend selection: a name → factory table, so the CLI, the
//! serving engine and the benchmarks all pick an execution substrate the
//! same way (`m2ru train --backend crossbar`).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use super::{ArtifactBackend, BackendCtx, ComputeBackend, CrossbarBackend, DenseBackend};

/// Builds one backend instance from a context. Factories are plain `fn`
/// pointers so a registry is cheap to clone and `Send + Sync` for free.
pub type BackendFactory = fn(&BackendCtx) -> Result<Box<dyn ComputeBackend>>;

/// Name → factory table with runtime lookup.
#[derive(Clone)]
pub struct BackendRegistry {
    entries: BTreeMap<String, BackendFactory>,
}

impl BackendRegistry {
    /// An empty registry (use [`BackendRegistry::with_defaults`] for the
    /// built-in set).
    pub fn new() -> BackendRegistry {
        BackendRegistry { entries: BTreeMap::new() }
    }

    /// The three built-in execution paths: `dense` (digital CMOS
    /// baseline), `crossbar` (memristive device simulator), `artifact`
    /// (AOT XLA via PJRT).
    pub fn with_defaults() -> BackendRegistry {
        let mut r = BackendRegistry::new();
        r.register("dense", DenseBackend::factory);
        r.register("crossbar", CrossbarBackend::factory);
        r.register("artifact", ArtifactBackend::factory);
        r
    }

    /// Register (or replace) a backend factory under `name`.
    pub fn register(&mut self, name: impl Into<String>, factory: BackendFactory) {
        self.entries.insert(name.into(), factory);
    }

    /// Look up a factory by name; the error lists what is available.
    ///
    /// ```
    /// use m2ru::backend::{BackendCtx, BackendRegistry, ComputeBackend};
    /// use m2ru::config::NetConfig;
    ///
    /// let registry = BackendRegistry::with_defaults();
    /// let factory = registry.get("dense").unwrap();
    /// let backend = factory(&BackendCtx::new(NetConfig::SMALL)).unwrap();
    /// assert_eq!(backend.name(), "dense");
    /// assert!(registry.get("tpu").is_err());
    /// ```
    pub fn get(&self, name: &str) -> Result<BackendFactory> {
        self.entries.get(name).copied().ok_or_else(|| {
            anyhow!("unknown backend `{name}` (available: {})", self.names().join(", "))
        })
    }

    /// Look up and instantiate in one step.
    pub fn create(&self, name: &str, ctx: &BackendCtx) -> Result<Box<dyn ComputeBackend>> {
        (self.get(name)?)(ctx)
    }

    /// Registered backend names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }
}

impl Default for BackendRegistry {
    fn default() -> BackendRegistry {
        BackendRegistry::with_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;

    #[test]
    fn defaults_cover_the_three_paths() {
        let r = BackendRegistry::with_defaults();
        assert_eq!(r.names(), vec!["artifact", "crossbar", "dense"]);
    }

    #[test]
    fn create_dense_and_crossbar() {
        let r = BackendRegistry::with_defaults();
        let ctx = BackendCtx::new(NetConfig::SMALL);
        assert_eq!(r.create("dense", &ctx).unwrap().name(), "dense");
        assert_eq!(r.create("crossbar", &ctx).unwrap().name(), "crossbar");
    }

    #[test]
    fn unknown_name_lists_available() {
        let r = BackendRegistry::with_defaults();
        let err = r.get("gpu").unwrap_err().to_string();
        assert!(err.contains("unknown backend `gpu`"), "{err}");
        assert!(err.contains("dense") && err.contains("crossbar"), "{err}");
    }

    #[test]
    fn artifact_factory_fails_gracefully_without_artifacts() {
        // offline build: no artifacts directory and a stub PJRT — the
        // factory must return an error, not panic
        let r = BackendRegistry::with_defaults();
        let ctx = BackendCtx {
            artifacts_dir: "/nonexistent/artifacts".to_string(),
            ..BackendCtx::new(NetConfig::SMALL)
        };
        assert!(r.create("artifact", &ctx).is_err());
    }

    #[test]
    fn custom_backend_registration() {
        let mut r = BackendRegistry::new();
        r.register("dense2", crate::backend::DenseBackend::factory);
        assert_eq!(r.create("dense2", &BackendCtx::new(NetConfig::SMALL)).unwrap().name(), "dense");
    }
}
