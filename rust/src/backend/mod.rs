//! Pluggable compute backends — the execution substrates behind the
//! serving engine (DESIGN.md §6).
//!
//! The paper's central comparison is *the same MiRU/DFA math on different
//! substrates*: a dense digital CMOS baseline, the memristive
//! crossbar datapath (WBS digitization → analog VMM → shared ADC), and
//! the AOT-compiled XLA artifacts. This module factors that comparison
//! into a trait so the coordinator, CLI and benchmarks select the
//! substrate at runtime instead of hard-wiring one path:
//!
//! * [`ComputeBackend`] — the substrate contract: forward pass, VMM
//!   primitive, DFA/Adam train steps, raw-gradient hooks for the
//!   multi-worker engine, and forking for per-worker instances.
//! * [`BackendRegistry`] — name → factory table with the three built-in
//!   backends registered ([`DenseBackend`], [`CrossbarBackend`],
//!   [`ArtifactBackend`]); `m2ru train --backend <name>` resolves here.
//! * [`BackendCtx`] — everything a factory needs to instantiate a
//!   backend (network shapes, hyper-parameters, seed, device model).
//!
//! Adding a backend is: implement [`ComputeBackend`], write a
//! `fn(&BackendCtx) -> Result<Box<dyn ComputeBackend>>` factory, and
//! `registry.register("name", factory)` — see DESIGN.md §6 for the
//! walkthrough.

mod artifact;
mod crossbar;
mod dense;
mod registry;

pub use artifact::ArtifactBackend;
pub use crossbar::CrossbarBackend;
pub use dense::DenseBackend;
pub use registry::{BackendFactory, BackendRegistry};

use anyhow::{anyhow, Result};

use crate::config::{NetConfig, RunConfig};
use crate::device::DeviceParams;
use crate::linalg::Mat;
use crate::nn::{kwta_inplace, DfaDeltas, MiruParams, SeqBatch};

/// Which crossbar (weight matrix) a [`ComputeBackend::vmm`] call targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSel {
    /// The hidden-layer crossbar holding `[W_h; U_h]`, driven by
    /// `[x_t | β·h]` wordline vectors of width `nx + nh`.
    Hidden,
    /// The readout crossbar holding `W_o`, driven by `h` (width `nh`).
    Readout,
}

/// Per-column cumulative device write counts of a substrate's two weight
/// crossbars — the wear signal behind the serve-path write-rationing
/// policy ([`crate::coordinator::ParallelEngine::train_whole_guarded`]).
/// `hidden` has one entry per hidden unit (the stacked `[W_h; U_h]`
/// crossbar's bitlines), `readout` one per class.
#[derive(Clone, Debug, Default)]
pub struct ColumnWear {
    pub hidden: Vec<u64>,
    pub readout: Vec<u64>,
}

/// The full durable wear record of a substrate: per-device write
/// counters of both crossbars (row-major, hidden `(nx+nh)×nh` then
/// readout `nh×ny`) plus the Ziksa programmer's cumulative totals.
/// Serialized into serve snapshots so write rationing and the projected
/// lifespan survive a kill/restart (DESIGN.md §9 used to document this
/// as a gap). Substrates without wear accounting have none.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WearState {
    /// Per-device writes of the stacked `[W_h; U_h]` crossbar.
    pub hidden: Vec<u64>,
    /// Per-device writes of the readout crossbar.
    pub readout: Vec<u64>,
    /// Ziksa update steps issued (2 per training commit).
    pub steps: u64,
    /// Cumulative devices programmed.
    pub writes: u64,
    /// Cumulative devices skipped (ζ-zeroed deltas).
    pub skipped: u64,
    /// Cumulative |Δw| applied (energy-model input).
    pub delta_magnitude: f64,
}

/// Training hyper-parameters a backend applies internally (and that the
/// multi-worker engine needs to finalize externally-merged gradients the
/// same way).
#[derive(Clone, Copy, Debug)]
pub struct TrainHyper {
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
    /// ζ keep fraction for the memristor-backed weight deltas
    /// (`None` → dense updates, the Fig. 5b baseline).
    pub keep_frac: Option<f32>,
}

/// Everything a backend factory needs to build an instance.
#[derive(Clone, Debug)]
pub struct BackendCtx {
    pub net: NetConfig,
    pub lam: f32,
    pub beta: f32,
    pub lr: f32,
    pub seed: u64,
    pub keep_frac: Option<f32>,
    /// Memristor model for device-aware backends.
    pub device: DeviceParams,
    /// Where the AOT artifacts live (artifact backend only).
    pub artifacts_dir: String,
}

impl BackendCtx {
    /// Context at the default operating point (see `RunConfig::default`).
    pub fn new(net: NetConfig) -> BackendCtx {
        BackendCtx::from_run(net, &RunConfig::default())
    }

    /// Context from a run configuration (the CLI path).
    pub fn from_run(net: NetConfig, run: &RunConfig) -> BackendCtx {
        BackendCtx {
            net,
            lam: run.lam,
            beta: run.beta,
            lr: run.lr,
            seed: run.seed,
            keep_frac: Some(net.keep_frac),
            device: DeviceParams::default(),
            artifacts_dir: "artifacts".to_string(),
        }
    }

    fn hyper(&self) -> TrainHyper {
        TrainHyper { lam: self.lam, beta: self.beta, lr: self.lr, keep_frac: self.keep_frac }
    }
}

/// One execution substrate for the MiRU network.
///
/// The gradient contract: [`ComputeBackend::dfa_raw_grads`] returns
/// *dense unit-lr deltas* (`d = −g`, no ζ, no learning-rate scaling) so
/// the multi-worker engine can merge shard gradients before
/// sparsification; [`finalize_update`] then applies ζ and the learning
/// rate, and [`ComputeBackend::apply_update`] commits the result to the
/// substrate (ideal adds for digital weights, Ziksa programming for
/// crossbars). The provided [`ComputeBackend::train_dfa`] composes
/// exactly these three steps, so a single-worker parallel engine and a
/// sequential backend step are bit-identical.
pub trait ComputeBackend: Send + Sync {
    /// Registry name ("dense", "crossbar", "artifact", ...).
    fn name(&self) -> &'static str;

    /// The hyper-parameters this backend trains with.
    fn hyper(&self) -> TrainHyper;

    /// The weights as this substrate currently realizes them (for the
    /// dense backend the stored weights; for crossbars the read-out
    /// conductances with discretization and variability folded in).
    fn effective_params(&self) -> MiruParams;

    /// Final-step logits `[b, ny]` through this backend's datapath.
    fn forward(&self, x: &SeqBatch) -> Result<Mat>;

    /// The VMM primitive on this substrate: `x` rows drive the selected
    /// layer's weight matrix. Crossbar backends digitize the drive (WBS)
    /// and return integrator voltages; digital backends return the exact
    /// product.
    fn vmm(&self, x: &Mat, layer: LayerSel) -> Result<Mat>;

    /// Advance caller-owned hidden state by one timestep: `h` is `[b, nh]`
    /// (one row per session), `x` is `[b, nx]`, and the result is the new
    /// `[b, nh]` hidden state through this substrate's datapath. The
    /// serving contract: driving a sequence one timestep at a time through
    /// `step_hidden` from a zero state, then calling
    /// [`ComputeBackend::readout`], must produce *bitwise-identical*
    /// logits to [`ComputeBackend::forward`] on the whole sequence.
    /// Backends lowered with whole-sequence static graphs cannot offer a
    /// single-step entry point and report an error.
    fn step_hidden(&self, _h: &Mat, _x: &Mat) -> Result<Mat> {
        Err(anyhow!("backend `{}` has no single-step serving entry point", self.name()))
    }

    /// Final-layer logits `[b, ny]` from a caller-owned hidden state
    /// `[b, nh]` — the readout half of the streaming contract (see
    /// [`ComputeBackend::step_hidden`]).
    fn readout(&self, _h: &Mat) -> Result<Mat> {
        Err(anyhow!("backend `{}` has no single-step serving entry point", self.name()))
    }

    /// [`ComputeBackend::step_hidden`] against an already-materialized
    /// weight snapshot (`p` should come from
    /// [`ComputeBackend::effective_params`]). The serving engine reads
    /// the substrate once per dispatched batch and shares the snapshot
    /// across worker shards — for crossbars that is one device read per
    /// batch instead of one per shard per step (the same discipline as
    /// [`ComputeBackend::dfa_raw_grads_from`] on the train path).
    /// Bitwise-identical to `step_hidden` on an unchanged substrate.
    fn step_hidden_from(&self, _p: &MiruParams, h: &Mat, x: &Mat) -> Result<Mat> {
        self.step_hidden(h, x)
    }

    /// [`ComputeBackend::readout`] against an already-materialized weight
    /// snapshot.
    fn readout_from(&self, _p: &MiruParams, h: &Mat) -> Result<Mat> {
        self.readout(h)
    }

    /// [`ComputeBackend::step_hidden_from`] through the int8 serving
    /// path: `q` holds the per-generation pre-quantized weight planes
    /// built alongside `p` by the committer (DESIGN.md §15). Backends
    /// without an integer datapath fall back to the f32 snapshot step —
    /// the precision toggle can never break a substrate.
    fn step_hidden_int8(
        &self,
        p: &MiruParams,
        _q: &crate::quant::QuantizedParams,
        h: &Mat,
        x: &Mat,
    ) -> Result<Mat> {
        self.step_hidden_from(p, h, x)
    }

    /// [`ComputeBackend::readout_from`] through the int8 serving path
    /// (see [`ComputeBackend::step_hidden_int8`]).
    fn readout_int8(
        &self,
        p: &MiruParams,
        _q: &crate::quant::QuantizedParams,
        h: &Mat,
    ) -> Result<Mat> {
        self.readout_from(p, h)
    }

    /// Dense unit-lr DFA deltas (`−g`) from an already-materialized
    /// weight snapshot. Pure (`&self`) so train shards can run on worker
    /// threads against one shared snapshot — the parallel engine reads
    /// the substrate once per step instead of once per worker.
    fn dfa_raw_grads_from(&self, p: &MiruParams, x: &SeqBatch) -> Result<DfaDeltas>;

    /// Dense unit-lr DFA deltas from the current effective weights.
    fn dfa_raw_grads(&self, x: &SeqBatch) -> Result<DfaDeltas> {
        self.dfa_raw_grads_from(&self.effective_params(), x)
    }

    /// Commit finalized deltas to the substrate.
    fn apply_update(&mut self, d: &DfaDeltas) -> Result<()>;

    /// One whole-batch DFA train step; returns the batch loss.
    fn train_dfa(&mut self, x: &SeqBatch) -> Result<f32> {
        let mut d = self.dfa_raw_grads(x)?;
        finalize_update(&mut d, &self.hyper());
        self.apply_update(&d)?;
        Ok(d.loss)
    }

    /// One BPTT + Adam train step; returns the batch loss.
    fn train_adam(&mut self, x: &SeqBatch) -> Result<f32>;

    /// An independent instance with identical current weights, for
    /// per-worker evaluation. Errors if the substrate cannot be cloned
    /// (compiled executables).
    fn fork(&self) -> Result<Box<dyn ComputeBackend>>;

    /// Backends lowered with static batch shapes (XLA artifacts) cannot
    /// profit from row-sharding; the parallel engine falls back to
    /// whole-batch execution when this is true.
    fn prefers_whole_batch(&self) -> bool {
        false
    }

    /// Human-readable substrate statistics (write pressure, endurance).
    fn stats(&self) -> Vec<String> {
        Vec::new()
    }

    /// Overwrite the substrate's weights from a checkpointed snapshot.
    /// Digital backends restore bit-exactly; crossbar backends reprogram
    /// the devices (discretization and write noise apply, exactly as an
    /// ex-situ reload of a physical chip would). Backends that cannot
    /// load weights (compiled executables) report an error.
    fn restore_params(&mut self, _p: &MiruParams) -> Result<()> {
        Err(anyhow!("backend `{}` cannot restore checkpointed weights", self.name()))
    }

    /// Per-column device write counts, for wear-aware write rationing.
    /// `None` on substrates without wear (digital weights never degrade).
    fn column_write_counts(&self) -> Option<ColumnWear> {
        None
    }

    /// The substrate's durable wear record (per-device write counters +
    /// programmer totals), for checkpointing. `None` on substrates
    /// without wear accounting.
    fn wear_state(&self) -> Option<WearState> {
        None
    }

    /// Overwrite the substrate's wear record from a checkpoint, so
    /// rationing decisions and the lifespan projection continue exactly
    /// where the snapshotted run stopped. Called *after*
    /// [`ComputeBackend::restore_params`]: the reload's own programming
    /// pulses are deliberately not double-counted — the restored
    /// counters are the snapshot's, making a restarted run
    /// wear-equivalent to the uninterrupted one. A no-op on substrates
    /// without wear accounting.
    fn restore_wear(&mut self, _w: &WearState) -> Result<()> {
        Ok(())
    }

    /// Projected device lifespan in years at the paper's 1 kHz commit
    /// rate, from mean per-device write pressure and the endurance limit.
    /// `None` on substrates without an endurance model.
    fn projected_lifespan_years(&self) -> Option<f64> {
        None
    }
}

/// Turn merged unit-lr deltas into the committed update: ζ-sparsify the
/// memristor-backed matrices (biases stay dense — they live in digital
/// registers), then scale everything by the learning rate. Applying this
/// to the output of [`ComputeBackend::dfa_raw_grads`] reproduces the
/// fused `dfa_grads(.., lr, keep_frac)` step exactly.
pub fn finalize_update(d: &mut DfaDeltas, hyper: &TrainHyper) {
    if let Some(f) = hyper.keep_frac {
        kwta_inplace(&mut d.d_wh, f);
        kwta_inplace(&mut d.d_uh, f);
        kwta_inplace(&mut d.d_wo, f);
    }
    d.d_wh.scale(hyper.lr);
    d.d_uh.scale(hyper.lr);
    d.d_wo.scale(hyper.lr);
    for v in &mut d.d_bh {
        *v *= hyper.lr;
    }
    for v in &mut d.d_bo {
        *v *= hyper.lr;
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::nn::{dfa_grads, make_psi};
    use crate::rng::GaussianRng;

    pub(crate) fn toy_batch(net: &NetConfig, b: usize, seed: u64) -> SeqBatch {
        let mut proto_rng = GaussianRng::new(99);
        let protos: Vec<Vec<f32>> =
            (0..net.ny).map(|_| (0..net.nx).map(|_| proto_rng.normal()).collect()).collect();
        let mut rng = GaussianRng::new(seed);
        let mut sb = SeqBatch::zeros(b, net.nt, net.nx);
        for i in 0..b {
            let label = rng.below(net.ny);
            sb.labels[i] = label;
            for t in 0..net.nt {
                for j in 0..net.nx {
                    sb.sample_mut(i)[t * net.nx + j] =
                        (0.25 * rng.normal() + 0.75 * protos[label][j]).clamp(-1.0, 1.0);
                }
            }
        }
        sb
    }

    #[test]
    fn finalize_matches_fused_dfa_step() {
        let net = NetConfig::SMALL;
        let p = MiruParams::init(net.nx, net.nh, net.ny, 3);
        let psi = make_psi(net.ny, net.nh, 5);
        let x = toy_batch(&net, 8, 7);
        let (lam, beta, lr) = (0.5, 0.7, 0.25);
        let hyper = TrainHyper { lam, beta, lr, keep_frac: Some(0.53) };

        let mut raw = dfa_grads(&p, &x, lam, beta, 1.0, &psi, None);
        finalize_update(&mut raw, &hyper);
        let fused = dfa_grads(&p, &x, lam, beta, lr, &psi, Some(0.53));

        for (a, b) in raw.d_wh.data.iter().zip(&fused.d_wh.data) {
            assert_eq!(a, b, "finalized raw grads must equal the fused step");
        }
        for (a, b) in raw.d_uh.data.iter().zip(&fused.d_uh.data) {
            assert_eq!(a, b);
        }
        for (a, b) in raw.d_bh.iter().zip(&fused.d_bh) {
            assert_eq!(a, b);
        }
        assert_eq!(raw.loss, fused.loss);
    }

    #[test]
    fn ctx_carries_run_operating_point() {
        let run = RunConfig { lam: 0.8, beta: 0.2, lr: 0.1, seed: 9, ..RunConfig::default() };
        let ctx = BackendCtx::from_run(NetConfig::SMALL, &run);
        assert_eq!(ctx.lam, 0.8);
        assert_eq!(ctx.seed, 9);
        assert_eq!(ctx.keep_frac, Some(NetConfig::SMALL.keep_frac));
        let h = ctx.hyper();
        assert_eq!(h.lr, 0.1);
    }
}
