//! Quantization primitives — the digital/analog boundary of the paper.
//!
//! Mirrors `python/compile/kernels/ref.py` bit-for-bit so the rust digital
//! baseline, the device simulator and the AOT artifacts agree on rounding:
//!
//! * [`wbs_input_quantize`] — the n_b-bit sign/magnitude digitization the
//!   WBS wordline drivers apply (§V-A).
//! * [`adc_quantize`] — the shared-ADC read-out of the integrator (§IV-B1).
//! * [`stochastic_round`] / [`uniform_truncate`] — the replay-path feature
//!   compression of Eqs. (4)–(6) and its biased baseline (Fig. 5a).

use crate::linalg::{kernels, Mat};
use crate::nn::MiruParams;
use crate::rng::Lfsr16;

/// n_b-bit sign/magnitude digitization of an analog value in [-1, 1]:
/// `sign(x) * round(|x| * (2^nb - 1)) / 2^nb` — exactly what the bit-serial
/// WBS stream reconstructs on the integrator.
#[inline]
pub fn wbs_input_quantize(x: f32, nb: u32) -> f32 {
    let full = (1u32 << nb) as f32;
    let mag = (x.abs() * (full - 1.0)).round();
    x.signum() * mag / full
}

/// Shared-ADC quantization: clip to ±v_scale, `bits`-bit signed levels.
#[inline]
pub fn adc_quantize(v: f32, bits: u32, v_scale: f32) -> f32 {
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let x = (v / v_scale).clamp(-1.0, 1.0);
    (x * levels).round() / levels * v_scale
}

/// Stochastic rounding of a feature in [0,1) to an `nb`-bit integer code
/// (Eqs. 4–6). `r` is the uniform draw — in hardware, the LFSR word.
#[inline]
pub fn stochastic_round(x: f32, r: f32, nb: u32) -> u8 {
    let full = (1u32 << nb) as f32;
    let z = x * full;
    let fl = z.floor();
    let frac = z - fl;
    if r < frac && fl < full - 1.0 {
        (fl + 1.0) as u8
    } else {
        fl as u8
    }
}

/// Plain truncation to an `nb`-bit code — the biased baseline of Fig. 5(a).
#[inline]
pub fn uniform_truncate(x: f32, nb: u32) -> u8 {
    let full = (1u32 << nb) as f32;
    (x * full).floor().clamp(0.0, full - 1.0) as u8
}

/// Dequantize an `nb`-bit code back to [0,1): `q / 2^nb`.
#[inline]
pub fn dequantize(q: u8, nb: u32) -> f32 {
    f32::from(q) / (1u32 << nb) as f32
}

/// The hardware stochastic quantizer: LFSR + comparator + incrementer
/// (§IV-A2), quantizing whole feature vectors for the replay buffer.
#[derive(Clone, Debug)]
pub struct StochasticQuantizer {
    lfsr: Lfsr16,
    pub nb: u32,
}

impl StochasticQuantizer {
    pub fn new(seed: u16, nb: u32) -> Self {
        assert!(nb >= 1 && nb <= 8);
        Self { lfsr: Lfsr16::new(seed), nb }
    }

    pub fn quantize(&mut self, x: f32) -> u8 {
        let r = self.lfsr.next_unit();
        stochastic_round(x.clamp(0.0, 0.999_999), r, self.nb)
    }

    pub fn quantize_vec(&mut self, xs: &[f32]) -> Vec<u8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Current LFSR word — never zero, so `Lfsr16::new(word)` reconstructs
    /// the register exactly (checkpoint/restore hook).
    pub fn lfsr_state(&self) -> u16 {
        self.lfsr.state()
    }

    /// Reconstruct the LFSR mid-stream from [`StochasticQuantizer::lfsr_state`].
    pub fn restore_lfsr(&mut self, state: u16) {
        self.lfsr = Lfsr16::new(state);
    }
}

// ---- int8 serving planes ---------------------------------------------------
//
// The serve-path weight quantization (DESIGN.md §15): per-column
// symmetric scales, built once per commit generation by the committer
// into the published `WeightSnapshot`, consumed by the i8×i8→i32 MAC
// kernels. The same sign/magnitude idea as `wbs_input_quantize`, with
// the scale carried per column instead of fixed at 1 so untrained and
// well-trained weights both use the full code range.

/// A weight matrix quantized to i8 codes with one symmetric scale per
/// column: `w[r][c] ≈ codes[r*cols + c] * scales[c]`. Column-major
/// scales match the MAC layout — every output column folds exactly one
/// scale, after the integer accumulation.
#[derive(Clone, Debug)]
pub struct QuantizedMat {
    pub rows: usize,
    pub cols: usize,
    /// Row-major i8 codes, `rows × cols`, |code| ≤ 127.
    pub codes: Vec<i8>,
    /// Per-column dequantization scale (`max|col| / 127`; 0 for an
    /// all-zero column, whose codes are all 0).
    pub scales: Vec<f32>,
}

impl QuantizedMat {
    /// Quantize `m` with per-column symmetric scales.
    pub fn from_mat(m: &Mat) -> QuantizedMat {
        let mut scales = vec![0.0f32; m.cols];
        for r in 0..m.rows {
            for (c, s) in scales.iter_mut().enumerate() {
                *s = s.max(m.at(r, c).abs());
            }
        }
        for s in &mut scales {
            *s /= 127.0;
        }
        let mut codes = vec![0i8; m.rows * m.cols];
        for r in 0..m.rows {
            let row = m.row(r);
            let orow = &mut codes[r * m.cols..(r + 1) * m.cols];
            for ((o, &w), &s) in orow.iter_mut().zip(row).zip(&scales) {
                if s > 0.0 {
                    *o = (w / s).round().clamp(-127.0, 127.0) as i8;
                }
            }
        }
        QuantizedMat { rows: m.rows, cols: m.cols, codes, scales }
    }

    /// The f32 matrix these codes represent (tests, error analysis).
    pub fn dequantize(&self) -> Mat {
        Mat::from_fn(self.rows, self.cols, |r, c| {
            f32::from(self.codes[r * self.cols + c]) * self.scales[c]
        })
    }
}

/// Quantize one activation row to i8 with a symmetric per-row scale;
/// returns the scale (`max|x| / 127`; 0 for an all-zero row, codes 0).
/// Per-row (not per-batch) scales keep the serve math row-independent,
/// so sharded dispatch stays bitwise-identical for every worker count.
pub fn quantize_row_i8(row: &[f32], out: &mut [i8]) -> f32 {
    let mut amax = 0.0f32;
    for &x in row {
        amax = amax.max(x.abs());
    }
    let scale = amax / 127.0;
    if scale > 0.0 {
        for (o, &x) in out.iter_mut().zip(row) {
            *o = (x / scale).round().clamp(-127.0, 127.0) as i8;
        }
    } else {
        for o in out.iter_mut() {
            *o = 0;
        }
    }
    scale
}

/// `a @ q` through the integer MAC: quantize each row of `a` to i8
/// (per-row scale), run the kernel-dispatched i8×i8→i32 matmul against
/// the pre-quantized codes, then rescale each output element once by
/// `row_scale · column_scale`. The integer accumulation is exact, so
/// the result is bitwise-identical across scalar/AVX2/NEON kernels.
pub fn matmul_i8_rowquant(a: &Mat, q: &QuantizedMat) -> Mat {
    assert_eq!(a.cols, q.rows, "matmul_i8 {}x{} @ {}x{}", a.rows, a.cols, q.rows, q.cols);
    let mut acodes = vec![0i8; a.rows * a.cols];
    let mut ascales = vec![0.0f32; a.rows];
    for r in 0..a.rows {
        ascales[r] = quantize_row_i8(a.row(r), &mut acodes[r * a.cols..(r + 1) * a.cols]);
    }
    let mut acc = vec![0i32; a.rows * q.cols];
    kernels::matmul_i8(&acodes, &q.codes, &mut acc, a.rows, a.cols, q.cols);
    let mut out = Mat::zeros(a.rows, q.cols);
    for r in 0..a.rows {
        let rs = ascales[r];
        let orow = out.row_mut(r);
        let arow = &acc[r * q.cols..(r + 1) * q.cols];
        for ((o, &v), &cs) in orow.iter_mut().zip(arow).zip(&q.scales) {
            *o = v as f32 * (rs * cs);
        }
    }
    out
}

/// The per-generation int8 weight planes carried by a serve
/// `WeightSnapshot`: the stacked hidden matrix `[W_h; U_h]`
/// (`(nx+nh)×nh`, the same layout the crossbar drives) and the readout
/// `W_o`, plus the L1 column norms of the *f32* weights so the crossbar
/// backend derives its ADC full-scales without re-reading the floats on
/// the hot path. Biases stay f32 (digital registers).
#[derive(Clone, Debug)]
pub struct QuantizedParams {
    /// `[W_h; U_h]` stacked row-wise, quantized per column.
    pub hidden: QuantizedMat,
    /// `W_o`, quantized per column.
    pub wo: QuantizedMat,
    /// `max_c Σ_r |hidden[r][c]|` of the f32 weights.
    pub hidden_l1max: f32,
    /// `max_c Σ_r |wo[r][c]|` of the f32 weights.
    pub wo_l1max: f32,
}

fn l1max(m: &Mat) -> f32 {
    let mut best = 0.0f32;
    for c in 0..m.cols {
        let mut s = 0.0;
        for r in 0..m.rows {
            s += m.at(r, c).abs();
        }
        best = best.max(s);
    }
    best
}

impl QuantizedParams {
    /// Build the serve planes from a full-precision snapshot — called
    /// once per commit generation, never on the dispatch path.
    pub fn build(p: &MiruParams) -> QuantizedParams {
        let stacked = Mat::vcat(&p.wh, &p.uh);
        let hidden_l1max = l1max(&stacked);
        let wo_l1max = l1max(&p.wo);
        QuantizedParams {
            hidden: QuantizedMat::from_mat(&stacked),
            wo: QuantizedMat::from_mat(&p.wo),
            hidden_l1max,
            wo_l1max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wbs_quantize_endpoints() {
        assert_eq!(wbs_input_quantize(1.0, 8), 255.0 / 256.0);
        assert_eq!(wbs_input_quantize(-1.0, 8), -255.0 / 256.0);
        assert_eq!(wbs_input_quantize(0.0, 8), 0.0);
    }

    #[test]
    fn wbs_quantize_error_bound() {
        // |err| <= 0.5/(2^nb - 1) + |x|/2^nb  (round + scale) — loose bound 1/2^nb.
        for nb in 1..=8u32 {
            for i in 0..1000 {
                let x = -1.0 + 2.0 * (i as f32 / 999.0);
                let q = wbs_input_quantize(x, nb);
                assert!((q - x).abs() <= 1.5 / (1u32 << nb) as f32, "nb={nb} x={x} q={q}");
            }
        }
    }

    #[test]
    fn adc_quantize_half_lsb_and_clip() {
        let lsb = 2.0 / 127.0;
        for i in 0..100 {
            let v = -2.0 + 4.0 * (i as f32 / 99.0);
            let q = adc_quantize(v, 8, 2.0);
            assert!((q - v).abs() <= lsb / 2.0 + 1e-6);
        }
        assert_eq!(adc_quantize(99.0, 8, 2.0), 2.0);
        assert_eq!(adc_quantize(-99.0, 8, 2.0), -2.0);
    }

    #[test]
    fn stochastic_round_matches_python_oracle_rules() {
        // frac = 0.75 with r below/above.
        let x = (4.0 + 0.75) / 16.0; // z = 4.75 at nb=4
        assert_eq!(stochastic_round(x, 0.5, 4), 5);
        assert_eq!(stochastic_round(x, 0.9, 4), 4);
        // never exceeds 2^nb - 1
        assert_eq!(stochastic_round(0.999, 0.0, 4), 15);
    }

    #[test]
    fn stochastic_quantizer_is_unbiased() {
        let mut q = StochasticQuantizer::new(0x1234, 4);
        let n = 40_000;
        let mut bias = 0.0f64;
        for i in 0..n {
            let x = 0.9 * (i as f32 / n as f32);
            let code = q.quantize(x);
            bias += f64::from(dequantize(code, 4)) - f64::from(x);
        }
        assert!((bias / f64::from(n)).abs() < 3e-3, "bias {}", bias / f64::from(n));
    }

    #[test]
    fn truncation_is_biased_low() {
        let n = 10_000;
        let mut bias = 0.0f64;
        for i in 0..n {
            let x = 0.9 * (i as f32 / n as f32);
            bias += f64::from(dequantize(uniform_truncate(x, 4), 4)) - f64::from(x);
        }
        // truncation loses ~half an LSB on average: 0.5/16 ≈ 0.031
        assert!(bias / f64::from(n) < -0.02);
    }

    #[test]
    fn round_trip_exact_codes() {
        for code in 0u8..16 {
            let x = dequantize(code, 4);
            assert_eq!(uniform_truncate(x, 4), code);
            assert_eq!(stochastic_round(x, 0.99, 4), code);
        }
    }

    #[test]
    fn quantized_mat_error_within_half_lsb_per_column() {
        let m = Mat::from_fn(13, 7, |r, c| ((r * 7 + c * 3) % 19) as f32 / 9.0 - 1.0);
        let q = QuantizedMat::from_mat(&m);
        let d = q.dequantize();
        for c in 0..m.cols {
            let lsb = q.scales[c];
            for r in 0..m.rows {
                assert!(
                    (m.at(r, c) - d.at(r, c)).abs() <= 0.5 * lsb + 1e-7,
                    "({r},{c}): {} vs {}",
                    m.at(r, c),
                    d.at(r, c)
                );
            }
        }
        // the column max always maps to the full code
        for c in 0..m.cols {
            let maxcode = (0..m.rows).map(|r| q.codes[r * q.cols + c].unsigned_abs()).max();
            assert_eq!(maxcode, Some(127), "col {c}");
        }
    }

    #[test]
    fn quantized_mat_zero_column_is_safe() {
        let m = Mat::from_fn(4, 2, |r, c| if c == 0 { 0.0 } else { r as f32 - 1.5 });
        let q = QuantizedMat::from_mat(&m);
        assert_eq!(q.scales[0], 0.0);
        assert!((0..4).all(|r| q.codes[r * 2] == 0));
        let d = q.dequantize();
        assert!((0..4).all(|r| d.at(r, 0) == 0.0));
    }

    #[test]
    fn matmul_i8_rowquant_tracks_f32_matmul() {
        let a = Mat::from_fn(5, 11, |r, c| ((r * 11 + c) % 13) as f32 / 6.5 - 1.0);
        let w = Mat::from_fn(11, 4, |r, c| ((r * 4 + c * 5) % 17) as f32 / 8.5 - 1.0);
        let q = QuantizedMat::from_mat(&w);
        let got = matmul_i8_rowquant(&a, &q);
        let want = a.matmul(&w);
        for (g, wv) in got.data.iter().zip(&want.data) {
            // two ~1% relative quantizations over k=11 terms
            assert!((g - wv).abs() <= 0.05 * (1.0 + wv.abs()), "{g} vs {wv}");
        }
        // zero activation row must produce exactly zero
        let z = Mat::zeros(1, 11);
        assert!(matmul_i8_rowquant(&z, &q).data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantized_params_carries_l1_norms() {
        let p = MiruParams::init(6, 8, 3, 42);
        let q = QuantizedParams::build(&p);
        assert_eq!(q.hidden.rows, 14);
        assert_eq!(q.hidden.cols, 8);
        assert_eq!(q.wo.rows, 8);
        assert_eq!(q.wo.cols, 3);
        let stacked = Mat::vcat(&p.wh, &p.uh);
        assert_eq!(q.hidden_l1max, l1max(&stacked));
        assert_eq!(q.wo_l1max, l1max(&p.wo));
        assert!(q.hidden_l1max > 0.0 && q.wo_l1max > 0.0);
    }
}
