//! Quantization primitives — the digital/analog boundary of the paper.
//!
//! Mirrors `python/compile/kernels/ref.py` bit-for-bit so the rust digital
//! baseline, the device simulator and the AOT artifacts agree on rounding:
//!
//! * [`wbs_input_quantize`] — the n_b-bit sign/magnitude digitization the
//!   WBS wordline drivers apply (§V-A).
//! * [`adc_quantize`] — the shared-ADC read-out of the integrator (§IV-B1).
//! * [`stochastic_round`] / [`uniform_truncate`] — the replay-path feature
//!   compression of Eqs. (4)–(6) and its biased baseline (Fig. 5a).

use crate::rng::Lfsr16;

/// n_b-bit sign/magnitude digitization of an analog value in [-1, 1]:
/// `sign(x) * round(|x| * (2^nb - 1)) / 2^nb` — exactly what the bit-serial
/// WBS stream reconstructs on the integrator.
#[inline]
pub fn wbs_input_quantize(x: f32, nb: u32) -> f32 {
    let full = (1u32 << nb) as f32;
    let mag = (x.abs() * (full - 1.0)).round();
    x.signum() * mag / full
}

/// Shared-ADC quantization: clip to ±v_scale, `bits`-bit signed levels.
#[inline]
pub fn adc_quantize(v: f32, bits: u32, v_scale: f32) -> f32 {
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let x = (v / v_scale).clamp(-1.0, 1.0);
    (x * levels).round() / levels * v_scale
}

/// Stochastic rounding of a feature in [0,1) to an `nb`-bit integer code
/// (Eqs. 4–6). `r` is the uniform draw — in hardware, the LFSR word.
#[inline]
pub fn stochastic_round(x: f32, r: f32, nb: u32) -> u8 {
    let full = (1u32 << nb) as f32;
    let z = x * full;
    let fl = z.floor();
    let frac = z - fl;
    if r < frac && fl < full - 1.0 {
        (fl + 1.0) as u8
    } else {
        fl as u8
    }
}

/// Plain truncation to an `nb`-bit code — the biased baseline of Fig. 5(a).
#[inline]
pub fn uniform_truncate(x: f32, nb: u32) -> u8 {
    let full = (1u32 << nb) as f32;
    (x * full).floor().clamp(0.0, full - 1.0) as u8
}

/// Dequantize an `nb`-bit code back to [0,1): `q / 2^nb`.
#[inline]
pub fn dequantize(q: u8, nb: u32) -> f32 {
    f32::from(q) / (1u32 << nb) as f32
}

/// The hardware stochastic quantizer: LFSR + comparator + incrementer
/// (§IV-A2), quantizing whole feature vectors for the replay buffer.
#[derive(Clone, Debug)]
pub struct StochasticQuantizer {
    lfsr: Lfsr16,
    pub nb: u32,
}

impl StochasticQuantizer {
    pub fn new(seed: u16, nb: u32) -> Self {
        assert!(nb >= 1 && nb <= 8);
        Self { lfsr: Lfsr16::new(seed), nb }
    }

    pub fn quantize(&mut self, x: f32) -> u8 {
        let r = self.lfsr.next_unit();
        stochastic_round(x.clamp(0.0, 0.999_999), r, self.nb)
    }

    pub fn quantize_vec(&mut self, xs: &[f32]) -> Vec<u8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Current LFSR word — never zero, so `Lfsr16::new(word)` reconstructs
    /// the register exactly (checkpoint/restore hook).
    pub fn lfsr_state(&self) -> u16 {
        self.lfsr.state()
    }

    /// Reconstruct the LFSR mid-stream from [`StochasticQuantizer::lfsr_state`].
    pub fn restore_lfsr(&mut self, state: u16) {
        self.lfsr = Lfsr16::new(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wbs_quantize_endpoints() {
        assert_eq!(wbs_input_quantize(1.0, 8), 255.0 / 256.0);
        assert_eq!(wbs_input_quantize(-1.0, 8), -255.0 / 256.0);
        assert_eq!(wbs_input_quantize(0.0, 8), 0.0);
    }

    #[test]
    fn wbs_quantize_error_bound() {
        // |err| <= 0.5/(2^nb - 1) + |x|/2^nb  (round + scale) — loose bound 1/2^nb.
        for nb in 1..=8u32 {
            for i in 0..1000 {
                let x = -1.0 + 2.0 * (i as f32 / 999.0);
                let q = wbs_input_quantize(x, nb);
                assert!((q - x).abs() <= 1.5 / (1u32 << nb) as f32, "nb={nb} x={x} q={q}");
            }
        }
    }

    #[test]
    fn adc_quantize_half_lsb_and_clip() {
        let lsb = 2.0 / 127.0;
        for i in 0..100 {
            let v = -2.0 + 4.0 * (i as f32 / 99.0);
            let q = adc_quantize(v, 8, 2.0);
            assert!((q - v).abs() <= lsb / 2.0 + 1e-6);
        }
        assert_eq!(adc_quantize(99.0, 8, 2.0), 2.0);
        assert_eq!(adc_quantize(-99.0, 8, 2.0), -2.0);
    }

    #[test]
    fn stochastic_round_matches_python_oracle_rules() {
        // frac = 0.75 with r below/above.
        let x = (4.0 + 0.75) / 16.0; // z = 4.75 at nb=4
        assert_eq!(stochastic_round(x, 0.5, 4), 5);
        assert_eq!(stochastic_round(x, 0.9, 4), 4);
        // never exceeds 2^nb - 1
        assert_eq!(stochastic_round(0.999, 0.0, 4), 15);
    }

    #[test]
    fn stochastic_quantizer_is_unbiased() {
        let mut q = StochasticQuantizer::new(0x1234, 4);
        let n = 40_000;
        let mut bias = 0.0f64;
        for i in 0..n {
            let x = 0.9 * (i as f32 / n as f32);
            let code = q.quantize(x);
            bias += f64::from(dequantize(code, 4)) - f64::from(x);
        }
        assert!((bias / f64::from(n)).abs() < 3e-3, "bias {}", bias / f64::from(n));
    }

    #[test]
    fn truncation_is_biased_low() {
        let n = 10_000;
        let mut bias = 0.0f64;
        for i in 0..n {
            let x = 0.9 * (i as f32 / n as f32);
            bias += f64::from(dequantize(uniform_truncate(x, 4), 4)) - f64::from(x);
        }
        // truncation loses ~half an LSB on average: 0.5/16 ≈ 0.031
        assert!(bias / f64::from(n) < -0.02);
    }

    #[test]
    fn round_trip_exact_codes() {
        for code in 0u8..16 {
            let x = dequantize(code, 4);
            assert_eq!(uniform_truncate(x, 4), code);
            assert_eq!(stochastic_round(x, 0.99, 4), code);
        }
    }
}
