//! Reservoir sampler (§IV-A1) — Algorithm R on the xorshift + modulus
//! circuit of Fig. 1.
//!
//! The buffer of length k fills with the first k examples; for example
//! i > k (1-based), a random j ∈ 1..=i is drawn by the xorshift + modulus
//! unit, and if j ≤ k the j-th slot is overwritten. Every element of the
//! stream ends up in the buffer with probability k/i — the property the
//! uniformity test below checks end-to-end through the hardware RNG.

use crate::rng::Xorshift32;

/// What to do with the incoming example.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReservoirDecision {
    /// Store into slot `usize` (0-based).
    Store(usize),
    /// Do not store.
    Discard,
}

/// Hardware-shaped reservoir sampler: counter + xorshift + modulus + index
/// checker.
#[derive(Clone, Debug)]
pub struct ReservoirSampler {
    k: usize,
    /// Stream position counter (the hardware counter), 1-based.
    count: u64,
    rng: Xorshift32,
}

impl ReservoirSampler {
    pub fn new(k: usize, seed: u32) -> Self {
        assert!(k > 0);
        Self { k, count: 0, rng: Xorshift32::new(seed) }
    }

    pub fn capacity(&self) -> usize {
        self.k
    }

    pub fn seen(&self) -> u64 {
        self.count
    }

    /// Present the next stream example; returns the slot decision.
    pub fn offer(&mut self) -> ReservoirDecision {
        self.count += 1;
        if self.count <= self.k as u64 {
            return ReservoirDecision::Store((self.count - 1) as usize);
        }
        // xorshift word folded to 1..=count by the modulus unit
        let i = u32::try_from(self.count).expect("stream longer than 2^32");
        let j = self.rng.next_index(i);
        if (j as usize) <= self.k {
            ReservoirDecision::Store((j - 1) as usize)
        } else {
            ReservoirDecision::Discard
        }
    }

    /// Reset the counter for a new stream (buffer contents untouched —
    /// the paper's buffer persists across tasks).
    pub fn reset_stream(&mut self) {
        self.count = 0;
    }

    /// Serializable state `(stream counter, xorshift word)`. The xorshift
    /// state is never zero, so `Xorshift32::new(word)` reconstructs the
    /// generator exactly (checkpoint/restore hook).
    pub fn state(&self) -> (u64, u32) {
        (self.count, self.rng.state())
    }

    /// Reconstruct mid-stream from [`ReservoirSampler::state`].
    pub fn restore_state(&mut self, count: u64, rng_state: u32) {
        self.count = count;
        self.rng = Xorshift32::new(rng_state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_k_fill_in_order() {
        let mut s = ReservoirSampler::new(4, 1);
        for i in 0..4 {
            assert_eq!(s.offer(), ReservoirDecision::Store(i));
        }
    }

    #[test]
    fn later_offers_store_with_probability_k_over_i() {
        let k = 32;
        let trials = 4000u32;
        let mut stores = 0u32;
        let mut s = ReservoirSampler::new(k, 7);
        for _ in 0..k {
            s.offer();
        }
        // at position i, P(store) = k/i; accumulate over i = k+1..k+trials
        let mut expected = 0.0f64;
        for t in 0..trials {
            let i = (k as u32 + 1 + t) as f64;
            expected += k as f64 / i;
            if matches!(s.offer(), ReservoirDecision::Store(_)) {
                stores += 1;
            }
        }
        let dev = (f64::from(stores) - expected).abs() / expected;
        assert!(dev < 0.07, "stores {stores} expected {expected:.1}");
    }

    #[test]
    fn every_element_equally_likely_to_survive() {
        // run many small streams; count survival per position.
        let k = 8;
        let n = 40; // stream length
        let runs = 3000;
        let mut survive = vec![0u32; n];
        for seed in 0..runs {
            let mut s = ReservoirSampler::new(k, 1000 + seed);
            let mut slots: Vec<usize> = vec![usize::MAX; k];
            for pos in 0..n {
                if let ReservoirDecision::Store(j) = s.offer() {
                    slots[j] = pos;
                }
            }
            for &p in &slots {
                if p != usize::MAX {
                    survive[p] += 1;
                }
            }
        }
        let expect = f64::from(runs) * k as f64 / n as f64;
        for (pos, &c) in survive.iter().enumerate() {
            let dev = (f64::from(c) - expect).abs() / expect;
            assert!(dev < 0.12, "position {pos}: {c} vs {expect}");
        }
    }

    #[test]
    fn store_slots_always_in_range() {
        let mut s = ReservoirSampler::new(5, 99);
        for _ in 0..10_000 {
            if let ReservoirDecision::Store(j) = s.offer() {
                assert!(j < 5);
            }
        }
    }

    #[test]
    fn reset_stream_restarts_counter_only() {
        let mut s = ReservoirSampler::new(3, 5);
        for _ in 0..10 {
            s.offer();
        }
        s.reset_stream();
        assert_eq!(s.seen(), 0);
        assert_eq!(s.offer(), ReservoirDecision::Store(0));
    }
}
