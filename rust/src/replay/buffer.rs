//! Replay buffer with 4-bit packed storage (§IV-A2, Fig. 5a).
//!
//! Features are stored as stochastically-rounded 4-bit codes, two per
//! byte — the 2× compression the paper cites. The buffer is segmented per
//! task (the paper provisions e.g. 1875 examples/task for pMNIST); each
//! segment is fed by its own reservoir sampler while that task streams.

use std::collections::BTreeSet;

use crate::data::Example;
use crate::quant::{dequantize, StochasticQuantizer};
use crate::rng::GaussianRng;

use super::reservoir::{ReservoirDecision, ReservoirSampler};

/// One stored example: packed 4-bit codes + label.
#[derive(Clone, Debug)]
pub struct QuantizedExample {
    /// Two 4-bit codes per byte, low nibble first.
    pub packed: Vec<u8>,
    /// Feature count (may be odd).
    pub len: usize,
    pub label: usize,
}

impl QuantizedExample {
    pub fn quantize(features: &[f32], label: usize, q: &mut StochasticQuantizer) -> Self {
        assert_eq!(q.nb, 4, "replay path is 4-bit by design");
        let mut packed = vec![0u8; features.len().div_ceil(2)];
        for (i, &f) in features.iter().enumerate() {
            let code = q.quantize(f);
            if i % 2 == 0 {
                packed[i / 2] |= code & 0x0F;
            } else {
                packed[i / 2] |= (code & 0x0F) << 4;
            }
        }
        Self { packed, len: features.len(), label }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        (0..self.len)
            .map(|i| {
                let byte = self.packed[i / 2];
                let code = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                dequantize(code, 4)
            })
            .collect()
    }

    /// Storage bytes used (the 2× claim: len/2 vs len at 8-bit).
    pub fn bytes(&self) -> usize {
        self.packed.len()
    }
}

/// Per-task-segmented replay buffer fed by reservoir samplers.
///
/// Each segment carries a stable id (assigned at creation, fresh after a
/// merge) and a dirty flag, so the serve-path delta snapshots can ship
/// only the segments whose contents changed since the last snapshot —
/// the id list alone captures reorderings and merges.
pub struct ReplayBuffer {
    /// capacity per task segment.
    pub per_task: usize,
    /// feature normalization into [0,1]: stored = (x - offset)/scale.
    pub offset: f32,
    pub scale: f32,
    segments: Vec<Vec<QuantizedExample>>,
    /// Stable segment ids, parallel to `segments`.
    ids: Vec<u64>,
    /// Next id to assign (monotone; merges consume fresh ids too).
    next_id: u64,
    /// Segments whose contents changed since the last snapshot mark.
    dirty: BTreeSet<u64>,
    sampler: ReservoirSampler,
    quantizer: StochasticQuantizer,
}

impl ReplayBuffer {
    pub fn new(per_task: usize, offset: f32, scale: f32, seed: u32) -> Self {
        Self {
            per_task,
            offset,
            scale,
            segments: Vec::new(),
            ids: Vec::new(),
            next_id: 1,
            dirty: BTreeSet::new(),
            sampler: ReservoirSampler::new(per_task, seed),
            quantizer: StochasticQuantizer::new((seed >> 16) as u16 ^ 0x5EED, 4),
        }
    }

    /// Open a new task segment (resets the reservoir stream counter).
    pub fn begin_task(&mut self) {
        self.segments.push(Vec::with_capacity(self.per_task));
        let id = self.next_id;
        self.next_id += 1;
        self.ids.push(id);
        self.dirty.insert(id);
        self.sampler.reset_stream();
    }

    pub fn num_tasks(&self) -> usize {
        self.segments.len()
    }

    pub fn stored_examples(&self) -> usize {
        self.segments.iter().map(Vec::len).sum()
    }

    pub fn stored_bytes(&self) -> usize {
        self.segments.iter().flatten().map(QuantizedExample::bytes).sum()
    }

    /// Offer a streaming example to the current task's reservoir.
    pub fn offer(&mut self, ex: &Example) {
        assert!(!self.segments.is_empty(), "begin_task before offering");
        match self.sampler.offer() {
            ReservoirDecision::Discard => {}
            ReservoirDecision::Store(slot) => {
                self.dirty.insert(*self.ids.last().expect("ids parallel to segments"));
                let norm: Vec<f32> = ex
                    .features
                    .iter()
                    .map(|&x| ((x - self.offset) / self.scale).clamp(0.0, 0.999))
                    .collect();
                let q = QuantizedExample::quantize(&norm, ex.label, &mut self.quantizer);
                let seg = self.segments.last_mut().unwrap();
                if slot < seg.len() {
                    seg[slot] = q;
                } else {
                    seg.push(q);
                }
            }
        }
    }

    /// Drop the oldest segments until at most `keep` remain. The
    /// offline task protocol never needs it (one segment per task, tasks
    /// are few); the streaming server prefers
    /// [`ReplayBuffer::merge_oldest_pair`], which bounds memory without
    /// discarding history outright.
    pub fn retain_recent_segments(&mut self, keep: usize) {
        if self.segments.len() > keep {
            let drop = self.segments.len() - keep;
            self.segments.drain(..drop);
            for id in self.ids.drain(..drop) {
                self.dirty.remove(&id);
            }
        }
    }

    /// Merge the two **oldest** segments into one by reservoir-downsampling
    /// their concatenation to the per-segment capacity (Algorithm R over
    /// the caller's rng). Bounds the segment count like
    /// [`ReplayBuffer::retain_recent_segments`], but old examples survive
    /// with decaying probability instead of being dropped wholesale — the
    /// replayable history span keeps growing under the same memory bound.
    /// Returns `false` when fewer than two segments exist.
    pub fn merge_oldest_pair(&mut self, rng: &mut GaussianRng) -> bool {
        if self.segments.len() < 2 {
            return false;
        }
        let a = self.segments.remove(0);
        let b = self.segments.remove(0);
        for id in self.ids.drain(..2) {
            self.dirty.remove(&id);
        }
        let cap = self.per_task.max(1);
        let mut merged: Vec<QuantizedExample> = Vec::with_capacity(cap);
        for (i, q) in a.into_iter().chain(b.into_iter()).enumerate() {
            if merged.len() < cap {
                merged.push(q);
            } else {
                let j = rng.below(i + 1);
                if j < cap {
                    merged[j] = q;
                }
            }
        }
        self.segments.insert(0, merged);
        // the merged segment is new content under a fresh id
        let id = self.next_id;
        self.next_id += 1;
        self.ids.insert(0, id);
        self.dirty.insert(id);
        true
    }

    /// Restore the segment cap by merging oldest pairs until at most
    /// `cap` segments remain (at least one always survives). A single
    /// [`ReplayBuffer::merge_oldest_pair`] per finalized commit is not
    /// enough under churn: a storm that finalizes segments faster than
    /// one merge per commit would grow the list past the cap without
    /// bound. Returns the number of merges performed.
    pub fn enforce_segment_cap(&mut self, cap: usize, rng: &mut GaussianRng) -> usize {
        let cap = cap.max(1);
        let mut merges = 0;
        while self.num_tasks() > cap {
            if !self.merge_oldest_pair(rng) {
                break;
            }
            merges += 1;
        }
        merges
    }

    /// The stored segments, oldest first (checkpoint/restore hook).
    pub fn segments(&self) -> &[Vec<QuantizedExample>] {
        &self.segments
    }

    /// Stable ids of the stored segments, parallel to
    /// [`ReplayBuffer::segments`].
    pub fn segment_ids(&self) -> &[u64] {
        &self.ids
    }

    /// The next segment id to be assigned (checkpoint/restore hook — a
    /// restored buffer must not reuse ids the snapshot chain has seen).
    pub fn next_segment_id(&self) -> u64 {
        self.next_id
    }

    /// Delta-snapshot hook: `(id, contents)` of every segment whose
    /// contents changed since the last snapshot mark, oldest first, and
    /// clears the dirty set. The full id order comes from
    /// [`ReplayBuffer::segment_ids`].
    pub fn take_dirty(&mut self) -> Vec<(u64, Vec<QuantizedExample>)> {
        let mut out = Vec::with_capacity(self.dirty.len());
        for (id, seg) in self.ids.iter().zip(&self.segments) {
            if self.dirty.contains(id) {
                out.push((*id, seg.clone()));
            }
        }
        self.dirty.clear();
        out
    }

    /// Full-snapshot hook: every segment is now captured.
    pub fn mark_clean(&mut self) {
        self.dirty.clear();
    }

    /// Reservoir-sampler state `(seen counter, xorshift word)`.
    pub fn sampler_state(&self) -> (u64, u32) {
        self.sampler.state()
    }

    /// Stochastic-quantizer LFSR word.
    pub fn quantizer_state(&self) -> u16 {
        self.quantizer.lfsr_state()
    }

    /// Reconstruct the buffer contents and both hardware RNG states from a
    /// checkpoint. `ids` must be parallel to `segments` and `next_id`
    /// strictly greater than every id in the chain.
    /// `offset`/`scale`/`per_task` are configuration, not state — the
    /// caller constructs the buffer with the live config first.
    pub fn restore_state(
        &mut self,
        segments: Vec<Vec<QuantizedExample>>,
        ids: Vec<u64>,
        next_id: u64,
        sampler_seen: u64,
        sampler_rng: u32,
        quant_lfsr: u16,
    ) {
        assert_eq!(ids.len(), segments.len(), "segment id list must be parallel");
        self.segments = segments;
        self.ids = ids;
        self.next_id = next_id.max(self.ids.iter().copied().max().unwrap_or(0) + 1);
        self.dirty.clear();
        self.sampler.restore_state(sampler_seen, sampler_rng);
        self.quantizer.restore_lfsr(quant_lfsr);
    }

    /// Draw `n` replay examples uniformly from *previous* tasks' segments
    /// (the current, still-filling segment is excluded: the paper replays
    /// old knowledge against the new stream).
    pub fn sample_past(&self, n: usize, rng: &mut GaussianRng) -> Vec<Example> {
        let past = self.segments.len().saturating_sub(1);
        let pool: Vec<&QuantizedExample> = self.segments[..past].iter().flatten().collect();
        if pool.is_empty() {
            return Vec::new();
        }
        (0..n)
            .map(|_| {
                let q = pool[rng.below(pool.len())];
                let features =
                    q.dequantize().iter().map(|&v| v * self.scale + self.offset).collect();
                Example { features, label: q.label }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(vals: &[f32], label: usize) -> Example {
        Example { features: vals.to_vec(), label }
    }

    #[test]
    fn pack_unpack_roundtrip_within_lsb() {
        let mut q = StochasticQuantizer::new(1, 4);
        let feats: Vec<f32> = (0..9).map(|i| i as f32 / 9.0).collect();
        let qe = QuantizedExample::quantize(&feats, 3, &mut q);
        assert_eq!(qe.bytes(), 5); // ceil(9/2)
        let back = qe.dequantize();
        assert_eq!(back.len(), 9);
        for (a, b) in back.iter().zip(&feats) {
            assert!((a - b).abs() <= 1.0 / 16.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_half_of_8bit() {
        let mut q = StochasticQuantizer::new(2, 4);
        let feats = vec![0.5f32; 784];
        let qe = QuantizedExample::quantize(&feats, 0, &mut q);
        assert_eq!(qe.bytes(), 392);
    }

    #[test]
    fn segments_fill_to_capacity() {
        let mut buf = ReplayBuffer::new(10, 0.0, 1.0, 42);
        buf.begin_task();
        for i in 0..100 {
            buf.offer(&ex(&[i as f32 / 100.0; 4], i % 3));
        }
        assert_eq!(buf.num_tasks(), 1);
        assert_eq!(buf.stored_examples(), 10);
    }

    #[test]
    fn sample_past_excludes_current_task() {
        let mut buf = ReplayBuffer::new(5, 0.0, 1.0, 7);
        buf.begin_task();
        for _ in 0..20 {
            buf.offer(&ex(&[0.25; 4], 1));
        }
        buf.begin_task();
        for _ in 0..20 {
            buf.offer(&ex(&[0.75; 4], 2));
        }
        let mut rng = GaussianRng::new(0);
        let got = buf.sample_past(50, &mut rng);
        assert_eq!(got.len(), 50);
        assert!(got.iter().all(|e| e.label == 1), "only task-1 examples may appear");
    }

    #[test]
    fn sample_past_empty_before_second_task() {
        let mut buf = ReplayBuffer::new(5, 0.0, 1.0, 7);
        buf.begin_task();
        buf.offer(&ex(&[0.5; 4], 0));
        let mut rng = GaussianRng::new(0);
        assert!(buf.sample_past(8, &mut rng).is_empty());
    }

    #[test]
    fn retain_recent_segments_drops_oldest() {
        let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 3);
        for task in 0..5 {
            buf.begin_task();
            for _ in 0..4 {
                buf.offer(&ex(&[0.2; 4], task));
            }
        }
        assert_eq!(buf.num_tasks(), 5);
        buf.retain_recent_segments(2);
        assert_eq!(buf.num_tasks(), 2);
        // survivors are the *newest* segments (labels 3 and 4)
        let mut rng = GaussianRng::new(0);
        let got = buf.sample_past(20, &mut rng);
        assert!(got.iter().all(|e| e.label == 3), "past pool is segment 3 only: {:?}",
                got.iter().map(|e| e.label).collect::<Vec<_>>());
        buf.retain_recent_segments(8); // no-op when under the cap
        assert_eq!(buf.num_tasks(), 2);
    }

    #[test]
    fn merge_oldest_pair_preserves_old_history_under_the_cap() {
        let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 3);
        for task in 0..5 {
            buf.begin_task();
            for _ in 0..4 {
                buf.offer(&ex(&[0.2; 4], task));
            }
        }
        let mut rng = GaussianRng::new(9);
        assert!(buf.merge_oldest_pair(&mut rng));
        assert_eq!(buf.num_tasks(), 4, "two oldest segments collapse into one");
        // the merged segment respects the per-segment capacity
        assert!(buf.segments()[0].len() <= 4);
        // survivors in the merged segment come only from tasks 0 and 1
        assert!(buf.segments()[0].iter().all(|q| q.label <= 1));
        // both merged tasks are represented (8 offers downsampled to 4:
        // with this seed at least one from each side survives)
        let labels: Vec<usize> = buf.segments()[0].iter().map(|q| q.label).collect();
        assert!(labels.contains(&0) || labels.contains(&1));
        // degenerate cases
        let mut tiny = ReplayBuffer::new(4, 0.0, 1.0, 3);
        assert!(!tiny.merge_oldest_pair(&mut rng), "no segments to merge");
        tiny.begin_task();
        assert!(!tiny.merge_oldest_pair(&mut rng), "one segment cannot merge");
    }

    #[test]
    fn enforce_segment_cap_restores_the_cap_after_a_finalization_flood() {
        // regression: a churn storm can finalize many segments between
        // merge opportunities; one merge per commit leaves the list over
        // the cap. The cap-restoring loop must close any backlog.
        let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 3);
        for task in 0..40 {
            buf.begin_task();
            for _ in 0..4 {
                buf.offer(&ex(&[0.2; 4], task % 3));
            }
        }
        assert_eq!(buf.num_tasks(), 40);
        let mut rng = GaussianRng::new(9);
        let merges = buf.enforce_segment_cap(16, &mut rng);
        assert_eq!(buf.num_tasks(), 16, "the cap must be restored in one call");
        assert_eq!(merges, 24, "each merge removes exactly one segment");
        // idempotent at the cap, and degenerate caps stay safe
        assert_eq!(buf.enforce_segment_cap(16, &mut rng), 0);
        buf.enforce_segment_cap(0, &mut rng);
        assert_eq!(buf.num_tasks(), 1, "cap 0 clamps to one surviving segment");
        assert!(buf.stored_examples() <= 4, "the survivor respects per-segment capacity");
    }

    #[test]
    fn restore_state_roundtrips_contents_and_rng() {
        let mut buf = ReplayBuffer::new(6, 0.0, 1.0, 11);
        buf.begin_task();
        for i in 0..20 {
            buf.offer(&ex(&[i as f32 / 20.0; 4], i % 3));
        }
        let segs = buf.segments().to_vec();
        let ids = buf.segment_ids().to_vec();
        let next_id = buf.next_segment_id();
        let (seen, rng_state) = buf.sampler_state();
        let lfsr = buf.quantizer_state();
        // a fresh buffer restored from that state behaves identically
        let mut twin = ReplayBuffer::new(6, 0.0, 1.0, 999);
        twin.restore_state(segs, ids, next_id, seen, rng_state, lfsr);
        for i in 20..40 {
            let e = ex(&[i as f32 / 40.0; 4], i % 3);
            buf.offer(&e);
            twin.offer(&e);
        }
        assert_eq!(buf.stored_examples(), twin.stored_examples());
        for (a, b) in buf.segments().iter().flatten().zip(twin.segments().iter().flatten()) {
            assert_eq!(a.packed, b.packed);
            assert_eq!(a.label, b.label);
        }
    }

    #[test]
    fn segment_ids_and_dirty_tracking_follow_mutations() {
        let mut buf = ReplayBuffer::new(4, 0.0, 1.0, 3);
        for task in 0..3 {
            buf.begin_task();
            for _ in 0..4 {
                buf.offer(&ex(&[0.2; 4], task));
            }
        }
        assert_eq!(buf.segment_ids(), &[1, 2, 3]);
        let dirty = buf.take_dirty();
        assert_eq!(dirty.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![1, 2, 3]);
        // nothing changed since the mark: empty delta
        assert!(buf.take_dirty().is_empty());
        // merging the two oldest consumes a fresh id and dirties only it
        let mut rng = GaussianRng::new(9);
        assert!(buf.merge_oldest_pair(&mut rng));
        assert_eq!(buf.segment_ids(), &[4, 3]);
        let dirty = buf.take_dirty();
        assert_eq!(dirty.len(), 1);
        assert_eq!(dirty[0].0, 4);
        // a fresh task rolls a new id; its first offer always stores
        buf.begin_task();
        buf.offer(&ex(&[0.4; 4], 0));
        let dirty = buf.take_dirty();
        assert_eq!(dirty.len(), 1);
        assert_eq!(dirty[0].0, 5);
        assert_eq!(buf.segment_ids(), &[4, 3, 5]);
        assert_eq!(buf.next_segment_id(), 6);
    }

    #[test]
    fn offset_scale_roundtrip_for_signed_features() {
        // cifar-features live in [-1,1]: offset -1, scale 2.
        let mut buf = ReplayBuffer::new(4, -1.0, 2.0, 9);
        buf.begin_task();
        for _ in 0..4 {
            buf.offer(&ex(&[-0.5, 0.0, 0.5, 0.9], 1));
        }
        buf.begin_task();
        let mut rng = GaussianRng::new(1);
        let got = buf.sample_past(4, &mut rng);
        for e in got {
            for (a, b) in e.features.iter().zip(&[-0.5f32, 0.0, 0.5, 0.9]) {
                assert!((a - b).abs() <= 2.0 / 16.0 + 1e-5, "{a} vs {b}");
            }
        }
    }
}
