//! Data-preparation unit (§IV-A): reservoir sampler → stochastic
//! quantizer → replay buffer.
//!
//! This is the hardware-integrated experience-replay mechanism that keeps
//! continual learning stable under domain shift: examples are captured
//! from the non-stationary stream with uniform probability (reservoir
//! sampling over an unknown-length stream), compressed 8-bit → 4-bit with
//! unbiased stochastic rounding (2× memory), and mixed back into every
//! training batch.

mod buffer;
mod reservoir;

pub use buffer::{QuantizedExample, ReplayBuffer};
pub use reservoir::{ReservoirDecision, ReservoirSampler};
