//! WBS bit-plane packing and the bit-serial crossbar MAC (paper §IV-B1,
//! DESIGN.md §12).
//!
//! The wordline drivers stream an n_b-bit sign/magnitude code per input:
//! plane b (weight 2^(b-n_b)) pulses every row whose magnitude code has
//! bit b set, and the integrator adds (or subtracts, per the sign bit)
//! that row's conductances. [`wbs_mac_bitloop`] is that datapath
//! transliterated — one input bit per iteration. [`BitPlanes`] packs the
//! same codes plane-major into `u64` words so [`wbs_mac_packed`] consumes
//! 64 input bits per word: a zero word skips 64 inputs in one compare,
//! set bits are walked with `trailing_zeros`, and each hit drives a
//! SIMD-dispatched row add/sub. Word-level popcounts give the pulse
//! statistics ([`BitPlanes::bit_activity`], [`BitPlanes::weighted_bit_sum`])
//! without touching the planes bit by bit.
//!
//! ## Layout
//!
//! ```text
//! inputs   x_0 x_1 x_2 ... x_63 | x_64 ... x_n
//!          └── word 0 ─────────┘ └── word 1 ...     (bit i%64 of word i/64)
//!
//! neg    : [w0][w1]...            1 = sign bit set (subtract the row)
//! plane 0: [w0][w1]...            magnitude bit 0 of every input (LSB)
//! plane 1: [w0][w1]...
//!   ...
//! plane nb-1: ...                 magnitude bit nb-1 (MSB)
//! ```
//!
//! ## Bitwise contract
//!
//! For finite inputs in `[-1, 1]`, `unpack(pack(x))` is bit-identical to
//! [`crate::quant::wbs_input_quantize`], and `wbs_mac_packed` is
//! bit-identical to `wbs_mac_bitloop`: both accumulate each plane's
//! partial sum in ascending input order, then combine planes in
//! ascending bit order scaled by the exact power of two `2^(b-n_b)`.
//! The exhaustive tests below and `tests/kernel_parity.rs` enforce both.

use crate::linalg::{kernels, Mat};

/// Sign/magnitude code of one analog value — the wordline register.
/// Matches `wbs_input_quantize`: `mag = round(|x| * (2^nb - 1))`,
/// clamped to the code range for robustness outside `[-1, 1]`.
#[inline]
fn code_of(x: f32, nb: u32) -> (u32, bool) {
    let full = (1u32 << nb) as f32;
    let mag = (x.abs() * (full - 1.0)).round();
    let code = if mag >= full - 1.0 { (1u32 << nb) - 1 } else { mag as u32 };
    (code, x.is_sign_negative())
}

/// A drive vector digitized to n_b sign/magnitude bit-planes, packed
/// 64 inputs per `u64` word (see the module docs for the layout).
#[derive(Clone, Debug)]
pub struct BitPlanes {
    nb: u32,
    n: usize,
    words: usize,
    /// sign mask: bit set → subtract that input's row
    neg: Vec<u64>,
    /// plane-major magnitude bits: `planes[b * words + w]`
    planes: Vec<u64>,
}

impl BitPlanes {
    /// Digitize and pack `xs` at `nb` magnitude bits (1 ≤ nb ≤ 16).
    pub fn pack(xs: &[f32], nb: u32) -> Self {
        assert!((1..=16u32).contains(&nb), "nb={nb} out of range 1..=16");
        let n = xs.len();
        let words = n.div_ceil(64);
        let mut neg = vec![0u64; words];
        let mut planes = vec![0u64; nb as usize * words];
        for (i, &x) in xs.iter().enumerate() {
            let (code, is_neg) = code_of(x, nb);
            let (w, bit) = (i / 64, (i % 64) as u32);
            if is_neg {
                neg[w] |= 1u64 << bit;
            }
            for b in 0..nb {
                if (code >> b) & 1 == 1 {
                    planes[b as usize * words + w] |= 1u64 << bit;
                }
            }
        }
        Self { nb, n, words, neg, planes }
    }

    pub fn nb(&self) -> u32 {
        self.nb
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Words of magnitude plane `b` (LSB plane is `b = 0`).
    pub fn plane(&self, b: u32) -> &[u64] {
        assert!(b < self.nb);
        &self.planes[b as usize * self.words..(b as usize + 1) * self.words]
    }

    /// The sign mask words.
    pub fn neg_mask(&self) -> &[u64] {
        &self.neg
    }

    /// Reconstruct the quantized values. Bit-identical to mapping
    /// `wbs_input_quantize(x, nb)` over the packed input for finite
    /// `x ∈ [-1, 1]` (including the `-0.0` of tiny negative values).
    pub fn unpack(&self) -> Vec<f32> {
        let full = (1u32 << self.nb) as f32;
        let mut out = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let (w, bit) = (i / 64, (i % 64) as u32);
            let mut code = 0u32;
            for b in 0..self.nb {
                code |= (((self.planes[b as usize * self.words + w] >> bit) & 1) as u32) << b;
            }
            let v = code as f32 / full;
            out.push(if (self.neg[w] >> bit) & 1 == 1 { -v } else { v });
        }
        out
    }

    /// Total magnitude pulses the stream issues — Σ popcount over all
    /// planes. The word-level activity statistic (energy proxy: every
    /// set bit is one wordline pulse).
    pub fn bit_activity(&self) -> u64 {
        self.planes.iter().map(|w| u64::from(w.count_ones())).sum()
    }

    /// Σᵢ ±codeᵢ as an integer — what a unit-conductance reference
    /// column integrates, via popcount-weighted partial sums:
    /// Σ_b 2^b · (popcount(plane_b & !neg) − popcount(plane_b & neg)).
    pub fn weighted_bit_sum(&self) -> i64 {
        let mut total = 0i64;
        for b in 0..self.nb {
            let mut pos = 0i64;
            let mut negc = 0i64;
            for (&pw, &nw) in self.plane(b).iter().zip(&self.neg) {
                pos += i64::from((pw & !nw).count_ones());
                negc += i64::from((pw & nw).count_ones());
            }
            total += (pos - negc) << b;
        }
        total
    }
}

/// Reference WBS MAC — the §IV-B1 datapath one bit at a time.
///
/// For each plane `b` (ascending), walk inputs in ascending order; every
/// set magnitude bit adds (sign clear) or subtracts (sign set) row `i`
/// of `g` into a partial sum, which is then folded into the output
/// scaled by the exact power of two `2^(b-nb)`. Returns the length-`g.cols`
/// bitline vector. This loop defines the bits; the packed MAC must match it.
pub fn wbs_mac_bitloop(xs: &[f32], g: &Mat, nb: u32) -> Vec<f32> {
    assert_eq!(xs.len(), g.rows, "drive length {} vs crossbar rows {}", xs.len(), g.rows);
    let codes: Vec<(u32, bool)> = xs.iter().map(|&x| code_of(x, nb)).collect();
    let full = (1u32 << nb) as f32;
    let mut out = vec![0.0f32; g.cols];
    let mut partial = vec![0.0f32; g.cols];
    for b in 0..nb {
        partial.iter_mut().for_each(|v| *v = 0.0);
        for (i, &(code, is_neg)) in codes.iter().enumerate() {
            if (code >> b) & 1 == 0 {
                continue;
            }
            let row = g.row(i);
            if is_neg {
                for (p, &w) in partial.iter_mut().zip(row) {
                    *p -= w;
                }
            } else {
                for (p, &w) in partial.iter_mut().zip(row) {
                    *p += w;
                }
            }
        }
        let scale = (1u32 << b) as f32 / full; // exact 2^(b-nb)
        for (o, &p) in out.iter_mut().zip(&partial) {
            *o += p * scale;
        }
    }
    out
}

/// Packed WBS MAC — 64 input bits per `u64` word, bit-identical to
/// [`wbs_mac_bitloop`].
///
/// A zero plane word skips 64 inputs in one compare; set bits are walked
/// in ascending input order with `trailing_zeros` (so the f32
/// accumulation order is exactly the reference loop's), and each hit
/// dispatches a kernel-vectorized row add/sub across all `g.cols`
/// bitlines. The per-plane fold uses the same exact power-of-two scale.
pub fn wbs_mac_packed(bp: &BitPlanes, g: &Mat) -> Vec<f32> {
    assert_eq!(bp.n, g.rows, "drive length {} vs crossbar rows {}", bp.n, g.rows);
    // resolve the kernel once — not per row-add inside the bit walk
    let kern = kernels::active();
    let full = (1u32 << bp.nb) as f32;
    let mut out = vec![0.0f32; g.cols];
    let mut partial = vec![0.0f32; g.cols];
    for b in 0..bp.nb {
        partial.iter_mut().for_each(|v| *v = 0.0);
        for (wi, &word) in bp.plane(b).iter().enumerate() {
            if word == 0 {
                continue; // 64 inputs skipped in one compare
            }
            let negw = bp.neg[wi];
            let mut rest = word;
            while rest != 0 {
                let bit = rest.trailing_zeros();
                rest &= rest - 1;
                let i = wi * 64 + bit as usize;
                let row = g.row(i);
                if (negw >> bit) & 1 == 1 {
                    kernels::sub_assign_with(kern, &mut partial, row);
                } else {
                    kernels::add_assign_with(kern, &mut partial, row);
                }
            }
        }
        let scale = (1u32 << b) as f32 / full; // exact 2^(b-nb)
        kernels::axpy_with(kern, &mut out, scale, &partial);
    }
    out
}

/// Packed WBS MAC over **pre-quantized i8 weight planes** — the int8
/// serving variant (DESIGN.md §15): per-plane partial sums accumulate
/// the signed i8 codes in i32, planes fold with exact integer shifts
/// into an i64 accumulator, and each bitline pays exactly one f32
/// rescale (`2^-nb · scale_j`) at the end. Every operation before that
/// rescale is exact integer arithmetic, so the result is identical
/// regardless of kernel or traversal order — no dispatch needed.
///
/// Semantically this is [`wbs_mac_packed`] with `g` replaced by the
/// dequantized codes (`codes[i][j] · scales[j]`); the tests below pin
/// that equivalence against a naive per-bit reference.
pub fn wbs_mac_packed_i32(bp: &BitPlanes, q: &crate::quant::QuantizedMat) -> Vec<f32> {
    assert_eq!(bp.n, q.rows, "drive length {} vs crossbar rows {}", bp.n, q.rows);
    let cols = q.cols;
    let full = (1u32 << bp.nb) as f32;
    // i64: a plane partial is bounded by n·127 (fits i32 comfortably),
    // but the shifted fold (≤ 2^15 per plane, 16 planes) can overflow
    // i32 for wide crossbars — accumulate the fold in i64
    let mut acc = vec![0i64; cols];
    let mut partial = vec![0i32; cols];
    for b in 0..bp.nb {
        partial.iter_mut().for_each(|v| *v = 0);
        for (wi, &word) in bp.plane(b).iter().enumerate() {
            if word == 0 {
                continue; // 64 inputs skipped in one compare
            }
            let negw = bp.neg[wi];
            let mut rest = word;
            while rest != 0 {
                let bit = rest.trailing_zeros();
                rest &= rest - 1;
                let i = wi * 64 + bit as usize;
                let row = &q.codes[i * cols..(i + 1) * cols];
                if (negw >> bit) & 1 == 1 {
                    for (p, &c) in partial.iter_mut().zip(row) {
                        *p -= i32::from(c);
                    }
                } else {
                    for (p, &c) in partial.iter_mut().zip(row) {
                        *p += i32::from(c);
                    }
                }
            }
        }
        for (a, &p) in acc.iter_mut().zip(&partial) {
            *a += i64::from(p) << b;
        }
    }
    acc.iter()
        .zip(&q.scales)
        .map(|(&a, &s)| (a as f32 / full) * s)
        .collect()
}

/// Digitize every row of `drive` and run the packed MAC against `g`:
/// the batch crossbar VMM (`drive [r,n] × g [n,c] → [r,c]`).
pub fn wbs_vmm(drive: &Mat, g: &Mat, nb: u32) -> Mat {
    assert_eq!(drive.cols, g.rows, "wbs_vmm {}x{} @ {}x{}", drive.rows, drive.cols, g.rows, g.cols);
    let mut out = Mat::zeros(drive.rows, g.cols);
    for r in 0..drive.rows {
        let bp = BitPlanes::pack(drive.row(r), nb);
        out.row_mut(r).copy_from_slice(&wbs_mac_packed(&bp, g));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::wbs_input_quantize;
    use crate::rng::GaussianRng;

    /// All values an nb-bit sign/magnitude code can represent, i.e. the
    /// exhaustive input space of the MAC after digitization.
    fn representable(nb: u32) -> Vec<f32> {
        let full = (1u32 << nb) as f32;
        let mut vals = Vec::new();
        for code in 0..(1u32 << nb) {
            vals.push(code as f32 / full);
            vals.push(-(code as f32) / full); // includes -0.0
        }
        vals
    }

    #[test]
    fn roundtrip_matches_wbs_quantize_exhaustively() {
        // every exact code point, every nb ≤ 8, both signs: x chosen so
        // |x|·(2^nb−1) is an integer → pack/unpack must equal
        // wbs_input_quantize bit for bit (including signed zeros)
        for nb in 1..=8u32 {
            let denom = ((1u32 << nb) - 1) as f32;
            let mut xs = Vec::new();
            for code in 0..(1u32 << nb) {
                xs.push(code as f32 / denom);
                xs.push(-(code as f32) / denom);
            }
            let bp = BitPlanes::pack(&xs, nb);
            let got = bp.unpack();
            for (&x, &g) in xs.iter().zip(&got) {
                let want = wbs_input_quantize(x, nb);
                assert_eq!(g.to_bits(), want.to_bits(), "nb={nb} x={x} got={g} want={want}");
            }
        }
    }

    #[test]
    fn roundtrip_matches_wbs_quantize_on_dense_grid() {
        // 4097 points across [-1, 1] (off-code values exercise rounding),
        // plus the signed-zero corner
        for nb in 1..=8u32 {
            for i in 0..=4096 {
                let x = -1.0 + 2.0 * (i as f32 / 4096.0);
                let bp = BitPlanes::pack(&[x, -0.0, 0.0], nb);
                let got = bp.unpack();
                for (v, want) in
                    got.iter().zip([x, -0.0, 0.0].iter().map(|&y| wbs_input_quantize(y, nb)))
                {
                    assert_eq!(v.to_bits(), want.to_bits(), "nb={nb} x={x}");
                }
            }
        }
    }

    #[test]
    fn packed_mac_matches_bitloop_exhaustively_small() {
        // exhaustive over the digitized input space: every combination of
        // representable values on tiny crossbars — the packed path can
        // never drift from the bit-serial reference
        let g2 = Mat::from_vec(2, 3, vec![0.5, -0.25, 1.0, -0.75, 0.125, 0.0]);
        for nb in 1..=2u32 {
            let vals = representable(nb);
            for &x0 in &vals {
                for &x1 in &vals {
                    let xs = [x0, x1];
                    let bit = wbs_mac_bitloop(&xs, &g2, nb);
                    let packed = wbs_mac_packed(&BitPlanes::pack(&xs, nb), &g2);
                    for (a, b) in bit.iter().zip(&packed) {
                        assert_eq!(a.to_bits(), b.to_bits(), "nb={nb} xs={xs:?}");
                    }
                }
            }
        }
        // width 1, all nb ≤ 8: every single-input code
        let g1 = Mat::from_vec(1, 2, vec![0.7, -0.3]);
        for nb in 1..=8u32 {
            for &x in &representable(nb) {
                let bit = wbs_mac_bitloop(&[x], &g1, nb);
                let packed = wbs_mac_packed(&BitPlanes::pack(&[x], nb), &g1);
                assert_eq!(bit[0].to_bits(), packed[0].to_bits(), "nb={nb} x={x}");
                assert_eq!(bit[1].to_bits(), packed[1].to_bits(), "nb={nb} x={x}");
            }
        }
    }

    #[test]
    fn packed_mac_matches_bitloop_across_word_boundaries() {
        // 65 and 129 inputs straddle u64 word boundaries; random drives
        // and weights, all serve-relevant nb
        let mut rng = GaussianRng::new(0xB17);
        for &n in &[63usize, 64, 65, 128, 129] {
            for nb in [1u32, 4, 8] {
                let xs: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                let g = Mat::from_fn(n, 17, |_, _| rng.uniform_in(-1.0, 1.0));
                let bit = wbs_mac_bitloop(&xs, &g, nb);
                let packed = wbs_mac_packed(&BitPlanes::pack(&xs, nb), &g);
                for (a, b) in bit.iter().zip(&packed) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} nb={nb}");
                }
            }
        }
    }

    /// Naive reference for the i32 MAC: per bit, per input, per column,
    /// exact i64 integer accumulation of the signed codes.
    fn i32_mac_reference(xs: &[f32], q: &crate::quant::QuantizedMat, nb: u32) -> Vec<f32> {
        let full = (1u32 << nb) as f32;
        let mut acc = vec![0i64; q.cols];
        for b in 0..nb {
            for (i, &x) in xs.iter().enumerate() {
                let (code, neg) = code_of(x, nb);
                if (code >> b) & 1 == 0 {
                    continue;
                }
                for (a, &c) in acc.iter_mut().zip(&q.codes[i * q.cols..(i + 1) * q.cols]) {
                    let c = i64::from(c) << b;
                    if neg {
                        *a -= c;
                    } else {
                        *a += c;
                    }
                }
            }
        }
        acc.iter().zip(&q.scales).map(|(&a, &s)| (a as f32 / full) * s).collect()
    }

    #[test]
    fn packed_i32_mac_matches_reference_and_tracks_f32() {
        let mut rng = GaussianRng::new(0x138);
        for &n in &[63usize, 64, 65, 129] {
            for nb in [1u32, 4, 8] {
                let xs: Vec<f32> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                let g = Mat::from_fn(n, 13, |_, _| rng.uniform_in(-1.0, 1.0));
                let q = crate::quant::QuantizedMat::from_mat(&g);
                let bp = BitPlanes::pack(&xs, nb);
                let got = wbs_mac_packed_i32(&bp, &q);
                // bitwise against the naive integer reference: the fold
                // is exact integers until one final rescale per column
                let want = i32_mac_reference(&xs, &q, nb);
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n} nb={nb}");
                }
                // value-close to the f32 packed MAC over the dequantized
                // codes (same math, f32 vs integer association)
                let approx = wbs_mac_packed(&bp, &q.dequantize());
                for (a, b) in got.iter().zip(&approx) {
                    assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "n={n} nb={nb}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn wbs_vmm_rows_are_independent_macs() {
        let mut rng = GaussianRng::new(7);
        let drive = Mat::from_fn(5, 70, |_, _| rng.uniform_in(-1.0, 1.0));
        let g = Mat::from_fn(70, 9, |_, _| rng.uniform_in(-1.0, 1.0));
        let out = wbs_vmm(&drive, &g, 8);
        for r in 0..drive.rows {
            let want = wbs_mac_bitloop(drive.row(r), &g, 8);
            for (a, b) in out.row(r).iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {r}");
            }
        }
    }

    #[test]
    fn weighted_bit_sum_equals_signed_code_sum() {
        // exhaustive at nb=2, width 3: popcount bookkeeping vs the direct
        // signed sum of codes
        let vals = representable(2);
        for &x0 in &vals {
            for &x1 in &vals {
                for &x2 in &vals {
                    let xs = [x0, x1, x2];
                    let bp = BitPlanes::pack(&xs, 2);
                    let want: i64 = xs
                        .iter()
                        .map(|&x| {
                            let (code, neg) = code_of(x, 2);
                            if neg {
                                -i64::from(code)
                            } else {
                                i64::from(code)
                            }
                        })
                        .sum();
                    assert_eq!(bp.weighted_bit_sum(), want, "xs={xs:?}");
                }
            }
        }
    }

    #[test]
    fn bit_activity_counts_every_pulse() {
        // code 3 at nb=2 sets both planes; code 2 sets one
        let bp = BitPlanes::pack(&[1.0, -2.0 / 4.0, 0.0], 2);
        assert_eq!(bp.bit_activity(), 3);
        assert_eq!(bp.weighted_bit_sum(), 3 - 2);
    }

    #[test]
    fn empty_and_zero_drives() {
        let g = Mat::from_fn(0, 4, |_, _| 1.0);
        let bp = BitPlanes::pack(&[], 8);
        assert!(bp.is_empty());
        assert_eq!(wbs_mac_packed(&bp, &g), vec![0.0; 4]);
        let g1 = Mat::from_fn(130, 4, |_, _| 1.0);
        let zeros = vec![0.0f32; 130];
        let bp0 = BitPlanes::pack(&zeros, 8);
        assert_eq!(bp0.bit_activity(), 0);
        assert_eq!(wbs_mac_packed(&bp0, &g1), wbs_mac_bitloop(&zeros, &g1, 8));
    }
}
