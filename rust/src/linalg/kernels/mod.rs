//! Runtime-dispatched compute kernels behind the [`crate::linalg::Mat`]
//! entry points (DESIGN.md §12).
//!
//! Three implementations of the same inner loops:
//!
//! * **scalar** — the register-blocked loops that were previously inlined
//!   in `linalg/mod.rs`, moved here verbatim. This is the bitwise floor
//!   every other kernel is parity-tested against.
//! * **avx2** — `std::arch` intrinsics vectorizing *across output
//!   columns* (the `j` loops), selected at runtime with
//!   `is_x86_feature_detected!("avx2")`.
//! * **neon** — aarch64 `std::arch` intrinsics (`vld1q_f32` et al.),
//!   4-wide across the same output-column loops; NEON is baseline on
//!   aarch64 so no runtime detection gate is needed.
//!
//! ## The bitwise-parity contract
//!
//! Every kernel must produce **bit-identical** `f32` results, because the
//! whole serving fleet's determinism story (per-session serve signatures,
//! delta-chain restores, the router's cross-shard equivalence harness) is
//! bitwise. The SIMD kernels therefore vectorize only across output
//! columns: each output element `out[i][j]` sees exactly the scalar
//! kernel's operation sequence — same k-order, a multiply then an add per
//! step (`_mm256_mul_ps` + `_mm256_add_ps`, never FMA), same zero-skips
//! (the skip predicate depends on the left operand only, never the lane)
//! — so IEEE-754 rounds identically lane by lane. `tests/kernel_parity.rs`
//! enforces this across random and ragged shapes; the CI kernel matrix
//! re-runs tier-1 under every forced kernel.
//!
//! ## Selection
//!
//! Precedence: [`force`] (the `[serve] kernel` config key / `--kernel`
//! flag) > the `M2RU_KERNEL` environment variable > auto-detection.
//! Values: `auto` (best available SIMD), `simd` (same, stated intent),
//! `scalar` (the floor). Requesting `simd` on a machine with no usable
//! SIMD falls back to scalar — parity makes the fallback invisible.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, Result};

mod avx2;
mod neon;
mod scalar;

/// One concrete kernel implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar loops — the parity floor.
    Scalar,
    /// 8-wide AVX2 across output columns (x86/x86_64 with AVX2).
    Avx2,
    /// 4-wide NEON across output columns (aarch64 baseline).
    Neon,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }
}

/// Function table of one kernel. All slots share the scalar semantics
/// documented on the dispatching wrappers below.
struct Ops {
    matmul_ikj: fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    matmul_blocked: fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    matmul_tn: fn(&[f32], &[f32], &mut [f32], usize, usize, usize),
    matmul_i8: fn(&[i8], &[i8], &mut [i32], usize, usize, usize),
    axpy: fn(&mut [f32], f32, &[f32]),
    add_assign: fn(&mut [f32], &[f32]),
    sub_assign: fn(&mut [f32], &[f32]),
}

static SCALAR_OPS: Ops = Ops {
    matmul_ikj: scalar::matmul_ikj,
    matmul_blocked: scalar::matmul_blocked,
    matmul_tn: scalar::matmul_tn,
    matmul_i8: scalar::matmul_i8,
    axpy: scalar::axpy,
    add_assign: scalar::add_assign,
    sub_assign: scalar::sub_assign,
};

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
static AVX2_OPS: Ops = Ops {
    matmul_ikj: avx2::matmul_ikj,
    matmul_blocked: avx2::matmul_blocked,
    matmul_tn: avx2::matmul_tn,
    matmul_i8: avx2::matmul_i8,
    axpy: avx2::axpy,
    add_assign: avx2::add_assign,
    sub_assign: avx2::sub_assign,
};

#[cfg(target_arch = "aarch64")]
static NEON_OPS: Ops = Ops {
    matmul_ikj: neon::matmul_ikj,
    matmul_blocked: neon::matmul_blocked,
    matmul_tn: neon::matmul_tn,
    matmul_i8: neon::matmul_i8,
    axpy: neon::axpy,
    add_assign: neon::add_assign,
    sub_assign: neon::sub_assign,
};

fn ops(k: Kernel) -> &'static Ops {
    match k {
        Kernel::Scalar => &SCALAR_OPS,
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        Kernel::Avx2 => &AVX2_OPS,
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => &NEON_OPS,
        // a force/env value naming a kernel this target cannot run is
        // normalized away by `resolve`; reaching here is a logic error
        #[allow(unreachable_patterns)]
        other => unreachable!("kernel {other:?} is not available on this target"),
    }
}

/// The best SIMD kernel this machine can run, if any.
pub fn best_simd() -> Option<Kernel> {
    static DETECTED: OnceLock<Option<Kernel>> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        {
            if is_x86_feature_detected!("avx2") {
                return Some(Kernel::Avx2);
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON is baseline on aarch64
            return Some(Kernel::Neon);
        }
        #[allow(unreachable_code)]
        None
    })
}

// forced-choice states (config/CLI override, then the env default)
const CHOICE_UNSET: u8 = 0;
const CHOICE_AUTO: u8 = 1;
const CHOICE_SCALAR: u8 = 2;
const CHOICE_SIMD: u8 = 3;

static FORCED: AtomicU8 = AtomicU8::new(CHOICE_UNSET);

fn parse_choice(name: &str) -> Result<u8> {
    match name {
        "" | "auto" => Ok(CHOICE_AUTO),
        "scalar" => Ok(CHOICE_SCALAR),
        "simd" => Ok(CHOICE_SIMD),
        other => bail!("unknown kernel `{other}` (expected auto|scalar|simd)"),
    }
}

/// Force the kernel choice for the whole process — the `[serve] kernel`
/// config key and `--kernel` flag land here. Overrides `M2RU_KERNEL`.
/// Passing `auto` (or `""`) returns to env/auto selection.
pub fn force(name: &str) -> Result<()> {
    let choice = parse_choice(name)?;
    FORCED.store(if name.is_empty() { CHOICE_UNSET } else { choice }, Ordering::SeqCst);
    Ok(())
}

/// `M2RU_KERNEL`, parsed once. An invalid value warns (once) and falls
/// back to auto rather than failing deep inside a matmul.
fn env_choice() -> u8 {
    static ENV: OnceLock<u8> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("M2RU_KERNEL") {
        Ok(v) => parse_choice(v.trim()).unwrap_or_else(|e| {
            eprintln!("warning: M2RU_KERNEL ignored: {e}");
            CHOICE_AUTO
        }),
        Err(_) => CHOICE_AUTO,
    })
}

fn resolve(choice: u8) -> Kernel {
    match choice {
        CHOICE_SCALAR => Kernel::Scalar,
        // auto and simd both take the best detected SIMD; they differ only
        // in intent (simd states it, auto is the default)
        _ => best_simd().unwrap_or(Kernel::Scalar),
    }
}

/// The kernel every dispatched entry point uses right now.
pub fn active() -> Kernel {
    let forced = FORCED.load(Ordering::SeqCst);
    if forced != CHOICE_UNSET {
        resolve(forced)
    } else {
        resolve(env_choice())
    }
}

/// Name of the active kernel (serve/router startup banners, stats).
pub fn active_name() -> &'static str {
    active().name()
}

/// SIMD features this machine actually has, for smoke logs and
/// `m2ru info` — independent of what was forced.
pub fn cpu_features() -> &'static str {
    match best_simd() {
        Some(Kernel::Avx2) => "avx2",
        Some(Kernel::Neon) => "neon",
        _ => "none",
    }
}

// ---- serving precision -----------------------------------------------------
//
// Selected exactly like the kernel: `force_precision` (the `[serve]
// precision` config key / `--precision` flag) > `M2RU_PRECISION` > the
// f32 default. The int8 path quantizes weights once per commit
// generation ([`crate::serve::WeightSnapshot`]) and runs the serve-path
// MACs through [`matmul_i8`]; training and every other code path stay
// f32 regardless.

/// Arithmetic precision of the serve hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 MACs — the default and the accuracy reference.
    F32,
    /// Pre-quantized per-column-symmetric i8 weights, i8×i8→i32 MACs,
    /// one f32 rescale per output element.
    Int8,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

const PCHOICE_UNSET: u8 = 0;
const PCHOICE_F32: u8 = 1;
const PCHOICE_INT8: u8 = 2;

static FORCED_PRECISION: AtomicU8 = AtomicU8::new(PCHOICE_UNSET);

fn parse_precision(name: &str) -> Result<u8> {
    match name {
        "" | "f32" => Ok(PCHOICE_F32),
        "int8" => Ok(PCHOICE_INT8),
        other => bail!("unknown precision `{other}` (expected f32|int8)"),
    }
}

/// Force the serving precision for the whole process — the `[serve]
/// precision` config key and `--precision` flag land here. Overrides
/// `M2RU_PRECISION`. Passing `""` returns to env/default selection.
pub fn force_precision(name: &str) -> Result<()> {
    let choice = parse_precision(name)?;
    FORCED_PRECISION.store(if name.is_empty() { PCHOICE_UNSET } else { choice }, Ordering::SeqCst);
    Ok(())
}

/// `M2RU_PRECISION`, parsed once. An invalid value warns (once) and
/// falls back to f32 rather than failing at the first dispatch.
fn env_precision() -> u8 {
    static ENV: OnceLock<u8> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("M2RU_PRECISION") {
        Ok(v) => parse_precision(v.trim()).unwrap_or_else(|e| {
            eprintln!("warning: M2RU_PRECISION ignored: {e}");
            PCHOICE_F32
        }),
        Err(_) => PCHOICE_F32,
    })
}

/// The serving precision in effect right now.
pub fn active_precision() -> Precision {
    let forced = FORCED_PRECISION.load(Ordering::SeqCst);
    let choice = if forced != PCHOICE_UNSET { forced } else { env_precision() };
    if choice == PCHOICE_INT8 {
        Precision::Int8
    } else {
        Precision::F32
    }
}

/// Name of the active precision (banners, stats, reports).
pub fn precision_name() -> &'static str {
    active_precision().name()
}

// ---- dispatched entry points ----------------------------------------------
//
// Shapes are the caller's contract (checked by `Mat`): `a` is `m×k`,
// `b` is `k×n`, `out` is `m×n`, all row-major; `out` arrives zeroed.

/// ikj loop order with the zero-skip on `a` — the small-shape matmul.
pub fn matmul_ikj(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    (ops(active()).matmul_ikj)(a, b, out, m, k, n)
}

/// Register-blocked matmul (KC/NC tiling, 4-row micro-kernel).
pub fn matmul_blocked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    (ops(active()).matmul_blocked)(a, b, out, m, k, n)
}

/// `aᵀ @ b` without materializing the transpose: `a` is `k×m`, `b` is
/// `k×n`, `out` is `m×n`.
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    (ops(active()).matmul_tn)(a, b, out, k, m, n)
}

/// Integer MAC: `a` (`m×k` i8 codes) × `b` (`k×n` i8 codes) → `out`
/// (`m×n` i32, zeroed). Exact in i32 for the serve shapes (k ≤ a few
/// hundred, |code| ≤ 127), so every kernel is bitwise-identical by
/// construction; the parity suite pins it anyway.
pub fn matmul_i8(a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    (ops(active()).matmul_i8)(a, b, out, m, k, n)
}

/// `out[j] += alpha * x[j]` (one rounded multiply + one rounded add per
/// element, never fused).
pub fn axpy(out: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    (ops(active()).axpy)(out, alpha, x)
}

/// `out[j] += x[j]` — the positive-drive row accumulation of the packed
/// WBS MAC ([`crate::linalg::bitplane`]).
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    (ops(active()).add_assign)(out, x)
}

/// `out[j] -= x[j]` — the negative-drive counterpart.
pub fn sub_assign(out: &mut [f32], x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    (ops(active()).sub_assign)(out, x)
}

// ---- explicit-kernel variants (parity tests, benches) ----------------------

pub fn matmul_ikj_with(kern: Kernel, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    (ops(kern).matmul_ikj)(a, b, out, m, k, n)
}

pub fn matmul_blocked_with(kern: Kernel, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    (ops(kern).matmul_blocked)(a, b, out, m, k, n)
}

pub fn matmul_tn_with(kern: Kernel, a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    (ops(kern).matmul_tn)(a, b, out, k, m, n)
}

pub fn matmul_i8_with(kern: Kernel, a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    (ops(kern).matmul_i8)(a, b, out, m, k, n)
}

pub fn axpy_with(kern: Kernel, out: &mut [f32], alpha: f32, x: &[f32]) {
    (ops(kern).axpy)(out, alpha, x)
}

pub fn add_assign_with(kern: Kernel, out: &mut [f32], x: &[f32]) {
    (ops(kern).add_assign)(out, x)
}

pub fn sub_assign_with(kern: Kernel, out: &mut [f32], x: &[f32]) {
    (ops(kern).sub_assign)(out, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_and_parse() {
        force("scalar").unwrap();
        assert_eq!(active(), Kernel::Scalar);
        force("simd").unwrap();
        assert_eq!(active(), best_simd().unwrap_or(Kernel::Scalar));
        force("auto").unwrap();
        assert!(force("sse9").is_err());
        force("").unwrap(); // back to env/auto
    }

    #[test]
    fn precision_parse_rules() {
        // parse only — setting int8 globally here would race with the
        // serve unit tests sharing this binary (unlike the kernel
        // choice, precision changes snapshot contents, not just
        // association). The full force path runs in
        // `tests/kernel_parity.rs` under its force lock.
        assert_eq!(parse_precision("").unwrap(), PCHOICE_F32);
        assert_eq!(parse_precision("f32").unwrap(), PCHOICE_F32);
        assert_eq!(parse_precision("int8").unwrap(), PCHOICE_INT8);
        assert!(parse_precision("fp16").is_err());
        // an invalid force must not clobber the current choice
        let before = active_precision();
        assert!(force_precision("bf16").is_err());
        assert_eq!(active_precision(), before);
        assert_eq!(Precision::Int8.name(), "int8");
        assert_eq!(Precision::F32.name(), "f32");
    }

    #[test]
    fn matmul_i8_all_kernels_match_scalar() {
        let a: Vec<i8> = (0..6).map(|v| (v as i8) - 3).collect();
        let b: Vec<i8> = (0..6).map(|v| 20 * ((v as i8) - 2)).collect();
        let mut want = [0i32; 4];
        matmul_i8_with(Kernel::Scalar, &a, &b, &mut want, 2, 3, 2);
        for k in [Kernel::Scalar].into_iter().chain(best_simd()) {
            let mut out = [0i32; 4];
            matmul_i8_with(k, &a, &b, &mut out, 2, 3, 2);
            assert_eq!(out, want, "{k:?}");
        }
    }

    #[test]
    fn active_is_always_runnable() {
        // whatever the machine, active() must resolve to a kernel whose
        // table exists on this target (ops() would panic otherwise)
        let k = active();
        let mut out = [0.0f32; 2];
        matmul_ikj_with(k, &[1.0, 2.0], &[3.0, 4.0, 5.0, 6.0], &mut out, 1, 2, 2);
        assert_eq!(out, [13.0, 16.0]);
    }

    #[test]
    fn axpy_family_basic() {
        for k in [Kernel::Scalar].into_iter().chain(best_simd()) {
            let mut out = vec![1.0f32; 11];
            axpy_with(k, &mut out, 2.0, &[0.5; 11]);
            assert!(out.iter().all(|&v| v == 2.0), "{k:?}");
            add_assign_with(k, &mut out, &[1.0; 11]);
            assert!(out.iter().all(|&v| v == 3.0), "{k:?}");
            sub_assign_with(k, &mut out, &[2.0; 11]);
            assert!(out.iter().all(|&v| v == 1.0), "{k:?}");
        }
    }
}
