//! Portable scalar kernels — the bodies that lived inline in
//! `linalg/mod.rs` before the dispatch layer, unchanged. Every other
//! kernel is bitwise parity-tested against these loops, so edits here
//! are semantic changes to the whole fleet's numerics.

/// ikj loop order (row-major friendly) with a zero-skip on the left
/// operand. `a` is `m×k`, `b` is `k×n`, `out` is `m×n` and zeroed.
pub fn matmul_ikj(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Blocked/tiled matmul: k split into `KC` panels, n into `NC` tiles, a
/// 4-row micro-kernel streaming each `b` row once per four rows of `a`.
/// Accumulation runs in ascending k order per tile.
pub fn matmul_blocked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    const KC: usize = 128;
    const NC: usize = 256;
    const MR: usize = 4;
    let mut acc = [[0.0f32; NC]; MR];
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let mut jj = 0;
        while jj < n {
            let w = (jj + NC).min(n) - jj;
            let mut i = 0;
            while i + MR <= m {
                for row in acc.iter_mut() {
                    for v in row[..w].iter_mut() {
                        *v = 0.0;
                    }
                }
                for p in kk..kend {
                    let brow = &b[p * n + jj..p * n + jj + w];
                    let a0 = a[i * k + p];
                    let a1 = a[(i + 1) * k + p];
                    let a2 = a[(i + 2) * k + p];
                    let a3 = a[(i + 3) * k + p];
                    let [acc0, acc1, acc2, acc3] = &mut acc;
                    for (jx, &bv) in brow.iter().enumerate() {
                        acc0[jx] += a0 * bv;
                        acc1[jx] += a1 * bv;
                        acc2[jx] += a2 * bv;
                        acc3[jx] += a3 * bv;
                    }
                }
                for (r, row) in acc.iter().enumerate() {
                    let start = (i + r) * n + jj;
                    let orow = &mut out[start..start + w];
                    for (o, &v) in orow.iter_mut().zip(&row[..w]) {
                        *o += v;
                    }
                }
                i += MR;
            }
            // remainder rows (m % MR): plain ikj on the tile
            while i < m {
                let orow = &mut out[i * n + jj..i * n + jj + w];
                for p in kk..kend {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n + jj..p * n + jj + w];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
                i += 1;
            }
            jj += NC;
        }
        kk += KC;
    }
}

/// `aᵀ @ b` without materializing the transpose: `a` is `k×m`, `b` is
/// `k×n`, `out` is `m×n` and zeroed (gradient outer-product accumulation).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..m {
            let av = arow[i];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Integer MAC floor: i8×i8→i32, ikj loop order with a zero-skip on
/// the left operand, accumulation in ascending k order per output row.
/// `a` is `m×k` i8 codes, `b` is `k×n` i8 codes, `out` is `m×n` i32
/// and zeroed. Integer addition is exactly associative, so any
/// column-vectorized reordering of the inner loop stays bitwise equal
/// to this floor — the parity contract the SIMD bodies are tested
/// against.
pub fn matmul_i8(a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
    }
}

/// `out[j] += alpha * x[j]`.
pub fn axpy(out: &mut [f32], alpha: f32, x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += alpha * v;
    }
}

/// `out[j] += x[j]`.
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

/// `out[j] -= x[j]`.
pub fn sub_assign(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o -= v;
    }
}
