//! NEON kernels (`std::arch::aarch64`), selected by the dispatcher on
//! aarch64 builds (NEON is a baseline feature of the architecture, so
//! unlike AVX2 there is no runtime-detection gate to fail).
//!
//! Parity discipline (DESIGN.md §12): these loops vectorize **across
//! output columns only**. Each output element keeps the scalar kernel's
//! exact operation sequence — ascending-k accumulation, one rounded
//! multiply then one rounded add per step (`vmulq_f32` + `vaddq_f32`;
//! `vfmaq_f32` would fuse the rounding and break bitwise parity), and
//! the same `a == 0.0` zero-skips, whose predicate depends only on the
//! left operand and is therefore uniform across lanes. Ragged column
//! tails fall back to the identical scalar statements.

#![cfg(target_arch = "aarch64")]

use std::arch::aarch64::*;

const LANES: usize = 4;

/// `out[0..w] += alpha * x[0..w]`, 4-wide with a scalar tail.
///
/// # Safety
/// Caller guarantees both pointers are valid for `w` reads/writes.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn axpy_w(out: *mut f32, x: *const f32, alpha: f32, w: usize) {
    let va = vdupq_n_f32(alpha);
    let mut j = 0;
    while j + LANES <= w {
        let xv = vld1q_f32(x.add(j));
        let ov = vld1q_f32(out.add(j));
        vst1q_f32(out.add(j), vaddq_f32(ov, vmulq_f32(va, xv)));
        j += LANES;
    }
    while j < w {
        *out.add(j) += alpha * *x.add(j);
        j += 1;
    }
}

/// `out[0..w] += x[0..w]`.
///
/// # Safety
/// As [`axpy_w`].
#[inline]
#[target_feature(enable = "neon")]
unsafe fn add_w(out: *mut f32, x: *const f32, w: usize) {
    let mut j = 0;
    while j + LANES <= w {
        let xv = vld1q_f32(x.add(j));
        let ov = vld1q_f32(out.add(j));
        vst1q_f32(out.add(j), vaddq_f32(ov, xv));
        j += LANES;
    }
    while j < w {
        *out.add(j) += *x.add(j);
        j += 1;
    }
}

/// `out[0..w] -= x[0..w]`.
///
/// # Safety
/// As [`axpy_w`].
#[inline]
#[target_feature(enable = "neon")]
unsafe fn sub_w(out: *mut f32, x: *const f32, w: usize) {
    let mut j = 0;
    while j + LANES <= w {
        let xv = vld1q_f32(x.add(j));
        let ov = vld1q_f32(out.add(j));
        vst1q_f32(out.add(j), vsubq_f32(ov, xv));
        j += LANES;
    }
    while j < w {
        *out.add(j) -= *x.add(j);
        j += 1;
    }
}

/// # Safety
/// Slices sized per the kernel contract.
#[target_feature(enable = "neon")]
unsafe fn matmul_ikj_impl(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let orow = out.as_mut_ptr().add(i * n);
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            axpy_w(orow, b.as_ptr().add(p * n), av, n);
        }
    }
}

/// # Safety
/// Slices sized per the kernel contract.
#[target_feature(enable = "neon")]
unsafe fn matmul_blocked_impl(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    // identical tiling constants and traversal order to the scalar kernel
    const KC: usize = 128;
    const NC: usize = 256;
    const MR: usize = 4;
    let mut acc = [[0.0f32; NC]; MR];
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let mut jj = 0;
        while jj < n {
            let w = (jj + NC).min(n) - jj;
            let mut i = 0;
            while i + MR <= m {
                for row in acc.iter_mut() {
                    for v in row[..w].iter_mut() {
                        *v = 0.0;
                    }
                }
                for p in kk..kend {
                    let brow = b.as_ptr().add(p * n + jj);
                    let a0 = a[i * k + p];
                    let a1 = a[(i + 1) * k + p];
                    let a2 = a[(i + 2) * k + p];
                    let a3 = a[(i + 3) * k + p];
                    let va0 = vdupq_n_f32(a0);
                    let va1 = vdupq_n_f32(a1);
                    let va2 = vdupq_n_f32(a2);
                    let va3 = vdupq_n_f32(a3);
                    let [acc0, acc1, acc2, acc3] = &mut acc;
                    let p0 = acc0.as_mut_ptr();
                    let p1 = acc1.as_mut_ptr();
                    let p2 = acc2.as_mut_ptr();
                    let p3 = acc3.as_mut_ptr();
                    let mut jx = 0;
                    while jx + LANES <= w {
                        let bv = vld1q_f32(brow.add(jx));
                        vst1q_f32(p0.add(jx), vaddq_f32(vld1q_f32(p0.add(jx)), vmulq_f32(va0, bv)));
                        vst1q_f32(p1.add(jx), vaddq_f32(vld1q_f32(p1.add(jx)), vmulq_f32(va1, bv)));
                        vst1q_f32(p2.add(jx), vaddq_f32(vld1q_f32(p2.add(jx)), vmulq_f32(va2, bv)));
                        vst1q_f32(p3.add(jx), vaddq_f32(vld1q_f32(p3.add(jx)), vmulq_f32(va3, bv)));
                        jx += LANES;
                    }
                    while jx < w {
                        let bv = *brow.add(jx);
                        *p0.add(jx) += a0 * bv;
                        *p1.add(jx) += a1 * bv;
                        *p2.add(jx) += a2 * bv;
                        *p3.add(jx) += a3 * bv;
                        jx += 1;
                    }
                }
                for (r, row) in acc.iter().enumerate() {
                    let start = (i + r) * n + jj;
                    add_w(out.as_mut_ptr().add(start), row.as_ptr(), w);
                }
                i += MR;
            }
            // remainder rows (m % MR): plain ikj on the tile
            while i < m {
                let orow = out.as_mut_ptr().add(i * n + jj);
                for p in kk..kend {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    axpy_w(orow, b.as_ptr().add(p * n + jj), av, w);
                }
                i += 1;
            }
            jj += NC;
        }
        kk += KC;
    }
}

/// # Safety
/// Slices sized per the kernel contract.
#[target_feature(enable = "neon")]
unsafe fn matmul_tn_impl(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = b.as_ptr().add(p * n);
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            axpy_w(out.as_mut_ptr().add(i * n), brow, av, n);
        }
    }
}

/// Integer MAC: i8×i8→i32, ikj order, vectorized across output columns
/// only. `vmull_s8` products are exact (|a|·|b| ≤ 127·127 fits i16) and
/// integer addition is exactly associative, so parity with the scalar
/// floor is structural; the loop keeps the same discipline (ascending
/// k, left-operand zero-skip, scalar column tail) as its f32 siblings.
///
/// # Safety
/// Slices sized per the kernel contract.
#[target_feature(enable = "neon")]
unsafe fn matmul_i8_impl(a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    const ILANES: usize = 8; // one int8x8_t of codes per step
    for i in 0..m {
        let orow = out.as_mut_ptr().add(i * n);
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0 {
                continue;
            }
            let va = vdup_n_s8(av);
            let av = av as i32;
            let brow = b.as_ptr().add(p * n);
            let mut j = 0;
            while j + ILANES <= n {
                // 8 exact i16 products, widened-added into 2× i32x4
                let prod = vmull_s8(va, vld1_s8(brow.add(j)));
                let lo = vaddw_s16(vld1q_s32(orow.add(j)), vget_low_s16(prod));
                let hi = vaddw_s16(vld1q_s32(orow.add(j + 4)), vget_high_s16(prod));
                vst1q_s32(orow.add(j), lo);
                vst1q_s32(orow.add(j + 4), hi);
                j += ILANES;
            }
            while j < n {
                *orow.add(j) += av * *brow.add(j) as i32;
                j += 1;
            }
        }
    }
}

// ---- safe wrappers (the dispatcher's fn-table entries) ---------------------
//
// SAFETY: NEON is part of the aarch64 baseline ISA, so a binary compiled
// for this module's `#[cfg]` always has it — the wrappers need no
// detection gate.

pub fn matmul_ikj(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    unsafe { matmul_ikj_impl(a, b, out, m, k, n) }
}

pub fn matmul_blocked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    unsafe { matmul_blocked_impl(a, b, out, m, k, n) }
}

pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    unsafe { matmul_tn_impl(a, b, out, k, m, n) }
}

pub fn matmul_i8(a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    unsafe { matmul_i8_impl(a, b, out, m, k, n) }
}

pub fn axpy(out: &mut [f32], alpha: f32, x: &[f32]) {
    let w = out.len().min(x.len());
    unsafe { axpy_w(out.as_mut_ptr(), x.as_ptr(), alpha, w) }
}

pub fn add_assign(out: &mut [f32], x: &[f32]) {
    let w = out.len().min(x.len());
    unsafe { add_w(out.as_mut_ptr(), x.as_ptr(), w) }
}

pub fn sub_assign(out: &mut [f32], x: &[f32]) {
    let w = out.len().min(x.len());
    unsafe { sub_w(out.as_mut_ptr(), x.as_ptr(), w) }
}
