//! NEON kernel slot (aarch64).
//!
//! Currently a documented stub: it delegates straight to the scalar
//! loops, so an aarch64 build dispatches, benches and parity-tests the
//! same way an x86 build does — the `Kernel::Neon` plumbing (detection,
//! forcing, CI matrix) is real, only the vector bodies are pending.
//! When real `vld1q_f32`/`vmulq_f32`/`vaddq_f32` bodies land they must
//! follow the same contract as the AVX2 kernels: vectorize across
//! output columns only, multiply-then-add (no `vfmaq_f32`), scalar
//! tails — see DESIGN.md §12.

#![cfg(target_arch = "aarch64")]

use super::scalar;

pub fn matmul_ikj(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    scalar::matmul_ikj(a, b, out, m, k, n)
}

pub fn matmul_blocked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    scalar::matmul_blocked(a, b, out, m, k, n)
}

pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    scalar::matmul_tn(a, b, out, k, m, n)
}

pub fn axpy(out: &mut [f32], alpha: f32, x: &[f32]) {
    scalar::axpy(out, alpha, x)
}

pub fn add_assign(out: &mut [f32], x: &[f32]) {
    scalar::add_assign(out, x)
}

pub fn sub_assign(out: &mut [f32], x: &[f32]) {
    scalar::sub_assign(out, x)
}
