//! AVX2 kernels (`std::arch`), selected at runtime by the dispatcher
//! after `is_x86_feature_detected!("avx2")` succeeds.
//!
//! Parity discipline (DESIGN.md §12): these loops vectorize **across
//! output columns only**. Each output element keeps the scalar kernel's
//! exact operation sequence — ascending-k accumulation, one rounded
//! multiply then one rounded add per step (`_mm256_mul_ps` +
//! `_mm256_add_ps`; FMA would fuse the rounding and break bitwise
//! parity), and the same `a == 0.0` zero-skips, whose predicate depends
//! only on the left operand and is therefore uniform across lanes.
//! Ragged column tails fall back to the identical scalar statements.

#![cfg(any(target_arch = "x86", target_arch = "x86_64"))]

#[cfg(target_arch = "x86")]
use std::arch::x86::*;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

const LANES: usize = 8;

/// `out[0..w] += alpha * x[0..w]`, 8-wide with a scalar tail.
///
/// # Safety
/// Caller guarantees AVX2 is available and both pointers are valid for
/// `w` reads/writes.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn axpy_w(out: *mut f32, x: *const f32, alpha: f32, w: usize) {
    let va = _mm256_set1_ps(alpha);
    let mut j = 0;
    while j + LANES <= w {
        let xv = _mm256_loadu_ps(x.add(j));
        let ov = _mm256_loadu_ps(out.add(j));
        _mm256_storeu_ps(out.add(j), _mm256_add_ps(ov, _mm256_mul_ps(va, xv)));
        j += LANES;
    }
    while j < w {
        *out.add(j) += alpha * *x.add(j);
        j += 1;
    }
}

/// `out[0..w] += x[0..w]`.
///
/// # Safety
/// As [`axpy_w`].
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn add_w(out: *mut f32, x: *const f32, w: usize) {
    let mut j = 0;
    while j + LANES <= w {
        let xv = _mm256_loadu_ps(x.add(j));
        let ov = _mm256_loadu_ps(out.add(j));
        _mm256_storeu_ps(out.add(j), _mm256_add_ps(ov, xv));
        j += LANES;
    }
    while j < w {
        *out.add(j) += *x.add(j);
        j += 1;
    }
}

/// `out[0..w] -= x[0..w]`.
///
/// # Safety
/// As [`axpy_w`].
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn sub_w(out: *mut f32, x: *const f32, w: usize) {
    let mut j = 0;
    while j + LANES <= w {
        let xv = _mm256_loadu_ps(x.add(j));
        let ov = _mm256_loadu_ps(out.add(j));
        _mm256_storeu_ps(out.add(j), _mm256_sub_ps(ov, xv));
        j += LANES;
    }
    while j < w {
        *out.add(j) -= *x.add(j);
        j += 1;
    }
}

/// # Safety
/// AVX2 available; slices sized per the kernel contract.
#[target_feature(enable = "avx2")]
unsafe fn matmul_ikj_impl(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let orow = out.as_mut_ptr().add(i * n);
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            axpy_w(orow, b.as_ptr().add(p * n), av, n);
        }
    }
}

/// # Safety
/// AVX2 available; slices sized per the kernel contract.
#[target_feature(enable = "avx2")]
unsafe fn matmul_blocked_impl(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    // identical tiling constants and traversal order to the scalar kernel
    const KC: usize = 128;
    const NC: usize = 256;
    const MR: usize = 4;
    let mut acc = [[0.0f32; NC]; MR];
    let mut kk = 0;
    while kk < k {
        let kend = (kk + KC).min(k);
        let mut jj = 0;
        while jj < n {
            let w = (jj + NC).min(n) - jj;
            let mut i = 0;
            while i + MR <= m {
                for row in acc.iter_mut() {
                    for v in row[..w].iter_mut() {
                        *v = 0.0;
                    }
                }
                for p in kk..kend {
                    let brow = b.as_ptr().add(p * n + jj);
                    let a0 = a[i * k + p];
                    let a1 = a[(i + 1) * k + p];
                    let a2 = a[(i + 2) * k + p];
                    let a3 = a[(i + 3) * k + p];
                    let va0 = _mm256_set1_ps(a0);
                    let va1 = _mm256_set1_ps(a1);
                    let va2 = _mm256_set1_ps(a2);
                    let va3 = _mm256_set1_ps(a3);
                    let [acc0, acc1, acc2, acc3] = &mut acc;
                    let p0 = acc0.as_mut_ptr();
                    let p1 = acc1.as_mut_ptr();
                    let p2 = acc2.as_mut_ptr();
                    let p3 = acc3.as_mut_ptr();
                    let mut jx = 0;
                    while jx + LANES <= w {
                        let bv = _mm256_loadu_ps(brow.add(jx));
                        _mm256_storeu_ps(p0.add(jx), _mm256_add_ps(_mm256_loadu_ps(p0.add(jx)), _mm256_mul_ps(va0, bv)));
                        _mm256_storeu_ps(p1.add(jx), _mm256_add_ps(_mm256_loadu_ps(p1.add(jx)), _mm256_mul_ps(va1, bv)));
                        _mm256_storeu_ps(p2.add(jx), _mm256_add_ps(_mm256_loadu_ps(p2.add(jx)), _mm256_mul_ps(va2, bv)));
                        _mm256_storeu_ps(p3.add(jx), _mm256_add_ps(_mm256_loadu_ps(p3.add(jx)), _mm256_mul_ps(va3, bv)));
                        jx += LANES;
                    }
                    while jx < w {
                        let bv = *brow.add(jx);
                        *p0.add(jx) += a0 * bv;
                        *p1.add(jx) += a1 * bv;
                        *p2.add(jx) += a2 * bv;
                        *p3.add(jx) += a3 * bv;
                        jx += 1;
                    }
                }
                for (r, row) in acc.iter().enumerate() {
                    let start = (i + r) * n + jj;
                    add_w(out.as_mut_ptr().add(start), row.as_ptr(), w);
                }
                i += MR;
            }
            // remainder rows (m % MR): plain ikj on the tile
            while i < m {
                let orow = out.as_mut_ptr().add(i * n + jj);
                for p in kk..kend {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    axpy_w(orow, b.as_ptr().add(p * n + jj), av, w);
                }
                i += 1;
            }
            jj += NC;
        }
        kk += KC;
    }
}

/// # Safety
/// AVX2 available; slices sized per the kernel contract.
#[target_feature(enable = "avx2")]
unsafe fn matmul_tn_impl(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = b.as_ptr().add(p * n);
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            axpy_w(out.as_mut_ptr().add(i * n), brow, av, n);
        }
    }
}

/// Integer MAC: i8×i8→i32, ikj order, vectorized across output columns
/// only. Integer addition is exactly associative, so parity with the
/// scalar floor is structural — but we keep the same loop discipline
/// (ascending k, left-operand zero-skip, scalar column tail) anyway so
/// the body reads like its f32 siblings and any future widening change
/// stays reviewable against them.
///
/// # Safety
/// AVX2 available; slices sized per the kernel contract.
#[target_feature(enable = "avx2")]
unsafe fn matmul_i8_impl(a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    for i in 0..m {
        let orow = out.as_mut_ptr().add(i * n);
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let va = _mm256_set1_epi32(av);
            let brow = b.as_ptr().add(p * n);
            let mut j = 0;
            while j + LANES <= n {
                // load 8 i8 codes, sign-extend to 8×i32, mul-accumulate
                let b8 = _mm_loadl_epi64(brow.add(j) as *const __m128i);
                let bv = _mm256_cvtepi8_epi32(b8);
                let ov = _mm256_loadu_si256(orow.add(j) as *const __m256i);
                _mm256_storeu_si256(
                    orow.add(j) as *mut __m256i,
                    _mm256_add_epi32(ov, _mm256_mullo_epi32(va, bv)),
                );
                j += LANES;
            }
            while j < n {
                *orow.add(j) += av * *brow.add(j) as i32;
                j += 1;
            }
        }
    }
}

// ---- safe wrappers (the dispatcher's fn-table entries) ---------------------
//
// SAFETY: the dispatcher only installs this table after
// `is_x86_feature_detected!("avx2")` succeeds; the debug_assert catches
// a test bypassing detection on an old machine.

pub fn matmul_ikj(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    unsafe { matmul_ikj_impl(a, b, out, m, k, n) }
}

pub fn matmul_blocked(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    unsafe { matmul_blocked_impl(a, b, out, m, k, n) }
}

pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    unsafe { matmul_tn_impl(a, b, out, k, m, n) }
}

pub fn matmul_i8(a: &[i8], b: &[i8], out: &mut [i32], m: usize, k: usize, n: usize) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    unsafe { matmul_i8_impl(a, b, out, m, k, n) }
}

pub fn axpy(out: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    let w = out.len().min(x.len());
    unsafe { axpy_w(out.as_mut_ptr(), x.as_ptr(), alpha, w) }
}

pub fn add_assign(out: &mut [f32], x: &[f32]) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    let w = out.len().min(x.len());
    unsafe { add_w(out.as_mut_ptr(), x.as_ptr(), w) }
}

pub fn sub_assign(out: &mut [f32], x: &[f32]) {
    debug_assert!(is_x86_feature_detected!("avx2"));
    let w = out.len().min(x.len());
    unsafe { sub_w(out.as_mut_ptr(), x.as_ptr(), w) }
}
