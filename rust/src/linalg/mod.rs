//! Minimal dense-matrix substrate (row-major `f32`).
//!
//! Backs the digital CMOS baseline (`nn`), the device crossbar simulator
//! and the host-side glue around the PJRT executables. Deliberately small:
//! only the operations the MiRU/DFA math needs, each with explicit shape
//! checks (panics are programming errors, not data errors).
//!
//! The matmul inner loops live in [`kernels`] (scalar / AVX2 / NEON,
//! runtime-dispatched, bitwise parity-tested against each other); the
//! WBS bit-plane packing and bit-serial crossbar MAC live in
//! [`bitplane`]. `Mat` keeps the shape checks and the shape-based
//! kernel choice.

pub mod bitplane;
pub mod kernels;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape {rows}x{cols} vs len {}", data.len());
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    // at/at_mut check bounds with real asserts, not debug_asserts: the
    // row-major index math means an out-of-range column aliases a
    // neighboring row's element, so in release builds an unchecked OOB
    // access would be silent numeric corruption, not a crash.
    #[inline]
    #[track_caller]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        assert!(r < self.rows && c < self.cols, "at({r},{c}) out of {}x{}", self.rows, self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    #[track_caller]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        assert!(
            r < self.rows && c < self.cols,
            "at_mut({r},{c}) out of {}x{}",
            self.rows,
            self.cols
        );
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of the contiguous row range `[start, start + len)` — row
    /// sharding for the parallel engines.
    pub fn rows_copy(&self, start: usize, len: usize) -> Mat {
        assert!(start + len <= self.rows, "rows_copy {start}+{len} > {}", self.rows);
        Mat {
            rows: len,
            cols: self.cols,
            data: self.data[start * self.cols..(start + len) * self.cols].to_vec(),
        }
    }

    /// self @ other: [m,k] x [k,n] -> [m,n].
    ///
    /// Dispatches between the simple ikj kernel ([`Mat::matmul_ikj`], best
    /// for the small shapes of the unit tests) and the register-blocked
    /// kernel ([`Mat::matmul_blocked`], the serving hot path) by shape.
    ///
    /// ```
    /// use m2ru::linalg::Mat;
    /// let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
    /// let identity = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
    /// assert_eq!(a.matmul(&identity).data, vec![1.0, 2.0, 3.0, 4.0]);
    /// ```
    pub fn matmul(&self, other: &Mat) -> Mat {
        if self.rows >= 4 && self.cols >= 64 && other.cols >= 64 {
            self.matmul_blocked(other)
        } else {
            self.matmul_ikj(other)
        }
    }

    /// Simple ikj loop order (row-major friendly) with a zero-skip on the
    /// left operand — the small-shape path of [`Mat::matmul`] and the
    /// benchmark baseline for `cargo bench matmul`. The loop body lives
    /// in [`kernels`] and is dispatched to the active kernel.
    pub fn matmul_ikj(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        kernels::matmul_ikj(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// Blocked/tiled matmul: k is split into `KC` panels and n into `NC`
    /// tiles so the active slab of `other` stays cache-resident, and a
    /// 4-row micro-kernel streams each `other` row once per *four* rows of
    /// `self` (4x fewer B-side loads than ikj, which re-reads the whole
    /// right operand for every output row). Accumulation runs in ascending
    /// k order per tile, so results match ikj up to f32 re-association
    /// across k-panels. The loop body lives in [`kernels`].
    pub fn matmul_blocked(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        kernels::matmul_blocked(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// selfᵀ @ other: [k,m]ᵀ x [k,n] -> [m,n] without materializing the
    /// transpose (gradient outer-product accumulation). The loop body
    /// lives in [`kernels`].
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "matmul_tn");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        kernels::matmul_tn(&self.data, &other.data, &mut out.data, k, m, n);
        out
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self.at(c, r))
    }

    /// self += alpha * other (elementwise).
    pub fn add_scaled(&mut self, other: &Mat, alpha: f32) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Add a row-vector bias to every row.
    pub fn add_row_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (x, &b) in self.row_mut(r).iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Vertical concat: [a; b] (crossbar layout: x-rows above h-rows).
    pub fn vcat(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.cols);
        let mut data = Vec::with_capacity((a.rows + b.rows) * a.cols);
        data.extend_from_slice(&a.data);
        data.extend_from_slice(&b.data);
        Mat { rows: a.rows + b.rows, cols: a.cols, data }
    }

    /// Horizontal concat per row: [a | b].
    pub fn hcat(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.rows, b.rows);
        let mut out = Mat::zeros(a.rows, a.cols + b.cols);
        for r in 0..a.rows {
            out.row_mut(r)[..a.cols].copy_from_slice(a.row(r));
            out.row_mut(r)[a.cols..].copy_from_slice(b.row(r));
        }
        out
    }
}

/// Row-wise softmax (numerically stable).
pub fn softmax_rows(m: &Mat) -> Mat {
    let mut out = m.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    out
}

/// Mean cross-entropy of softmax(logits) against one-hot labels.
pub fn cross_entropy(logits: &Mat, labels: &[usize]) -> f32 {
    assert_eq!(logits.rows, labels.len());
    let p = softmax_rows(logits);
    let mut loss = 0.0;
    for (r, &y) in labels.iter().enumerate() {
        loss -= p.at(r, y).max(1e-12).ln();
    }
    loss / logits.rows as f32
}

/// Row-wise argmax.
pub fn argmax_rows(m: &Mat) -> Vec<usize> {
    (0..m.rows)
        .map(|r| {
            m.row(r)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_tn_equals_transpose_then_matmul() {
        let a = Mat::from_fn(5, 3, |r, c| (r * 3 + c) as f32 * 0.1);
        let b = Mat::from_fn(5, 4, |r, c| (r + c) as f32 * 0.2 - 0.3);
        let got = a.matmul_tn(&b);
        let want = a.transpose().matmul(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 7, |r, c| (r * 7 + c) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_order_preserved() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 100.0]);
        let p = softmax_rows(&m);
        for r in 0..2 {
            let s: f32 = p.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(p.at(0, 2) > p.at(0, 1) && p.at(0, 1) > p.at(0, 0));
        assert!(p.at(1, 2) > 0.99);
    }

    #[test]
    fn cross_entropy_perfect_prediction_near_zero() {
        let logits = Mat::from_vec(1, 3, vec![100.0, 0.0, 0.0]);
        assert!(cross_entropy(&logits, &[0]) < 1e-6);
        assert!(cross_entropy(&logits, &[1]) > 10.0);
    }

    #[test]
    fn argmax_rows_works() {
        let m = Mat::from_vec(2, 3, vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn vcat_hcat_shapes() {
        let a = Mat::zeros(2, 3);
        let b = Mat::from_fn(4, 3, |_, _| 1.0);
        let v = Mat::vcat(&a, &b);
        assert_eq!((v.rows, v.cols), (6, 3));
        assert_eq!(v.at(3, 0), 1.0);
        let c = Mat::from_fn(2, 2, |_, _| 2.0);
        let h = Mat::hcat(&a, &c);
        assert_eq!((h.rows, h.cols), (2, 5));
        assert_eq!(h.at(1, 4), 2.0);
    }

    #[test]
    fn add_row_bias_and_scale() {
        let mut m = Mat::zeros(2, 2);
        m.add_row_bias(&[1.0, 2.0]);
        m.scale(2.0);
        assert_eq!(m.data, vec![2.0, 4.0, 2.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::rng::GaussianRng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.uniform_in(-1.0, 1.0))
    }

    #[test]
    fn blocked_matches_ikj_across_shapes() {
        // covers: micro-kernel only, remainder rows, multiple k-panels,
        // multiple n-tiles, and degenerate tiny shapes
        for &(m, k, n, seed) in &[
            (4usize, 8usize, 8usize, 1u64),
            (7, 150, 300, 2),   // remainder rows + >1 k-panel + >1 n-tile
            (9, 128, 256, 3),   // exact panel boundaries + remainder row
            (1, 5, 1, 4),
            (8, 257, 65, 5),    // k-panel remainder
        ] {
            let a = rand_mat(m, k, seed);
            let b = rand_mat(k, n, seed ^ 0xB10C);
            let fast = a.matmul_blocked(&b);
            let slow = a.matmul_ikj(&b);
            assert_eq!((fast.rows, fast.cols), (m, n));
            for (x, y) in fast.data.iter().zip(&slow.data) {
                // identical up to f32 re-association across k-panels
                assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{m}x{k}x{n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn matmul_dispatch_agrees_with_both_kernels() {
        let a = rand_mat(32, 100, 7);
        let b = rand_mat(100, 100, 8);
        let via_dispatch = a.matmul(&b);
        let blocked = a.matmul_blocked(&b);
        assert_eq!(via_dispatch.data, blocked.data, "large shapes take the blocked path");
    }
}
