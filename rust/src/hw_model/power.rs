//! Power model and Fig. 5(d) breakdown (§VI-D).
//!
//! Every term = unit power × architecture count. Inference at the paper's
//! operating point must total 48.62 mW; training activates the projection
//! circuit, write-control logic and error unit (+8.35 mW → 56.97 mW).

use super::components::*;
use super::ArchConfig;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PowerMode {
    Inference,
    Training,
}

/// Per-unit power breakdown, mW (the Fig. 5d pie).
#[derive(Clone, Debug)]
pub struct PowerBreakdown {
    pub adc: f64,
    pub neurons: f64,
    pub drivers: f64,
    pub digital: f64,
    pub tanh: f64,
    pub crossbar: f64,
    /// Projection + write control + error unit (0 in inference).
    pub training: f64,
}

impl PowerBreakdown {
    pub fn for_config(a: &ArchConfig, mode: PowerMode) -> Self {
        let adc = a.adc_count() as f64 * P_ADC_MW;
        let neurons = (a.nh + a.ny) as f64 * P_NEURON_MW;
        // wordlines: hidden crossbar (nx+nh) + readout crossbar (nh)
        let drivers = ((a.nx + a.nh) + a.nh) as f64 * P_DRIVER_MW;
        let digital = P_CTRL_BASE_MW
            + a.tiles as f64 * P_INTERP_TILE_MW
            + a.nh as f64 * P_SREG_PER_UNIT_MW;
        let crossbar = a.memristor_count() as f64 * P_XBAR_PER_DEVICE_MW;
        let training = match mode {
            PowerMode::Inference => 0.0,
            PowerMode::Training => P_PROJECTION_MW + P_WRITE_CTRL_MW + P_ERROR_UNIT_MW,
        };
        Self { adc, neurons, drivers, digital, tanh: P_TANH_MW, crossbar, training }
    }

    /// Total power, mW.
    pub fn total_mw(&self) -> f64 {
        self.adc + self.neurons + self.drivers + self.digital + self.tanh + self.crossbar
            + self.training
    }

    /// Named rows for reporting, (label, mW, fraction).
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let t = self.total_mw();
        let mut rows = vec![
            ("ADC (shared, 1.28 GSps)", self.adc, self.adc / t),
            ("Neuron circuits (op-amp + integrator)", self.neurons, self.neurons / t),
            ("Wordline drivers + level shifters", self.drivers, self.drivers / t),
            ("Digital control / FIFO / interpolation", self.digital, self.digital / t),
            ("tanh PWL unit", self.tanh, self.tanh / t),
            ("Crossbar read", self.crossbar, self.crossbar / t),
        ];
        if self.training > 0.0 {
            rows.push(("Training logic (Ψ, Ziksa, error unit)", self.training, self.training / t));
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_totals_48_62_mw_at_paper_point() {
        let p = PowerBreakdown::for_config(&ArchConfig::paper_default(), PowerMode::Inference);
        let total = p.total_mw();
        assert!((total - 48.62).abs() < 48.62 * 0.01, "total {total}");
    }

    #[test]
    fn training_totals_56_97_mw() {
        let p = PowerBreakdown::for_config(&ArchConfig::paper_default(), PowerMode::Training);
        let total = p.total_mw();
        assert!((total - 56.97).abs() < 56.97 * 0.01, "total {total}");
        assert!((p.training - 8.35).abs() < 1e-9);
    }

    #[test]
    fn analog_front_end_dominates() {
        // §VI-D: "most of the power is directed towards the analog
        // front-end circuits, particularly the ADCs and Op-Amps".
        let p = PowerBreakdown::for_config(&ArchConfig::paper_default(), PowerMode::Inference);
        assert!(p.adc + p.neurons > 0.6 * p.total_mw());
        assert!(p.adc > p.drivers && p.neurons > p.digital);
    }

    #[test]
    fn tanh_is_microwatts() {
        let p = PowerBreakdown::for_config(&ArchConfig::paper_default(), PowerMode::Inference);
        assert!((p.tanh - 0.00374).abs() < 1e-9);
    }

    #[test]
    fn fractions_sum_to_one() {
        let p = PowerBreakdown::for_config(&ArchConfig::paper_default(), PowerMode::Training);
        let s: f64 = p.rows().iter().map(|r| r.2).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn power_scales_with_network_size() {
        let base = PowerBreakdown::for_config(&ArchConfig::paper_default(), PowerMode::Inference);
        let big = PowerBreakdown::for_config(
            &ArchConfig::paper_default().with_nh(256),
            PowerMode::Inference,
        );
        assert!(big.total_mw() > base.total_mw() * 1.5);
        assert!(big.adc > base.adc); // extra shared ADC kicks in past 128
    }
}
