//! Component-level constants of the 65 nm @ 20 MHz implementation.
//!
//! Each constant is a per-unit power (mW) or timing figure; the totals are
//! calibrated so that the paper's published operating points come out of
//! the *formulas*, not hard-coded: 48.62 mW inference / 56.97 mW training
//! at 28×100×10, 1.85 µs per feature set, 312 GOPS/W, 29× over digital.
//! See `EXPERIMENTS.md` §Calibration for the audit trail.

/// System clock period at 20 MHz, seconds.
pub const T_CYCLE_S: f64 = 50.0e-9;

/// WBS pulse duration T_s (§V-A): one clock cycle.
pub const T_PULSE_S: f64 = 50.0e-9;

/// Shared high-speed ADC: 1.28 GSps ⇒ ~2 ns per channel (§IV-B1).
pub const ADC_NS_PER_CHANNEL: f64 = 2.0;

/// Power of one 1.28 GSps 8-bit SAR ADC, mW (dominant analog block).
pub const P_ADC_MW: f64 = 8.75;

/// One neuron circuit: inverting op-amp + integrator + hold switches, mW.
pub const P_NEURON_MW: f64 = 0.169;

/// One wordline driver + level shifter (Fig. 3-Left), mW.
pub const P_DRIVER_MW: f64 = 0.0215;

/// Digital control base: FSM, counters, clocking, mW.
pub const P_CTRL_BASE_MW: f64 = 2.6;

/// One tile's interpolation datapath (multiplier + adder + muxing), mW.
pub const P_INTERP_TILE_MW: f64 = 0.35;

/// FIFO + shift-register storage per hidden unit, mW.
pub const P_SREG_PER_UNIT_MW: f64 = 0.022;

/// Piecewise-linear tanh unit, mW (paper: ~3.74 µW, shared).
pub const P_TANH_MW: f64 = 0.00374;

/// Average crossbar read power per device at 0.1 V drive, mW
/// (V²·G_avg ≈ 0.01 · 275 nS = 2.75 nW), with ~50% bit activity.
pub const P_XBAR_PER_DEVICE_MW: f64 = 2.75e-6 * 0.5;

// --- training-only blocks (§VI-D: +8.35 mW during training) -------------

/// DFA projection circuit (Ψ MAC datapath), mW.
pub const P_PROJECTION_MW: f64 = 3.1;

/// Write drivers + Ziksa programming control, mW.
pub const P_WRITE_CTRL_MW: f64 = 4.0;

/// Error-computing unit (§IV-B2), mW.
pub const P_ERROR_UNIT_MW: f64 = 1.25;

// --- latency model -------------------------------------------------------

/// Fixed per-step control overhead, cycles: buffer load, FIFO transfer,
/// wordline setup (calibrated to the 1.85 µs operating point).
pub const C_CTRL_CYCLES: u64 = 12;

/// Upper bound on tiled interpolation latency, cycles (§VI-C: "no more
/// than 16 cycles ... regardless of the hidden layer size").
pub const INTERP_CYCLE_CAP: u64 = 16;

// --- digital CMOS MiRU baseline (Table I comparator) ---------------------

/// Digital MAC (multiplier + adder + pipeline regs), pJ/op at 65 nm.
pub const E_DIG_MAC_PJ: f64 = 4.5;

/// Weight SRAM read per op (no crossbar: every MAC refetches), pJ/op.
pub const E_DIG_SRAM_PJ: f64 = 60.0;

/// Activation buffering + movement per op, pJ/op.
pub const E_DIG_MOVE_PJ: f64 = 18.0;

/// Control/clocking overhead per op, pJ/op.
pub const E_DIG_CTRL_PJ: f64 = 10.6;
