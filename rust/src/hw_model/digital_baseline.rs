//! Digital CMOS MiRU baseline — the "29× improvement" comparator (§VI-D).
//!
//! A fully digital 65 nm MiRU pays, per MAC-op, for the multiplier itself
//! plus the weight SRAM fetch, activation movement and control that the
//! crossbar design amortizes away. The per-op energy terms live in
//! `components`; their sum calibrates to the paper's implied 93 pJ/op
//! (3.21 pJ/op × 29).

use super::components::*;
use super::power::PowerMode;
use super::throughput::gops_per_watt;
use super::ArchConfig;

/// Energy per operation of the digital 65 nm MiRU, pJ.
pub fn digital_energy_per_op_pj() -> f64 {
    E_DIG_MAC_PJ + E_DIG_SRAM_PJ + E_DIG_MOVE_PJ + E_DIG_CTRL_PJ
}

/// Digital baseline efficiency, GOPS/W.
pub fn digital_gops_per_watt() -> f64 {
    1000.0 / digital_energy_per_op_pj()
}

/// M2RU energy-efficiency gain over the digital baseline (paper: 29×).
pub fn efficiency_gain(a: &ArchConfig) -> f64 {
    gops_per_watt(a, PowerMode::Inference) / digital_gops_per_watt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digital_energy_is_93_pj_per_op() {
        let e = digital_energy_per_op_pj();
        assert!((e - 93.1).abs() < 0.01, "{e}");
    }

    #[test]
    fn gain_is_about_29x() {
        let gain = efficiency_gain(&ArchConfig::paper_default());
        assert!((gain - 29.0).abs() < 1.5, "{gain}");
    }

    #[test]
    fn sram_fetch_dominates_digital_energy() {
        // the architectural argument for in-memory computing
        assert!(E_DIG_SRAM_PJ > 0.5 * digital_energy_per_op_pj());
    }
}
