//! Throughput / efficiency metrics (§VI-C headline numbers).

use super::latency::{seq_latency_s, step_latency_s};
use super::power::{PowerBreakdown, PowerMode};
use super::ArchConfig;

/// Operations per MiRU time step: the two crossbar VMMs as MACs
/// (2 ops each) — the dominant compute the paper counts.
pub fn ops_per_step(a: &ArchConfig) -> u64 {
    (2 * ((a.nx + a.nh) * a.nh + a.nh * a.ny)) as u64
}

/// Sustained compute throughput, GOPS.
pub fn gops(a: &ArchConfig) -> f64 {
    ops_per_step(a) as f64 / step_latency_s(a) / 1e9
}

/// Sequences classified per second.
pub fn seqs_per_second(a: &ArchConfig) -> f64 {
    1.0 / seq_latency_s(a)
}

/// Energy efficiency, GOPS/W, in the given power mode.
pub fn gops_per_watt(a: &ArchConfig, mode: PowerMode) -> f64 {
    gops(a) / (PowerBreakdown::for_config(a, mode).total_mw() / 1000.0)
}

/// Energy per operation, pJ/op.
pub fn pj_per_op(a: &ArchConfig, mode: PowerMode) -> f64 {
    1000.0 / gops_per_watt(a, mode)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_15_gops() {
        let a = ArchConfig::paper_default();
        let g = gops(&a);
        assert!((g - 15.0).abs() < 0.3, "{g}"); // 27600 ops / 1.85 µs = 14.92
    }

    #[test]
    fn headline_19305_seqs_per_second() {
        let a = ArchConfig::paper_default();
        assert!((seqs_per_second(&a) - 19305.0).abs() < 10.0);
    }

    #[test]
    fn headline_312_gops_per_watt() {
        let a = ArchConfig::paper_default();
        let e = gops_per_watt(&a, PowerMode::Inference);
        // paper: 312 GOPS/W (3.21 pJ/op); our formulas give ~307
        assert!((e - 312.0).abs() < 312.0 * 0.05, "{e}");
        let pj = pj_per_op(&a, PowerMode::Inference);
        assert!((pj - 3.21).abs() < 0.2, "{pj}");
    }

    #[test]
    fn ops_count_matches_hand_arithmetic() {
        let a = ArchConfig::paper_default();
        assert_eq!(ops_per_step(&a), 2 * (128 * 100 + 100 * 10));
    }

    #[test]
    fn efficiency_degrades_without_tiling() {
        let a = ArchConfig::paper_default();
        let untiled = a.with_tiles(1, false);
        assert!(gops(&untiled) < 0.5 * gops(&a));
    }
}
