//! Step/sequence latency model (Fig. 5c, §VI-C).
//!
//! One time step of the MiRU layer costs, in 20 MHz cycles:
//!
//!   control overhead            (fixed)
//! + n_b WBS pulses              (bit-serial input streaming)
//! + ADC scan of the bitlines    (shared 1.28 GSps ADC, 2 ns/channel)
//! + hidden-state interpolation  (serialized; tiled ⇒ ≤ 16 cycles)
//!
//! Without tiling the interpolation serializes over all n_h units and
//! dominates — the dotted lines of Fig. 5(c) where bit precision barely
//! matters. With tiling the cap is 16 cycles and n_b becomes roughly a
//! third of the step (§VI-C).

use super::components::*;
use super::ArchConfig;

/// Cycle-level breakdown of one MiRU time step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CycleBreakdown {
    pub control: u64,
    pub wbs: u64,
    pub adc_scan: u64,
    pub interpolation: u64,
}

impl CycleBreakdown {
    pub fn total(&self) -> u64 {
        self.control + self.wbs + self.adc_scan + self.interpolation
    }
}

/// ADC scan cycles: n_h channels at 2 ns each, split across the layer's
/// shared ADCs, rounded up to whole clock cycles.
fn adc_scan_cycles(a: &ArchConfig) -> u64 {
    let adcs_hidden = a.nh.div_ceil(128) as f64;
    let scan_ns = a.nh as f64 * ADC_NS_PER_CHANNEL / adcs_hidden;
    (scan_ns / (T_CYCLE_S * 1e9)).ceil() as u64
}

/// Cycles to compute one MiRU time step.
pub fn step_cycles(a: &ArchConfig) -> CycleBreakdown {
    let interpolation = if a.tiling {
        (a.nh.div_ceil(a.tiles) as u64).min(INTERP_CYCLE_CAP)
    } else {
        a.nh as u64
    };
    CycleBreakdown {
        control: C_CTRL_CYCLES,
        wbs: u64::from(a.nb),
        adc_scan: adc_scan_cycles(a),
        interpolation,
    }
}

/// Latency of one time step ("one set of features"), seconds.
pub fn step_latency_s(a: &ArchConfig) -> f64 {
    step_cycles(a).total() as f64 / a.clock_hz
}

/// Latency of one full sequence (n_T steps), seconds.
pub fn seq_latency_s(a: &ArchConfig) -> f64 {
    step_latency_s(a) * a.nt as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_operating_point_is_1_85_us() {
        let a = ArchConfig::paper_default();
        let bd = step_cycles(&a);
        // 12 ctrl + 8 wbs + 4 adc + ceil(100/8)=13 interp = 37 cycles
        assert_eq!(bd, CycleBreakdown { control: 12, wbs: 8, adc_scan: 4, interpolation: 13 });
        assert!((step_latency_s(&a) - 1.85e-6).abs() < 1e-9, "{}", step_latency_s(&a));
    }

    #[test]
    fn sequence_latency_and_seqs_per_second() {
        let a = ArchConfig::paper_default();
        let seq = seq_latency_s(&a);
        assert!((seq - 51.8e-6).abs() < 1e-9);
        let sps = 1.0 / seq;
        assert!((sps - 19305.0).abs() < 10.0, "{sps}");
    }

    #[test]
    fn untiled_interpolation_dominates_and_masks_precision() {
        let a = ArchConfig::paper_default().with_tiles(1, false);
        let bd = step_cycles(&a);
        assert_eq!(bd.interpolation, 100);
        // doubling nb changes total by < 10% when untiled (Fig 5c dotted)
        let t8 = step_cycles(&a).total() as f64;
        let t16 = step_cycles(&a.with_nb(16)).total() as f64;
        assert!((t16 - t8) / t8 < 0.10);
        // but by ~20%+ when tiled
        let a_t = ArchConfig::paper_default();
        let s8 = step_cycles(&a_t).total() as f64;
        let s16 = step_cycles(&a_t.with_nb(16)).total() as f64;
        assert!((s16 - s8) / s8 > 0.18);
    }

    #[test]
    fn tiling_caps_interpolation_at_16_cycles() {
        for nh in [64, 128, 256, 512, 1024] {
            let a = ArchConfig::paper_default().with_nh(nh).with_tiles(nh.div_ceil(16), true);
            assert!(step_cycles(&a).interpolation <= 16, "nh={nh}");
        }
    }

    #[test]
    fn latency_linear_in_nb_when_tiled() {
        let a = ArchConfig::paper_default();
        let deltas: Vec<u64> = (2..8)
            .map(|nb| step_cycles(&a.with_nb(nb + 1)).total() - step_cycles(&a.with_nb(nb)).total())
            .collect();
        assert!(deltas.iter().all(|&d| d == 1), "{deltas:?}");
    }

    #[test]
    fn scaling_nh_without_tiling_is_linear() {
        let base = ArchConfig::paper_default().with_tiles(1, false);
        let t100 = step_cycles(&base).total();
        let t200 = step_cycles(&base.with_nh(200)).total();
        // interpolation grows by exactly 100 cycles; the ADC scan stays
        // flat because a second shared ADC is provisioned past 128 lines
        assert_eq!(t200 - t100, 100);
    }
}
