//! Weighted-bit-streaming electrical design (§V-A, Eqs. 11–19).
//!
//! WBS replaces high-resolution DACs with bit-serial pulses whose
//! significance comes from the memristor ratio (M_f/M_i)_k = 2^-k. This
//! module sizes the integrator and checks the feasibility constraints the
//! paper derives:
//!
//! * Eq. (16–19): worst-case integrator swing V_int ≈ I_max·T_s/C_f
//!   (the geometric series Σ 2^-k = 1 − 2^-n_b ≈ 1 bounds the sum);
//! * the single-feedback-memristor alternative needs M_f spanning
//!   [2^-1, 2^-n_b]·M_min — more than two orders of magnitude at 8 bits,
//!   beyond practical device windows (the reason for ratio-based tuning);
//! * the level shifter's 0.1 V drive bounds the bitline current.

use super::components::T_PULSE_S;

/// Electrical operating point of one WBS bitline + integrator.
#[derive(Clone, Copy, Debug)]
pub struct WbsDesign {
    /// Input bit precision.
    pub nb: u32,
    /// Worst-case bitline current per pulse, A (paper: ≈3.2 µA).
    pub i_max: f64,
    /// Pulse duration T_s, s (one 20 MHz cycle).
    pub t_pulse: f64,
    /// Integrator feedback capacitor, F (paper: 1 pF).
    pub c_f: f64,
    /// Level-shifted pulse amplitude, V (paper: 0.1 V).
    pub v_pulse: f64,
}

impl Default for WbsDesign {
    fn default() -> Self {
        Self { nb: 8, i_max: 3.2e-6, t_pulse: T_PULSE_S, c_f: 1.0e-12, v_pulse: 0.1 }
    }
}

impl WbsDesign {
    /// Σ_{k=1..nb} 2^-k = 1 − 2^-nb (Eq. 18).
    pub fn significance_sum(&self) -> f64 {
        1.0 - 2.0f64.powi(-(self.nb as i32))
    }

    /// Worst-case integrator swing over a full bit stream (Eq. 16/19), V.
    pub fn v_int_max(&self) -> f64 {
        self.i_max * self.t_pulse / self.c_f * self.significance_sum()
    }

    /// Capacitor required for a target output swing (Eq. 19 inverted), F.
    pub fn c_f_for_swing(&self, v_swing: f64) -> f64 {
        self.i_max * self.t_pulse / v_swing * self.significance_sum()
    }

    /// Worst-case bitline current implied by the pulse amplitude and the
    /// total wordline conductance (all devices at g_max, all bits high).
    pub fn i_max_for(&self, wordlines: usize, g_max: f64) -> f64 {
        self.v_pulse * wordlines as f64 * g_max
    }

    /// Resistance span the *single feedback memristor* alternative would
    /// need: M_f ∈ [2^-nb, 2^-1]·M_min ⇒ span ratio 2^(nb-1). The paper
    /// rejects this for nb = 8 (> two orders of magnitude).
    pub fn single_device_span(&self) -> f64 {
        2.0f64.powi(self.nb as i32 - 1)
    }

    /// The ratio-based scheme only needs each of M_f, M_i to cover
    /// √(2^(nb-1)) — within the TaOx 10× window for nb ≤ 8 when split
    /// across both devices (√128 ≈ 11.3 ≈ the paper's R_off/R_on = 10,
    /// with the residual absorbed by the integrator gain).
    pub fn ratio_device_span(&self) -> f64 {
        self.single_device_span().sqrt()
    }

    /// Latency of streaming one multi-bit input, s.
    pub fn stream_latency(&self) -> f64 {
        f64::from(self.nb) * self.t_pulse
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_operating_point_swing() {
        // Eq. 19 at I_max = 3.2 µA, T_s = 50 ns, C_f = 1 pF: ≈ 0.16 V
        // (times 1 − 2^-8).
        let d = WbsDesign::default();
        let v = d.v_int_max();
        assert!((v - 0.16 * (1.0 - 1.0 / 256.0)).abs() < 1e-4, "{v}");
        assert!(v < 0.55, "swing must stay inside the integrator range");
    }

    #[test]
    fn geometric_series_eq18() {
        for nb in 1..=12 {
            let d = WbsDesign { nb, ..WbsDesign::default() };
            let direct: f64 = (1..=nb).map(|k| 2.0f64.powi(-(k as i32))).sum();
            assert!((d.significance_sum() - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn capacitor_sizing_roundtrips() {
        let d = WbsDesign::default();
        let c = d.c_f_for_swing(d.v_int_max());
        assert!((c - d.c_f).abs() < 1e-18, "{c}");
    }

    #[test]
    fn worst_case_current_matches_paper_order() {
        // 128 wordlines at g_max = 500 nS driven at 0.1 V → 6.4 µA bound;
        // the paper's 3.2 µA corresponds to ~50% simultaneous activity.
        let d = WbsDesign::default();
        let i = d.i_max_for(128, 5.0e-7);
        assert!((i - 6.4e-6).abs() < 1e-9);
        assert!(d.i_max <= i);
    }

    #[test]
    fn single_feedback_device_is_infeasible_at_8_bits() {
        let d = WbsDesign::default();
        assert!(d.single_device_span() > 100.0); // > two orders of magnitude
        // ratio-based: each device within ~order-of-magnitude window
        assert!(d.ratio_device_span() < 12.0);
    }

    #[test]
    fn stream_latency_is_nb_cycles() {
        let d = WbsDesign::default();
        assert!((d.stream_latency() - 8.0 * 50.0e-9).abs() < 1e-15);
    }
}
