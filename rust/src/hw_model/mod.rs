//! Analytical 65 nm @ 20 MHz architecture model (§VI-C, §VI-D).
//!
//! The paper measures latency/power from a Cadence Genus + Virtuoso
//! mixed-signal simulation of the synthesized design; we rebuild that
//! evaluation as an explicit component-level model: every term is a
//! named constant (`components`) times an architecture count, and the
//! calibration anchors — the published operating points — are asserted by
//! tests:
//!
//! * step latency 1.85 µs and throughput 19,305 seq/s (28×100×10, 8-bit,
//!   tiled) — `latency`;
//! * 15 GOPS, 48.62 mW inference / 56.97 mW training, 312 GOPS/W —
//!   `power` + `throughput`;
//! * 29× energy-efficiency over the digital CMOS MiRU — `digital_baseline`.
//!
//! The *shapes* (scaling trends, tiling crossovers, breakdown proportions)
//! then follow from the counts, which is what Fig. 5(c,d) plot.

pub mod components;
mod digital_baseline;
mod latency;
mod power;
mod throughput;
mod wbs;

pub use digital_baseline::{digital_energy_per_op_pj, digital_gops_per_watt, efficiency_gain};
pub use latency::{step_cycles, step_latency_s, seq_latency_s, CycleBreakdown};
pub use power::{PowerBreakdown, PowerMode};
pub use throughput::{gops, gops_per_watt, ops_per_step, pj_per_op, seqs_per_second};
pub use wbs::WbsDesign;

/// Architecture instantiation the model evaluates (mirrors `NetConfig`
/// plus the physical knobs of §VI).
#[derive(Clone, Copy, Debug)]
pub struct ArchConfig {
    pub nx: usize,
    pub nh: usize,
    pub ny: usize,
    pub nt: usize,
    /// WBS input precision (bits streamed per step).
    pub nb: u32,
    /// ADC resolution.
    pub adc_bits: u32,
    /// Hidden-layer tiles working concurrently (paper: 4–16).
    pub tiles: usize,
    /// Whether hidden-state interpolation is tiled at all (Fig. 5c dotted
    /// lines are `false`).
    pub tiling: bool,
    /// System clock, Hz (paper: 20 MHz).
    pub clock_hz: f64,
}

impl ArchConfig {
    /// The paper's primary operating point: 28×100×10 @ 20 MHz, 8-bit.
    pub fn paper_default() -> Self {
        Self {
            nx: 28,
            nh: 100,
            ny: 10,
            nt: 28,
            nb: 8,
            adc_bits: 8,
            tiles: 8,
            tiling: true,
            clock_hz: 20.0e6,
        }
    }

    pub fn with_nh(mut self, nh: usize) -> Self {
        self.nh = nh;
        self
    }
    pub fn with_nb(mut self, nb: u32) -> Self {
        self.nb = nb;
        self
    }
    pub fn with_tiles(mut self, tiles: usize, tiling: bool) -> Self {
        self.tiles = tiles;
        self.tiling = tiling;
        self
    }

    /// Total tunable memristors: differential pairs over both crossbars,
    /// 2·[(nx+nh)·nh + nh·ny] (§IV-B1).
    pub fn memristor_count(&self) -> usize {
        2 * ((self.nx + self.nh) * self.nh + self.nh * self.ny)
    }

    /// Shared high-speed ADCs per layer: one when the layer has < 128
    /// bitlines (§VI-D), else one per 128.
    pub fn adc_count(&self) -> usize {
        let per_layer = |n: usize| n.div_ceil(128);
        per_layer(self.nh) + per_layer(self.ny)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memristor_count_matches_section_iv() {
        let a = ArchConfig::paper_default();
        assert_eq!(a.memristor_count(), 2 * ((28 + 100) * 100 + 100 * 10));
    }

    #[test]
    fn adc_policy() {
        let a = ArchConfig::paper_default();
        assert_eq!(a.adc_count(), 2); // one per layer under 128 bitlines
        assert_eq!(a.with_nh(256).adc_count(), 3); // 2 for hidden + 1 readout
        assert_eq!(a.with_nh(512).adc_count(), 5);
    }
}
