//! Fig. 5(c) — impact of network scaling (n_h) and bit precision (n_b) on
//! per-step latency, with and without hidden-layer tiling.

use anyhow::Result;

use crate::hw_model::{step_latency_s, ArchConfig};

use super::Report;

pub fn run_fig5c() -> Result<Report> {
    let mut report = Report::new("fig5c");
    report.line("Fig.5(c) — step latency (µs) vs hidden size and bit precision");
    report.line("(dotted lines of the paper = untiled: serialized interpolation dominates)");
    report.blank();

    let nhs = [64usize, 100, 128, 256, 512];
    let nbs = [2u32, 4, 6, 8];

    report.line("tiled (tiles = ceil(nh/16), interpolation capped at 16 cycles):");
    report.line(format!(
        "{:>6} {}",
        "nh",
        nbs.iter().map(|nb| format!("{:>9}", format!("nb={nb}"))).collect::<String>()
    ));
    for &nh in &nhs {
        let row: String = nbs
            .iter()
            .map(|&nb| {
                let a = ArchConfig::paper_default()
                    .with_nh(nh)
                    .with_nb(nb)
                    .with_tiles(nh.div_ceil(16), true);
                format!("{:>9.2}", step_latency_s(&a) * 1e6)
            })
            .collect();
        report.line(format!("{nh:>6} {row}"));
    }

    report.blank();
    report.line("untiled (single interpolation unit, dotted lines):");
    report.line(format!(
        "{:>6} {}",
        "nh",
        nbs.iter().map(|nb| format!("{:>9}", format!("nb={nb}"))).collect::<String>()
    ));
    for &nh in &nhs {
        let row: String = nbs
            .iter()
            .map(|&nb| {
                let a = ArchConfig::paper_default().with_nh(nh).with_nb(nb).with_tiles(1, false);
                format!("{:>9.2}", step_latency_s(&a) * 1e6)
            })
            .collect();
        report.line(format!("{nh:>6} {row}"));
    }

    // headline shape checks, reported inline
    let tiled = ArchConfig::paper_default();
    let frac = f64::from(tiled.nb) / crate::hw_model::step_cycles(&tiled).total() as f64;
    report.blank();
    report.line(format!(
        "at the paper's operating point: step latency {:.2} µs, WBS bits are {:.0}% of the step (paper: ~one-third when tiled)",
        step_latency_s(&tiled) * 1e6,
        100.0 * frac
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw_model::{step_cycles, ArchConfig};

    #[test]
    fn tiling_flattens_nh_scaling() {
        // untiled latency grows ~linearly in nh; tiled stays near-flat.
        let lat = |nh: usize, tiled: bool| {
            let tiles = if tiled { nh.div_ceil(16) } else { 1 };
            step_latency_s(&ArchConfig::paper_default().with_nh(nh).with_tiles(tiles, tiled))
        };
        let untiled_ratio = lat(512, false) / lat(64, false);
        let tiled_ratio = lat(512, true) / lat(64, true);
        assert!(untiled_ratio > 4.0, "{untiled_ratio}");
        assert!(tiled_ratio < 1.5, "{tiled_ratio}");
    }

    #[test]
    fn precision_fraction_larger_when_tiled() {
        let frac = |tiled: bool| {
            let a = ArchConfig::paper_default().with_tiles(if tiled { 8 } else { 1 }, tiled);
            f64::from(a.nb) / step_cycles(&a).total() as f64
        };
        assert!(frac(true) > 2.0 * frac(false));
    }
}
