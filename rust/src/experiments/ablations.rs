//! Ablations called out in DESIGN.md §5: replay on/off (catastrophic
//! forgetting), ζ sparsification on/off (accuracy cost of the write
//! savings), and the xorshift-vs-LFSR reservoir-index study that backs
//! the paper's §IV-A1 design choice.

use anyhow::Result;

use crate::config::{Manifest, NetConfig, RunConfig};
use crate::coordinator::{ContinualTrainer, HardwareEngine, XlaDfaEngine};
use crate::data::permuted_task_stream;
use crate::device::DeviceParams;
use crate::replay::{ReservoirDecision, ReservoirSampler};
use crate::rng::Lfsr16;
use crate::runtime::{ModelBundle, Runtime};

use super::Report;

/// Replay on/off on the permuted stream (software DFA engine).
pub fn run_ablation_replay(
    rt: &Runtime,
    manifest: &Manifest,
    run: &RunConfig,
) -> Result<Report> {
    let cfg = NetConfig::PMNIST100;
    let bundle = ModelBundle::load(rt, manifest, cfg)?;
    let stream =
        permuted_task_stream(run.num_tasks, run.train_per_task, run.test_per_task, run.seed);
    let mut report = Report::new("ablation_replay");
    report.line(format!(
        "Ablation: experience replay on/off (sw-DFA, pmnist100, {} tasks x {})",
        run.num_tasks, run.train_per_task
    ));
    for replay in [true, false] {
        let mut eng = XlaDfaEngine::new(&bundle, run.lam, run.beta, run.lr, run.seed);
        let mut tr = ContinualTrainer::new(
            &stream,
            RunConfig { replay, ..run.clone() },
            cfg.b_train,
            cfg.b_eval,
        );
        tr.run_all(&mut eng)?;
        report.line(format!(
            "  replay={replay:<5} curve={:?} final MA={:.3} forgetting={:.3}",
            tr.matrix.curve().iter().map(|a| (a * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
            tr.matrix.mean_final(),
            tr.matrix.forgetting()
        ));
    }
    report.line("paper: replay buffers are what keep degradation graceful (§VI-A)".to_string());
    Ok(report)
}

/// ζ on/off on the *hardware* engine: accuracy cost of the 47% write cut.
pub fn run_ablation_zeta(rt: &Runtime, manifest: &Manifest, run: &RunConfig) -> Result<Report> {
    let cfg = NetConfig::PMNIST100;
    let bundle = ModelBundle::load(rt, manifest, cfg)?;
    let stream =
        permuted_task_stream(run.num_tasks, run.train_per_task, run.test_per_task, run.seed);
    let mut report = Report::new("ablation_zeta");
    report.line(format!(
        "Ablation: ζ gradient sparsification on the hardware engine ({} tasks x {})",
        run.num_tasks, run.train_per_task
    ));
    for (label, dense) in [("zeta(keep=0.53)", false), ("dense", true)] {
        let mut eng =
            HardwareEngine::new(&bundle, run.lam, run.beta, run.lr, DeviceParams::default(), run.seed);
        eng.use_dense = dense;
        let mut tr = ContinualTrainer::new(&stream, run.clone(), cfg.b_train, cfg.b_eval);
        tr.run_all(&mut eng)?;
        report.line(format!(
            "  {label:<16} final MA={:.3} forgetting={:.3} writes={} ({:.0}/step)",
            tr.matrix.mean_final(),
            tr.matrix.forgetting(),
            eng.programmer.total.writes,
            eng.programmer.writes_per_step()
        ));
    }
    report.line(
        "paper: ζ at ~47% write reduction costs no accuracy; cutting to keep≈0.30 costs 3–4% MA"
            .to_string(),
    );
    Ok(report)
}

/// Reservoir-index uniformity: xorshift (the paper's choice) vs an LFSR
/// driving the same modulus unit. Measures the worst per-position survival
/// deviation over many small streams.
pub fn sampler_bias(runs: u32, k: usize, n: usize) -> (f64, f64) {
    let survival_dev = |use_lfsr: bool| -> f64 {
        let mut survive = vec![0u32; n];
        for seed in 0..runs {
            let mut lfsr = Lfsr16::new(1 + seed as u16);
            let mut xs = ReservoirSampler::new(k, 1000 + seed);
            let mut slots: Vec<usize> = vec![usize::MAX; k];
            for pos in 0..n {
                let dec = if use_lfsr {
                    // LFSR word folded by the same modulus unit
                    let i = (pos + 1) as u32;
                    if pos < k {
                        ReservoirDecision::Store(pos)
                    } else {
                        let j = (u32::from(lfsr.next_u16()) % i) + 1;
                        if (j as usize) <= k {
                            ReservoirDecision::Store((j - 1) as usize)
                        } else {
                            ReservoirDecision::Discard
                        }
                    }
                } else {
                    xs.offer()
                };
                if let ReservoirDecision::Store(j) = dec {
                    slots[j] = pos;
                }
            }
            for &p in &slots {
                if p != usize::MAX {
                    survive[p] += 1;
                }
            }
        }
        let expect = f64::from(runs) * k as f64 / n as f64;
        survive
            .iter()
            .map(|&c| (f64::from(c) - expect).abs() / expect)
            .fold(0.0, f64::max)
    };
    (survival_dev(false), survival_dev(true))
}

pub fn run_ablation_sampler() -> Result<Report> {
    let mut report = Report::new("ablation_sampler");
    report.line("Ablation: reservoir index source — xorshift32 vs 16-bit LFSR (§IV-A1)");
    let (xs, lf) = sampler_bias(4000, 8, 40);
    report.line(format!(
        "  max per-position survival deviation over 4000 streams (k=8, n=40):"
    ));
    report.line(format!("    xorshift32: {:.3}", xs));
    report.line(format!("    LFSR16:     {:.3}", lf));
    report.line(format!(
        "  paper: xorshift produces decorrelated, uniform, unbiased indices, unlike LFSR ({})",
        if lf > xs { "confirmed" } else { "not reproduced at this scale" }
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_is_at_least_as_uniform_as_lfsr() {
        let (xs, lf) = sampler_bias(1500, 8, 40);
        // xorshift should not be *worse*; typically the LFSR's correlated
        // low-period structure shows a larger worst-position deviation.
        assert!(xs <= lf + 0.05, "xorshift {xs} vs lfsr {lf}");
        assert!(xs < 0.2, "xorshift deviation too large: {xs}");
    }
}
