//! Fig. 5(a) — average percentage error of the VMM during replay under
//! uniform (truncation) vs stochastic quantization, as a function of the
//! replay-storage bit width.
//!
//! Protocol: draw feature vectors from the synthetic digit distribution,
//! store them through each quantizer, and drive the *bitline current* of a
//! memristive crossbar (positive conductances — the differential
//! subtraction happens after sensing, Eq. 7). At the bitline, truncation's
//! systematic half-LSB bias accumulates coherently across all wordlines,
//! while stochastic rounding's zero-mean error grows only as √n — the
//! paper's claim that stochastic quantization keeps the replay VMM error
//! below ~5% down to 4 bits while truncation degrades much faster.

use anyhow::Result;

use crate::data::synthetic_mnist;
use crate::linalg::Mat;
use crate::quant::{dequantize, stochastic_round, uniform_truncate};
use crate::rng::{GaussianRng, Lfsr16};

use super::Report;

/// Mean relative bitline-current error (%) for both quantizers.
pub fn vmm_errors(bits: &[u32], n_samples: usize, seed: u64) -> Vec<(u32, f64, f64)> {
    let samples = synthetic_mnist(n_samples, seed);
    let dim = 784;
    let n_out = 64;
    // positive conductances in the normalized window [g_min, g_max] —
    // the physical quantity the quantized pulses multiply into.
    let mut wrng = GaussianRng::new(seed ^ 0xFACE);
    let g = Mat::from_fn(dim, n_out, |_, _| wrng.uniform_in(0.1, 1.0));

    let mut lfsr = Lfsr16::new(0x7777);
    let mut out = Vec::new();
    for &nb in bits {
        let (mut err_s, mut err_u) = (0.0f64, 0.0f64);
        let mut n_terms = 0usize;
        for ex in &samples {
            let x = Mat::from_vec(1, dim, ex.features.clone());
            let exact = x.matmul(&g);
            let xs = Mat::from_vec(
                1,
                dim,
                ex.features
                    .iter()
                    .map(|&v| {
                        let r = lfsr.next_unit();
                        dequantize(stochastic_round(v.min(0.999), r, nb), nb)
                    })
                    .collect(),
            );
            let xu = Mat::from_vec(
                1,
                dim,
                ex.features.iter().map(|&v| dequantize(uniform_truncate(v, nb), nb)).collect(),
            );
            let is = xs.matmul(&g);
            let iu = xu.matmul(&g);
            for j in 0..n_out {
                let denom = f64::from(exact.at(0, j)).max(1e-9);
                err_s += f64::from((is.at(0, j) - exact.at(0, j)).abs()) / denom;
                err_u += f64::from((iu.at(0, j) - exact.at(0, j)).abs()) / denom;
                n_terms += 1;
            }
        }
        out.push((nb, 100.0 * err_s / n_terms as f64, 100.0 * err_u / n_terms as f64));
    }
    out
}

pub fn run_fig5a(n_samples: usize, seed: u64) -> Result<Report> {
    let mut report = Report::new("fig5a");
    report.line("Fig.5(a) — VMM % error during replay: stochastic vs uniform quantization");
    report.line(format!("{:>5} {:>14} {:>14} {:>8}", "bits", "stochastic(%)", "uniform(%)", "ratio"));
    let rows = vmm_errors(&[2, 3, 4, 5, 6, 7, 8], n_samples, seed);
    for (nb, s, u) in &rows {
        report.line(format!("{nb:>5} {s:>14.2} {u:>14.2} {:>8.2}", u / s.max(1e-12)));
    }
    let four_bit = rows.iter().find(|r| r.0 == 4).unwrap();
    report.blank();
    report.line(format!(
        "paper: stochastic error stays < ~5% at 4 bits; measured {:.2}% (uniform {:.2}%)",
        four_bit.1, four_bit.2
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stochastic_beats_uniform_at_every_width() {
        let rows = vmm_errors(&[2, 4, 6, 8], 6, 0);
        for (nb, s, u) in rows {
            assert!(s < u, "nb={nb}: stochastic {s} vs uniform {u}");
        }
    }

    #[test]
    fn four_bit_stochastic_error_under_five_percent() {
        let rows = vmm_errors(&[4], 10, 1);
        assert!(rows[0].1 < 5.0, "{:?}", rows[0]);
    }

    #[test]
    fn error_decreases_with_bits() {
        let rows = vmm_errors(&[2, 4, 8], 6, 2);
        assert!(rows[0].1 > rows[1].1 && rows[1].1 > rows[2].1, "{rows:?}");
    }
}
