//! The paper's headline metrics: 15 GOPS @ 48.62 mW → 312 GOPS/W
//! (3.21 pJ/op), 19,305 seq/s, 29× over the digital CMOS MiRU, and the
//! 12.2-year device-aware lifespan.

use anyhow::Result;

use crate::device::{lifespan_years, SECONDS_PER_YEAR};
use crate::hw_model::{
    digital_energy_per_op_pj, digital_gops_per_watt, efficiency_gain, gops, gops_per_watt,
    pj_per_op, seqs_per_second, step_latency_s, ArchConfig, PowerBreakdown, PowerMode,
};

use super::Report;

pub fn run_headline() -> Result<Report> {
    let a = ArchConfig::paper_default();
    let mut report = Report::new("headline");
    report.line("M2RU headline metrics (28x100x10 @ 20 MHz, 65 nm)");
    report.line(format!("{:<38} {:>12} {:>12}", "metric", "paper", "this repo"));

    let rows: Vec<(&str, String, String)> = vec![
        ("throughput (GOPS)", "15".into(), format!("{:.2}", gops(&a))),
        (
            "inference power (mW)",
            "48.62".into(),
            format!("{:.2}", PowerBreakdown::for_config(&a, PowerMode::Inference).total_mw()),
        ),
        (
            "training power (mW)",
            "56.97".into(),
            format!("{:.2}", PowerBreakdown::for_config(&a, PowerMode::Training).total_mw()),
        ),
        (
            "energy efficiency (GOPS/W)",
            "312".into(),
            format!("{:.1}", gops_per_watt(&a, PowerMode::Inference)),
        ),
        ("energy (pJ/op)", "3.21".into(), format!("{:.2}", pj_per_op(&a, PowerMode::Inference))),
        ("step latency (µs)", "1.85".into(), format!("{:.2}", step_latency_s(&a) * 1e6)),
        ("sequences/s", "19305".into(), format!("{:.0}", seqs_per_second(&a))),
        (
            "digital baseline (pJ/op)",
            "~93".into(),
            format!("{:.1}", digital_energy_per_op_pj()),
        ),
        (
            "digital baseline (GOPS/W)",
            "~10.8".into(),
            format!("{:.2}", digital_gops_per_watt()),
        ),
        ("efficiency gain vs digital", "29x".into(), format!("{:.1}x", efficiency_gain(&a))),
    ];
    for (m, paper, ours) in rows {
        report.line(format!("{m:<38} {paper:>12} {ours:>12}"));
    }

    // lifespan arithmetic at the paper's anchor
    let anchor = 1.0e9 / (6.9 * SECONDS_PER_YEAR) / 1000.0;
    report.blank();
    report.line(format!(
        "lifespan @1ms updates, 1e9 endurance: dense {:.1}y; with ζ (measured ~47% write cut) {:.1}y (paper: 6.9y → 12.2y)",
        lifespan_years(1_000_000_000, anchor, 1000.0),
        lifespan_years(1_000_000_000, anchor * 0.53, 1000.0),
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_values_within_five_percent_of_paper() {
        let a = ArchConfig::paper_default();
        assert!((gops(&a) - 15.0).abs() / 15.0 < 0.05);
        assert!((gops_per_watt(&a, PowerMode::Inference) - 312.0).abs() / 312.0 < 0.05);
        assert!((seqs_per_second(&a) - 19305.0).abs() / 19305.0 < 0.01);
        assert!((efficiency_gain(&a) - 29.0).abs() / 29.0 < 0.06);
    }
}
