//! Fig. 5(d) — power breakdown across the core units of the accelerator
//! (n_h = 100, 20 MHz).

use anyhow::Result;

use crate::hw_model::{ArchConfig, PowerBreakdown, PowerMode};

use super::Report;

pub fn run_fig5d() -> Result<Report> {
    let mut report = Report::new("fig5d");
    let a = ArchConfig::paper_default();
    report.line("Fig.5(d) — power breakdown, n_h=100 @ 20 MHz, 65 nm");
    for (mode, label, paper) in [
        (PowerMode::Inference, "inference", 48.62),
        (PowerMode::Training, "training", 56.97),
    ] {
        let p = PowerBreakdown::for_config(&a, mode);
        report.blank();
        report.line(format!("{label} (paper total: {paper} mW):"));
        for (name, mw, frac) in p.rows() {
            report.line(format!("  {name:<42} {mw:>9.3} mW  {:>5.1}%", 100.0 * frac));
        }
        report.line(format!("  {:<42} {:>9.3} mW", "TOTAL", p.total_mw()));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_contains_both_modes() {
        let r = run_fig5d().unwrap();
        let text = r.lines.join("\n");
        assert!(text.contains("inference") && text.contains("training"));
        assert!(text.contains("ADC"));
    }
}
