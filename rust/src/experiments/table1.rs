//! Table I — comparison with prior memristor-based RNN accelerators.
//!
//! The prior-work rows are literature values (the paper itself calls the
//! table "a high-level reference template ... rather than an absolute
//! comparison"); the "This work" row is *computed* from our hardware
//! model.

use anyhow::Result;

use crate::hw_model::{
    seqs_per_second, step_latency_s, ArchConfig, PowerBreakdown, PowerMode,
};

use super::Report;

struct Row {
    algorithm: &'static str,
    freq: &'static str,
    network: &'static str,
    power: String,
    dataset: &'static str,
    latency: String,
    topology: &'static str,
    node: &'static str,
    cl: &'static str,
    training: &'static str,
}

pub fn run_table1() -> Result<Report> {
    let a = ArchConfig::paper_default();
    let p = PowerBreakdown::for_config(&a, PowerMode::Inference);

    let rows = vec![
        Row {
            algorithm: "M-GRU [42]",
            freq: "-",
            network: "6x8k x36",
            power: "173.65 mW".into(),
            dataset: "CASIA",
            latency: "45 ns/cell".into(),
            topology: "GRU",
            node: "40 nm",
            cl: "No",
            training: "Off-chip",
        },
        Row {
            algorithm: "MDGN [43]",
            freq: "200 MHz",
            network: "3x150x1",
            power: "25.07 mW".into(),
            dataset: "CALCE",
            latency: "1.22 s".into(),
            topology: "GRU",
            node: "-",
            cl: "No",
            training: "Off-chip",
        },
        Row {
            algorithm: "HGRU [10]",
            freq: "-",
            network: "28x128x10",
            power: "-".into(),
            dataset: "MNIST & IMDB",
            latency: "5.14 us".into(),
            topology: "Minimal GRU",
            node: "-",
            cl: "No",
            training: "Off-chip",
        },
        Row {
            algorithm: "MBLSTM [11]",
            freq: "-",
            network: "-",
            power: "<1.5 W".into(),
            dataset: "MNIST & IMDB",
            latency: "-".into(),
            topology: "LSTM",
            node: "-",
            cl: "No",
            training: "On-chip",
        },
        Row {
            algorithm: "This work",
            freq: "20 MHz",
            network: "28x100x10",
            power: format!("{:.2} mW", p.total_mw()),
            dataset: "MNIST & CIFAR-10 (synthetic)",
            latency: format!("{:.2} us", step_latency_s(&a) * 1e6),
            topology: "MiRU",
            node: "65 nm",
            cl: "DIL-CL",
            training: "On-chip",
        },
    ];

    let mut report = Report::new("table1");
    report.line("Table I — comparison with memristor-based RNN ASIC accelerators");
    report.line(format!(
        "{:<12} {:>8} {:>11} {:>11} {:>28} {:>11} {:>12} {:>6} {:>7} {:>9}",
        "Algorithm", "Freq", "Network", "Power", "Dataset", "Latency", "Topology", "Node", "CL", "Training"
    ));
    for r in &rows {
        report.line(format!(
            "{:<12} {:>8} {:>11} {:>11} {:>28} {:>11} {:>12} {:>6} {:>7} {:>9}",
            r.algorithm, r.freq, r.network, r.power, r.dataset, r.latency, r.topology, r.node, r.cl, r.training
        ));
    }
    report.blank();
    report.line(format!(
        "'This work' row computed from hw_model: {:.2} mW, {:.2} µs/step, {:.0} seq/s",
        p.total_mw(),
        step_latency_s(&a) * 1e6,
        seqs_per_second(&a)
    ));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn this_work_row_matches_paper_operating_point() {
        let r = run_table1().unwrap();
        let text = r.lines.join("\n");
        assert!(text.contains("48.6"), "{text}"); // 48.62 mW
        assert!(text.contains("1.85 us"), "{text}");
        assert!(text.contains("DIL-CL"));
        assert!(text.contains("On-chip"));
    }
}
