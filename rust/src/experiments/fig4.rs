//! Fig. 4 — average test accuracy after each task, for the software models
//! (Adam, DFA) and the M2RU hardware model, on permuted MNIST and split
//! CIFAR-10 features, with n_h ∈ {100, 256}.

use anyhow::{bail, Result};

use crate::config::{Manifest, NetConfig, RunConfig};
use crate::coordinator::{ContinualTrainer, Engine, HardwareEngine, XlaAdamEngine, XlaDfaEngine};
use crate::data::{feature_task_stream, permuted_task_stream, TaskStream};
use crate::device::DeviceParams;
use crate::runtime::{ModelBundle, Runtime};

use super::Report;

#[derive(Clone, Debug)]
pub struct Fig4Options {
    pub dataset: String,
    pub nh: usize,
    /// comma-set of curves: adam,dfa,hw
    pub engines: Vec<String>,
    pub run: RunConfig,
}

impl Default for Fig4Options {
    fn default() -> Self {
        Self {
            dataset: "pmnist".into(),
            nh: 100,
            engines: vec!["adam".into(), "dfa".into(), "hw".into()],
            run: RunConfig::default(),
        }
    }
}

pub fn stream_for(opts: &Fig4Options) -> Result<(TaskStream, NetConfig)> {
    let cfg_name = match (opts.dataset.as_str(), opts.nh) {
        ("pmnist", 100) => "pmnist100",
        ("pmnist", 256) => "pmnist256",
        ("cifarfeat", 100) => "cifar100",
        ("cifarfeat", 256) => "cifar256",
        (d, nh) => bail!("no artifact config for dataset={d} nh={nh}"),
    };
    let cfg = NetConfig::by_name(cfg_name).unwrap();
    let r = &opts.run;
    let stream = match opts.dataset.as_str() {
        "pmnist" => permuted_task_stream(r.num_tasks, r.train_per_task, r.test_per_task, r.seed),
        "cifarfeat" => {
            feature_task_stream(r.num_tasks, r.train_per_task, r.test_per_task, 0.8, r.seed)
        }
        other => bail!("unknown dataset {other}"),
    };
    Ok((stream, cfg))
}

fn run_curve(
    report: &mut Report,
    label: &str,
    engine: &mut dyn Engine,
    stream: &TaskStream,
    cfg: &NetConfig,
    run: &RunConfig,
) -> Result<Vec<f32>> {
    let mut trainer = ContinualTrainer::new(stream, run.clone(), cfg.b_train, cfg.b_eval);
    let results = trainer.run_all(engine)?;
    let curve: Vec<f32> = results.iter().map(|r| r.mean_acc).collect();
    let pts: Vec<String> = curve.iter().enumerate().map(|(t, a)| format!("T{}={:.3}", t + 1, a)).collect();
    report.line(format!(
        "  {label:<10} MA: {}  final={:.3} forgetting={:.3}",
        pts.join(" "),
        curve.last().copied().unwrap_or(0.0),
        trainer.matrix.forgetting()
    ));
    Ok(curve)
}

/// Run the Fig. 4 panel selected by `opts`. Returns (report, curves by
/// engine label) so integration tests can assert the shapes.
pub fn run_fig4(
    rt: &Runtime,
    manifest: &Manifest,
    opts: &Fig4Options,
) -> Result<(Report, Vec<(String, Vec<f32>)>)> {
    let (stream, cfg) = stream_for(opts)?;
    let mut report = Report::new(format!("fig4_{}_{}", opts.dataset, opts.nh));
    report.line(format!(
        "Fig.4 [{} n_h={}] tasks={} train/task={} replay/task={} epochs={} (paper protocol: DIL, shared head)",
        opts.dataset, opts.nh, opts.run.num_tasks, opts.run.train_per_task,
        opts.run.replay_per_task, opts.run.epochs
    ));
    let bundle = ModelBundle::load(rt, manifest, cfg)?;
    let r = &opts.run;
    let mut curves = Vec::new();
    for eng in &opts.engines {
        let curve = match eng.as_str() {
            "adam" => {
                // BPTT+Adam wants a smaller lr than DFA-SGD
                let mut e = XlaAdamEngine::new(&bundle, r.lam, r.beta, r.lr * 0.05, r.seed);
                run_curve(&mut report, "sw-adam", &mut e, &stream, &cfg, r)?
            }
            "dfa" => {
                let mut e = XlaDfaEngine::new(&bundle, r.lam, r.beta, r.lr, r.seed);
                run_curve(&mut report, "sw-dfa", &mut e, &stream, &cfg, r)?
            }
            "hw" => {
                let mut e = HardwareEngine::new(
                    &bundle,
                    r.lam,
                    r.beta,
                    r.lr,
                    DeviceParams::default(),
                    r.seed,
                );
                run_curve(&mut report, "m2ru-hw", &mut e, &stream, &cfg, r)?
            }
            other => bail!("unknown engine `{other}` (adam|dfa|hw)"),
        };
        curves.push((eng.clone(), curve));
    }
    Ok((report, curves))
}
