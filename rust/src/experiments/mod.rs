//! Regeneration of every figure and table in the paper's evaluation
//! (DESIGN.md §5 experiment index).
//!
//! Each submodule produces the rows/series of one paper artifact and
//! returns a [`Report`]; the CLI (`m2ru experiment <id>`) and the bench
//! harness both dispatch here. Reports are printed and archived under
//! `results/`.

mod ablations;
mod fault;
mod fig4;
mod fig5a;
mod fig5b;
mod fig5c;
mod fig5d;
mod headline;
mod table1;

pub use ablations::{run_ablation_replay, run_ablation_sampler, run_ablation_zeta, sampler_bias};
pub use fault::{accuracy_with_frozen, run_fault};
pub use fig4::{run_fig4, Fig4Options};
pub use fig5a::run_fig5a;
pub use fig5b::{run_fig5b, Fig5bOptions};
pub use fig5c::run_fig5c;
pub use fig5d::run_fig5d;
pub use headline::run_headline;
pub use table1::run_table1;

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// A text report: printed to stdout and archived under `results/<id>.txt`.
#[derive(Clone, Debug)]
pub struct Report {
    pub id: String,
    pub lines: Vec<String>,
}

impl Report {
    pub fn new(id: impl Into<String>) -> Self {
        Self { id: id.into(), lines: Vec::new() }
    }

    pub fn line(&mut self, s: impl Into<String>) {
        let s = s.into();
        println!("{s}");
        self.lines.push(s);
    }

    pub fn blank(&mut self) {
        self.line("");
    }

    /// Write the archived copy.
    pub fn save(&self, results_dir: impl AsRef<Path>) -> Result<std::path::PathBuf> {
        let dir = results_dir.as_ref();
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(format!("{}.txt", self.id));
        let mut f = std::fs::File::create(&path)?;
        for l in &self.lines {
            writeln!(f, "{l}")?;
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_saves_lines() {
        let mut r = Report::new("unit_test_report");
        r.line("alpha");
        r.line("beta");
        let dir = std::env::temp_dir().join(format!("m2ru_results_{}", std::process::id()));
        let path = r.save(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "alpha\nbeta\n");
    }
}
