//! Fault-tolerance study: on-chip learning under frozen (elasticity-lost)
//! devices — the paper's §VI-B failure mode and its future-work lever
//! ("one could extend the lifespan ... if frozen memristors are used for
//! learning"). Sweeps the frozen fraction injected *before* training and
//! measures how much of the learning capability survives.

use anyhow::Result;

use crate::config::{Manifest, NetConfig, RunConfig};
use crate::coordinator::{ContinualTrainer, HardwareEngine};
use crate::data::permuted_task_stream;
use crate::device::DeviceParams;
use crate::runtime::{ModelBundle, Runtime};

use super::Report;

/// Train the hardware engine with `frac` of devices frozen; return MA.
pub fn accuracy_with_frozen(
    rt: &Runtime,
    manifest: &Manifest,
    run: &RunConfig,
    frac: f64,
) -> Result<f32> {
    let cfg = NetConfig::PMNIST100;
    let bundle = ModelBundle::load(rt, manifest, cfg)?;
    let stream =
        permuted_task_stream(run.num_tasks, run.train_per_task, run.test_per_task, run.seed);
    let mut eng =
        HardwareEngine::new(&bundle, run.lam, run.beta, run.lr, DeviceParams::default(), run.seed);
    eng.xbar_hidden.freeze_fraction(frac);
    eng.xbar_out.freeze_fraction(frac);
    let mut tr = ContinualTrainer::new(&stream, run.clone(), cfg.b_train, cfg.b_eval);
    tr.run_all(&mut eng)?;
    Ok(tr.matrix.mean_final())
}

pub fn run_fault(rt: &Runtime, manifest: &Manifest, run: &RunConfig) -> Result<Report> {
    let mut report = Report::new("fault");
    report.line(format!(
        "Fault tolerance: frozen-device sweep (hw engine, pmnist100, {} task(s) x {})",
        run.num_tasks, run.train_per_task
    ));
    report.line(format!("{:>10} {:>10}", "frozen", "final MA"));
    let mut accs = Vec::new();
    for frac in [0.0, 0.1, 0.25, 0.5] {
        let ma = accuracy_with_frozen(rt, manifest, run, frac)?;
        report.line(format!("{:>9.0}% {:>10.3}", frac * 100.0, ma));
        accs.push((frac, ma));
    }
    let (f0, a0) = accs[0];
    let degraded = accs.iter().find(|(_, a)| *a < 0.7 * a0).map(|(f, _)| *f);
    report.blank();
    report.line(format!(
        "graceful degradation: {} (baseline {:.3} at {:.0}% frozen; first >30% drop at {})",
        if degraded.map_or(true, |f| f >= 0.25) { "yes" } else { "no" },
        a0,
        f0 * 100.0,
        degraded.map_or("never".to_string(), |f| format!("{:.0}%", f * 100.0)),
    ));
    Ok(report)
}
