//! Fig. 5(b) — CDF of memristor writes before/after gradient
//! sparsification, overstressed fraction at the endurance horizon, and the
//! projected lifespan (paper: 6.9 → 12.2 years at 1 ms updates, 10⁹
//! endurance, ~47% write reduction at ζ keep ≈ 53%).

use anyhow::Result;

use crate::config::{NetConfig, RunConfig};
use crate::coordinator::{ContinualTrainer, HardwareEngine};
use crate::data::permuted_task_stream;
use crate::device::{lifespan_years, DeviceParams, EnduranceReport, SECONDS_PER_YEAR};
use crate::runtime::{ModelBundle, Runtime};

use super::Report;

#[derive(Clone, Debug)]
pub struct Fig5bOptions {
    pub run: RunConfig,
    /// endurance used for the overstress projection.
    pub endurance: u64,
    /// learning-event rate (paper: 1 kHz, "1 ms").
    pub update_rate_hz: f64,
}

impl Default for Fig5bOptions {
    fn default() -> Self {
        Self {
            run: RunConfig {
                num_tasks: 2,
                train_per_task: 320,
                test_per_task: 100,
                epochs: 1,
                ..RunConfig::default()
            },
            endurance: 1_000_000_000,
            update_rate_hz: 1000.0,
        }
    }
}

/// Run the continual workload once with dense deltas and once with ζ,
/// collecting per-device write counters from the hardware engine.
pub fn measure_writes(
    rt: &Runtime,
    manifest: &crate::config::Manifest,
    opts: &Fig5bOptions,
) -> Result<(EnduranceReport, EnduranceReport)> {
    let cfg = NetConfig::PMNIST100;
    let bundle = ModelBundle::load(rt, manifest, cfg)?;
    let r = &opts.run;
    let stream = permuted_task_stream(r.num_tasks, r.train_per_task, r.test_per_task, r.seed);

    let run_once = |dense: bool| -> Result<EnduranceReport> {
        let mut eng =
            HardwareEngine::new(&bundle, r.lam, r.beta, r.lr, DeviceParams::default(), r.seed);
        eng.use_dense = dense;
        let mut trainer = ContinualTrainer::new(&stream, r.clone(), cfg.b_train, cfg.b_eval);
        trainer.run_all(&mut eng)?;
        // subtract the single initial programming write per device
        let counts: Vec<u64> =
            eng.write_counts().into_iter().map(|c| c.saturating_sub(1)).collect();
        Ok(EnduranceReport::from_counts(counts, eng.programmer.steps / 2))
    };

    Ok((run_once(true)?, run_once(false)?))
}

pub fn run_fig5b(
    rt: &Runtime,
    manifest: &crate::config::Manifest,
    opts: &Fig5bOptions,
) -> Result<Report> {
    let (dense, sparse) = measure_writes(rt, manifest, opts)?;
    let mut report = Report::new("fig5b");
    report.line("Fig.5(b) — memristor write CDF before/after gradient sparsification (ζ keep=0.53)");
    report.line(format!(
        "updates measured: dense={} sparse={}",
        dense.updates, sparse.updates
    ));
    report.line(format!(
        "mean writes/device: dense={:.1} sparse={:.1}  reduction={:.1}% (paper: ~47%)",
        dense.mean_writes,
        sparse.mean_writes,
        100.0 * (1.0 - sparse.mean_writes / dense.mean_writes)
    ));

    report.blank();
    report.line("write-count CDF (fraction of devices ≤ w):");
    report.line(format!("{:>12} {:>10} | {:>12} {:>10}", "dense w", "cdf", "sparse w", "cdf"));
    let dc = dense.cdf(10);
    let sc = sparse.cdf(10);
    for (d, s) in dc.iter().zip(&sc) {
        report.line(format!("{:>12} {:>10.3} | {:>12} {:>10.3}", d.0, d.1, s.0, s.1));
    }

    // Overstress projection: the paper plots the distributions forward to
    // the endurance limit; in the dense run most of the array crosses it
    // together (abrupt loss of elasticity — 58.28% overstressed at their
    // horizon), while the sparsified run crosses gradually. We project at
    // a horizon 2% past the dense-mean crossing and also report the
    // spread (p90−p10)/mean, which quantifies abrupt-vs-gradual.
    let horizon =
        (1.02 * opts.endurance as f64 / dense.writes_per_update().max(1e-12)) as u64;
    let over_dense = dense.overstressed_fraction(opts.endurance, horizon);
    let over_sparse = sparse.overstressed_fraction(opts.endurance, horizon);
    let spread = |r: &EnduranceReport| {
        let n = r.sorted_writes.len();
        let p10 = r.sorted_writes[n / 10] as f64;
        let p90 = r.sorted_writes[n * 9 / 10] as f64;
        (p90 - p10) / r.mean_writes.max(1e-12)
    };
    report.blank();
    report.line(format!(
        "projected overstressed fraction just past the dense-mean horizon: dense={:.1}% sparse={:.1}% (paper: 58.28% abrupt vs gradual)",
        100.0 * over_dense,
        100.0 * over_sparse
    ));
    report.line(format!(
        "write-count spread (p90-p10)/mean: dense={:.3} (abrupt step) sparse={:.3} (gradual)",
        spread(&dense),
        spread(&sparse)
    ));

    // Lifespan: the paper anchors the dense run at 6.9 years (1 ms events,
    // 1e9 endurance); the sparsification gain follows from the measured
    // write-pressure ratio. We report both the anchored projection and the
    // raw formula output for our measured pressures.
    let anchor_pressure = opts.endurance as f64 / (6.9 * SECONDS_PER_YEAR) / opts.update_rate_hz;
    let ratio = sparse.writes_per_update() / dense.writes_per_update().max(1e-12);
    let life_dense = lifespan_years(opts.endurance, anchor_pressure, opts.update_rate_hz);
    let life_sparse = lifespan_years(opts.endurance, anchor_pressure * ratio, opts.update_rate_hz);
    report.blank();
    report.line(format!(
        "lifespan (anchored at paper's 6.9y dense operating point): dense={life_dense:.1}y sparse={life_sparse:.1}y (paper: 6.9y → 12.2y)"
    ));
    report.line(format!(
        "raw measured write pressure: dense={:.3} sparse={:.3} writes/device/update (ratio {:.3})",
        dense.writes_per_update(),
        sparse.writes_per_update(),
        ratio
    ));
    Ok(report)
}
