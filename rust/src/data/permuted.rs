//! Permuted-MNIST task stream (the paper's §VI-A protocol).
//!
//! Task i applies a fixed random pixel permutation π_i to every image;
//! task 0 is the identity (plain digits). All tasks share the 10-way
//! output head and no task identity is revealed — domain-incremental.

use crate::rng::GaussianRng;

use super::synthetic_mnist::synthetic_mnist;
use super::{Example, TaskData, TaskStream};

/// Build `num_tasks` permuted tasks with `n_train`/`n_test` samples each.
pub fn permuted_task_stream(
    num_tasks: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> TaskStream {
    let mut perm_rng = GaussianRng::new(seed ^ 0xA5A5_5A5A);
    let mut tasks = Vec::with_capacity(num_tasks);
    for t in 0..num_tasks {
        // every task gets its own permutation; task 0 keeps the identity
        // (plain digits) exactly as the paper's first task.
        let perm: Vec<usize> = if t == 0 {
            (0..784).collect()
        } else {
            perm_rng.permutation(784)
        };
        let apply = |ex: Vec<Example>| -> Vec<Example> {
            ex.into_iter()
                .map(|e| Example {
                    features: perm.iter().map(|&p| e.features[p]).collect(),
                    label: e.label,
                })
                .collect()
        };
        // fresh digit draws per task (a new data distribution arriving)
        let train = apply(synthetic_mnist(n_train, seed.wrapping_add(1000 + t as u64)));
        let test = apply(synthetic_mnist(n_test, seed.wrapping_add(2000 + t as u64)));
        tasks.push(TaskData { train, test });
    }
    TaskStream {
        name: "permuted-mnist".into(),
        nx: 28,
        nt: 28,
        ny: 10,
        tasks,
        feat_offset: 0.0,
        feat_scale: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_zero_is_identity_permutation() {
        let s = permuted_task_stream(2, 10, 5, 0);
        let raw = synthetic_mnist(10, 1000);
        assert_eq!(s.tasks[0].train[0].features, raw[0].features);
    }

    #[test]
    fn later_tasks_are_permuted_but_preserve_pixel_multiset() {
        let s = permuted_task_stream(3, 10, 5, 0);
        let a = &s.tasks[0].train[0].features;
        let b = &s.tasks[1].train[0].features;
        // same underlying digit draw seed differs; instead check within
        // task 1: pixel multiset of a permuted image equals the unpermuted
        // draw it came from.
        let raw = synthetic_mnist(10, 1001);
        let mut x: Vec<_> = b.iter().map(|v| v.to_bits()).collect();
        let mut y: Vec<_> = raw[0].features.iter().map(|v| v.to_bits()).collect();
        x.sort_unstable();
        y.sort_unstable();
        assert_eq!(x, y);
        assert_ne!(a, b);
    }

    #[test]
    fn permutations_differ_across_tasks() {
        let s = permuted_task_stream(4, 5, 5, 0);
        // images from the same generator seed but different tasks must
        // differ (different permutations).
        let imgs: Vec<_> = (1..4).map(|t| s.tasks[t].train[0].features.clone()).collect();
        assert_ne!(imgs[0], imgs[1]);
        assert_ne!(imgs[1], imgs[2]);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = permuted_task_stream(2, 5, 5, 42);
        let b = permuted_task_stream(2, 5, 5, 42);
        assert_eq!(a.tasks[1].train[0].features, b.tasks[1].train[0].features);
    }

    #[test]
    fn labels_span_all_classes() {
        let s = permuted_task_stream(1, 50, 20, 0);
        let mut seen = [false; 10];
        for e in &s.tasks[0].train {
            seen[e.label] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
