//! Split-feature task stream — the split CIFAR-10 / frozen ResNet-18
//! stand-in (DESIGN.md §4).
//!
//! The paper feeds *precomputed frozen features* to MiRU: the learner
//! never sees an image. We therefore synthesize class-conditional
//! Gaussian embeddings (512-d, presented as a 16×32 sequence), split the
//! 10 classes into 5 two-class tasks with a shared binary head (the
//! domain-incremental protocol: no task identity at inference).

use crate::rng::GaussianRng;

use super::{Example, TaskData, TaskStream};

const DIM: usize = 512;
const NT: usize = 16;
const NX: usize = 32;

/// Build the 5-task split stream. `sep` controls class separability
/// (≈0.8 gives the paper-like noisy regime).
pub fn feature_task_stream(
    num_tasks: usize,
    n_train: usize,
    n_test: usize,
    sep: f32,
    seed: u64,
) -> TaskStream {
    assert!(num_tasks <= 5, "split CIFAR-10 has 5 two-class tasks");
    let mut proto_rng = GaussianRng::new(seed ^ 0x0C1F_A210);
    // 10 class prototype embeddings
    let protos: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..DIM).map(|_| proto_rng.normal() * sep).collect())
        .collect();

    let mut tasks = Vec::with_capacity(num_tasks);
    for t in 0..num_tasks {
        let classes = [2 * t, 2 * t + 1];
        let mut rng = GaussianRng::new(seed.wrapping_add(77 + t as u64));
        let mut gen = |n: usize| -> Vec<Example> {
            (0..n)
                .map(|i| {
                    let which = i % 2; // balanced binary labels
                    let proto = &protos[classes[which]];
                    let features = proto
                        .iter()
                        .map(|&m| (m + rng.normal()).clamp(-1.0, 1.0) * 0.999)
                        .collect();
                    Example { features, label: which }
                })
                .collect()
        };
        tasks.push(TaskData { train: gen(n_train), test: gen(n_test) });
    }
    TaskStream {
        name: "split-cifar10-features".into(),
        nx: NX,
        nt: NT,
        ny: 2,
        tasks,
        feat_offset: -1.0,
        feat_scale: 2.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_is_16x32_eq_512() {
        let s = feature_task_stream(5, 10, 10, 0.8, 0);
        assert_eq!(s.nx * s.nt, DIM);
        assert_eq!(s.ny, 2);
        assert_eq!(s.num_tasks(), 5);
    }

    #[test]
    fn features_clamped_to_unit_ball() {
        let s = feature_task_stream(2, 20, 10, 1.5, 1);
        for t in &s.tasks {
            for e in &t.train {
                assert!(e.features.iter().all(|&v| v.abs() < 1.0));
            }
        }
    }

    #[test]
    fn binary_labels_balanced() {
        let s = feature_task_stream(3, 40, 20, 0.8, 2);
        for t in &s.tasks {
            let ones = t.train.iter().filter(|e| e.label == 1).count();
            assert_eq!(ones, 20);
        }
    }

    #[test]
    fn tasks_use_distinct_class_pairs() {
        // a centroid classifier trained on task 0 should be ~chance on
        // task 1 (different underlying classes ⇒ domain shift is real).
        let s = feature_task_stream(2, 100, 100, 1.0, 3);
        let centroid = |ex: &[Example], lbl: usize| -> Vec<f32> {
            let sel: Vec<_> = ex.iter().filter(|e| e.label == lbl).collect();
            let mut c = vec![0.0f32; DIM];
            for e in &sel {
                for (a, &b) in c.iter_mut().zip(&e.features) {
                    *a += b;
                }
            }
            for a in &mut c {
                *a /= sel.len() as f32;
            }
            c
        };
        let c0 = centroid(&s.tasks[0].train, 0);
        let c1 = centroid(&s.tasks[0].train, 1);
        let acc = |ex: &[Example]| -> f32 {
            let d = |a: &[f32], b: &[f32]| -> f32 {
                a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum()
            };
            ex.iter()
                .filter(|e| {
                    let pred = usize::from(d(&e.features, &c1) < d(&e.features, &c0));
                    pred == e.label
                })
                .count() as f32
                / ex.len() as f32
        };
        assert!(acc(&s.tasks[0].test) > 0.9, "same-task acc {}", acc(&s.tasks[0].test));
        let cross = acc(&s.tasks[1].test);
        assert!((0.2..0.8).contains(&cross), "cross-task acc {cross}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = feature_task_stream(2, 5, 5, 0.8, 9);
        let b = feature_task_stream(2, 5, 5, 0.8, 9);
        assert_eq!(a.tasks[1].test[0].features, b.tasks[1].test[0].features);
    }
}
