//! Procedural 28×28 digit generator — the MNIST stand-in.
//!
//! Each class is a set of stroke segments on the 28×28 grid; a sample is
//! rendered by drawing the strokes with ~2 px width, then applying a
//! per-sample random translation, stroke-intensity variation and pixel
//! noise. The result is a linearly-separable-ish 10-class image problem
//! with the same geometry and value range ([0,1]) as MNIST — which is all
//! the permutation/replay/forgetting machinery observes.

use crate::rng::GaussianRng;

use super::Example;

const W: usize = 28;

/// Stroke endpoints (x0, y0, x1, y1) in a 0..28 coordinate box per digit.
fn strokes(class: usize) -> &'static [(f32, f32, f32, f32)] {
    match class {
        // 0: ring
        0 => &[
            (8.0, 5.0, 19.0, 5.0),
            (19.0, 5.0, 21.0, 22.0),
            (21.0, 22.0, 8.0, 22.0),
            (8.0, 22.0, 6.0, 5.0),
            (6.0, 5.0, 8.0, 5.0),
        ],
        // 1: vertical bar with serif
        1 => &[(13.0, 4.0, 14.0, 23.0), (9.0, 8.0, 13.0, 4.0), (9.0, 23.0, 19.0, 23.0)],
        // 2
        2 => &[
            (7.0, 7.0, 13.0, 4.0),
            (13.0, 4.0, 20.0, 8.0),
            (20.0, 8.0, 7.0, 22.0),
            (7.0, 22.0, 21.0, 22.0),
        ],
        // 3
        3 => &[
            (7.0, 5.0, 20.0, 5.0),
            (20.0, 5.0, 12.0, 13.0),
            (12.0, 13.0, 20.0, 19.0),
            (20.0, 19.0, 8.0, 23.0),
        ],
        // 4
        4 => &[(16.0, 4.0, 6.0, 16.0), (6.0, 16.0, 21.0, 16.0), (16.0, 4.0, 16.0, 24.0)],
        // 5
        5 => &[
            (20.0, 4.0, 7.0, 4.0),
            (7.0, 4.0, 7.0, 13.0),
            (7.0, 13.0, 19.0, 14.0),
            (19.0, 14.0, 18.0, 23.0),
            (18.0, 23.0, 7.0, 22.0),
        ],
        // 6
        6 => &[
            (18.0, 4.0, 9.0, 12.0),
            (9.0, 12.0, 8.0, 21.0),
            (8.0, 21.0, 19.0, 22.0),
            (19.0, 22.0, 19.0, 14.0),
            (19.0, 14.0, 9.0, 14.0),
        ],
        // 7
        7 => &[(7.0, 5.0, 21.0, 5.0), (21.0, 5.0, 11.0, 23.0), (10.0, 13.0, 18.0, 13.0)],
        // 8
        8 => &[
            (13.0, 4.0, 8.0, 8.0),
            (8.0, 8.0, 19.0, 14.0),
            (19.0, 14.0, 8.0, 20.0),
            (8.0, 20.0, 13.0, 24.0),
            (13.0, 24.0, 20.0, 20.0),
            (13.0, 4.0, 19.0, 8.0),
            (19.0, 8.0, 8.0, 14.0),
            (8.0, 14.0, 20.0, 20.0),
        ],
        // 9
        _ => &[
            (19.0, 10.0, 12.0, 4.0),
            (12.0, 4.0, 8.0, 10.0),
            (8.0, 10.0, 19.0, 12.0),
            (19.0, 10.0, 18.0, 23.0),
        ],
    }
}

/// Render one digit sample: 784 pixels in [0,1].
pub fn render_digit(class: usize, rng: &mut GaussianRng) -> Vec<f32> {
    let mut img = vec![0.0f32; W * W];
    let dx = rng.uniform_in(-2.0, 2.0);
    let dy = rng.uniform_in(-2.0, 2.0);
    let intensity = rng.uniform_in(0.75, 1.0);
    let thickness = rng.uniform_in(1.2, 1.9);

    for &(x0, y0, x1, y1) in strokes(class) {
        // jitter stroke endpoints slightly for within-class variety
        let (x0, y0) = (x0 + dx + rng.normal() * 0.4, y0 + dy + rng.normal() * 0.4);
        let (x1, y1) = (x1 + dx + rng.normal() * 0.4, y1 + dy + rng.normal() * 0.4);
        let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1e-3);
        let steps = (len * 3.0) as usize + 2;
        for s in 0..=steps {
            let t = s as f32 / steps as f32;
            let cx = x0 + t * (x1 - x0);
            let cy = y0 + t * (y1 - y0);
            // splat a soft disc of radius `thickness`
            let r = thickness.ceil() as i32;
            for oy in -r..=r {
                for ox in -r..=r {
                    let px = cx + ox as f32;
                    let py = cy + oy as f32;
                    if px < 0.0 || py < 0.0 || px >= W as f32 || py >= W as f32 {
                        continue;
                    }
                    let d2 = ((px - cx).powi(2) + (py - cy).powi(2)) / (thickness * thickness);
                    if d2 <= 1.0 {
                        let idx = py as usize * W + px as usize;
                        img[idx] = img[idx].max(intensity * (1.0 - 0.5 * d2));
                    }
                }
            }
        }
    }
    // pixel noise, clamped to [0,1)
    for p in &mut img {
        *p = (*p + 0.04 * rng.normal().abs()).clamp(0.0, 0.999);
    }
    img
}

/// Generate a balanced labeled set of `n` synthetic digits.
pub fn synthetic_mnist(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = GaussianRng::new(seed);
    (0..n)
        .map(|i| {
            let label = i % 10;
            Example { features: render_digit(label, &mut rng), label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_are_28x28_in_unit_range() {
        let ex = synthetic_mnist(20, 0);
        for e in &ex {
            assert_eq!(e.features.len(), 784);
            assert!(e.features.iter().all(|&p| (0.0..1.0).contains(&p)));
        }
    }

    #[test]
    fn digits_have_ink() {
        for e in synthetic_mnist(10, 1) {
            let ink: f32 = e.features.iter().sum();
            assert!(ink > 20.0, "class {} has ink {ink}", e.label);
        }
    }

    #[test]
    fn classes_are_distinguishable_by_template() {
        // mean images of different classes should differ clearly more than
        // samples within a class differ from their own mean.
        let n = 400;
        let ex = synthetic_mnist(n, 2);
        let mut means = vec![vec![0.0f32; 784]; 10];
        let mut counts = [0usize; 10];
        for e in &ex {
            counts[e.label] += 1;
            for (m, &p) in means[e.label].iter_mut().zip(&e.features) {
                *m += p;
            }
        }
        for (c, m) in means.iter_mut().enumerate() {
            for v in m.iter_mut() {
                *v /= counts[c] as f32;
            }
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        let mut min_between = f32::INFINITY;
        for i in 0..10 {
            for j in (i + 1)..10 {
                min_between = min_between.min(dist(&means[i], &means[j]));
            }
        }
        let mut max_within = 0.0f32;
        for e in &ex {
            max_within = max_within.max(dist(&e.features, &means[e.label]) / 3.0);
        }
        assert!(min_between > max_within, "between {min_between} within*3 {max_within}");
    }

    #[test]
    fn balanced_labels() {
        let ex = synthetic_mnist(100, 3);
        for c in 0..10 {
            assert_eq!(ex.iter().filter(|e| e.label == c).count(), 10);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synthetic_mnist(5, 7);
        let b = synthetic_mnist(5, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.features, y.features);
        }
    }
}
