//! Synthetic workload generators (DESIGN.md §4 substitutions).
//!
//! The paper evaluates on permuted sequential MNIST and split CIFAR-10
//! through frozen ResNet-18 features. Neither raw dataset is available in
//! this offline environment, so we build generators that preserve what the
//! continual-learning evaluation actually exercises:
//!
//! * [`synthetic_mnist`] — procedural 28×28 grayscale digits (stroke
//!   templates + jitter + noise), presented row-by-row as 28-step
//!   sequences;
//! * [`permuted`] — fixed per-task pixel permutations over those digits
//!   (the paper's permuted-MNIST protocol, verbatim);
//! * [`feature_tasks`] — class-conditional Gaussian features standing in
//!   for frozen ResNet-18 embeddings, split into 2-class tasks with a
//!   shared binary head (domain-incremental, §VI-A).

mod feature_tasks;
mod permuted;
mod synthetic_mnist;

pub use feature_tasks::feature_task_stream;
pub use permuted::permuted_task_stream;
pub use synthetic_mnist::{render_digit, synthetic_mnist};

/// One labeled sequence sample: `features` is nt*nx, row-major in time.
#[derive(Clone, Debug)]
pub struct Example {
    pub features: Vec<f32>,
    pub label: usize,
}

/// Train/test split for one task.
#[derive(Clone, Debug)]
pub struct TaskData {
    pub train: Vec<Example>,
    pub test: Vec<Example>,
}

/// A domain-incremental task stream with fixed sequence geometry.
#[derive(Clone, Debug)]
pub struct TaskStream {
    pub name: String,
    pub nx: usize,
    pub nt: usize,
    pub ny: usize,
    pub tasks: Vec<TaskData>,
    /// Feature range for replay-buffer quantization: (offset, scale) such
    /// that stored = (x - offset) / scale ∈ [0, 1].
    pub feat_offset: f32,
    pub feat_scale: f32,
}

impl TaskStream {
    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_have_consistent_geometry() {
        let s = permuted_task_stream(3, 40, 20, 0);
        assert_eq!(s.nx * s.nt, 784);
        for t in &s.tasks {
            for e in t.train.iter().chain(&t.test) {
                assert_eq!(e.features.len(), s.nx * s.nt);
                assert!(e.label < s.ny);
            }
        }
    }
}
