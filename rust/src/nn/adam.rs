//! BPTT + Adam software baseline (the "Adam optimizer" curves of Fig. 4).
//!
//! True gradients through the unrolled MiRU recurrence, then Adam. The
//! backward pass is hand-derived (no autodiff substrate in this crate):
//!
//!   h_t = λ h_{t-1} + (1-λ) tanh(pre_t),  pre_t = x_t Wh + (β h_{t-1}) Uh + bh
//!   ∂h_t/∂h_{t-1} = λ I + (1-λ) diag(1-cand²) β Uhᵀ
//!
//! Loss is CE at the final step, matching `model.train_adam`.

use crate::linalg::{softmax_rows, Mat};
use crate::nn::{MiruParams, SeqBatch};

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const EPS: f32 = 1e-8;

/// Adam moments over the flattened parameter vector (artifact order).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
}

impl AdamState {
    pub fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], t: 0.0 }
    }

    /// One Adam update given the flattened gradient; returns the update
    /// vector to *subtract* from the flattened params.
    pub fn step(&mut self, grad: &[f32], lr: f32) -> Vec<f32> {
        assert_eq!(grad.len(), self.m.len());
        self.t += 1.0;
        let (c1, c2) = (1.0 - B1.powf(self.t), 1.0 - B2.powf(self.t));
        grad.iter()
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
            .map(|(&g, (m, v))| {
                *m = B1 * *m + (1.0 - B1) * g;
                *v = B2 * *v + (1.0 - B2) * g * g;
                lr * (*m / c1) / ((*v / c2).sqrt() + EPS)
            })
            .collect()
    }
}

/// Exact BPTT gradients of the final-step CE loss, flattened in artifact
/// order (wh, uh, bh, wo, bo). Returns (grad, loss).
pub fn bptt_grads(p: &MiruParams, x: &SeqBatch, lam: f32, beta: f32) -> (Vec<f32>, f32) {
    let b = x.b;
    let (nx, nh, ny) = (p.nx(), p.nh(), p.ny());
    let trace = p.forward_trace(x, lam, beta);
    let logits = p.logits(&trace);
    let probs = softmax_rows(&logits);

    let mut loss = 0.0;
    for (i, &l) in x.labels.iter().enumerate() {
        loss -= probs.at(i, l).max(1e-12).ln();
    }
    loss /= b as f32;

    let y = x.one_hot(ny);
    let mut delta_o = probs;
    delta_o.add_scaled(&y, -1.0);
    delta_o.scale(1.0 / b as f32);

    let g_wo = trace.h_final.matmul_tn(&delta_o);
    let mut g_bo = vec![0.0; ny];
    for r in 0..b {
        for (s, &v) in g_bo.iter_mut().zip(delta_o.row(r)) {
            *s += v;
        }
    }

    // dL/dh_T
    let mut dh = delta_o.matmul(&p.wo.transpose()); // [b, nh]
    let mut g_wh = Mat::zeros(nx, nh);
    let mut g_uh = Mat::zeros(nh, nh);
    let mut g_bh = vec![0.0; nh];
    let uh_t = p.uh.transpose();

    for t in (0..x.nt).rev() {
        let cand = &trace.cand[t];
        // dpre = dh * (1-λ) * (1-cand²)
        let mut dpre = Mat::zeros(b, nh);
        for r in 0..b {
            for c in 0..nh {
                *dpre.at_mut(r, c) =
                    dh.at(r, c) * (1.0 - lam) * (1.0 - cand.at(r, c) * cand.at(r, c));
            }
        }
        let xt = x.step(t);
        g_wh.add_scaled(&xt.matmul_tn(&dpre), 1.0);
        let mut hp = trace.h_prev[t].clone();
        hp.scale(beta);
        g_uh.add_scaled(&hp.matmul_tn(&dpre), 1.0);
        for r in 0..b {
            for (s, &v) in g_bh.iter_mut().zip(dpre.row(r)) {
                *s += v;
            }
        }
        // dh_{t-1} = λ dh + β (dpre @ Uhᵀ)
        let carry = dpre.matmul(&uh_t);
        let mut dh_prev = dh;
        dh_prev.scale(lam);
        dh_prev.add_scaled(&carry, beta);
        dh = dh_prev;
    }

    let mut grad = Vec::with_capacity(p.count());
    grad.extend_from_slice(&g_wh.data);
    grad.extend_from_slice(&g_uh.data);
    grad.extend_from_slice(&g_bh);
    grad.extend_from_slice(&g_wo.data);
    grad.extend_from_slice(&g_bo);
    (grad, loss)
}

impl MiruParams {
    /// Subtract a flattened update vector (Adam step output).
    pub fn apply_flat_update(&mut self, upd: &[f32]) {
        assert_eq!(upd.len(), self.count());
        let mut off = 0;
        for chunk in [&mut self.wh.data, &mut self.uh.data] {
            for x in chunk.iter_mut() {
                *x -= upd[off];
                off += 1;
            }
        }
        for x in self.bh.iter_mut() {
            *x -= upd[off];
            off += 1;
        }
        for x in self.wo.data.iter_mut() {
            *x -= upd[off];
            off += 1;
        }
        for x in self.bo.iter_mut() {
            *x -= upd[off];
            off += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianRng;

    fn toy_batch(b: usize, nt: usize, nx: usize, ny: usize, seed: u64) -> SeqBatch {
        let mut proto_rng = GaussianRng::new(99);
        let protos: Vec<Vec<f32>> =
            (0..ny).map(|_| (0..nx).map(|_| proto_rng.normal()).collect()).collect();
        let mut rng = GaussianRng::new(seed);
        let mut sb = SeqBatch::zeros(b, nt, nx);
        for i in 0..b {
            let label = rng.below(ny);
            sb.labels[i] = label;
            for t in 0..nt {
                for j in 0..nx {
                    sb.sample_mut(i)[t * nx + j] =
                        (0.25 * rng.normal() + 0.75 * protos[label][j]).clamp(-1.0, 1.0);
                }
            }
        }
        sb
    }

    /// Central finite differences on a few random coordinates validate the
    /// hand-derived BPTT backward.
    #[test]
    fn bptt_matches_finite_differences() {
        let p = MiruParams::init(4, 6, 3, 5);
        let x = toy_batch(3, 4, 4, 3, 1);
        let (lam, beta) = (0.5, 0.7);
        let (grad, _) = bptt_grads(&p, &x, lam, beta);
        let eps = 1e-3f32;
        let loss_at = |p: &MiruParams| {
            let logits = p.forward(&x, lam, beta);
            crate::linalg::cross_entropy(&logits, &x.labels)
        };
        // probe coordinates across all five tensors
        let probes = [0usize, 10, 4 * 6 + 3, 4 * 6 + 36 + 2, 4 * 6 + 36 + 6 + 7, p.count() - 1];
        for &idx in &probes {
            let mut flat_plus = p.flatten();
            flat_plus[idx] += eps;
            let mut flat_minus = p.flatten();
            flat_minus[idx] -= eps;
            let rebuild = |flat: &[f32]| {
                let mut q = p.clone();
                let mut off = 0;
                for (dst_len, dst) in [
                    (q.wh.data.len(), &mut q.wh.data),
                    (q.uh.data.len(), &mut q.uh.data),
                ] {
                    dst.copy_from_slice(&flat[off..off + dst_len]);
                    off += dst_len;
                }
                let nbh = q.bh.len();
                q.bh.copy_from_slice(&flat[off..off + nbh]);
                off += nbh;
                let n = q.wo.data.len();
                q.wo.data.copy_from_slice(&flat[off..off + n]);
                off += n;
                let nbo = q.bo.len();
                q.bo.copy_from_slice(&flat[off..off + nbo]);
                q
            };
            let num = (loss_at(&rebuild(&flat_plus)) - loss_at(&rebuild(&flat_minus))) / (2.0 * eps);
            let ana = grad[idx];
            assert!(
                (num - ana).abs() < 2e-3 + 0.05 * num.abs().max(ana.abs()),
                "idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn adam_learns_toy_task() {
        let mut p = MiruParams::init(8, 16, 4, 17);
        let mut st = AdamState::new(p.count());
        let mut losses = Vec::new();
        for i in 0..40 {
            let x = toy_batch(8, 5, 8, 4, 100 + i);
            let (g, loss) = bptt_grads(&p, &x, 0.5, 0.7);
            let upd = st.step(&g, 0.01);
            p.apply_flat_update(&upd);
            losses.push(loss);
        }
        let head: f32 = losses[..8].iter().sum::<f32>() / 8.0;
        let tail: f32 = losses[32..].iter().sum::<f32>() / 8.0;
        assert!(tail < 0.6 * head, "head {head} tail {tail}");
        assert_eq!(st.t, 40.0);
    }

    #[test]
    fn adam_state_bias_correction_first_step() {
        // First step with constant grad g: update = lr * g/|g| (sign-ish).
        let mut st = AdamState::new(3);
        let upd = st.step(&[0.5, -0.5, 0.0], 0.1);
        assert!((upd[0] - 0.1).abs() < 1e-3);
        assert!((upd[1] + 0.1).abs() < 1e-3);
        assert_eq!(upd[2], 0.0);
    }
}
