//! K-winner-take-all gradient sparsifier ζ (Algorithm 1, lines 19–21).
//!
//! Keeps the top `ceil(keep_frac * n)` entries by magnitude and zeroes the
//! rest — the mechanism behind the ~47% write-activity reduction and the
//! 6.9 → 12.2-year lifespan extension (Fig. 5b). Selection semantics match
//! `model._kwta`: threshold at the k-th largest |g|, ties at the threshold
//! all survive.

use crate::linalg::Mat;

/// Number of entries ζ keeps for a tensor of `n` elements.
pub fn kwta_keep_count(n: usize, keep_frac: f32) -> usize {
    ((keep_frac * n as f32).ceil() as usize).clamp(1, n)
}

/// Apply ζ in place. Returns the number of surviving (non-zero) entries,
/// which is ≥ the keep count only when ties straddle the threshold.
pub fn kwta_inplace(g: &mut Mat, keep_frac: f32) -> usize {
    let n = g.data.len();
    let keep = kwta_keep_count(n, keep_frac);
    if keep >= n {
        return g.count_nonzero();
    }
    let mut mags: Vec<f32> = g.data.iter().map(|x| x.abs()).collect();
    // k-th largest = element at index n-keep of the ascending order.
    let idx = n - keep;
    mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
    let thresh = mags[idx];
    let mut survived = 0;
    for x in &mut g.data {
        if x.abs() >= thresh && *x != 0.0 {
            survived += 1;
        } else {
            *x = 0.0;
        }
    }
    survived
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianRng;

    #[test]
    fn keep_count_rounds_up() {
        assert_eq!(kwta_keep_count(100, 0.53), 53);
        assert_eq!(kwta_keep_count(3, 0.5), 2);
        assert_eq!(kwta_keep_count(1, 0.01), 1);
        assert_eq!(kwta_keep_count(10, 1.0), 10);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let mut g = Mat::from_vec(1, 6, vec![0.1, -5.0, 0.2, 3.0, -0.05, 1.0]);
        let survived = kwta_inplace(&mut g, 0.5);
        assert_eq!(survived, 3);
        assert_eq!(g.data, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn survivor_count_matches_keep_for_distinct_values() {
        let mut rng = GaussianRng::new(0);
        let mut g = Mat::from_fn(40, 25, |_, _| rng.normal());
        let survived = kwta_inplace(&mut g, 0.53);
        assert_eq!(survived, kwta_keep_count(1000, 0.53));
        assert_eq!(g.count_nonzero(), survived);
    }

    #[test]
    fn values_pass_through_unscaled() {
        let mut g = Mat::from_vec(1, 4, vec![4.0, -3.0, 2.0, 1.0]);
        let orig = g.clone();
        kwta_inplace(&mut g, 0.5);
        for (a, b) in g.data.iter().zip(&orig.data) {
            assert!(*a == 0.0 || a == b);
        }
    }

    #[test]
    fn full_keep_is_identity() {
        let mut g = Mat::from_vec(1, 4, vec![0.0, 1.0, -1.0, 0.5]);
        let orig = g.clone();
        kwta_inplace(&mut g, 1.0);
        assert_eq!(g, orig);
    }
}
