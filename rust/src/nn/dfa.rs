//! Direct Feedback Alignment through time — Algorithm 1 of the paper.
//!
//! The error at the readout is projected straight to the hidden layer by a
//! fixed random matrix Ψ (no transposed forward weights, no backward
//! locking) and accumulated back over the sequence. Mirrors
//! `model._dfa_grads` exactly, including the paper's λ factor on the hidden
//! delta (line 14 — kept verbatim; it only rescales the effective lr).

use crate::linalg::{softmax_rows, Mat};
use crate::nn::{kwta_inplace, MiruParams, SeqBatch};
use crate::rng::GaussianRng;

/// Scaled parameter deltas (already include −lr) plus the batch loss.
#[derive(Clone, Debug)]
pub struct DfaDeltas {
    pub d_wh: Mat,
    pub d_uh: Mat,
    pub d_bh: Vec<f32>,
    pub d_wo: Mat,
    pub d_bo: Vec<f32>,
    pub loss: f32,
}

/// Fixed random projection Ψ ∈ [ny, nh], scaled 1/sqrt(nh) like the python
/// harness.
pub fn make_psi(ny: usize, nh: usize, seed: u64) -> Mat {
    let mut rng = GaussianRng::new(seed);
    let s = 1.0 / (nh as f32).sqrt();
    Mat::from_fn(ny, nh, |_, _| rng.normal() * s)
}

/// One DFA step. `keep_frac = None` → dense deltas (Fig. 5b baseline);
/// `Some(f)` → ζ-sparsified weight deltas (biases always dense — they live
/// in digital registers, not memristors).
pub fn dfa_grads(
    p: &MiruParams,
    x: &SeqBatch,
    lam: f32,
    beta: f32,
    lr: f32,
    psi: &Mat,
    keep_frac: Option<f32>,
) -> DfaDeltas {
    let b = x.b;
    let ny = p.ny();
    assert_eq!((psi.rows, psi.cols), (ny, p.nh()));

    let trace = p.forward_trace(x, lam, beta);
    let logits = p.logits(&trace);
    let probs = softmax_rows(&logits);

    // loss + delta_o = (softmax - onehot)/B
    let y = x.one_hot(ny);
    let mut loss = 0.0;
    for (i, &l) in x.labels.iter().enumerate() {
        loss -= probs.at(i, l).max(1e-12).ln();
    }
    loss /= b as f32;
    let mut delta_o = probs;
    delta_o.add_scaled(&y, -1.0);
    delta_o.scale(1.0 / b as f32);

    // Output layer (lines 9-10): only the final hidden state is used.
    let d_wo = trace.h_final.matmul_tn(&delta_o);
    let mut d_bo = vec![0.0; ny];
    for r in 0..b {
        for (s, &v) in d_bo.iter_mut().zip(delta_o.row(r)) {
            *s += v;
        }
    }

    // Line 13: e = delta_o @ Psi (same for all t — final-step loss).
    let e = delta_o.matmul(psi); // [b, nh]

    // Lines 14-16 accumulated over time.
    let mut d_wh = Mat::zeros(p.nx(), p.nh());
    let mut d_uh = Mat::zeros(p.nh(), p.nh());
    let mut d_bh = vec![0.0; p.nh()];
    for t in 0..x.nt {
        let cand = &trace.cand[t];
        // dh = lam * e ⊙ (1 - cand²)
        let mut dh = Mat::zeros(b, p.nh());
        for r in 0..b {
            for c in 0..p.nh() {
                *dh.at_mut(r, c) = lam * e.at(r, c) * (1.0 - cand.at(r, c) * cand.at(r, c));
            }
        }
        let xt = x.step(t);
        d_wh.add_scaled(&xt.matmul_tn(&dh), 1.0);
        let mut hp = trace.h_prev[t].clone();
        hp.scale(beta);
        d_uh.add_scaled(&hp.matmul_tn(&dh), 1.0);
        for r in 0..b {
            for (s, &v) in d_bh.iter_mut().zip(dh.row(r)) {
                *s += v;
            }
        }
    }

    // ζ sparsification on the memristor-backed matrices, then −lr scaling.
    let mut d_wo = d_wo;
    if let Some(f) = keep_frac {
        kwta_inplace(&mut d_wh, f);
        kwta_inplace(&mut d_uh, f);
        kwta_inplace(&mut d_wo, f);
    }
    d_wh.scale(-lr);
    d_uh.scale(-lr);
    d_wo.scale(-lr);
    for v in &mut d_bh {
        *v *= -lr;
    }
    for v in &mut d_bo {
        *v *= -lr;
    }
    DfaDeltas { d_wh, d_uh, d_bh, d_wo, d_bo, loss }
}

impl MiruParams {
    /// Apply deltas (the "ideal write" path; the device-aware path goes
    /// through `device::programming` instead).
    pub fn apply(&mut self, d: &DfaDeltas) {
        self.wh.add_scaled(&d.d_wh, 1.0);
        self.uh.add_scaled(&d.d_uh, 1.0);
        self.wo.add_scaled(&d.d_wo, 1.0);
        for (b, &v) in self.bh.iter_mut().zip(&d.d_bh) {
            *b += v;
        }
        for (b, &v) in self.bo.iter_mut().zip(&d.d_bo) {
            *b += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_task_batch(c: (usize, usize, usize, usize), b: usize, seed: u64) -> SeqBatch {
        // class-conditional prototype sequences, same recipe as the python
        // toy_batch: x = 0.25*noise + 0.75*proto[label]
        let (nx, _nh, ny, nt) = c;
        let mut proto_rng = GaussianRng::new(99);
        let protos: Vec<Vec<f32>> =
            (0..ny).map(|_| (0..nx).map(|_| proto_rng.normal()).collect()).collect();
        let mut rng = GaussianRng::new(seed);
        let mut sb = SeqBatch::zeros(b, nt, nx);
        for i in 0..b {
            let label = rng.below(ny);
            sb.labels[i] = label;
            for t in 0..nt {
                for j in 0..nx {
                    let v = 0.25 * rng.normal() + 0.75 * protos[label][j];
                    sb.sample_mut(i)[t * nx + j] = v.clamp(-1.0, 1.0);
                }
            }
        }
        sb
    }

    #[test]
    fn shapes_are_correct() {
        let p = MiruParams::init(8, 16, 4, 0);
        let psi = make_psi(4, 16, 1);
        let x = toy_task_batch((8, 16, 4, 5), 8, 2);
        let d = dfa_grads(&p, &x, 0.5, 0.7, 0.1, &psi, Some(0.53));
        assert_eq!((d.d_wh.rows, d.d_wh.cols), (8, 16));
        assert_eq!((d.d_uh.rows, d.d_uh.cols), (16, 16));
        assert_eq!((d.d_wo.rows, d.d_wo.cols), (16, 4));
        assert_eq!(d.d_bh.len(), 16);
        assert_eq!(d.d_bo.len(), 4);
        assert!(d.loss.is_finite());
    }

    #[test]
    fn learns_toy_task() {
        let mut p = MiruParams::init(8, 16, 4, 7);
        let psi = make_psi(4, 16, 11);
        let mut losses = Vec::new();
        for i in 0..60 {
            let x = toy_task_batch((8, 16, 4, 5), 8, i);
            let d = dfa_grads(&p, &x, 0.5, 0.7, 0.5, &psi, Some(0.53));
            p.apply(&d);
            losses.push(d.loss);
        }
        let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
        let tail: f32 = losses[50..].iter().sum::<f32>() / 10.0;
        assert!(tail < 0.6 * head, "head {head} tail {tail}");
    }

    #[test]
    fn sparse_deltas_are_masked_dense_deltas() {
        let p = MiruParams::init(8, 16, 4, 9);
        let psi = make_psi(4, 16, 13);
        let x = toy_task_batch((8, 16, 4, 5), 8, 1);
        let ds = dfa_grads(&p, &x, 0.5, 0.7, 0.1, &psi, Some(0.53));
        let dd = dfa_grads(&p, &x, 0.5, 0.7, 0.1, &psi, None);
        for (s, d) in ds.d_wh.data.iter().zip(&dd.d_wh.data) {
            assert!(*s == 0.0 || (s - d).abs() < 1e-7);
        }
        assert!((ds.loss - dd.loss).abs() < 1e-7);
        assert!(ds.d_wh.count_nonzero() < dd.d_wh.count_nonzero());
        // biases always dense (digital registers)
        assert_eq!(
            ds.d_bh.iter().filter(|v| **v != 0.0).count(),
            dd.d_bh.iter().filter(|v| **v != 0.0).count()
        );
    }

    #[test]
    fn zero_lr_means_zero_deltas() {
        let p = MiruParams::init(8, 16, 4, 3);
        let psi = make_psi(4, 16, 5);
        let x = toy_task_batch((8, 16, 4, 5), 4, 0);
        let d = dfa_grads(&p, &x, 0.5, 0.7, 0.0, &psi, None);
        assert!(d.d_wh.data.iter().all(|&v| v == 0.0));
        assert!(d.loss > 0.0);
    }
}
