//! Pure-rust MiRU network — the *digital CMOS baseline* of Table I and the
//! second correctness oracle for the AOT artifacts.
//!
//! Semantics mirror `python/compile/model.py` exactly (same parameter
//! order, same final-step loss, same DFA Algorithm 1 including the paper's
//! λ factor on the hidden delta, same ζ keep rule) so integration tests can
//! diff rust-vs-XLA outputs numerically.

mod adam;
mod dfa;
mod kwta;
mod miru;

pub use adam::{bptt_grads, AdamState};
pub use dfa::{dfa_grads, make_psi, DfaDeltas};
pub use kwta::{kwta_inplace, kwta_keep_count};
pub use miru::{MiruParams, MiruTrace};

use crate::linalg::Mat;

/// Batch of fixed-length sequences: x[b][t] is an `nx`-length feature row.
#[derive(Clone, Debug)]
pub struct SeqBatch {
    pub b: usize,
    pub nt: usize,
    pub nx: usize,
    /// [b * nt * nx], sequence-major per sample.
    pub data: Vec<f32>,
    pub labels: Vec<usize>,
}

impl SeqBatch {
    pub fn zeros(b: usize, nt: usize, nx: usize) -> Self {
        Self { b, nt, nx, data: vec![0.0; b * nt * nx], labels: vec![0; b] }
    }

    #[inline]
    pub fn step(&self, t: usize) -> Mat {
        // Gather time slice t across the batch: [b, nx].
        let mut m = Mat::zeros(self.b, self.nx);
        for i in 0..self.b {
            let src = &self.data[(i * self.nt + t) * self.nx..(i * self.nt + t + 1) * self.nx];
            m.row_mut(i).copy_from_slice(src);
        }
        m
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.data[i * self.nt * self.nx..(i + 1) * self.nt * self.nx]
    }

    pub fn sample_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.nt * self.nx..(i + 1) * self.nt * self.nx]
    }

    /// One-hot label matrix [b, ny].
    pub fn one_hot(&self, ny: usize) -> Mat {
        let mut y = Mat::zeros(self.b, ny);
        for (i, &l) in self.labels.iter().enumerate() {
            assert!(l < ny, "label {l} out of range for {ny} classes (sample {i})");
            *y.at_mut(i, l) = 1.0;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seqbatch_step_slices_correctly() {
        let mut sb = SeqBatch::zeros(2, 3, 4);
        for i in 0..sb.data.len() {
            sb.data[i] = i as f32;
        }
        let t1 = sb.step(1);
        // sample 0, t=1 starts at 4; sample 1, t=1 starts at (1*3+1)*4=16
        assert_eq!(t1.row(0), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(t1.row(1), &[16.0, 17.0, 18.0, 19.0]);
    }

    #[test]
    fn one_hot_rows() {
        let mut sb = SeqBatch::zeros(3, 1, 1);
        sb.labels = vec![2, 0, 1];
        let y = sb.one_hot(3);
        assert_eq!(y.row(0), &[0.0, 0.0, 1.0]);
        assert_eq!(y.row(1), &[1.0, 0.0, 0.0]);
        assert_eq!(y.row(2), &[0.0, 1.0, 0.0]);
    }
}
