//! MiRU forward pass — Eqs. (1)–(3) of the paper.

use crate::linalg::Mat;
use crate::nn::SeqBatch;
use crate::rng::GaussianRng;

/// MiRU network parameters. Order matches the AOT artifact contract:
/// (wh [nx,nh], uh [nh,nh], bh [nh], wo [nh,ny], bo [ny]).
#[derive(Clone, Debug)]
pub struct MiruParams {
    pub wh: Mat,
    pub uh: Mat,
    pub bh: Vec<f32>,
    pub wo: Mat,
    pub bo: Vec<f32>,
}

/// Per-step activations recorded during the forward pass; the DFA backward
/// consumes them (the hardware recomputes instead of storing — same math).
pub struct MiruTrace {
    /// h^{t-1} entering step t: nt matrices of [b, nh].
    pub h_prev: Vec<Mat>,
    /// candidate h~^t at step t.
    pub cand: Vec<Mat>,
    /// final hidden state h^{nT}.
    pub h_final: Mat,
}

impl MiruParams {
    /// Glorot-style init, matching the python test harness scale.
    pub fn init(nx: usize, nh: usize, ny: usize, seed: u64) -> Self {
        let mut rng = GaussianRng::new(seed);
        let sx = 0.3 / (nx as f32).sqrt();
        let sh = 0.3 / (nh as f32).sqrt();
        Self {
            wh: Mat::from_fn(nx, nh, |_, _| rng.normal() * sx),
            uh: Mat::from_fn(nh, nh, |_, _| rng.normal() * sh),
            bh: vec![0.0; nh],
            wo: Mat::from_fn(nh, ny, |_, _| rng.normal() * sh),
            bo: vec![0.0; ny],
        }
    }

    pub fn nx(&self) -> usize {
        self.wh.rows
    }
    pub fn nh(&self) -> usize {
        self.uh.rows
    }
    pub fn ny(&self) -> usize {
        self.wo.cols
    }

    /// Total parameter count (matches `model.param_count`).
    pub fn count(&self) -> usize {
        self.wh.data.len() + self.uh.data.len() + self.bh.len() + self.wo.data.len() + self.bo.len()
    }

    /// Flatten in artifact order (wh, uh, bh, wo, bo).
    pub fn flatten(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.count());
        v.extend_from_slice(&self.wh.data);
        v.extend_from_slice(&self.uh.data);
        v.extend_from_slice(&self.bh);
        v.extend_from_slice(&self.wo.data);
        v.extend_from_slice(&self.bo);
        v
    }

    /// One recurrent update on a caller-owned hidden state (Eqs. 1–2):
    /// `cand = tanh(x_t @ Wh + (β·h) @ Uh + bh)`, `h' = λ·h + (1−λ)·cand`.
    /// Returns `(cand, h')`. [`MiruParams::forward_trace`] composes exactly
    /// this function, so streaming a sequence one timestep at a time is
    /// bitwise-identical to the whole-sequence forward pass — the contract
    /// the serving session store relies on.
    pub fn step(&self, h: &Mat, xt: &Mat, lam: f32, beta: f32) -> (Mat, Mat) {
        let mut bh_scaled = h.clone();
        bh_scaled.scale(beta);
        let mut pre = xt.matmul(&self.wh);
        pre.add_scaled(&bh_scaled.matmul(&self.uh), 1.0);
        pre.add_row_bias(&self.bh);
        let cand = pre.map(f32::tanh);
        let mut h_new = h.clone();
        h_new.scale(lam);
        h_new.add_scaled(&cand, 1.0 - lam);
        (cand, h_new)
    }

    /// Run the MiRU layer over a sequence batch, recording the trace.
    pub fn forward_trace(&self, x: &SeqBatch, lam: f32, beta: f32) -> MiruTrace {
        assert_eq!(x.nx, self.nx());
        let nh = self.nh();
        let mut h = Mat::zeros(x.b, nh);
        let mut h_prev = Vec::with_capacity(x.nt);
        let mut cand_v = Vec::with_capacity(x.nt);
        for t in 0..x.nt {
            let xt = x.step(t);
            let (cand, h_new) = self.step(&h, &xt, lam, beta);
            h_prev.push(h);
            cand_v.push(cand);
            h = h_new;
        }
        MiruTrace { h_prev, cand: cand_v, h_final: h }
    }

    /// Final-step logits: h^{nT} @ Wo + bo.
    pub fn logits(&self, trace: &MiruTrace) -> Mat {
        let mut l = trace.h_final.matmul(&self.wo);
        l.add_row_bias(&self.bo);
        l
    }

    /// Convenience: forward + logits.
    pub fn forward(&self, x: &SeqBatch, lam: f32, beta: f32) -> Mat {
        let tr = self.forward_trace(x, lam, beta);
        self.logits(&tr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::argmax_rows;

    fn toy_batch(b: usize, nt: usize, nx: usize, seed: u64) -> SeqBatch {
        let mut rng = GaussianRng::new(seed);
        let mut sb = SeqBatch::zeros(b, nt, nx);
        for v in &mut sb.data {
            *v = rng.uniform_in(-1.0, 1.0);
        }
        sb
    }

    #[test]
    fn lambda_one_freezes_state() {
        let p = MiruParams::init(4, 8, 3, 0);
        let x = toy_batch(2, 5, 4, 1);
        let logits = p.forward(&x, 1.0, 0.7);
        // h stays zero -> logits == bo == 0
        for v in &logits.data {
            assert!(v.abs() < 1e-6);
        }
    }

    #[test]
    fn forward_matches_manual_single_step() {
        let p = MiruParams::init(3, 4, 2, 2);
        let x = toy_batch(1, 1, 3, 3);
        let (lam, beta) = (0.4, 0.8);
        let logits = p.forward(&x, lam, beta);
        // manual: h0=0 -> cand=tanh(x@Wh+bh), h=(1-lam)*cand
        let xt = x.step(0);
        let mut pre = xt.matmul(&p.wh);
        pre.add_row_bias(&p.bh);
        let cand = pre.map(f32::tanh);
        let mut h = cand.clone();
        h.scale(1.0 - lam);
        let mut want = h.matmul(&p.wo);
        want.add_row_bias(&p.bo);
        for (a, b) in logits.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn trace_shapes() {
        let p = MiruParams::init(5, 7, 3, 4);
        let x = toy_batch(4, 6, 5, 5);
        let tr = p.forward_trace(&x, 0.5, 0.7);
        assert_eq!(tr.h_prev.len(), 6);
        assert_eq!(tr.cand.len(), 6);
        assert_eq!((tr.h_final.rows, tr.h_final.cols), (4, 7));
        // h_prev[0] must be zeros
        assert!(tr.h_prev[0].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hidden_state_stays_bounded() {
        // |h| <= 1 always: tanh-bounded candidate, convex interpolation.
        let p = MiruParams::init(4, 6, 2, 6);
        let x = toy_batch(3, 50, 4, 7);
        let tr = p.forward_trace(&x, 0.9, 0.9);
        assert!(tr.h_final.data.iter().all(|&v| v.abs() <= 1.0));
    }

    #[test]
    fn streaming_steps_match_forward_trace() {
        let p = MiruParams::init(4, 6, 3, 11);
        let x = toy_batch(3, 7, 4, 12);
        let tr = p.forward_trace(&x, 0.6, 0.8);
        let mut h = Mat::zeros(3, 6);
        for t in 0..7 {
            h = p.step(&h, &x.step(t), 0.6, 0.8).1;
        }
        assert_eq!(h.data, tr.h_final.data);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = MiruParams::init(4, 6, 3, 42);
        let q = MiruParams::init(4, 6, 3, 42);
        assert_eq!(p.wh, q.wh);
        let x = toy_batch(2, 3, 4, 9);
        assert_eq!(p.forward(&x, 0.5, 0.7).data, q.forward(&x, 0.5, 0.7).data);
    }

    #[test]
    fn flatten_roundtrip_len() {
        let p = MiruParams::init(28, 100, 10, 0);
        assert_eq!(p.count(), 28 * 100 + 100 * 100 + 100 + 100 * 10 + 10);
        assert_eq!(p.flatten().len(), p.count());
        let _ = argmax_rows(&p.wo); // silence unused import in some cfgs
    }
}
