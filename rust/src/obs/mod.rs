//! Serve-path observability (DESIGN.md §13): an atomic metrics registry,
//! fixed-bucket log2 histograms for stage spans, and a bounded flight
//! recorder of structured lifecycle events — std-only, no dependencies.
//!
//! ## Two planes, one hard rule
//!
//! Everything in this module lives on the **timing plane**: it observes
//! the serve path but never feeds back into it. No dispatch decision, no
//! batch boundary, no weight, no session-id ever reads an instrument.
//! The enforced consequence (tests/obs_invariance.rs): the deterministic
//! serve signature is bitwise-identical with observability on, off, or
//! sampled, across worker and shard counts. This is the same separation
//! [`crate::serve::ServeMetrics`] draws between deterministic counters
//! and wall-clock latencies, extended to a live-scrapable registry.
//!
//! ## Registry
//!
//! [`Registry`] hands out three instrument kinds, all backed by plain
//! atomics so the hot path pays one `fetch_add` per observation and the
//! scrape path needs no locks beyond the registration list:
//!
//! * [`Counter`] — monotone `u64` (`_total` series). Mirror counters for
//!   deterministic quantities (requests, batches, commits) are *set* at
//!   render time from [`crate::serve::ServeMetrics`], so they are exact
//!   even under sampling and cost the hot path nothing.
//! * [`Gauge`] — an `f64` point-in-time value (occupancy, commit lag,
//!   projected lifespan, windowed accuracy).
//! * [`Histogram`] — log2 buckets (`le = 2^i`): one `leading_zeros` and
//!   three relaxed `fetch_add`s per observation, no allocation, no lock.
//!   Stage spans (queue wait, kernel step, snapshot write) land here.
//!
//! Rendering ([`Registry::render`]) produces Prometheus text exposition
//! in registration order — stable output for diffing and for the
//! router's per-shard relabel + fleet rollup ([`relabel`], [`rollup`]).
//!
//! ## Flight recorder
//!
//! [`FlightRecorder`] keeps the last `capacity` structured events
//! (session create/evict, connection sever with reason, shard
//! down/restart, checkpoint epochs) in a ring, dumpable as JSONL on
//! demand (the `events` selector of the `MetricsDump` wire frame) or on
//! panic ([`install_panic_dump`]). Events carry the logical tick, never
//! a wall clock, so a dump is meaningful next to the deterministic log.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

use anyhow::{bail, Result};

// ---------------------------------------------------------------- mode

/// How much the serve path records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObsMode {
    /// Record nothing; instruments exist but are never touched from the
    /// hot path (render-time mirrors still work).
    Off,
    /// Record every observation (the default — the whole layer is cheap
    /// enough to leave enabled).
    On,
    /// Record every `sample_every`-th span observation; counters and
    /// render-time mirrors stay exact.
    Sampled,
}

impl ObsMode {
    /// Parse the `[obs] mode` config value.
    pub fn parse(s: &str) -> Result<ObsMode> {
        match s {
            "off" => Ok(ObsMode::Off),
            "on" => Ok(ObsMode::On),
            "sampled" => Ok(ObsMode::Sampled),
            other => bail!("unknown obs mode `{other}` (expected off|on|sampled)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ObsMode::Off => "off",
            ObsMode::On => "on",
            ObsMode::Sampled => "sampled",
        }
    }
}

// ---------------------------------------------------------- instruments

/// Monotone counter (`_total`). Clones share the underlying atomic.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the value — for render-time mirrors of deterministic
    /// counters that are authoritative elsewhere (`ServeMetrics`).
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time `f64` value. Clones share the underlying atomic.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, d: f64) {
        let _ = self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |bits| {
            Some((f64::from_bits(bits) + d).to_bits())
        });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Bucket count of [`Histogram`]: upper bounds `2^0 .. 2^31` plus one
/// overflow (`+Inf`) bucket. 2^31 µs is ~36 minutes — far beyond any
/// span this registry times — so the overflow bucket stays a safety net.
pub const HIST_BUCKETS: usize = 33;

struct HistInner {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// Fixed log2-bucket histogram: bucket `i` covers `(2^(i-1), 2^i]`
/// (bucket 0 covers `[0, 1]`, the last bucket everything above `2^31`).
/// One observation is a `leading_zeros` plus three relaxed `fetch_add`s.
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram(Arc::new(HistInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }
}

/// Index of the log2 bucket value `v` falls in.
pub fn bucket_of(v: u64) -> usize {
    if v <= 1 {
        return 0;
    }
    // ceil(log2(v)) for v >= 2
    let b = (64 - (v - 1).leading_zeros()) as usize;
    b.min(HIST_BUCKETS - 1)
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        self.0.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Per-bucket (non-cumulative) counts, index = [`bucket_of`].
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

// ------------------------------------------------------------- registry

enum Instrument {
    C(Counter),
    G(Gauge),
    H(Histogram),
}

impl Instrument {
    fn type_name(&self) -> &'static str {
        match self {
            Instrument::C(_) => "counter",
            Instrument::G(_) => "gauge",
            Instrument::H(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    help: String,
    inst: Instrument,
}

/// Named instruments in registration order. Registration takes a lock;
/// the returned handles never do — hot paths hold [`Counter`]/[`Gauge`]/
/// [`Histogram`] clones directly, the registry is only walked at render
/// time. Registration is idempotent by name (a second request for an
/// existing name of the same kind returns a handle to the same atomic).
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        let mut es = self.entries.lock().expect("obs registry poisoned");
        if let Some(e) = es.iter().find(|e| e.name == name) {
            match &e.inst {
                Instrument::C(c) => return c.clone(),
                other => panic!("obs metric `{name}` already registered as {}", other.type_name()),
            }
        }
        let c = Counter::default();
        es.push(Entry { name: name.into(), help: help.into(), inst: Instrument::C(c.clone()) });
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        let mut es = self.entries.lock().expect("obs registry poisoned");
        if let Some(e) = es.iter().find(|e| e.name == name) {
            match &e.inst {
                Instrument::G(g) => return g.clone(),
                other => panic!("obs metric `{name}` already registered as {}", other.type_name()),
            }
        }
        let g = Gauge::default();
        es.push(Entry { name: name.into(), help: help.into(), inst: Instrument::G(g.clone()) });
        g
    }

    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        let mut es = self.entries.lock().expect("obs registry poisoned");
        if let Some(e) = es.iter().find(|e| e.name == name) {
            match &e.inst {
                Instrument::H(h) => return h.clone(),
                other => panic!("obs metric `{name}` already registered as {}", other.type_name()),
            }
        }
        let h = Histogram::default();
        es.push(Entry { name: name.into(), help: help.into(), inst: Instrument::H(h.clone()) });
        h
    }

    /// Prometheus text exposition, in registration order.
    pub fn render(&self) -> String {
        let es = self.entries.lock().expect("obs registry poisoned");
        let mut out = String::new();
        for e in es.iter() {
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            out.push_str(&format!("# TYPE {} {}\n", e.name, e.inst.type_name()));
            match &e.inst {
                Instrument::C(c) => out.push_str(&format!("{} {}\n", e.name, c.get())),
                Instrument::G(g) => out.push_str(&format!("{} {}\n", e.name, fmt_f64(g.get()))),
                Instrument::H(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, c) in counts.iter().enumerate() {
                        cum += c;
                        let le = if i + 1 == HIST_BUCKETS {
                            "+Inf".to_string()
                        } else {
                            (1u64 << i).to_string()
                        };
                        out.push_str(&format!("{}_bucket{{le=\"{le}\"}} {cum}\n", e.name));
                    }
                    out.push_str(&format!("{}_sum {}\n", e.name, h.sum()));
                    out.push_str(&format!("{}_count {}\n", e.name, h.count()));
                }
            }
        }
        out
    }
}

/// Render an `f64` gauge value the way Prometheus text expects (no
/// exponent games needed for our value ranges; non-finite as +Inf/-Inf/NaN).
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{v}")
    }
}

// -------------------------------------------- fleet relabel and rollup

/// Inject one `label="value"` pair into every sample line of a rendered
/// exposition (comment lines pass through). Used by the router to mark
/// each shard's series before concatenating them into the fleet dump.
pub fn relabel(text: &str, label: &str, value: &str) -> String {
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            out.push_str(line);
            out.push('\n');
            continue;
        }
        // a sample line is `name[{labels}] value`; the name ends at the
        // first `{` or space
        let cut = line.find(['{', ' ']).unwrap_or(line.len());
        let (name, rest) = line.split_at(cut);
        if rest.starts_with('{') {
            out.push_str(&format!("{name}{{{label}=\"{value}\",{}\n", &rest[1..]));
        } else {
            out.push_str(&format!("{name}{{{label}=\"{value}\"}}{rest}\n"));
        }
    }
    out
}

/// Sum counter and histogram series by name across several shard
/// expositions, producing the fleet-rollup section. Gauges are skipped —
/// a summed point-in-time value is rarely meaningful; per-shard gauges
/// stay visible in the relabeled sections. Series order follows first
/// appearance, so rollups of identically-shaped shards are stable.
pub fn rollup(texts: &[String]) -> String {
    // (series key, summed value), plus the TYPE map gathered on the way
    let mut order: Vec<String> = Vec::new();
    let mut sums: std::collections::HashMap<String, f64> = std::collections::HashMap::new();
    let mut kinds: std::collections::HashMap<String, String> = std::collections::HashMap::new();
    for text in texts {
        let mut current_kind = String::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().unwrap_or("").to_string();
                current_kind = it.next().unwrap_or("").to_string();
                kinds.insert(name, current_kind.clone());
                continue;
            }
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if current_kind != "counter" && current_kind != "histogram" {
                continue;
            }
            let Some(at) = line.rfind(' ') else { continue };
            let (series, val) = line.split_at(at);
            let Ok(v) = val.trim().parse::<f64>() else { continue };
            match sums.get_mut(series) {
                Some(s) => *s += v,
                None => {
                    order.push(series.to_string());
                    sums.insert(series.to_string(), v);
                }
            }
        }
    }
    let mut out = String::new();
    let mut last_name = String::new();
    for series in &order {
        let cut = series.find(['{', ' ']).unwrap_or(series.len());
        let name = &series[..cut];
        if *name != last_name {
            if let Some(kind) = kinds.get(name) {
                out.push_str(&format!("# TYPE {name} {kind}\n"));
            }
            last_name = name.to_string();
        }
        out.push_str(&format!("{series} {}\n", fmt_f64(sums[series])));
    }
    out
}

// ------------------------------------------------------ flight recorder

/// One structured lifecycle event. `tick` is the logical serve clock at
/// record time (wall clocks never enter the recorder).
#[derive(Clone, Debug)]
pub struct FlightEvent {
    pub seq: u64,
    pub tick: u64,
    pub kind: &'static str,
    pub fields: Vec<(&'static str, String)>,
}

struct FlightInner {
    ring: VecDeque<FlightEvent>,
    capacity: usize,
    next_seq: u64,
    dropped: u64,
}

/// Bounded ring of [`FlightEvent`]s: the last `capacity` lifecycle events
/// (session create/evict, connection sever, shard down/restart,
/// checkpoint epochs), dumpable as JSONL on demand or on panic. Events
/// are rare relative to requests, so a mutex-guarded ring is cheap; the
/// hot dispatch loop itself records no events.
pub struct FlightRecorder {
    inner: Mutex<FlightInner>,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            inner: Mutex::new(FlightInner {
                ring: VecDeque::with_capacity(capacity.max(1)),
                capacity: capacity.max(1),
                next_seq: 0,
                dropped: 0,
            }),
        }
    }

    pub fn record(&self, tick: u64, kind: &'static str, fields: Vec<(&'static str, String)>) {
        let mut g = self.inner.lock().expect("flight recorder poisoned");
        if g.ring.len() == g.capacity {
            g.ring.pop_front();
            g.dropped += 1;
        }
        let seq = g.next_seq;
        g.next_seq += 1;
        g.ring.push_back(FlightEvent { seq, tick, kind, fields });
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("flight recorder poisoned").ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events evicted from the ring since boot.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().expect("flight recorder poisoned").dropped
    }

    /// The retained events as JSON Lines, oldest first — one object per
    /// line: `{"seq":N,"tick":N,"kind":"...","field":"value",...}`.
    pub fn dump_jsonl(&self) -> String {
        let g = self.inner.lock().expect("flight recorder poisoned");
        let mut out = String::new();
        for e in g.ring.iter() {
            out.push_str(&format!("{{\"seq\":{},\"tick\":{},\"kind\":\"{}\"", e.seq, e.tick, e.kind));
            for (k, v) in &e.fields {
                out.push_str(&format!(",\"{}\":\"{}\"", k, json_escape(v)));
            }
            out.push_str("}\n");
        }
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// ------------------------------------------------------ panic dumping

static PANIC_RECORDERS: Mutex<Vec<Weak<FlightRecorder>>> = Mutex::new(Vec::new());
static PANIC_HOOK: OnceLock<()> = OnceLock::new();

/// Register a recorder for dumping to stderr if the process panics. The
/// hook chains the previous panic hook (installed once, process-wide);
/// dropped recorders unregister themselves lazily via `Weak`.
pub fn install_panic_dump(recorder: &Arc<FlightRecorder>) {
    PANIC_RECORDERS
        .lock()
        .expect("panic recorder list poisoned")
        .push(Arc::downgrade(recorder));
    PANIC_HOOK.get_or_init(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if let Ok(mut list) = PANIC_RECORDERS.lock() {
                list.retain(|w| w.strong_count() > 0);
                for w in list.iter() {
                    if let Some(rec) = w.upgrade() {
                        let dump = rec.dump_jsonl();
                        if !dump.is_empty() {
                            eprintln!("[obs] flight recorder at panic:\n{dump}");
                        }
                    }
                }
            }
            prev(info);
        }));
    });
}

// ------------------------------------------------------------- sampler

/// The per-component observability handle: mode + sampling decision +
/// shared registry and flight recorder. Cheap to clone; everything
/// inside is behind `Arc`s.
#[derive(Clone)]
pub struct Obs {
    mode: ObsMode,
    sample_every: u64,
    sample_ctr: Arc<AtomicU64>,
    pub registry: Arc<Registry>,
    pub recorder: Arc<FlightRecorder>,
}

impl Obs {
    pub fn new(mode: ObsMode, sample_every: u64, flight_capacity: usize) -> Obs {
        Obs {
            mode,
            sample_every: sample_every.max(1),
            sample_ctr: Arc::new(AtomicU64::new(0)),
            registry: Arc::new(Registry::new()),
            recorder: Arc::new(FlightRecorder::new(flight_capacity)),
        }
    }

    /// Build from the `[obs]` config block.
    pub fn from_cfg(cfg: &crate::config::ObsConfig) -> Result<Obs> {
        Ok(Obs::new(ObsMode::parse(&cfg.mode)?, cfg.sample_every, cfg.flight_capacity))
    }

    pub fn mode(&self) -> ObsMode {
        self.mode
    }

    /// Anything at all recorded?
    pub fn enabled(&self) -> bool {
        self.mode != ObsMode::Off
    }

    /// Should this span observation be recorded? `Off` → never, `On` →
    /// always, `Sampled` → every `sample_every`-th call. The decision
    /// only gates *recording* — it can never influence dispatch.
    pub fn should_sample(&self) -> bool {
        match self.mode {
            ObsMode::Off => false,
            ObsMode::On => true,
            ObsMode::Sampled => {
                self.sample_ctr.fetch_add(1, Ordering::Relaxed) % self.sample_every == 0
            }
        }
    }

    /// Record a flight event (no-op when off).
    pub fn event(&self, tick: u64, kind: &'static str, fields: Vec<(&'static str, String)>) {
        if self.enabled() {
            self.recorder.record(tick, kind, fields);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_render_in_registration_order() {
        let r = Registry::new();
        let c = r.counter("m2ru_test_total", "a counter");
        let g = r.gauge("m2ru_test_gauge", "a gauge");
        let h = r.histogram("m2ru_test_us", "a span");
        c.add(3);
        g.set(1.5);
        h.observe(5);
        let text = r.render();
        let c_at = text.find("m2ru_test_total 3").expect("counter sample");
        let g_at = text.find("m2ru_test_gauge 1.5").expect("gauge sample");
        let h_at = text.find("m2ru_test_us_count 1").expect("histogram count");
        assert!(c_at < g_at && g_at < h_at, "registration order must be render order");
        assert!(text.contains("# TYPE m2ru_test_us histogram"));
        // 5 lands in the (4, 8] bucket; cumulative from le=8 on
        assert!(text.contains("m2ru_test_us_bucket{le=\"4\"} 0"));
        assert!(text.contains("m2ru_test_us_bucket{le=\"8\"} 1"));
        assert!(text.contains("m2ru_test_us_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("m2ru_test_us_sum 5"));
    }

    #[test]
    fn registration_is_idempotent_by_name() {
        let r = Registry::new();
        let a = r.counter("m2ru_same_total", "first");
        let b = r.counter("m2ru_same_total", "second");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same name must share the atomic");
        assert_eq!(r.render().matches("# TYPE m2ru_same_total").count(), 1);
    }

    #[test]
    fn log2_buckets_partition_the_value_range() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(5), 3);
        assert_eq!(bucket_of(1 << 31), 31);
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // every boundary value lands in the bucket whose `le` admits it
        for i in 0..31 {
            assert!(bucket_of(1u64 << i) <= i.max(1) as usize);
        }
    }

    #[test]
    fn histogram_bucket_counts_sum_to_observations() {
        let h = Histogram::default();
        let mut expect_sum = 0u64;
        for v in [0u64, 1, 2, 7, 63, 64, 65, 4096, 1 << 20, u64::MAX / 2] {
            h.observe(v);
            expect_sum = expect_sum.wrapping_add(v);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), h.count());
        assert_eq!(h.sum(), expect_sum);
    }

    #[test]
    fn relabel_injects_into_bare_and_labeled_samples() {
        let text = "# TYPE a counter\na_total 5\nb_bucket{le=\"4\"} 2\n";
        let got = relabel(text, "shard", "1");
        assert!(got.contains("a_total{shard=\"1\"} 5"));
        assert!(got.contains("b_bucket{shard=\"1\",le=\"4\"} 2"));
        assert!(got.contains("# TYPE a counter"), "comments pass through");
    }

    #[test]
    fn rollup_sums_counters_and_histograms_but_not_gauges() {
        let shard = |n: u64| {
            format!(
                "# TYPE m2ru_req_total counter\nm2ru_req_total {n}\n\
                 # TYPE m2ru_lag gauge\nm2ru_lag {n}\n\
                 # TYPE m2ru_span histogram\nm2ru_span_bucket{{le=\"2\"}} {n}\nm2ru_span_count {n}\n"
            )
        };
        let got = rollup(&[shard(2), shard(3)]);
        assert!(got.contains("m2ru_req_total 5"));
        assert!(got.contains("m2ru_span_bucket{le=\"2\"} 5"));
        assert!(got.contains("m2ru_span_count 5"));
        assert!(!got.contains("m2ru_lag"), "gauges must not be summed");
    }

    #[test]
    fn flight_recorder_ring_is_bounded_and_dumps_jsonl() {
        let rec = FlightRecorder::new(3);
        for i in 0..5u64 {
            rec.record(i, "session_create", vec![("session", format!("{i}"))]);
        }
        assert_eq!(rec.len(), 3);
        assert_eq!(rec.dropped(), 2);
        let dump = rec.dump_jsonl();
        let lines: Vec<&str> = dump.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(
            lines[0],
            "{\"seq\":2,\"tick\":2,\"kind\":\"session_create\",\"session\":\"2\"}"
        );
        // every line is a JSON object with balanced quotes
        for l in &lines {
            assert!(l.starts_with('{') && l.ends_with('}'));
            assert_eq!(l.matches('"').count() % 2, 0);
        }
    }

    #[test]
    fn json_escaping_keeps_lines_parseable() {
        let rec = FlightRecorder::new(4);
        rec.record(0, "conn_severed", vec![("reason", "peer said \"bye\"\nearly".to_string())]);
        let dump = rec.dump_jsonl();
        assert_eq!(dump.lines().count(), 1, "escapes must not split the line");
        assert!(dump.contains("peer said \\\"bye\\\"\\nearly"));
    }

    #[test]
    fn sampled_mode_records_every_nth() {
        let obs = Obs::new(ObsMode::Sampled, 4, 8);
        let hits = (0..16).filter(|_| obs.should_sample()).count();
        assert_eq!(hits, 4);
        assert!(!Obs::new(ObsMode::Off, 1, 8).should_sample());
        assert!(Obs::new(ObsMode::On, 1, 8).should_sample());
    }
}
