//! Online continual learning on the serve path: labeled steps feed the
//! reservoir replay buffer, and every `update_every` labels one
//! replay-mixed DFA window is finalized into a [`CommitBatch`].
//!
//! The learner itself never touches weights. It owns the *deterministic*
//! half of the commit protocol — window accumulation, replay sampling
//! (Box–Muller stream), reservoir rolling and segment merging — all of
//! which runs on the serve thread, so the sequence of finalized batches
//! depends only on the seed and the traffic. The batches are then queued
//! to the committer thread ([`super::commit`]), the single writer that
//! applies them through
//! [`crate::coordinator::ParallelEngine::train_whole_guarded`] in
//! enqueue order:
//!
//! * **snapshot read** — the committer reads the substrate's effective
//!   weights once per commit, computes gradients against that snapshot,
//!   and only then programs the update;
//! * **single writer** — exactly one thread ever mutates weights, and
//!   commits apply in generation order, so the committed weights after N
//!   commits are bit-identical to applying the same batches inline;
//! * **replay stabilization** — each commit mixes the fresh window with
//!   examples replayed from *earlier* windows (reservoir-sampled,
//!   4-bit-quantized — the paper's §IV-A data-preparation unit), so the
//!   stream's drift does not erase earlier behavior. After a window is
//!   finalized the buffer rolls to a fresh reservoir segment and the
//!   committed window becomes replayable history.

use crate::config::ServeConfig;
use crate::data::Example;
use crate::nn::SeqBatch;
use crate::replay::{QuantizedExample, ReplayBuffer};
use crate::rng::GaussianRng;

/// Replay segments retained (newest-first) across commits. One segment
/// rolls per commit; beyond the cap the two **oldest** segments are
/// reservoir-merged into one ([`ReplayBuffer::merge_oldest_pair`]), so the
/// learner's memory and per-commit `sample_past` pool stay bounded while
/// the replayable history span keeps growing on long-lived serve loops.
const MAX_REPLAY_SEGMENTS: usize = 16;

/// One finalized training window, ready for the committer thread: the
/// fresh labeled window mixed with replayed history, plus the wear-guard
/// ratio the commit must apply. Assembled deterministically on the serve
/// thread; applied (in generation order) by the single-writer committer.
pub struct CommitBatch {
    pub batch: SeqBatch,
    /// Wear guard forwarded to `train_whole_guarded` (0 = no rationing).
    pub wear_ratio: f32,
}

/// Accumulates labeled sequences and finalizes replay-mixed DFA windows.
pub struct OnlineLearner {
    nt: usize,
    nx: usize,
    /// Labeled steps per commit; 0 disables training (inference-only).
    update_every: usize,
    /// Fraction of each commit batch drawn from replay.
    mix: f32,
    /// Wear guard: columns beyond `wear_ratio ×` mean writes skip commits
    /// (0 disables; only wear-accounting substrates ration).
    wear_ratio: f32,
    buffer: ReplayBuffer,
    rng: GaussianRng,
    /// The not-yet-committed window, each entry tagged with the session
    /// that produced it so a migration (DESIGN.md §14) can carve one
    /// session's contribution out of the window without reordering the
    /// rest.
    pending: Vec<(u64, Example)>,
    pub observed: u64,
    /// Windows finalized (== commit generations enqueued).
    pub updates: u64,
    /// Cumulative columns rationed by the wear guard (fed back from the
    /// committer's results by [`super::ServeCore`]).
    pub rationed_cols: u64,
}

/// The learner's full durable state, as serialized by `serve::checkpoint`:
/// counters, the not-yet-committed window, the Box–Muller sampling stream,
/// and the replay buffer's segments (with their stable ids, so delta
/// snapshots can ship changed segments only) plus both hardware RNG
/// states. A learner restored from this continues bit-identically.
#[derive(Clone, Debug)]
pub struct LearnerState {
    pub observed: u64,
    pub updates: u64,
    pub rationed_cols: u64,
    /// `(session, example)` — the window entries keep their producing
    /// session's id across checkpoint/restore so migrations stay
    /// possible after a restart.
    pub pending: Vec<(u64, Example)>,
    pub rng_state: u64,
    pub rng_spare: Option<f32>,
    pub segments: Vec<Vec<QuantizedExample>>,
    pub segment_ids: Vec<u64>,
    pub next_segment_id: u64,
    pub sampler_seen: u64,
    pub sampler_rng: u32,
    pub quant_lfsr: u16,
}

/// The learner's delta against the last snapshot: everything scalar (it
/// is small), but segment *contents* only for segments that changed —
/// `segment_order` alone captures rolls, merges and drops.
#[derive(Clone, Debug)]
pub struct LearnerDelta {
    pub observed: u64,
    pub updates: u64,
    pub rationed_cols: u64,
    pub pending: Vec<(u64, Example)>,
    pub rng_state: u64,
    pub rng_spare: Option<f32>,
    /// Full segment id order, oldest first.
    pub segment_order: Vec<u64>,
    /// `(id, contents)` of segments dirtied since the last snapshot.
    pub changed: Vec<(u64, Vec<QuantizedExample>)>,
    pub next_segment_id: u64,
    pub sampler_seen: u64,
    pub sampler_rng: u32,
    pub quant_lfsr: u16,
}

impl OnlineLearner {
    /// Features are expected in [-1, 1] (the synthetic serve workload's
    /// range), matching the replay quantizer's offset/scale.
    pub fn new(nt: usize, nx: usize, cfg: &ServeConfig, seed: u64) -> OnlineLearner {
        let mut buffer = ReplayBuffer::new(cfg.replay_cap, -1.0, 2.0, seed as u32 ^ 0x0911_CE5E);
        buffer.begin_task();
        OnlineLearner {
            nt,
            nx,
            update_every: cfg.update_every,
            // programmatic construction bypasses ServeConfig::validate;
            // mix = 1.0 would make the replay-share formula divide by
            // zero, so enforce the same [0, 0.9] bound here
            mix: cfg.replay_mix.clamp(0.0, 0.9),
            wear_ratio: if cfg.wear_ratio >= 1.0 { cfg.wear_ratio } else { 0.0 },
            buffer,
            rng: GaussianRng::new(seed ^ 0x0911_0B5E),
            pending: Vec::new(),
            observed: 0,
            updates: 0,
            rationed_cols: 0,
        }
    }

    /// Capture the learner's durable state for a full checkpoint.
    pub fn snapshot(&self) -> LearnerState {
        let (rng_state, rng_spare) = self.rng.state();
        let (sampler_seen, sampler_rng) = self.buffer.sampler_state();
        LearnerState {
            observed: self.observed,
            updates: self.updates,
            rationed_cols: self.rationed_cols,
            pending: self.pending.clone(),
            rng_state,
            rng_spare,
            segments: self.buffer.segments().to_vec(),
            segment_ids: self.buffer.segment_ids().to_vec(),
            next_segment_id: self.buffer.next_segment_id(),
            sampler_seen,
            sampler_rng,
            quant_lfsr: self.buffer.quantizer_state(),
        }
    }

    /// Capture the delta since the last snapshot mark and clear the
    /// replay dirty set (the caller owns getting the delta to disk).
    pub fn delta(&mut self) -> LearnerDelta {
        let (rng_state, rng_spare) = self.rng.state();
        let (sampler_seen, sampler_rng) = self.buffer.sampler_state();
        LearnerDelta {
            observed: self.observed,
            updates: self.updates,
            rationed_cols: self.rationed_cols,
            pending: self.pending.clone(),
            rng_state,
            rng_spare,
            segment_order: self.buffer.segment_ids().to_vec(),
            changed: self.buffer.take_dirty(),
            next_segment_id: self.buffer.next_segment_id(),
            sampler_seen,
            sampler_rng,
            quant_lfsr: self.buffer.quantizer_state(),
        }
    }

    /// Full-snapshot hook: every segment was captured, restart the delta
    /// tracking from a clean slate.
    pub fn mark_clean(&mut self) {
        self.buffer.mark_clean();
    }

    /// Restore from [`OnlineLearner::snapshot`]; policy knobs
    /// (`update_every`, mix, wear ratio, capacities) stay as configured.
    pub fn restore(&mut self, s: LearnerState) {
        self.observed = s.observed;
        self.updates = s.updates;
        self.rationed_cols = s.rationed_cols;
        self.pending = s.pending;
        self.rng = GaussianRng::from_state(s.rng_state, s.rng_spare);
        self.buffer.restore_state(
            s.segments,
            s.segment_ids,
            s.next_segment_id,
            s.sampler_seen,
            s.sampler_rng,
            s.quant_lfsr,
        );
    }

    /// Record one labeled `nt*nx` sequence produced by `session`.
    /// Returns `Some(batch)` when this observation filled the window:
    /// the finalized replay-mixed commit batch, which the caller queues
    /// to the committer thread.
    pub fn observe(&mut self, session: u64, features: Vec<f32>, label: usize) -> Option<CommitBatch> {
        debug_assert_eq!(features.len(), self.nt * self.nx);
        self.observed += 1;
        if self.update_every == 0 {
            // inference-only mode: don't quantize into the reservoir or
            // grow `pending` for data that will never be trained on
            return None;
        }
        let ex = Example { features, label };
        self.buffer.offer(&ex);
        self.pending.push((session, ex));
        if self.pending.len() < self.update_every {
            return None;
        }
        Some(self.roll_window())
    }

    /// Migration hook (DESIGN.md §14): carve `session`'s uncommitted
    /// window entries out of `pending`, preserving the relative order of
    /// both what leaves and what stays. Already-committed history is
    /// baked into this shard's weights and reservoir and does not move —
    /// the attributable contribution of a live session is exactly its
    /// not-yet-committed examples.
    pub fn extract_pending(&mut self, session: u64) -> Vec<Example> {
        let mut moved = Vec::new();
        self.pending.retain_mut(|(sid, ex)| {
            if *sid == session {
                moved.push(std::mem::replace(ex, Example { features: Vec::new(), label: 0 }));
                false
            } else {
                true
            }
        });
        moved
    }

    /// Migration hook: append a migrated session's uncommitted window
    /// entries (in their original order) to this learner's window. They
    /// are *not* re-offered to the reservoir — the reservoir is
    /// shard-local history and the source shard already sampled them
    /// (the determinism contract in DESIGN.md §14 pins this down). The
    /// window finalizes at the next [`OnlineLearner::observe`] even if
    /// the injection pushed it past `update_every`.
    pub fn inject_pending(&mut self, session: u64, examples: Vec<Example>) {
        if self.update_every == 0 {
            return; // inference-only target: nothing will ever train
        }
        for ex in examples {
            self.pending.push((session, ex));
        }
    }

    /// Labeled sequences waiting for the next commit window to fill.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Replay segments accumulated so far (one per committed window).
    pub fn replay_segments(&self) -> usize {
        self.buffer.num_tasks()
    }

    /// Finalize the filled window into a commit batch and roll the
    /// reservoir: this window's examples become replayable history for
    /// the next commit; beyond the retention cap the two oldest segments
    /// reservoir-merge into one, so a long-lived server stays bounded
    /// without forgetting its oldest windows outright.
    fn roll_window(&mut self) -> CommitBatch {
        // replay share: mix = r/(fresh+r)  =>  r = fresh * mix/(1-mix)
        let n_replay = if self.mix > 0.0 {
            ((self.pending.len() as f32) * self.mix / (1.0 - self.mix)).round() as usize
        } else {
            0
        };
        let replayed = self.buffer.sample_past(n_replay, &mut self.rng);
        let b = self.pending.len() + replayed.len();
        let mut sb = SeqBatch::zeros(b, self.nt, self.nx);
        for (i, ex) in self.pending.iter().map(|(_, ex)| ex).chain(replayed.iter()).enumerate() {
            sb.sample_mut(i).copy_from_slice(&ex.features);
            sb.labels[i] = ex.label;
        }
        self.buffer.begin_task();
        // A single merge per commit is not enough: a restore (or a
        // migration flood) can hand this learner a buffer already far
        // past the cap, and merging one pair per finalized window would
        // leave it over-cap for many commits. `enforce_segment_cap`
        // loops until the retention cap actually holds.
        self.buffer.enforce_segment_cap(MAX_REPLAY_SEGMENTS, &mut self.rng);
        self.pending.clear();
        self.updates += 1;
        CommitBatch { batch: sb, wear_ratio: self.wear_ratio }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendCtx, BackendRegistry};
    use crate::config::NetConfig;
    use crate::coordinator::ParallelEngine;

    fn engine(seed: u64) -> ParallelEngine {
        let ctx = BackendCtx { seed, ..BackendCtx::new(NetConfig::SMALL) };
        ParallelEngine::new(BackendRegistry::with_defaults().create("dense", &ctx).unwrap(), 1)
    }

    /// Apply a finalized window the way the committer thread does.
    fn apply(engine: &mut ParallelEngine, cb: CommitBatch) -> f32 {
        let (loss, _) = engine.train_whole_guarded(&cb.batch, cb.wear_ratio).unwrap();
        loss
    }

    fn seq(net: &NetConfig, label: usize, seed: u64) -> Vec<f32> {
        let mut rng = GaussianRng::new(seed);
        (0..net.nt * net.nx)
            .map(|_| (0.5 * rng.normal() + 0.2 * label as f32).clamp(-1.0, 1.0))
            .collect()
    }

    #[test]
    fn finalizes_every_update_every_labels() {
        let net = NetConfig::SMALL;
        let cfg = ServeConfig { update_every: 4, ..ServeConfig::default() };
        let mut learner = OnlineLearner::new(net.nt, net.nx, &cfg, 1);
        let mut eng = engine(1);
        let mut commits = 0;
        for i in 0..12u64 {
            let label = (i % net.ny as u64) as usize;
            if let Some(cb) = learner.observe(i, seq(&net, label, 100 + i), label) {
                apply(&mut eng, cb);
                commits += 1;
            }
        }
        assert_eq!(commits, 3);
        assert_eq!(learner.updates, 3);
        assert_eq!(learner.observed, 12);
        assert_eq!(learner.pending(), 0);
        // 3 committed windows rolled + 1 live segment
        assert_eq!(learner.replay_segments(), 4);
    }

    #[test]
    fn replay_history_stays_bounded_across_many_commits() {
        let net = NetConfig::SMALL;
        let cfg = ServeConfig { update_every: 1, ..ServeConfig::default() };
        let mut learner = OnlineLearner::new(net.nt, net.nx, &cfg, 3);
        for i in 0..(MAX_REPLAY_SEGMENTS as u64 + 20) {
            // windows finalize deterministically whether or not a
            // committer ever applies them
            let _ = learner.observe(i, seq(&net, 0, i), 0);
        }
        assert_eq!(learner.updates, MAX_REPLAY_SEGMENTS as u64 + 20);
        assert_eq!(learner.replay_segments(), MAX_REPLAY_SEGMENTS);
    }

    #[test]
    fn update_every_zero_disables_training() {
        let net = NetConfig::SMALL;
        let cfg = ServeConfig { update_every: 0, ..ServeConfig::default() };
        let mut learner = OnlineLearner::new(net.nt, net.nx, &cfg, 2);
        for i in 0..10u64 {
            assert!(learner.observe(i, seq(&net, 0, i), 0).is_none());
        }
        assert_eq!(learner.updates, 0);
        assert_eq!(learner.pending(), 0, "inference-only mode must not accumulate windows");
    }

    #[test]
    fn snapshot_restore_resumes_bit_identically() {
        let net = NetConfig::SMALL;
        let cfg = ServeConfig { update_every: 3, ..ServeConfig::default() };
        // learner A runs 7 observations straight through
        let mut a = OnlineLearner::new(net.nt, net.nx, &cfg, 11);
        let mut eng_a = engine(11);
        for i in 0..4u64 {
            if let Some(cb) = a.observe(i, seq(&net, 0, 300 + i), 0) {
                apply(&mut eng_a, cb);
            }
        }
        // learner B snapshots at step 4 and restores into a fresh instance
        let state = a.snapshot();
        let mut b = OnlineLearner::new(net.nt, net.nx, &cfg, 999);
        b.restore(state);
        assert_eq!(b.observed, 4);
        assert_eq!(b.pending(), a.pending());
        // identical continuation: same finalized windows, same weights
        // (engine B's weights are first restored to A's current state)
        let mut eng_b = engine(11);
        eng_b.restore_params(&eng_a.backend().effective_params()).unwrap();
        for i in 4..7u64 {
            let ca = a.observe(i, seq(&net, 1, 300 + i), 1);
            let cb = b.observe(i, seq(&net, 1, 300 + i), 1);
            match (ca, cb) {
                (Some(wa), Some(wb)) => {
                    assert_eq!(wa.batch.data, wb.batch.data, "windows diverge at observation {i}");
                    assert_eq!(apply(&mut eng_a, wa), apply(&mut eng_b, wb), "losses diverge");
                }
                (None, None) => {}
                _ => panic!("window boundaries diverge at observation {i}"),
            }
        }
        assert_eq!(
            eng_a.backend().effective_params().flatten(),
            eng_b.backend().effective_params().flatten(),
            "restored learner must finalize bit-identical windows"
        );
    }

    #[test]
    fn extract_pending_carves_one_session_preserving_order() {
        let net = NetConfig::SMALL;
        let cfg = ServeConfig { update_every: 100, ..ServeConfig::default() };
        let mut learner = OnlineLearner::new(net.nt, net.nx, &cfg, 13);
        // interleave two sessions: 7, 9, 7, 9, 7
        for (i, sid) in [7u64, 9, 7, 9, 7].iter().enumerate() {
            let _ = learner.observe(*sid, seq(&net, i % 2, 600 + i as u64), i % 2);
        }
        assert_eq!(learner.pending(), 5);
        let moved = learner.extract_pending(7);
        assert_eq!(moved.len(), 3, "exactly session 7's entries leave");
        assert_eq!(learner.pending(), 2, "session 9's entries stay");
        assert!(learner.extract_pending(7).is_empty(), "double extract finds nothing");
        // inject into a fresh learner; the entries append in order and
        // the window finalizes at the next observe
        let cfg2 = ServeConfig { update_every: 4, ..ServeConfig::default() };
        let mut target = OnlineLearner::new(net.nt, net.nx, &cfg2, 14);
        target.inject_pending(7, moved);
        assert_eq!(target.pending(), 3);
        let cb = target.observe(7, seq(&net, 0, 700), 0);
        assert!(cb.is_some(), "injection counts toward the window");
        assert_eq!(cb.unwrap().batch.labels.len() >= 4, true);
        // an inference-only target drops the contribution outright
        let cfg3 = ServeConfig { update_every: 0, ..ServeConfig::default() };
        let mut frozen = OnlineLearner::new(net.nt, net.nx, &cfg3, 15);
        frozen.inject_pending(7, vec![Example { features: vec![0.0; net.nt * net.nx], label: 0 }]);
        assert_eq!(frozen.pending(), 0);
    }

    #[test]
    fn merged_history_retains_oldest_windows() {
        let net = NetConfig::SMALL;
        // tiny replay segments force many rolls past the 16-segment cap
        let cfg =
            ServeConfig { update_every: 1, replay_cap: 4, replay_mix: 0.0, ..ServeConfig::default() };
        let mut learner = OnlineLearner::new(net.nt, net.nx, &cfg, 5);
        for i in 0..(MAX_REPLAY_SEGMENTS as u64 + 8) {
            let _ = learner.observe(i, seq(&net, 0, i), 0);
        }
        assert_eq!(learner.replay_segments(), MAX_REPLAY_SEGMENTS, "cap still enforced");
    }

    #[test]
    fn applied_windows_change_weights_deterministically() {
        let net = NetConfig::SMALL;
        let cfg = ServeConfig { update_every: 3, ..ServeConfig::default() };
        let run = |eng_seed: u64| -> Vec<f32> {
            let mut learner = OnlineLearner::new(net.nt, net.nx, &cfg, 7);
            let mut eng = engine(eng_seed);
            for i in 0..6u64 {
                let label = (i % net.ny as u64) as usize;
                if let Some(cb) = learner.observe(i, seq(&net, label, 50 + i), label) {
                    apply(&mut eng, cb);
                }
            }
            eng.backend().effective_params().flatten()
        };
        let a = run(5);
        let b = run(5);
        assert_ne!(a, engine(5).backend().effective_params().flatten(), "weights moved");
        assert_eq!(a, b, "online training must be deterministic given the seed");
    }
}
