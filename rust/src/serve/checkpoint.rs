//! Durable sessions: serialize the entire serve-loop state to a chain of
//! versioned binary snapshots — periodic **full** rewrites plus
//! incremental **deltas** against the last full — and restore it on
//! boot, so a killed and restarted server resumes every live session
//! with bitwise-identical hidden state (DESIGN.md §10).
//!
//! ## Files in a checkpoint directory
//!
//! ```text
//! snapshot.m2ck              the last full snapshot (format v4)
//! delta-<epoch>-<seq>.m2cd   deltas since it, seq = 1, 2, …
//! ```
//!
//! Every full snapshot carries a fresh random `epoch`; its deltas embed
//! that epoch in both the filename and the payload. Restore reads the
//! full snapshot, then applies the *contiguous* run of its own deltas
//! `1..n` — a gap, a checksum failure or an epoch mismatch ends the
//! chain there (crash-consistent prefix). Writing a new full snapshot
//! starts a new epoch and deletes the previous chain's delta files
//! (compaction); a crash between the rename and the cleanup leaves
//! stale deltas that the epoch check makes inert.
//!
//! ## File envelope (shared by both forms, all integers little-endian)
//!
//! ```text
//! magic    u32   "M2CK" (full) / "M2CD" (delta)
//! version  u32   4
//! len      u64   payload byte count
//! payload  [len] sections (see DESIGN.md §10)
//! checksum u64   FNV-1a 64 over the payload
//! ```
//!
//! A full payload holds: shapes (refused on mismatch), model weights in
//! artifact order, the substrate wear record (per-device write counters
//! + Ziksa totals), the logical tick, the session-id secret, the chain
//! epoch, deterministic serve metrics, batcher counters **and the
//! batcher's still-queued requests** (a crash snapshot resumes queued
//! work), the session store (every live slot with its exact LRU touch
//! value), and the online learner (counters, session-tagged pending
//! window, Box–Muller stream, 4-bit replay segments with stable ids,
//! reservoir + LFSR states). A delta payload holds the same scalars
//! (they are tiny) but only the *dirty* sessions, the removed session
//! ids, the replay segments whose contents changed, and — because the
//! ζ-sparse learning rule touches a rationed subset of columns per
//! update — a **sparse weight delta**: the columns (hidden j across
//! `wh[:,j]`/`uh[:,j]`/`bh[j]`, readout c across `wo[:,c]`/`bo[c]`)
//! that differ bitwise from the chain's base full snapshot, cumulative
//! since that base. Restore reconstructs weights as base + columns, so
//! a column that reverts to its base value simply drops out of later
//! deltas. The dominant state (weights, session slabs, replay history)
//! is written incrementally.
//!
//! Writes go to a temp file in the same directory followed by an atomic
//! rename. The `[net] fsync_policy` knob picks the durability point:
//! `always` fsyncs every file (and the directory) before trusting it,
//! `full` fsyncs only full snapshots (a crash may lose the delta tail —
//! never the full baseline), `never` trusts the OS cache. Loads verify
//! magic, version, length and checksum; corruption of the full snapshot
//! makes [`try_restore`] report [`RestoreOutcome::Corrupt`] and the
//! server boots fresh with a warning instead of dying.
//!
//! A snapshot holds *state*, not configuration: restore assumes the
//! server boots with the same run configuration (seed, shapes, serve
//! policy), from which config-derived constants — notably the DFA
//! feedback matrix ψ — are reconstructed identically. Shapes are
//! verified; the rest is the operator's contract, like any database's
//! config file.
//!
//! Snapshot *writing* runs on the committer thread (`serve::commit`):
//! the serve loop assembles the state and queues it; encoding, fsync
//! and rename never stall dispatch. [`save_checkpoint`]/[`save_delta`]
//! are the synchronous variants for tests and benches.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::backend::WearState;
use crate::codec::{LeReader, LeWriter};
use crate::config::{FsyncPolicy, TransportConfig};
use crate::data::Example;
use crate::linalg::Mat;
use crate::nn::MiruParams;
use crate::replay::QuantizedExample;

use super::batcher::{BatcherStats, QueuedStep};
use super::core::ServeCore;
use super::metrics::ServeMetrics;
use super::online::{LearnerDelta, LearnerState};
use super::session::{SessionSnapshot, SessionStats};

const MAGIC: u32 = u32::from_le_bytes(*b"M2CK");
const DELTA_MAGIC: u32 = u32::from_le_bytes(*b"M2CD");
const VERSION: u32 = 4;
/// Full-snapshot file name inside `--checkpoint-dir`.
pub const SNAPSHOT_FILE: &str = "snapshot.m2ck";
const TMP_SUFFIX: &str = ".tmp";

/// When a chain of snapshots is written and which files are fsynced —
/// from `[net] snapshot_full_every` / `fsync_policy`.
#[derive(Clone, Debug)]
pub struct SnapshotPolicy {
    /// Every Nth snapshot is a full rewrite (1 = always full, i.e.
    /// incremental snapshots off).
    pub full_every: u64,
    pub fsync: FsyncPolicy,
}

impl SnapshotPolicy {
    /// The policy configured in `[net]`.
    pub fn from_net(net: &TransportConfig) -> Result<SnapshotPolicy> {
        Ok(SnapshotPolicy { full_every: net.snapshot_full_every.max(1), fsync: net.fsync()? })
    }

    /// Full snapshots every time, everything fsynced — the pre-v3
    /// behavior, and what [`save_checkpoint`] uses.
    pub fn always_full() -> SnapshotPolicy {
        SnapshotPolicy { full_every: 1, fsync: FsyncPolicy::Always }
    }

    pub fn fsync_full(&self) -> bool {
        matches!(self.fsync, FsyncPolicy::Always | FsyncPolicy::FullOnly)
    }

    pub fn fsync_delta(&self) -> bool {
        matches!(self.fsync, FsyncPolicy::Always)
    }
}

/// The scalar half of a snapshot — small enough to ride in *every*
/// file, full or delta, as one unit. Keeping it one struct with one
/// encoder/decoder pair means a new durable scalar cannot be added to
/// the full form and silently missed by the delta form (or by
/// [`merge_delta`], which replaces it wholesale). The model weights are
/// *not* scalars since v4: a full snapshot carries them whole, a delta
/// carries the sparse changed-columns diff (see [`ParamsDelta`]).
#[derive(Clone)]
pub struct SnapshotScalars {
    pub wear: Option<WearState>,
    pub tick: u64,
    pub session_secret: u64,
    pub metrics: ServeMetrics,
    pub batcher: BatcherStats,
    /// The batcher's still-queued requests at snapshot time.
    pub pending: Vec<QueuedStep>,
    pub touch_counter: u64,
    pub store_stats: SessionStats,
}

/// Everything a full snapshot holds, decoded (after a chain restore,
/// the merged view of full + deltas).
#[derive(Clone)]
pub struct Snapshot {
    pub nh: usize,
    pub nx: usize,
    pub nt: usize,
    pub ny: usize,
    /// Chain epoch of the base full snapshot.
    pub epoch: u64,
    /// Model weights, whole — the base the chain's sparse weight
    /// deltas are applied against.
    pub params: MiruParams,
    pub scalars: SnapshotScalars,
    pub sessions: Vec<SessionSnapshot>,
    pub learner: LearnerState,
}

/// The columns of the model that differ bitwise from the chain's base
/// full snapshot — the ζ-sparse learning rule's natural write unit
/// (DESIGN.md §10). Cumulative since the base: restore reconstructs
/// weights as `base + columns`, so each delta stands alone against its
/// full snapshot and a column that reverts to its base value drops out.
#[derive(Clone, Default)]
pub struct ParamsDelta {
    /// Hidden columns `(j, wh[:,j], uh[:,j], bh[j])`, ascending `j`.
    pub hidden: Vec<(u32, Vec<f32>, Vec<f32>, f32)>,
    /// Readout columns `(c, wo[:,c], bo[c])`, ascending `c`.
    pub readout: Vec<(u32, Vec<f32>, f32)>,
}

impl ParamsDelta {
    /// Changed columns in total (a full model is `nh + ny`).
    pub fn cols(&self) -> usize {
        self.hidden.len() + self.readout.len()
    }
}

/// The columns of `cur` that differ bitwise from `base` (any element of
/// the column differing marks the whole column changed).
pub(crate) fn params_delta(base: &MiruParams, cur: &MiruParams) -> ParamsDelta {
    let nh = base.bh.len();
    let ny = base.bo.len();
    let mut d = ParamsDelta::default();
    for j in 0..nh {
        let wh_col: Vec<f32> = cur.wh.data.iter().skip(j).step_by(nh).copied().collect();
        let uh_col: Vec<f32> = cur.uh.data.iter().skip(j).step_by(nh).copied().collect();
        let same = cur.bh[j].to_bits() == base.bh[j].to_bits()
            && base.wh.data.iter().skip(j).step_by(nh).zip(&wh_col).all(|(a, b)| a.to_bits() == b.to_bits())
            && base.uh.data.iter().skip(j).step_by(nh).zip(&uh_col).all(|(a, b)| a.to_bits() == b.to_bits());
        if !same {
            d.hidden.push((j as u32, wh_col, uh_col, cur.bh[j]));
        }
    }
    for c in 0..ny {
        let wo_col: Vec<f32> = cur.wo.data.iter().skip(c).step_by(ny).copied().collect();
        let same = cur.bo[c].to_bits() == base.bo[c].to_bits()
            && base.wo.data.iter().skip(c).step_by(ny).zip(&wo_col).all(|(a, b)| a.to_bits() == b.to_bits());
        if !same {
            d.readout.push((c as u32, wo_col, cur.bo[c]));
        }
    }
    d
}

/// Scatter the delta's columns into `params` (which starts as a clone
/// of the chain's base).
pub(crate) fn apply_params_delta(params: &mut MiruParams, d: &ParamsDelta) -> Result<()> {
    let nh = params.bh.len();
    let ny = params.bo.len();
    for (j, wh_col, uh_col, bh) in &d.hidden {
        let j = *j as usize;
        ensure!(j < nh, "weight delta hidden column {j} out of range (nh {nh})");
        ensure!(
            wh_col.len() * nh == params.wh.data.len() && uh_col.len() * nh == params.uh.data.len(),
            "weight delta hidden column sizes inconsistent with shapes"
        );
        for (i, v) in wh_col.iter().enumerate() {
            params.wh.data[i * nh + j] = *v;
        }
        for (i, v) in uh_col.iter().enumerate() {
            params.uh.data[i * nh + j] = *v;
        }
        params.bh[j] = *bh;
    }
    for (c, wo_col, bo) in &d.readout {
        let c = *c as usize;
        ensure!(c < ny, "weight delta readout column {c} out of range (ny {ny})");
        ensure!(
            wo_col.len() * ny == params.wo.data.len(),
            "weight delta readout column size inconsistent with shapes"
        );
        for (i, v) in wo_col.iter().enumerate() {
            params.wo.data[i * ny + c] = *v;
        }
        params.bo[c] = *bo;
    }
    Ok(())
}

/// One incremental snapshot: full scalars, dirty state only.
#[derive(Clone)]
pub struct Delta {
    pub nh: usize,
    pub nx: usize,
    pub nt: usize,
    pub ny: usize,
    pub epoch: u64,
    pub seq: u64,
    pub scalars: SnapshotScalars,
    /// Weight columns changed (bitwise) since the base full snapshot.
    pub params: ParamsDelta,
    /// Session ids evicted/expired since the previous snapshot.
    pub removed: Vec<u64>,
    /// Sessions mutated since the previous snapshot (exact LRU touches).
    pub dirty_sessions: Vec<SessionSnapshot>,
    pub learner: LearnerDelta,
}

/// A snapshot write assembled by the serve thread, executed on the
/// committer thread.
pub enum SnapshotJob {
    Full { state: Box<Snapshot>, dir: PathBuf, fsync: bool },
    Delta { state: Box<Delta>, dir: PathBuf, fsync: bool },
}

impl SnapshotJob {
    /// Where this snapshot will land.
    pub fn path(&self) -> PathBuf {
        match self {
            SnapshotJob::Full { dir, .. } => dir.join(SNAPSHOT_FILE),
            SnapshotJob::Delta { state, dir, .. } => dir.join(delta_file_name(state.epoch, state.seq)),
        }
    }
}

/// Execute one snapshot job (committer thread). A full write also
/// compacts the chain: stale delta files from previous epochs are
/// removed (best-effort — leftovers are inert under the epoch check).
pub(crate) fn write_snapshot_job(job: SnapshotJob) -> Result<PathBuf> {
    match job {
        SnapshotJob::Full { state, dir, fsync } => {
            let path = write_full(&state, &dir, fsync)?;
            purge_stale_deltas(&dir, state.epoch);
            Ok(path)
        }
        SnapshotJob::Delta { state, dir, fsync } => write_delta(&state, &dir, fsync),
    }
}

/// What booting against a checkpoint directory found.
#[derive(Debug)]
pub enum RestoreOutcome {
    /// No snapshot present — fresh boot.
    Fresh,
    /// Snapshot chain restored; every live session resumes its state.
    Restored { sessions: usize, tick: u64, deltas: usize },
    /// Snapshot present but unusable (bad checksum, truncation, shape
    /// mismatch) — the server boots fresh; the caller should warn.
    Corrupt { error: String },
}

/// A fresh nonzero chain epoch (OS entropy via the standard library's
/// hash seeding — a file-chain tag, never serving state).
pub(crate) fn random_epoch() -> u64 {
    use std::hash::{BuildHasher, Hasher};
    loop {
        let e = std::collections::hash_map::RandomState::new().build_hasher().finish();
        if e != 0 {
            return e;
        }
    }
}

/// `delta-<epoch>-<seq>.m2cd`.
fn delta_file_name(epoch: u64, seq: u64) -> String {
    format!("delta-{epoch:016x}-{seq:06}.m2cd")
}

/// Parse a delta file name back to `(epoch, seq)`.
fn parse_delta_name(name: &str) -> Option<(u64, u64)> {
    let middle = name.strip_prefix("delta-")?.strip_suffix(".m2cd")?;
    let (epoch_hex, seq_str) = middle.split_once('-')?;
    let epoch = u64::from_str_radix(epoch_hex, 16).ok()?;
    let seq = seq_str.parse::<u64>().ok()?;
    Some((epoch, seq))
}

// ---------------------------------------------------------------- encoding

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

pub(crate) fn enc_shapes(w: &mut LeWriter, nh: usize, nx: usize, nt: usize, ny: usize) {
    w.u32(nh as u32);
    w.u32(nx as u32);
    w.u32(nt as u32);
    w.u32(ny as u32);
}

pub(crate) fn dec_shapes(r: &mut LeReader) -> Result<(usize, usize, usize, usize)> {
    let nh = r.u32()? as usize;
    let nx = r.u32()? as usize;
    let nt = r.u32()? as usize;
    let ny = r.u32()? as usize;
    ensure!(nh >= 1 && nx >= 1 && nt >= 1 && ny >= 1, "degenerate snapshot shapes");
    Ok((nh, nx, nt, ny))
}

fn enc_params(w: &mut LeWriter, p: &MiruParams) {
    // weights, artifact order
    w.f32s(&p.wh.data);
    w.f32s(&p.uh.data);
    w.f32s(&p.bh);
    w.f32s(&p.wo.data);
    w.f32s(&p.bo);
}

fn dec_params(r: &mut LeReader, nh: usize, nx: usize, ny: usize) -> Result<MiruParams> {
    let wh = r.f32s()?;
    let uh = r.f32s()?;
    let bh = r.f32s()?;
    let wo = r.f32s()?;
    let bo = r.f32s()?;
    ensure!(
        wh.len() == nx * nh && uh.len() == nh * nh && bh.len() == nh && wo.len() == nh * ny
            && bo.len() == ny,
        "weight section sizes inconsistent with shapes"
    );
    Ok(MiruParams {
        wh: Mat::from_vec(nx, nh, wh),
        uh: Mat::from_vec(nh, nh, uh),
        bh,
        wo: Mat::from_vec(nh, ny, wo),
        bo,
    })
}

fn enc_wear(w: &mut LeWriter, wear: &Option<WearState>) {
    match wear {
        None => w.u8(0),
        Some(ws) => {
            w.u8(1);
            w.u64s(&ws.hidden);
            w.u64s(&ws.readout);
            w.u64(ws.steps);
            w.u64(ws.writes);
            w.u64(ws.skipped);
            w.f64(ws.delta_magnitude);
        }
    }
}

fn dec_wear(r: &mut LeReader) -> Result<Option<WearState>> {
    match r.u8()? {
        0 => Ok(None),
        1 => Ok(Some(WearState {
            hidden: r.u64s()?,
            readout: r.u64s()?,
            steps: r.u64()?,
            writes: r.u64()?,
            skipped: r.u64()?,
            delta_magnitude: r.f64()?,
        })),
        other => bail!("bad wear flag {other}"),
    }
}

/// Deterministic metrics only (wall clock and latency samples are
/// measurements, not state). `latency_overwrites` is likewise excluded
/// on purpose: it describes the discarded latency samples, so a restored
/// server starts with a fresh, unwrapped window (decode leaves it 0).
fn enc_metrics(w: &mut LeWriter, m: &ServeMetrics) {
    w.u64(m.requests);
    w.u64(m.batches);
    w.u64(m.padded_rows);
    w.u64(m.valid_rows);
    w.u64(m.wait_ticks_sum);
    w.u64(m.pred_fingerprint);
    w.u64(m.labeled);
    w.u64(m.labeled_correct);
    w.u64(m.online_updates);
    w.f64(m.online_loss_sum);
    w.u64(m.wear_rationed);
}

fn dec_metrics(r: &mut LeReader) -> Result<ServeMetrics> {
    let mut m = ServeMetrics::default();
    m.requests = r.u64()?;
    m.batches = r.u64()?;
    m.padded_rows = r.u64()?;
    m.valid_rows = r.u64()?;
    m.wait_ticks_sum = r.u64()?;
    m.pred_fingerprint = r.u64()?;
    m.labeled = r.u64()?;
    m.labeled_correct = r.u64()?;
    m.online_updates = r.u64()?;
    m.online_loss_sum = r.f64()?;
    m.wear_rationed = r.u64()?;
    Ok(m)
}

fn enc_batcher(w: &mut LeWriter, b: &BatcherStats) {
    w.u64(b.enqueued);
    w.u64(b.batches);
    w.u64(b.dispatched);
    w.u64(b.deferred_dups);
}

fn dec_batcher(r: &mut LeReader) -> Result<BatcherStats> {
    Ok(BatcherStats {
        enqueued: r.u64()?,
        batches: r.u64()?,
        dispatched: r.u64()?,
        deferred_dups: r.u64()?,
    })
}

/// Queued requests: `label` rides as `0` (none) or `label + 1`.
fn enc_pending(w: &mut LeWriter, pending: &[QueuedStep]) {
    w.u32(pending.len() as u32);
    for q in pending {
        w.u64(q.session);
        w.u32(match q.label {
            None => 0,
            Some(l) => l as u32 + 1,
        });
        w.u64(q.enqueued_tick);
        w.u64(q.tag);
        w.f32s(&q.x);
    }
}

fn dec_pending(r: &mut LeReader, nx: usize, ny: usize) -> Result<Vec<QueuedStep>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let session = r.u64()?;
        let label = match r.u32()? {
            0 => None,
            l => {
                // out-of-range labels would index the one-hot/loss rows
                // out of bounds at dispatch — a malformed snapshot must
                // error here, never panic later (same rule as the wire)
                let l = (l - 1) as usize;
                ensure!(l < ny, "queued request label {l} out of range (ny {ny})");
                Some(l)
            }
        };
        let enqueued_tick = r.u64()?;
        let tag = r.u64()?;
        let x = r.f32s()?;
        ensure!(x.len() == nx, "queued request width {} != nx {nx}", x.len());
        out.push(QueuedStep { session, x, label, enqueued_tick, tag });
    }
    Ok(out)
}

fn enc_store_stats(w: &mut LeWriter, s: &SessionStats) {
    w.u64(s.created);
    w.u64(s.hits);
    w.u64(s.misses);
    w.u64(s.evicted_lru);
    w.u64(s.expired_ttl);
}

fn dec_store_stats(r: &mut LeReader) -> Result<SessionStats> {
    Ok(SessionStats {
        created: r.u64()?,
        hits: r.u64()?,
        misses: r.u64()?,
        evicted_lru: r.u64()?,
        expired_ttl: r.u64()?,
    })
}

pub(crate) fn enc_sessions(w: &mut LeWriter, sessions: &[SessionSnapshot]) {
    w.u32(sessions.len() as u32);
    for s in sessions {
        w.u64(s.id);
        w.u64(s.last_tick);
        w.u64(s.last_touch);
        w.u64(s.steps);
        w.u32(s.hist_rows as u32);
        w.u32(s.hist_head as u32);
        w.f32s(&s.h);
        w.f32s(&s.hist);
    }
}

pub(crate) fn dec_sessions(
    r: &mut LeReader,
    nh: usize,
    nt: usize,
    nx: usize,
) -> Result<Vec<SessionSnapshot>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let id = r.u64()?;
        let last_tick = r.u64()?;
        let last_touch = r.u64()?;
        let steps = r.u64()?;
        let hist_rows = r.u32()? as usize;
        let hist_head = r.u32()? as usize;
        let h = r.f32s()?;
        let hist = r.f32s()?;
        ensure!(h.len() == nh, "session hidden width {} != nh {nh}", h.len());
        ensure!(hist.len() == nt * nx, "session history size {} != nt*nx", hist.len());
        out.push(SessionSnapshot { id, h, hist, hist_rows, hist_head, last_tick, last_touch, steps });
    }
    Ok(out)
}

pub(crate) fn enc_examples(w: &mut LeWriter, exs: &[Example]) {
    w.u32(exs.len() as u32);
    for ex in exs {
        w.u32(ex.label as u32);
        w.f32s(&ex.features);
    }
}

pub(crate) fn dec_examples(r: &mut LeReader, nt: usize, nx: usize, ny: usize) -> Result<Vec<Example>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let label = r.u32()? as usize;
        ensure!(label < ny, "window label {label} out of range (ny {ny})");
        let features = r.f32s()?;
        ensure!(features.len() == nt * nx, "pending window size {} != nt*nx", features.len());
        out.push(Example { features, label });
    }
    Ok(out)
}

/// The learner's pending window rides with its session tags (v4): a
/// live migration must carve one session's uncommitted examples out of
/// the window, so the snapshot preserves whose example each one is.
fn enc_tagged_examples(w: &mut LeWriter, exs: &[(u64, Example)]) {
    w.u32(exs.len() as u32);
    for (session, ex) in exs {
        w.u64(*session);
        w.u32(ex.label as u32);
        w.f32s(&ex.features);
    }
}

fn dec_tagged_examples(
    r: &mut LeReader,
    nt: usize,
    nx: usize,
    ny: usize,
) -> Result<Vec<(u64, Example)>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let session = r.u64()?;
        let label = r.u32()? as usize;
        ensure!(label < ny, "window label {label} out of range (ny {ny})");
        let features = r.f32s()?;
        ensure!(features.len() == nt * nx, "pending window size {} != nt*nx", features.len());
        out.push((session, Example { features, label }));
    }
    Ok(out)
}

fn enc_params_delta(w: &mut LeWriter, d: &ParamsDelta) {
    w.u32(d.hidden.len() as u32);
    for (j, wh_col, uh_col, bh) in &d.hidden {
        w.u32(*j);
        w.f32s(wh_col);
        w.f32s(uh_col);
        w.f32(*bh);
    }
    w.u32(d.readout.len() as u32);
    for (c, wo_col, bo) in &d.readout {
        w.u32(*c);
        w.f32s(wo_col);
        w.f32(*bo);
    }
}

fn dec_params_delta(r: &mut LeReader, nh: usize, nx: usize, ny: usize) -> Result<ParamsDelta> {
    let n_hidden = r.u32()? as usize;
    let mut hidden = Vec::with_capacity(n_hidden.min(1 << 20));
    for _ in 0..n_hidden {
        let j = r.u32()?;
        ensure!((j as usize) < nh, "weight delta hidden column {j} out of range (nh {nh})");
        let wh_col = r.f32s()?;
        let uh_col = r.f32s()?;
        let bh = r.f32()?;
        ensure!(
            wh_col.len() == nx && uh_col.len() == nh,
            "weight delta hidden column sizes inconsistent with shapes"
        );
        hidden.push((j, wh_col, uh_col, bh));
    }
    let n_readout = r.u32()? as usize;
    let mut readout = Vec::with_capacity(n_readout.min(1 << 20));
    for _ in 0..n_readout {
        let c = r.u32()?;
        ensure!((c as usize) < ny, "weight delta readout column {c} out of range (ny {ny})");
        let wo_col = r.f32s()?;
        let bo = r.f32()?;
        ensure!(wo_col.len() == nh, "weight delta readout column size inconsistent with shapes");
        readout.push((c, wo_col, bo));
    }
    Ok(ParamsDelta { hidden, readout })
}

fn enc_segment(w: &mut LeWriter, seg: &[QuantizedExample]) {
    w.u32(seg.len() as u32);
    for q in seg {
        w.u32(q.label as u32);
        w.u32(q.len as u32);
        w.bytes(&q.packed);
    }
}

fn dec_segment(r: &mut LeReader, ny: usize) -> Result<Vec<QuantizedExample>> {
    let n = r.u32()? as usize;
    let mut seg = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        let label = r.u32()? as usize;
        ensure!(label < ny, "replay label {label} out of range (ny {ny})");
        let len = r.u32()? as usize;
        let packed = r.byte_vec()?;
        ensure!(packed.len() == len.div_ceil(2), "packed length inconsistent with len");
        seg.push(QuantizedExample { packed, len, label });
    }
    Ok(seg)
}

fn enc_rng(w: &mut LeWriter, state: u64, spare: Option<f32>) {
    w.u64(state);
    match spare {
        Some(v) => {
            w.u8(1);
            w.f32(v);
        }
        None => w.u8(0),
    }
}

fn dec_rng(r: &mut LeReader) -> Result<(u64, Option<f32>)> {
    let state = r.u64()?;
    let spare = match r.u8()? {
        0 => None,
        1 => Some(r.f32()?),
        other => bail!("bad rng spare flag {other}"),
    };
    Ok((state, spare))
}

fn enc_learner(w: &mut LeWriter, l: &LearnerState) {
    w.u64(l.observed);
    w.u64(l.updates);
    w.u64(l.rationed_cols);
    enc_tagged_examples(w, &l.pending);
    enc_rng(w, l.rng_state, l.rng_spare);
    debug_assert_eq!(l.segments.len(), l.segment_ids.len());
    w.u32(l.segments.len() as u32);
    for (id, seg) in l.segment_ids.iter().zip(&l.segments) {
        w.u64(*id);
        enc_segment(w, seg);
    }
    w.u64(l.next_segment_id);
    w.u64(l.sampler_seen);
    w.u32(l.sampler_rng);
    w.u16(l.quant_lfsr);
}

fn dec_learner(r: &mut LeReader, nt: usize, nx: usize, ny: usize) -> Result<LearnerState> {
    let observed = r.u64()?;
    let updates = r.u64()?;
    let rationed_cols = r.u64()?;
    let pending = dec_tagged_examples(r, nt, nx, ny)?;
    let (rng_state, rng_spare) = dec_rng(r)?;
    let n_segs = r.u32()? as usize;
    let mut segments = Vec::with_capacity(n_segs.min(1 << 20));
    let mut segment_ids = Vec::with_capacity(n_segs.min(1 << 20));
    for _ in 0..n_segs {
        segment_ids.push(r.u64()?);
        segments.push(dec_segment(r, ny)?);
    }
    let next_segment_id = r.u64()?;
    let sampler_seen = r.u64()?;
    let sampler_rng = r.u32()?;
    let quant_lfsr = r.u16()?;
    Ok(LearnerState {
        observed,
        updates,
        rationed_cols,
        pending,
        rng_state,
        rng_spare,
        segments,
        segment_ids,
        next_segment_id,
        sampler_seen,
        sampler_rng,
        quant_lfsr,
    })
}

fn enc_learner_delta(w: &mut LeWriter, l: &LearnerDelta) {
    w.u64(l.observed);
    w.u64(l.updates);
    w.u64(l.rationed_cols);
    enc_tagged_examples(w, &l.pending);
    enc_rng(w, l.rng_state, l.rng_spare);
    w.u64s(&l.segment_order);
    w.u32(l.changed.len() as u32);
    for (id, seg) in &l.changed {
        w.u64(*id);
        enc_segment(w, seg);
    }
    w.u64(l.next_segment_id);
    w.u64(l.sampler_seen);
    w.u32(l.sampler_rng);
    w.u16(l.quant_lfsr);
}

fn dec_learner_delta(r: &mut LeReader, nt: usize, nx: usize, ny: usize) -> Result<LearnerDelta> {
    let observed = r.u64()?;
    let updates = r.u64()?;
    let rationed_cols = r.u64()?;
    let pending = dec_tagged_examples(r, nt, nx, ny)?;
    let (rng_state, rng_spare) = dec_rng(r)?;
    let segment_order = r.u64s()?;
    let n_changed = r.u32()? as usize;
    let mut changed = Vec::with_capacity(n_changed.min(1 << 20));
    for _ in 0..n_changed {
        let id = r.u64()?;
        changed.push((id, dec_segment(r, ny)?));
    }
    let next_segment_id = r.u64()?;
    let sampler_seen = r.u64()?;
    let sampler_rng = r.u32()?;
    let quant_lfsr = r.u16()?;
    Ok(LearnerDelta {
        observed,
        updates,
        rationed_cols,
        pending,
        rng_state,
        rng_spare,
        segment_order,
        changed,
        next_segment_id,
        sampler_seen,
        sampler_rng,
        quant_lfsr,
    })
}

fn enc_scalars(w: &mut LeWriter, s: &SnapshotScalars) {
    enc_wear(w, &s.wear);
    w.u64(s.tick);
    w.u64(s.session_secret);
    enc_metrics(w, &s.metrics);
    enc_batcher(w, &s.batcher);
    enc_pending(w, &s.pending);
    w.u64(s.touch_counter);
    enc_store_stats(w, &s.store_stats);
}

fn dec_scalars(r: &mut LeReader, nx: usize, ny: usize) -> Result<SnapshotScalars> {
    Ok(SnapshotScalars {
        wear: dec_wear(r)?,
        tick: r.u64()?,
        session_secret: r.u64()?,
        metrics: dec_metrics(r)?,
        batcher: dec_batcher(r)?,
        pending: dec_pending(r, nx, ny)?,
        touch_counter: r.u64()?,
        store_stats: dec_store_stats(r)?,
    })
}

fn encode_full(s: &Snapshot) -> Vec<u8> {
    let mut w = LeWriter::new();
    enc_shapes(&mut w, s.nh, s.nx, s.nt, s.ny);
    w.u64(s.epoch);
    enc_params(&mut w, &s.params);
    enc_scalars(&mut w, &s.scalars);
    enc_sessions(&mut w, &s.sessions);
    enc_learner(&mut w, &s.learner);
    w.into_vec()
}

fn decode_full(buf: &[u8]) -> Result<Snapshot> {
    let mut r = LeReader::new(buf);
    let (nh, nx, nt, ny) = dec_shapes(&mut r)?;
    let epoch = r.u64()?;
    let params = dec_params(&mut r, nh, nx, ny)?;
    let scalars = dec_scalars(&mut r, nx, ny)?;
    let sessions = dec_sessions(&mut r, nh, nt, nx)?;
    let learner = dec_learner(&mut r, nt, nx, ny)?;
    r.done()?;
    Ok(Snapshot { nh, nx, nt, ny, epoch, params, scalars, sessions, learner })
}

fn encode_delta(d: &Delta) -> Vec<u8> {
    let mut w = LeWriter::new();
    enc_shapes(&mut w, d.nh, d.nx, d.nt, d.ny);
    w.u64(d.epoch);
    w.u64(d.seq);
    enc_params_delta(&mut w, &d.params);
    enc_scalars(&mut w, &d.scalars);
    w.u64s(&d.removed);
    enc_sessions(&mut w, &d.dirty_sessions);
    enc_learner_delta(&mut w, &d.learner);
    w.into_vec()
}

fn decode_delta(buf: &[u8]) -> Result<Delta> {
    let mut r = LeReader::new(buf);
    let (nh, nx, nt, ny) = dec_shapes(&mut r)?;
    let epoch = r.u64()?;
    let seq = r.u64()?;
    let params = dec_params_delta(&mut r, nh, nx, ny)?;
    let scalars = dec_scalars(&mut r, nx, ny)?;
    let removed = r.u64s()?;
    let dirty_sessions = dec_sessions(&mut r, nh, nt, nx)?;
    let learner = dec_learner_delta(&mut r, nt, nx, ny)?;
    r.done()?;
    Ok(Delta { nh, nx, nt, ny, epoch, seq, params, scalars, removed, dirty_sessions, learner })
}

// ---------------------------------------------------------------- envelope

pub(crate) fn seal(magic: u32, payload: &[u8]) -> Vec<u8> {
    let mut f = LeWriter::from_vec(Vec::with_capacity(payload.len() + 24));
    f.u32(magic);
    f.u32(VERSION);
    f.u64(payload.len() as u64);
    f.raw(payload);
    f.u64(fnv1a64(payload));
    f.into_vec()
}

pub(crate) fn unseal(magic: u32, raw: &[u8]) -> Result<&[u8]> {
    ensure!(raw.len() >= 24, "snapshot shorter than its header");
    let got = u32::from_le_bytes([raw[0], raw[1], raw[2], raw[3]]);
    ensure!(got == magic, "bad snapshot magic {got:#010x}");
    let version = u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]);
    ensure!(version == VERSION, "unsupported snapshot version {version}");
    let len64 =
        u64::from_le_bytes([raw[8], raw[9], raw[10], raw[11], raw[12], raw[13], raw[14], raw[15]]);
    // bounds-check before any arithmetic: a hostile length field must not
    // overflow or allocate
    ensure!(
        len64 == (raw.len() as u64).saturating_sub(24),
        "snapshot length field inconsistent with file size"
    );
    let len = len64 as usize;
    let payload = &raw[16..16 + len];
    let stored = u64::from_le_bytes([
        raw[16 + len],
        raw[17 + len],
        raw[18 + len],
        raw[19 + len],
        raw[20 + len],
        raw[21 + len],
        raw[22 + len],
        raw[23 + len],
    ]);
    let computed = fnv1a64(payload);
    ensure!(stored == computed, "snapshot checksum mismatch ({stored:#x} != {computed:#x})");
    Ok(payload)
}

/// Validate and decode raw full-snapshot bytes.
fn parse_snapshot(raw: &[u8]) -> Result<Snapshot> {
    decode_full(unseal(MAGIC, raw)?)
}

/// Validate and decode raw delta bytes.
fn parse_delta(raw: &[u8]) -> Result<Delta> {
    decode_delta(unseal(DELTA_MAGIC, raw)?)
}

// ------------------------------------------------------------------- file IO

/// Write `bytes` into `dir/name` via temp file + atomic rename;
/// `fsync` controls whether the data and the rename are forced to disk
/// before returning (without it a power loss may lose this file — but a
/// *torn* file is still impossible, the rename is atomic either way).
fn write_file(dir: &Path, name: &str, bytes: &[u8], fsync: bool) -> Result<PathBuf> {
    use std::io::Write as _;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let tmp = dir.join(format!("{name}{TMP_SUFFIX}"));
    let path = dir.join(name);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes).with_context(|| format!("writing {}", tmp.display()))?;
        if fsync {
            // data must be on disk BEFORE the rename can be allowed to
            // commit — otherwise power loss can make the rename durable
            // with torn data, destroying the previous good file
            f.sync_all().with_context(|| format!("fsyncing {}", tmp.display()))?;
        }
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    if fsync {
        // make the rename itself durable (directory metadata);
        // directories cannot be opened on every platform, but where they
        // can, a failing fsync is a real durability error
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().with_context(|| format!("fsyncing directory {}", dir.display()))?;
        }
    }
    Ok(path)
}

fn write_full(state: &Snapshot, dir: &Path, fsync: bool) -> Result<PathBuf> {
    write_file(dir, SNAPSHOT_FILE, &seal(MAGIC, &encode_full(state)), fsync)
}

fn write_delta(state: &Delta, dir: &Path, fsync: bool) -> Result<PathBuf> {
    write_file(dir, &delta_file_name(state.epoch, state.seq), &seal(DELTA_MAGIC, &encode_delta(state)), fsync)
}

/// Remove delta files from epochs other than `keep_epoch` (compaction
/// after a full snapshot). Best-effort: leftovers are inert — restore
/// ignores deltas whose epoch does not match the full snapshot's.
fn purge_stale_deltas(dir: &Path, keep_epoch: u64) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some((epoch, _)) = parse_delta_name(name) {
            if epoch != keep_epoch {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

// ---------------------------------------------------------------- chain

/// Merge one delta into the (staged) base snapshot. `base_params` is
/// the *original* full snapshot's weights: each delta's column set is
/// cumulative against them, so the merged weights are reconstructed
/// `base + columns` every time (a column missing from this delta holds
/// its base value, even if an earlier delta changed it).
fn merge_delta(snap: &mut Snapshot, d: Delta, base_params: &MiruParams) -> Result<()> {
    ensure!(
        d.nh == snap.nh && d.nx == snap.nx && d.nt == snap.nt && d.ny == snap.ny,
        "delta shapes do not match the base snapshot"
    );
    ensure!(d.epoch == snap.epoch, "delta epoch does not match the base snapshot");
    // every scalar travels in every delta: replace them as one unit
    snap.scalars = d.scalars;
    let mut params = base_params.clone();
    apply_params_delta(&mut params, &d.params)?;
    snap.params = params;
    // sessions: remove, then upsert the dirty ones; order by exact touch
    let mut by_id: BTreeMap<u64, SessionSnapshot> =
        std::mem::take(&mut snap.sessions).into_iter().map(|s| (s.id, s)).collect();
    for id in &d.removed {
        by_id.remove(id);
    }
    for s in d.dirty_sessions {
        by_id.insert(s.id, s);
    }
    let mut sessions: Vec<SessionSnapshot> = by_id.into_values().collect();
    sessions.sort_by_key(|s| s.last_touch);
    snap.sessions = sessions;
    // learner: rebuild the segment list from the delta's id order; any
    // id neither in the base nor in the changed set breaks the chain
    let l = &mut snap.learner;
    let mut segs: BTreeMap<u64, Vec<QuantizedExample>> = std::mem::take(&mut l.segments)
        .into_iter()
        .zip(std::mem::take(&mut l.segment_ids))
        .map(|(seg, id)| (id, seg))
        .collect();
    for (id, seg) in d.learner.changed {
        segs.insert(id, seg);
    }
    let mut segments = Vec::with_capacity(d.learner.segment_order.len());
    for id in &d.learner.segment_order {
        let seg = segs
            .remove(id)
            .with_context(|| format!("delta references unknown replay segment {id}"))?;
        segments.push(seg);
    }
    l.segments = segments;
    l.segment_ids = d.learner.segment_order;
    l.next_segment_id = d.learner.next_segment_id;
    l.observed = d.learner.observed;
    l.updates = d.learner.updates;
    l.rationed_cols = d.learner.rationed_cols;
    l.pending = d.learner.pending;
    l.rng_state = d.learner.rng_state;
    l.rng_spare = d.learner.rng_spare;
    l.sampler_seen = d.learner.sampler_seen;
    l.sampler_rng = d.learner.sampler_rng;
    l.quant_lfsr = d.learner.quant_lfsr;
    Ok(())
}

/// Apply the contiguous run of this epoch's deltas (`1..=n`) on top of
/// `snap`. Lenient by design: a gap, an unreadable/corrupt delta, or a
/// merge inconsistency ends the chain at the last good prefix — that is
/// the crash-consistency contract (each delta is a complete consistent
/// state at its tick). Returns the number of deltas applied.
fn apply_chain(snap: &mut Snapshot, dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut seqs: Vec<(u64, PathBuf)> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some((epoch, seq)) = parse_delta_name(name) {
            if epoch == snap.epoch {
                seqs.push((seq, entry.path()));
            }
        }
    }
    seqs.sort_by_key(|(seq, _)| *seq);
    // the base full snapshot's weights, against which every delta's
    // cumulative column set is resolved
    let base_params = snap.params.clone();
    let mut applied = 0;
    for (i, (seq, path)) in seqs.into_iter().enumerate() {
        if seq != i as u64 + 1 {
            break; // gap: later deltas are not a consistent continuation
        }
        let Ok(raw) = std::fs::read(&path) else { break };
        let Ok(delta) = parse_delta(&raw) else { break };
        if delta.seq != seq {
            break;
        }
        let mut staged = snap.clone();
        if merge_delta(&mut staged, delta, &base_params).is_err() {
            break;
        }
        *snap = staged;
        applied += 1;
    }
    applied
}

/// Read and fully validate the snapshot chain in `dir`: the full
/// snapshot plus every contiguous delta, merged. `Ok(None)` when no
/// snapshot exists; `Err` on I/O failure or a corrupt *full* snapshot
/// (corrupt deltas just end the chain early).
pub fn read_snapshot(dir: &Path) -> Result<Option<Snapshot>> {
    let path = dir.join(SNAPSHOT_FILE);
    if !path.exists() {
        return Ok(None);
    }
    let raw = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let mut snap = parse_snapshot(&raw)?;
    apply_chain(&mut snap, dir);
    Ok(Some(snap))
}

// ----------------------------------------------------------- sync variants

/// Synchronously write a **full** snapshot of `core` into `dir`
/// (everything fsynced) and start a new chain epoch — the simple
/// one-call durability path for tests, benches and embedders. The
/// server's periodic path is [`ServeCore::snapshot_async`].
pub fn save_checkpoint(core: &mut ServeCore, dir: &Path) -> Result<PathBuf> {
    let wear = core.fetch_wear()?;
    let epoch = random_epoch();
    let state = core.full_state(epoch, wear);
    core.chain_epoch = epoch;
    core.next_delta_seq = 1;
    core.snapshots_taken += 1;
    let path = write_full(&state, dir, true)?;
    purge_stale_deltas(dir, epoch);
    Ok(path)
}

/// Synchronously write a **delta** snapshot against the current chain
/// (requires a preceding [`save_checkpoint`] in this process lifetime).
pub fn save_delta(core: &mut ServeCore, dir: &Path) -> Result<PathBuf> {
    let wear = core.fetch_wear()?;
    ensure!(core.chain_epoch != 0, "no full snapshot to delta against (save_checkpoint first)");
    let seq = core.next_delta_seq;
    core.next_delta_seq += 1;
    core.snapshots_taken += 1;
    let state = core.delta_state(core.chain_epoch, seq, wear);
    write_delta(&state, dir, true)
}

// ---------------------------------------------------------------- restore

/// Boot-time restore: load the snapshot chain in `dir` (if any) into
/// `core`. A corrupt or shape-mismatched full snapshot is reported as
/// [`RestoreOutcome::Corrupt`] so the server can boot fresh with a
/// warning. Filesystem read failures and a failing weight restore
/// (substrate cannot load weights) are hard errors instead: a transient
/// I/O hiccup must not silently discard a valid snapshot that the next
/// checkpoint would then overwrite.
pub fn try_restore(core: &mut ServeCore, dir: &Path) -> Result<RestoreOutcome> {
    let path = dir.join(SNAPSHOT_FILE);
    if !path.exists() {
        return Ok(RestoreOutcome::Fresh);
    }
    let raw = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let mut snap = match parse_snapshot(&raw) {
        Ok(s) => s,
        Err(e) => return Ok(RestoreOutcome::Corrupt { error: e.to_string() }),
    };
    let net = core.net;
    if snap.nh != net.nh || snap.nx != net.nx || snap.nt != net.nt || snap.ny != net.ny {
        return Ok(RestoreOutcome::Corrupt {
            error: format!(
                "snapshot shapes (nh={}, nx={}, nt={}, ny={}) do not match net `{}`",
                snap.nh, snap.nx, snap.nt, snap.ny, net.name
            ),
        });
    }
    let deltas = apply_chain(&mut snap, dir);
    let Snapshot { params, scalars, sessions, learner, .. } = snap;
    let tick = scalars.tick;
    core.restore_weights(params, scalars.wear)?;
    core.tick = scalars.tick;
    core.session_secret = scalars.session_secret;
    let wall = core.metrics.wall;
    core.metrics = scalars.metrics;
    core.metrics.wall = wall;
    core.batcher.stats = scalars.batcher;
    core.batcher.restore_queue(scalars.pending);
    let restored = sessions.len();
    core.store.restore(scalars.touch_counter, scalars.store_stats, sessions);
    core.learner.restore(learner);
    // the restored dirty baselines are unknown: start a fresh chain, so
    // the next snapshot is a full one
    core.chain_epoch = 0;
    core.next_delta_seq = 1;
    core.snapshots_taken = 0;
    Ok(RestoreOutcome::Restored { sessions: restored, tick, deltas })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetConfig, RunConfig, ServeConfig};
    use crate::serve::session_id_for_user;
    use crate::serve::workload::SyntheticWorkload;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("m2ru_ckpt_{}_{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_core(seed: u64) -> ServeCore {
        let mut run = RunConfig::default();
        run.seed = seed;
        run.serve = ServeConfig {
            max_batch: 4,
            max_wait: 1,
            capacity: 8,
            update_every: 5,
            ..ServeConfig::default()
        };
        ServeCore::new(NetConfig::SMALL, &run).unwrap()
    }

    fn feed(core: &mut ServeCore, workload: &mut SyntheticWorkload, requests: u64) {
        let mut issued = 0;
        while issued < requests {
            for _ in 0..4 {
                if issued >= requests {
                    break;
                }
                let (u, x, label) = workload.next();
                core.submit(session_id_for_user(u), x, label, 0);
                issued += 1;
            }
            core.drain_ready().unwrap();
            if issued >= requests {
                core.flush_all().unwrap();
            }
            core.advance_tick();
        }
        // commit losses land with their outcomes; settle them so
        // signatures and snapshots below see the complete state
        core.sync_commits().unwrap();
    }

    fn delta_files(d: &Path) -> Vec<String> {
        let mut out: Vec<String> = std::fs::read_dir(d)
            .map(|it| {
                it.flatten()
                    .filter_map(|e| e.file_name().to_str().map(str::to_string))
                    .filter(|n| parse_delta_name(n).is_some())
                    .collect()
            })
            .unwrap_or_default();
        out.sort();
        out
    }

    #[test]
    fn save_restore_roundtrips_sessions_bitwise() {
        let d = dir("roundtrip");
        let net = NetConfig::SMALL;
        let mut a = small_core(3);
        let mut w = SyntheticWorkload::new(&net, 6, 3);
        feed(&mut a, &mut w, 80);
        let path = save_checkpoint(&mut a, &d).unwrap();
        assert!(path.exists());

        let mut b = small_core(3);
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Restored { sessions, tick, deltas } => {
                assert!(sessions > 0);
                assert_eq!(tick, a.tick());
                assert_eq!(deltas, 0);
            }
            other => panic!("expected restore, got {other:?}"),
        }
        // hidden states, history rings and recency restore bitwise
        assert_eq!(b.store().snapshot_slots(), a.store().snapshot_slots());
        assert_eq!(b.metrics().signature(&b.store().stats), a.metrics().signature(&a.store().stats));
        // continuing identical traffic produces identical behavior
        let mut wa = SyntheticWorkload::new(&net, 6, 3);
        wa.skip(80);
        let mut wb = SyntheticWorkload::new(&net, 6, 3);
        wb.skip(80);
        feed(&mut a, &mut wa, 60);
        feed(&mut b, &mut wb, 60);
        assert_eq!(
            b.metrics().signature(&b.store().stats),
            a.metrics().signature(&a.store().stats),
            "restored core must continue bit-identically"
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn delta_chain_restores_bitwise_and_queued_requests_survive() {
        let d = dir("chain");
        let net = NetConfig::SMALL;
        // reference: one uninterrupted core over the same three 40-request
        // segments (each `feed` ends with the driver's tail flush, which
        // dispatches deferred same-session duplicates — the reference
        // must see identical flush boundaries to be comparable)
        let mut reference = small_core(9);
        let mut wr = SyntheticWorkload::new(&net, 6, 9);
        feed(&mut reference, &mut wr, 40);
        feed(&mut reference, &mut wr, 40);
        feed(&mut reference, &mut wr, 40);

        // chained: full after 40, deltas after 80 and 120
        let mut a = small_core(9);
        let mut w = SyntheticWorkload::new(&net, 6, 9);
        feed(&mut a, &mut w, 40);
        save_checkpoint(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 40);
        save_delta(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 40);
        // leave two requests queued (not drained): crash snapshots must
        // carry the batcher's pending queue
        let (u1, x1, l1) = w.next();
        a.submit(session_id_for_user(u1), x1, l1, 0);
        let (u2, x2, l2) = w.next();
        a.submit(session_id_for_user(u2), x2, l2, 0);
        save_delta(&mut a, &d).unwrap();
        assert_eq!(delta_files(&d).len(), 2, "two deltas on the chain");

        let mut b = small_core(9);
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Restored { sessions, tick, deltas } => {
                assert!(sessions > 0);
                assert_eq!(tick, a.tick());
                assert_eq!(deltas, 2, "both deltas must apply");
            }
            other => panic!("expected restore, got {other:?}"),
        }
        assert_eq!(b.store().snapshot_slots(), a.store().snapshot_slots());
        assert_eq!(
            b.metrics().signature(&b.store().stats),
            reference.metrics().signature(&reference.store().stats),
            "chain restore must reproduce the uninterrupted run's state"
        );
        // the queued requests came back and are servable
        assert_eq!(b.batcher.queued(), a.batcher.queued());
        assert_eq!(b.batcher.queued().len(), 2);
        let served = b.flush_all().unwrap();
        assert_eq!(served.len(), 2, "restored queue must dispatch");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn compaction_purges_stale_deltas() {
        let d = dir("compact");
        let net = NetConfig::SMALL;
        let mut a = small_core(4);
        let mut w = SyntheticWorkload::new(&net, 4, 4);
        feed(&mut a, &mut w, 30);
        save_checkpoint(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 10);
        save_delta(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 10);
        save_delta(&mut a, &d).unwrap();
        assert_eq!(delta_files(&d).len(), 2);
        // a new full snapshot starts a fresh epoch and compacts the chain
        save_checkpoint(&mut a, &d).unwrap();
        assert!(delta_files(&d).is_empty(), "compaction must remove old deltas");
        let snap = read_snapshot(&d).unwrap().unwrap();
        assert_eq!(snap.scalars.tick, a.tick());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_or_gapped_deltas_restore_the_good_prefix() {
        let d = dir("prefix");
        let net = NetConfig::SMALL;
        let mut a = small_core(6);
        let mut w = SyntheticWorkload::new(&net, 4, 6);
        feed(&mut a, &mut w, 30);
        save_checkpoint(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 10);
        let tick_after_one = a.tick();
        save_delta(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 10);
        save_delta(&mut a, &d).unwrap();
        let files = delta_files(&d);
        assert_eq!(files.len(), 2);
        // corrupt the second delta: restore applies only the first
        let p2 = d.join(&files[1]);
        let mut raw = std::fs::read(&p2).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(&p2, &raw).unwrap();
        let mut b = small_core(6);
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Restored { tick, deltas, .. } => {
                assert_eq!(deltas, 1, "chain must stop at the corrupt delta");
                assert_eq!(tick, tick_after_one);
            }
            other => panic!("expected restore, got {other:?}"),
        }
        // remove the first delta entirely: the gap drops the whole tail
        std::fs::remove_file(d.join(&files[0])).unwrap();
        let mut c = small_core(6);
        match try_restore(&mut c, &d).unwrap() {
            RestoreOutcome::Restored { deltas, .. } => assert_eq!(deltas, 0),
            other => panic!("expected restore, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn corrupt_full_snapshot_boots_fresh_and_never_applies_orphan_deltas() {
        // a chain whose *base* is corrupt has no consistent state at all:
        // the deltas are upserts against a baseline that cannot be
        // trusted, so the restore must report Corrupt and leave the core
        // untouched — applying "just the deltas" would resurrect a
        // partial, internally inconsistent session set
        let d = dir("orphan");
        let net = NetConfig::SMALL;
        let mut a = small_core(8);
        let mut w = SyntheticWorkload::new(&net, 4, 8);
        feed(&mut a, &mut w, 30);
        save_checkpoint(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 10);
        save_delta(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 10);
        save_delta(&mut a, &d).unwrap();
        assert_eq!(delta_files(&d).len(), 2, "the chain holds two live deltas");
        // flip one payload byte of the full snapshot: checksum kills it
        let p = d.join(SNAPSHOT_FILE);
        let mut raw = std::fs::read(&p).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(&p, &raw).unwrap();

        let mut b = small_core(8);
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => assert!(!error.is_empty()),
            other => panic!("expected corrupt, got {other:?}"),
        }
        // the orphan deltas were NOT applied: the core is factory-fresh
        assert!(b.store().is_empty(), "no session may leak out of an orphaned delta");
        assert_eq!(b.tick(), 0, "a fresh boot starts at tick 0");
        assert_eq!(b.metrics().requests, 0);
        // the read path agrees with the restore path
        assert!(read_snapshot(&d).is_err(), "a corrupt base must fail the chain read");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn delta_removals_for_evicted_sessions_apply_and_skip_cleanly() {
        // capacity 2 with 5 users forces LRU evictions between snapshots,
        // so the delta's `removed` set names (a) sessions present in the
        // base snapshot and (b) sessions created *and* evicted entirely
        // between the base and the delta — the latter are unknown to the
        // base and their removal must skip cleanly, never error
        let d = dir("evicted");
        let net = NetConfig::SMALL;
        let mut run = RunConfig::default();
        run.seed = 12;
        run.serve = ServeConfig {
            max_batch: 2,
            max_wait: 1,
            capacity: 2,
            update_every: 0,
            ..ServeConfig::default()
        };
        let mut a = ServeCore::new(net, &run).unwrap();
        // two sessions live -> full snapshot
        let nx = net.nx;
        for (tick, id) in [(0u64, 100u64), (1, 200)] {
            a.submit(id, vec![0.1; nx], None, 0);
            a.drain_ready().unwrap();
            a.flush_all().unwrap();
            let _ = tick;
            a.advance_tick();
        }
        save_checkpoint(&mut a, &d).unwrap();
        // churn: 300 evicts 100, 400 evicts 200, 500 evicts 300 — so the
        // delta removes two base sessions AND session 300, which the base
        // snapshot has never heard of
        for id in [300u64, 400, 500] {
            a.submit(id, vec![0.2; nx], None, 0);
            a.drain_ready().unwrap();
            a.flush_all().unwrap();
            a.advance_tick();
        }
        save_delta(&mut a, &d).unwrap();

        let mut b = ServeCore::new(net, &run).unwrap();
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Restored { sessions, deltas, .. } => {
                assert_eq!(deltas, 1, "the delta must apply despite the unknown removal");
                assert_eq!(sessions, 2, "only the two live sessions survive");
            }
            other => panic!("expected restore, got {other:?}"),
        }
        assert!(!b.store().contains(100) && !b.store().contains(200));
        assert!(!b.store().contains(300), "a session evicted between snapshots must not revive");
        assert!(b.store().contains(400) && b.store().contains(500));
        assert_eq!(b.store().snapshot_slots(), a.store().snapshot_slots());
        assert_eq!(
            b.metrics().signature(&b.store().stats),
            a.metrics().signature(&a.store().stats)
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn params_delta_diffs_and_applies_column_wise() {
        let (nh, nx, ny) = (4usize, 3usize, 2usize);
        let base = MiruParams {
            wh: Mat::from_vec(nx, nh, (0..nx * nh).map(|i| i as f32 * 0.5).collect()),
            uh: Mat::from_vec(nh, nh, (0..nh * nh).map(|i| i as f32 * 0.25).collect()),
            bh: (0..nh).map(|i| i as f32).collect(),
            wo: Mat::from_vec(nh, ny, (0..nh * ny).map(|i| i as f32 * 0.125).collect()),
            bo: (0..ny).map(|i| i as f32).collect(),
        };
        // identical params diff to the empty delta
        let empty = params_delta(&base, &base.clone());
        assert_eq!(empty.cols(), 0, "no change must diff to no columns");
        // touch hidden column 2 (one wh element) and readout column 1 (bo)
        let mut cur = base.clone();
        *cur.wh.at_mut(1, 2) += 1.0;
        cur.bo[1] -= 3.0;
        let d = params_delta(&base, &cur);
        assert_eq!(d.hidden.len(), 1);
        assert_eq!(d.hidden[0].0, 2);
        assert_eq!(d.readout.len(), 1);
        assert_eq!(d.readout[0].0, 1);
        // applying onto a base clone reconstructs cur bitwise
        let mut rebuilt = base.clone();
        apply_params_delta(&mut rebuilt, &d).unwrap();
        assert_eq!(rebuilt.wh.data, cur.wh.data);
        assert_eq!(rebuilt.uh.data, cur.uh.data);
        assert_eq!(rebuilt.bh, cur.bh);
        assert_eq!(rebuilt.wo.data, cur.wo.data);
        assert_eq!(rebuilt.bo, cur.bo);
        // a column reverted bitwise to base drops out of the diff, and
        // base + (empty diff) is the base — the cumulative contract
        let reverted = params_delta(&base, &base.clone());
        let mut back = base.clone();
        apply_params_delta(&mut back, &reverted).unwrap();
        assert_eq!(back.wh.data, base.wh.data);
        // out-of-range columns are rejected, never a panic
        let mut bad = ParamsDelta::default();
        bad.readout.push((ny as u32, vec![0.0; nh], 0.0));
        assert!(apply_params_delta(&mut base.clone(), &bad).is_err());
    }

    #[test]
    fn frozen_weights_produce_empty_weight_deltas() {
        // with online learning off the weights never change, so every
        // delta's ζ-sparse weight section must be empty — the whole
        // point of moving params out of the every-file scalars
        let d = dir("frozen");
        let net = NetConfig::SMALL;
        let mut run = RunConfig::default();
        run.seed = 11;
        run.serve = ServeConfig {
            max_batch: 4,
            max_wait: 1,
            capacity: 8,
            update_every: 0,
            ..ServeConfig::default()
        };
        let mut a = ServeCore::new(net, &run).unwrap();
        let mut w = SyntheticWorkload::new(&net, 6, 11);
        feed(&mut a, &mut w, 40);
        save_checkpoint(&mut a, &d).unwrap();
        feed(&mut a, &mut w, 40);
        save_delta(&mut a, &d).unwrap();
        let files = delta_files(&d);
        assert_eq!(files.len(), 1);
        let raw = std::fs::read(d.join(&files[0])).unwrap();
        let delta = parse_delta(&raw).unwrap();
        assert_eq!(delta.params.cols(), 0, "frozen weights must not ride in a delta");
        // and the chain still restores bitwise
        let mut b = ServeCore::new(net, &run).unwrap();
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Restored { deltas, .. } => assert_eq!(deltas, 1),
            other => panic!("expected restore, got {other:?}"),
        }
        assert_eq!(b.store().snapshot_slots(), a.store().snapshot_slots());
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_snapshot_boots_fresh() {
        let d = dir("fresh");
        let mut c = small_core(1);
        assert!(matches!(try_restore(&mut c, &d).unwrap(), RestoreOutcome::Fresh));
    }

    #[test]
    fn corrupted_snapshot_reports_corrupt_not_panic() {
        let d = dir("corrupt");
        std::fs::create_dir_all(&d).unwrap();
        // garbage file
        std::fs::write(d.join(SNAPSHOT_FILE), b"not a snapshot at all").unwrap();
        let mut c = small_core(1);
        match try_restore(&mut c, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => assert!(!error.is_empty()),
            other => panic!("expected corrupt, got {other:?}"),
        }
        // valid snapshot with one payload byte flipped: checksum catches it
        let net = NetConfig::SMALL;
        let mut a = small_core(2);
        let mut w = SyntheticWorkload::new(&net, 4, 2);
        feed(&mut a, &mut w, 30);
        save_checkpoint(&mut a, &d).unwrap();
        let mut raw = std::fs::read(d.join(SNAPSHOT_FILE)).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(d.join(SNAPSHOT_FILE), &raw).unwrap();
        match try_restore(&mut c, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => {
                assert!(error.contains("checksum") || error.contains("truncated"), "{error}")
            }
            other => panic!("expected corrupt, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn shape_mismatch_is_corrupt_not_fatal() {
        let d = dir("shapes");
        let net = NetConfig::SMALL;
        let mut a = small_core(5);
        let mut w = SyntheticWorkload::new(&net, 4, 5);
        feed(&mut a, &mut w, 20);
        save_checkpoint(&mut a, &d).unwrap();
        // a core with different shapes must refuse the snapshot gracefully
        let run = RunConfig::default();
        let mut other = ServeCore::new(NetConfig::PMNIST100, &run).unwrap();
        match try_restore(&mut other, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => assert!(error.contains("shapes"), "{error}"),
            out => panic!("expected corrupt, got {out:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }
}
