//! Durable sessions: serialize the entire serve-loop state to a single
//! versioned binary snapshot and restore it on boot, so a killed and
//! restarted server resumes every live session with bitwise-identical
//! hidden state (DESIGN.md §9).
//!
//! ## Snapshot file (`snapshot.m2ck`, all integers little-endian)
//!
//! ```text
//! magic    u32   "M2CK"
//! version  u32   2
//! len      u64   payload byte count
//! payload  [len] sections below
//! checksum u64   FNV-1a 64 over the payload
//! ```
//!
//! Payload sections, in order: network shapes (nh, nx, nt, ny — refused
//! on mismatch), model weights in artifact order (wh, uh, bh, wo, bo),
//! the logical tick, the session-id secret (v2 — the TCP frontend's
//! per-boot key, persisted so restored sessions keep their ids),
//! deterministic serve metrics, batcher counters, the session store
//! (touch counter, lifecycle stats, then every live slot in LRU order:
//! id, ticks, history cursor, hidden state, history ring), and the online
//! learner (counters, pending window, Box–Muller stream, 4-bit replay
//! segments, reservoir + LFSR states).
//!
//! Writes go to a temp file in the same directory followed by an atomic
//! rename, with the temp file fsynced before the rename and the directory
//! fsynced after it — so a crash (including power loss) mid-write can
//! never destroy the previous good snapshot, and a completed rename is
//! durable with its data. Loads verify magic, version, length and
//! checksum; any corruption makes [`try_restore`] report
//! [`RestoreOutcome::Corrupt`] and the server boots fresh with a warning
//! instead of dying.
//!
//! A snapshot holds *state*, not configuration: restore assumes the
//! server boots with the same run configuration (seed, shapes, serve
//! policy), from which config-derived constants — notably the DFA
//! feedback matrix ψ — are reconstructed identically. Shapes are
//! verified; the rest is the operator's contract, like any database's
//! config file.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::data::Example;
use crate::linalg::Mat;
use crate::nn::MiruParams;
use crate::replay::QuantizedExample;

use super::batcher::BatcherStats;
use super::core::ServeCore;
use super::metrics::ServeMetrics;
use super::online::LearnerState;
use super::session::{SessionSnapshot, SessionStats};

const MAGIC: u32 = u32::from_le_bytes(*b"M2CK");
const VERSION: u32 = 2;
/// Snapshot file name inside `--checkpoint-dir`.
pub const SNAPSHOT_FILE: &str = "snapshot.m2ck";
const TMP_FILE: &str = "snapshot.m2ck.tmp";

/// Everything a snapshot holds, decoded.
pub struct Snapshot {
    pub nh: usize,
    pub nx: usize,
    pub nt: usize,
    pub ny: usize,
    pub params: MiruParams,
    pub tick: u64,
    pub session_secret: u64,
    pub metrics: ServeMetrics,
    pub batcher: BatcherStats,
    pub touch_counter: u64,
    pub store_stats: SessionStats,
    pub sessions: Vec<SessionSnapshot>,
    pub learner: LearnerState,
}

/// What booting against a checkpoint directory found.
#[derive(Debug)]
pub enum RestoreOutcome {
    /// No snapshot present — fresh boot.
    Fresh,
    /// Snapshot restored; every live session resumes its hidden state.
    Restored { sessions: usize, tick: u64 },
    /// Snapshot present but unusable (bad checksum, truncation, shape
    /// mismatch) — the server boots fresh; the caller should warn.
    Corrupt { error: String },
}

// ---------------------------------------------------------------- encoding

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Little-endian byte sink.
struct W {
    buf: Vec<u8>,
}

impl W {
    fn new() -> W {
        W { buf: Vec::new() }
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }
    fn bytes(&mut self, vs: &[u8]) {
        self.u32(vs.len() as u32);
        self.buf.extend_from_slice(vs);
    }
}

/// Little-endian cursor with hard bounds checks (malformed snapshots must
/// error, never panic).
struct R<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> R<'a> {
    fn new(b: &'a [u8]) -> R<'a> {
        R { b, p: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.b.len() - self.p >= n, "snapshot truncated at byte {}", self.p);
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }
    fn f32(&mut self) -> Result<f32> {
        let s = self.take(4)?;
        Ok(f32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn f64(&mut self) -> Result<f64> {
        let s = self.take(8)?;
        Ok(f64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn byte_vec(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    fn done(&self) -> Result<()> {
        ensure!(self.p == self.b.len(), "snapshot has {} trailing bytes", self.b.len() - self.p);
        Ok(())
    }
}

fn encode_payload(core: &ServeCore) -> Vec<u8> {
    let net = core.net;
    let p = core.engine.backend().effective_params();
    let m = &core.metrics;
    let learner = core.learner.snapshot();
    let mut w = W::new();
    // shapes
    w.u32(net.nh as u32);
    w.u32(net.nx as u32);
    w.u32(net.nt as u32);
    w.u32(net.ny as u32);
    // weights, artifact order
    w.f32s(&p.wh.data);
    w.f32s(&p.uh.data);
    w.f32s(&p.bh);
    w.f32s(&p.wo.data);
    w.f32s(&p.bo);
    // clock
    w.u64(core.tick);
    // session-id key (the TCP frontend's per-boot secret)
    w.u64(core.session_secret);
    // deterministic metrics (wall clock and latency samples are not state)
    w.u64(m.requests);
    w.u64(m.batches);
    w.u64(m.padded_rows);
    w.u64(m.valid_rows);
    w.u64(m.wait_ticks_sum);
    w.u64(m.pred_fingerprint);
    w.u64(m.labeled);
    w.u64(m.labeled_correct);
    w.u64(m.online_updates);
    w.f64(m.online_loss_sum);
    w.u64(m.wear_rationed);
    // batcher counters
    let b = &core.batcher.stats;
    w.u64(b.enqueued);
    w.u64(b.batches);
    w.u64(b.dispatched);
    w.u64(b.deferred_dups);
    // session store
    w.u64(core.store.touch_counter());
    let s = &core.store.stats;
    w.u64(s.created);
    w.u64(s.hits);
    w.u64(s.misses);
    w.u64(s.evicted_lru);
    w.u64(s.expired_ttl);
    let slots = core.store.snapshot_slots();
    w.u32(slots.len() as u32);
    for slot in &slots {
        w.u64(slot.id);
        w.u64(slot.last_tick);
        w.u64(slot.steps);
        w.u32(slot.hist_rows as u32);
        w.u32(slot.hist_head as u32);
        w.f32s(&slot.h);
        w.f32s(&slot.hist);
    }
    // online learner
    w.u64(learner.observed);
    w.u64(learner.updates);
    w.u64(learner.rationed_cols);
    w.u32(learner.pending.len() as u32);
    for ex in &learner.pending {
        w.u32(ex.label as u32);
        w.f32s(&ex.features);
    }
    w.u64(learner.rng_state);
    match learner.rng_spare {
        Some(v) => {
            w.buf.push(1);
            w.f32(v);
        }
        None => w.buf.push(0),
    }
    w.u32(learner.segments.len() as u32);
    for seg in &learner.segments {
        w.u32(seg.len() as u32);
        for q in seg {
            w.u32(q.label as u32);
            w.u32(q.len as u32);
            w.bytes(&q.packed);
        }
    }
    w.u64(learner.sampler_seen);
    w.u32(learner.sampler_rng);
    w.u16(learner.quant_lfsr);
    w.buf
}

fn decode_payload(buf: &[u8]) -> Result<Snapshot> {
    let mut r = R::new(buf);
    let nh = r.u32()? as usize;
    let nx = r.u32()? as usize;
    let nt = r.u32()? as usize;
    let ny = r.u32()? as usize;
    ensure!(nh >= 1 && nx >= 1 && nt >= 1 && ny >= 1, "degenerate snapshot shapes");
    let wh = r.f32s()?;
    let uh = r.f32s()?;
    let bh = r.f32s()?;
    let wo = r.f32s()?;
    let bo = r.f32s()?;
    ensure!(
        wh.len() == nx * nh && uh.len() == nh * nh && bh.len() == nh && wo.len() == nh * ny
            && bo.len() == ny,
        "weight section sizes inconsistent with shapes"
    );
    let params = MiruParams {
        wh: Mat::from_vec(nx, nh, wh),
        uh: Mat::from_vec(nh, nh, uh),
        bh,
        wo: Mat::from_vec(nh, ny, wo),
        bo,
    };
    let tick = r.u64()?;
    let session_secret = r.u64()?;
    let mut metrics = ServeMetrics::default();
    metrics.requests = r.u64()?;
    metrics.batches = r.u64()?;
    metrics.padded_rows = r.u64()?;
    metrics.valid_rows = r.u64()?;
    metrics.wait_ticks_sum = r.u64()?;
    metrics.pred_fingerprint = r.u64()?;
    metrics.labeled = r.u64()?;
    metrics.labeled_correct = r.u64()?;
    metrics.online_updates = r.u64()?;
    metrics.online_loss_sum = r.f64()?;
    metrics.wear_rationed = r.u64()?;
    let batcher = BatcherStats {
        enqueued: r.u64()?,
        batches: r.u64()?,
        dispatched: r.u64()?,
        deferred_dups: r.u64()?,
    };
    let touch_counter = r.u64()?;
    let store_stats = SessionStats {
        created: r.u64()?,
        hits: r.u64()?,
        misses: r.u64()?,
        evicted_lru: r.u64()?,
        expired_ttl: r.u64()?,
    };
    let n_sessions = r.u32()? as usize;
    let mut sessions = Vec::with_capacity(n_sessions.min(1 << 20));
    for _ in 0..n_sessions {
        let id = r.u64()?;
        let last_tick = r.u64()?;
        let steps = r.u64()?;
        let hist_rows = r.u32()? as usize;
        let hist_head = r.u32()? as usize;
        let h = r.f32s()?;
        let hist = r.f32s()?;
        ensure!(h.len() == nh, "session hidden width {} != nh {nh}", h.len());
        ensure!(hist.len() == nt * nx, "session history size {} != nt*nx", hist.len());
        sessions.push(SessionSnapshot { id, h, hist, hist_rows, hist_head, last_tick, steps });
    }
    let observed = r.u64()?;
    let updates = r.u64()?;
    let rationed_cols = r.u64()?;
    let n_pending = r.u32()? as usize;
    let mut pending = Vec::with_capacity(n_pending.min(1 << 20));
    for _ in 0..n_pending {
        let label = r.u32()? as usize;
        let features = r.f32s()?;
        ensure!(features.len() == nt * nx, "pending window size {} != nt*nx", features.len());
        pending.push(Example { features, label });
    }
    let rng_state = r.u64()?;
    let rng_spare = match r.take(1)?[0] {
        0 => None,
        1 => Some(r.f32()?),
        other => bail!("bad rng spare flag {other}"),
    };
    let n_segs = r.u32()? as usize;
    let mut segments = Vec::with_capacity(n_segs.min(1 << 20));
    for _ in 0..n_segs {
        let n_ex = r.u32()? as usize;
        let mut seg = Vec::with_capacity(n_ex.min(1 << 20));
        for _ in 0..n_ex {
            let label = r.u32()? as usize;
            let len = r.u32()? as usize;
            let packed = r.byte_vec()?;
            ensure!(packed.len() == len.div_ceil(2), "packed length inconsistent with len");
            seg.push(QuantizedExample { packed, len, label });
        }
        segments.push(seg);
    }
    let sampler_seen = r.u64()?;
    let sampler_rng = r.u32()?;
    let quant_lfsr = r.u16()?;
    r.done()?;
    let learner = LearnerState {
        observed,
        updates,
        rationed_cols,
        pending,
        rng_state,
        rng_spare,
        segments,
        sampler_seen,
        sampler_rng,
        quant_lfsr,
    };
    Ok(Snapshot {
        nh,
        nx,
        nt,
        ny,
        params,
        tick,
        session_secret,
        metrics,
        batcher,
        touch_counter,
        store_stats,
        sessions,
        learner,
    })
}

// ------------------------------------------------------------------- file IO

/// Serialize the core's durable state and atomically replace the snapshot
/// in `dir`: write to a temp file, fsync it, rename it into place, then
/// fsync the directory. The fsyncs matter — without them a power loss can
/// make the rename durable while the file data is not, replacing the
/// previous good snapshot with a corrupt one. Returns the snapshot path.
pub fn save_checkpoint(core: &ServeCore, dir: &Path) -> Result<PathBuf> {
    use std::io::Write as _;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    let payload = encode_payload(core);
    let mut file = Vec::with_capacity(payload.len() + 24);
    file.extend_from_slice(&MAGIC.to_le_bytes());
    file.extend_from_slice(&VERSION.to_le_bytes());
    file.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    file.extend_from_slice(&payload);
    file.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    let tmp = dir.join(TMP_FILE);
    let path = dir.join(SNAPSHOT_FILE);
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&file).with_context(|| format!("writing {}", tmp.display()))?;
        // data must be on disk BEFORE the rename can be allowed to commit
        f.sync_all().with_context(|| format!("fsyncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    // make the rename itself durable (directory metadata); directories
    // cannot be opened on every platform, but where they can, a failing
    // fsync is a real durability error
    if let Ok(d) = std::fs::File::open(dir) {
        d.sync_all().with_context(|| format!("fsyncing directory {}", dir.display()))?;
    }
    Ok(path)
}

/// Read and fully validate the snapshot in `dir`. `Ok(None)` when no
/// snapshot exists; `Err` on I/O failure or any corruption (bad
/// magic/version, short file, checksum mismatch, malformed payload).
pub fn read_snapshot(dir: &Path) -> Result<Option<Snapshot>> {
    let path = dir.join(SNAPSHOT_FILE);
    if !path.exists() {
        return Ok(None);
    }
    let raw = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    Ok(Some(parse_snapshot(&raw)?))
}

/// Validate and decode raw snapshot bytes.
fn parse_snapshot(raw: &[u8]) -> Result<Snapshot> {
    ensure!(raw.len() >= 24, "snapshot shorter than its header");
    let magic = u32::from_le_bytes([raw[0], raw[1], raw[2], raw[3]]);
    ensure!(magic == MAGIC, "bad snapshot magic {magic:#010x}");
    let version = u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]);
    ensure!(version == VERSION, "unsupported snapshot version {version}");
    let len64 =
        u64::from_le_bytes([raw[8], raw[9], raw[10], raw[11], raw[12], raw[13], raw[14], raw[15]]);
    // bounds-check before any arithmetic: a hostile length field must not
    // overflow or allocate
    ensure!(
        len64 == (raw.len() as u64).saturating_sub(24),
        "snapshot length field inconsistent with file size"
    );
    let len = len64 as usize;
    let payload = &raw[16..16 + len];
    let stored = u64::from_le_bytes([
        raw[16 + len],
        raw[17 + len],
        raw[18 + len],
        raw[19 + len],
        raw[20 + len],
        raw[21 + len],
        raw[22 + len],
        raw[23 + len],
    ]);
    let computed = fnv1a64(payload);
    ensure!(stored == computed, "snapshot checksum mismatch ({stored:#x} != {computed:#x})");
    decode_payload(payload)
}

/// Boot-time restore: load the snapshot in `dir` (if any) into `core`.
/// A corrupt or shape-mismatched snapshot is reported as
/// [`RestoreOutcome::Corrupt`] so the server can boot fresh with a
/// warning. Filesystem read failures and a failing weight restore
/// (substrate cannot load weights) are hard errors instead: a transient
/// I/O hiccup must not silently discard a valid snapshot that the next
/// checkpoint would then overwrite.
pub fn try_restore(core: &mut ServeCore, dir: &Path) -> Result<RestoreOutcome> {
    let path = dir.join(SNAPSHOT_FILE);
    if !path.exists() {
        return Ok(RestoreOutcome::Fresh);
    }
    let raw = std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    let snap = match parse_snapshot(&raw) {
        Ok(s) => s,
        Err(e) => return Ok(RestoreOutcome::Corrupt { error: e.to_string() }),
    };
    let net = core.net;
    if snap.nh != net.nh || snap.nx != net.nx || snap.nt != net.nt || snap.ny != net.ny {
        return Ok(RestoreOutcome::Corrupt {
            error: format!(
                "snapshot shapes (nh={}, nx={}, nt={}, ny={}) do not match net `{}`",
                snap.nh, snap.nx, snap.nt, snap.ny, net.name
            ),
        });
    }
    core.engine.restore_params(&snap.params)?;
    core.tick = snap.tick;
    core.session_secret = snap.session_secret;
    let wall = core.metrics.wall;
    core.metrics = snap.metrics;
    core.metrics.wall = wall;
    core.batcher.stats = snap.batcher;
    let restored = snap.sessions.len();
    core.store.restore(snap.touch_counter, snap.store_stats, snap.sessions);
    core.learner.restore(snap.learner);
    Ok(RestoreOutcome::Restored { sessions: restored, tick: snap.tick })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetConfig, RunConfig, ServeConfig};
    use crate::serve::session_id_for_user;
    use crate::serve::workload::SyntheticWorkload;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("m2ru_ckpt_{}_{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_core(seed: u64) -> ServeCore {
        let mut run = RunConfig::default();
        run.seed = seed;
        run.serve = ServeConfig {
            max_batch: 4,
            max_wait: 1,
            capacity: 8,
            update_every: 5,
            ..ServeConfig::default()
        };
        ServeCore::new(NetConfig::SMALL, &run).unwrap()
    }

    fn feed(core: &mut ServeCore, workload: &mut SyntheticWorkload, requests: u64) {
        let mut issued = 0;
        while issued < requests {
            for _ in 0..4 {
                if issued >= requests {
                    break;
                }
                let (u, x, label) = workload.next();
                core.submit(session_id_for_user(u), x, label, 0);
                issued += 1;
            }
            core.drain_ready().unwrap();
            if issued >= requests {
                core.flush_all().unwrap();
            }
            core.advance_tick();
        }
    }

    #[test]
    fn save_restore_roundtrips_sessions_bitwise() {
        let d = dir("roundtrip");
        let net = NetConfig::SMALL;
        let mut a = small_core(3);
        let mut w = SyntheticWorkload::new(&net, 6, 3);
        feed(&mut a, &mut w, 80);
        let path = save_checkpoint(&a, &d).unwrap();
        assert!(path.exists());

        let mut b = small_core(3);
        match try_restore(&mut b, &d).unwrap() {
            RestoreOutcome::Restored { sessions, tick } => {
                assert!(sessions > 0);
                assert_eq!(tick, a.tick());
            }
            other => panic!("expected restore, got {other:?}"),
        }
        // hidden states, history rings and recency restore bitwise
        assert_eq!(b.store().snapshot_slots(), a.store().snapshot_slots());
        assert_eq!(b.metrics().signature(&b.store().stats), a.metrics().signature(&a.store().stats));
        // continuing identical traffic produces identical behavior
        let mut wa = SyntheticWorkload::new(&net, 6, 3);
        wa.skip(80);
        let mut wb = SyntheticWorkload::new(&net, 6, 3);
        wb.skip(80);
        feed(&mut a, &mut wa, 60);
        feed(&mut b, &mut wb, 60);
        assert_eq!(
            b.metrics().signature(&b.store().stats),
            a.metrics().signature(&a.store().stats),
            "restored core must continue bit-identically"
        );
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_snapshot_boots_fresh() {
        let d = dir("fresh");
        let mut c = small_core(1);
        assert!(matches!(try_restore(&mut c, &d).unwrap(), RestoreOutcome::Fresh));
    }

    #[test]
    fn corrupted_snapshot_reports_corrupt_not_panic() {
        let d = dir("corrupt");
        std::fs::create_dir_all(&d).unwrap();
        // garbage file
        std::fs::write(d.join(SNAPSHOT_FILE), b"not a snapshot at all").unwrap();
        let mut c = small_core(1);
        match try_restore(&mut c, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => assert!(!error.is_empty()),
            other => panic!("expected corrupt, got {other:?}"),
        }
        // valid snapshot with one payload byte flipped: checksum catches it
        let net = NetConfig::SMALL;
        let mut a = small_core(2);
        let mut w = SyntheticWorkload::new(&net, 4, 2);
        feed(&mut a, &mut w, 30);
        save_checkpoint(&a, &d).unwrap();
        let mut raw = std::fs::read(d.join(SNAPSHOT_FILE)).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0xFF;
        std::fs::write(d.join(SNAPSHOT_FILE), &raw).unwrap();
        match try_restore(&mut c, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => {
                assert!(error.contains("checksum") || error.contains("truncated"), "{error}")
            }
            other => panic!("expected corrupt, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn shape_mismatch_is_corrupt_not_fatal() {
        let d = dir("shapes");
        let net = NetConfig::SMALL;
        let mut a = small_core(5);
        let mut w = SyntheticWorkload::new(&net, 4, 5);
        feed(&mut a, &mut w, 20);
        save_checkpoint(&a, &d).unwrap();
        // a core with different shapes must refuse the snapshot gracefully
        let run = RunConfig::default();
        let mut other = ServeCore::new(NetConfig::PMNIST100, &run).unwrap();
        match try_restore(&mut other, &d).unwrap() {
            RestoreOutcome::Corrupt { error } => assert!(error.contains("shapes"), "{error}"),
            out => panic!("expected corrupt, got {out:?}"),
        }
        let _ = std::fs::remove_dir_all(&d);
    }
}
