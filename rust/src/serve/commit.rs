//! The async commit pipeline: a background **committer thread** owns the
//! mutable weights; the serve loop only ever reads an immutable,
//! atomically swapped weight snapshot (DESIGN.md §10).
//!
//! ## Protocol
//!
//! ```text
//! serve thread                          committer thread
//! ------------                          ----------------
//! step batches against Arc<snapshot g>
//! window fills → enqueue Commit{g+1} ─▶ train_whole_guarded (single writer)
//! keep serving at generation g          publish Arc<snapshot g+1> (swap)
//! ...                                   send Outcome::Commit{g+1, loss, ...}
//! next dispatch: await gen g+1 ◀──────── (already done in the common case)
//! ```
//!
//! * **Generation counter** — every commit carries the generation it
//!   produces; the serve loop tags each dispatched batch with the
//!   generation it stepped against ([`super::CompletedStep::gen`]).
//! * **Deterministic visibility** — before dispatching a batch, the
//!   serve loop waits until every commit it has *enqueued* is applied
//!   and adopts the new snapshot. Commit visibility is therefore exactly
//!   the synchronous single-thread semantics (a commit triggered by
//!   batch N is visible from batch N+1 on), bit-for-bit, while the
//!   commit's gradient/programming work overlaps response routing,
//!   socket traffic and snapshot writes instead of stalling them.
//! * **Bounded queue** — the job channel holds at most
//!   `serve.commit_queue_depth` jobs; a serve loop outrunning its
//!   committer blocks on enqueue (back-pressure) rather than buffering
//!   unboundedly.
//! * **Snapshot I/O off-thread** — durable snapshot writes
//!   ([`super::checkpoint`]) travel the same FIFO queue, so a snapshot
//!   job observes exactly the commits enqueued before it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::backend::{ComputeBackend, WearState};
use crate::coordinator::ParallelEngine;
use crate::nn::{MiruParams, SeqBatch};
use crate::obs::Histogram;

use super::checkpoint::{write_snapshot_job, SnapshotJob};

/// An immutable weight snapshot at a known commit generation. The serve
/// loop steps sessions against exactly one of these per dispatched
/// batch; the committer publishes a fresh one after every applied
/// commit (and after a restore).
pub struct WeightSnapshot {
    /// Commits applied to produce these weights (0 = boot weights).
    pub gen: u64,
    /// The substrate's effective weights at that generation.
    pub params: MiruParams,
    /// Pre-quantized i8 weight planes, built once per generation when
    /// the int8 serving precision is active (DESIGN.md §15) — the
    /// dispatch hot path reads these and pays zero quantization cost.
    /// `None` under f32.
    pub quant: Option<crate::quant::QuantizedParams>,
}

impl WeightSnapshot {
    /// Assemble a snapshot, quantizing the weight planes iff the
    /// process-wide serving precision is int8. Called on the committer
    /// thread (and once at boot), never on the dispatch path.
    pub fn new(gen: u64, params: MiruParams) -> WeightSnapshot {
        let quant = match crate::linalg::kernels::active_precision() {
            crate::linalg::kernels::Precision::Int8 => {
                Some(crate::quant::QuantizedParams::build(&params))
            }
            crate::linalg::kernels::Precision::F32 => None,
        };
        WeightSnapshot { gen, params, quant }
    }
}

/// Substrate-side facts the serve thread cannot read directly anymore
/// (the committer owns the backend): report lines and the lifespan
/// projection. Refreshed with every committer outcome and cached by
/// [`super::ServeCore`]. The (large, per-device) wear record is *not*
/// carried here — snapshots fetch it on demand with [`Job::ReadWear`],
/// so the commit hot path never copies wear counters.
#[derive(Clone, Debug, Default)]
pub struct SubstrateStatus {
    pub stats: Vec<String>,
    pub lifespan_years: Option<f64>,
}

impl SubstrateStatus {
    pub(crate) fn of(backend: &dyn ComputeBackend) -> SubstrateStatus {
        SubstrateStatus {
            stats: backend.stats(),
            lifespan_years: backend.projected_lifespan_years(),
        }
    }
}

/// The atomically swapped snapshot cell. The committer stores, the serve
/// loop (and anything else holding the handle) loads; a load is one
/// mutex-guarded `Arc::clone` — never a weight copy.
pub(crate) struct WeightCell {
    gen: AtomicU64,
    slot: Mutex<Arc<WeightSnapshot>>,
}

impl WeightCell {
    fn new(snap: Arc<WeightSnapshot>) -> WeightCell {
        WeightCell { gen: AtomicU64::new(snap.gen), slot: Mutex::new(snap) }
    }

    pub(crate) fn load(&self) -> Arc<WeightSnapshot> {
        self.slot.lock().expect("weight cell poisoned").clone()
    }

    fn store(&self, snap: Arc<WeightSnapshot>) {
        let gen = snap.gen;
        *self.slot.lock().expect("weight cell poisoned") = snap;
        // published after the slot so `gen()` never reports a generation
        // that `load()` cannot yet observe
        self.gen.store(gen, Ordering::SeqCst);
    }

    pub(crate) fn gen(&self) -> u64 {
        self.gen.load(Ordering::SeqCst)
    }
}

/// Work queued to the committer thread, in strict FIFO order.
pub(crate) enum Job {
    /// Apply one finalized training window, producing generation `gen`.
    Commit { gen: u64, batch: SeqBatch, wear_ratio: f32 },
    /// Write a durable snapshot (full or delta) assembled by the serve
    /// thread — file encoding and fsync happen on the committer.
    Snapshot(SnapshotJob),
    /// Boot-time restore: load checkpointed weights (and wear) into the
    /// substrate and republish the snapshot.
    Restore { params: MiruParams, wear: Option<WearState> },
    /// Read the substrate's durable wear record (snapshot assembly).
    ReadWear,
}

/// What the committer reports back, in job order.
pub(crate) enum Outcome {
    Commit { gen: u64, loss: f32, rationed: u64, status: SubstrateStatus },
    Snapshot { path: std::path::PathBuf },
    Restored { status: SubstrateStatus },
    Wear { wear: Option<WearState> },
    /// A job failed; the serve loop surfaces this as a hard error.
    Failed { what: &'static str, error: String },
}

/// Handle to the committer thread held by [`super::ServeCore`].
pub(crate) struct Committer {
    jobs: Option<SyncSender<Job>>,
    results: Receiver<Outcome>,
    cell: Arc<WeightCell>,
    handle: Option<JoinHandle<()>>,
}

impl Committer {
    /// Move `engine` onto a fresh committer thread. Returns the handle,
    /// the boot weight snapshot (generation 0) and the boot substrate
    /// status, both read before the engine crosses threads.
    /// `snapshot_write_us` (when observability is on) times each durable
    /// snapshot write on the committer thread — timing plane only.
    pub(crate) fn spawn(
        engine: ParallelEngine,
        queue_depth: usize,
        snapshot_write_us: Option<Histogram>,
    ) -> (Committer, Arc<WeightSnapshot>, SubstrateStatus) {
        let snap = Arc::new(WeightSnapshot::new(0, engine.backend().effective_params()));
        let status = SubstrateStatus::of(engine.backend());
        let cell = Arc::new(WeightCell::new(snap.clone()));
        let (jtx, jrx) = sync_channel::<Job>(queue_depth.max(1));
        let (rtx, rrx) = channel::<Outcome>();
        let thread_cell = cell.clone();
        let handle = std::thread::Builder::new()
            .name("m2ru-committer".to_string())
            .spawn(move || committer_loop(engine, thread_cell, jrx, rtx, snapshot_write_us))
            .expect("spawning the committer thread");
        (Committer { jobs: Some(jtx), results: rrx, cell, handle: Some(handle) }, snap, status)
    }

    /// Enqueue a job; blocks when `commit_queue_depth` jobs are in
    /// flight (back-pressure toward the serve loop).
    pub(crate) fn send(&self, job: Job) -> Result<()> {
        self.jobs
            .as_ref()
            .ok_or_else(|| anyhow!("committer already shut down"))?
            .send(job)
            .map_err(|_| anyhow!("committer thread is gone"))
    }

    /// Block for the next outcome.
    pub(crate) fn recv(&self) -> Result<Outcome> {
        self.results.recv().map_err(|_| anyhow!("committer thread is gone"))
    }

    /// Non-blocking outcome poll. `Ok(None)` when nothing is ready.
    pub(crate) fn try_recv(&self) -> Result<Option<Outcome>> {
        match self.results.try_recv() {
            Ok(o) => Ok(Some(o)),
            Err(TryRecvError::Empty) => Ok(None),
            // after shutdown the committer is gone but queued outcomes
            // were already drained; treat a closed, empty channel as done
            Err(TryRecvError::Disconnected) => Ok(None),
        }
    }

    /// The current published snapshot.
    pub(crate) fn load(&self) -> Arc<WeightSnapshot> {
        self.cell.load()
    }

    /// Close the job queue and join the thread; a panicked committer is
    /// a hard error (its queued jobs — including snapshot writes — died
    /// with it). Outcomes already sent stay readable via `try_recv`.
    /// Idempotent.
    pub(crate) fn shutdown(&mut self) -> Result<()> {
        self.jobs.take();
        if let Some(h) = self.handle.take() {
            if h.join().is_err() {
                anyhow::bail!("committer thread panicked; queued jobs were lost");
            }
        }
        Ok(())
    }
}

impl Drop for Committer {
    fn drop(&mut self) {
        // best-effort teardown; panics cannot propagate out of Drop
        self.jobs.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The committer thread body: apply jobs in FIFO order; after every
/// weight mutation publish a fresh snapshot *before* reporting the
/// outcome, so a serve loop that has seen generation `g`'s outcome can
/// always load a snapshot of generation ≥ `g`.
fn committer_loop(
    mut engine: ParallelEngine,
    cell: Arc<WeightCell>,
    jobs: Receiver<Job>,
    out: Sender<Outcome>,
    snapshot_write_us: Option<Histogram>,
) {
    while let Ok(job) = jobs.recv() {
        let outcome = match job {
            Job::Commit { gen, batch, wear_ratio } => {
                match engine.train_whole_guarded(&batch, wear_ratio) {
                    Ok((loss, rationed)) => {
                        cell.store(Arc::new(WeightSnapshot::new(
                            gen,
                            engine.backend().effective_params(),
                        )));
                        let status = SubstrateStatus::of(engine.backend());
                        Outcome::Commit { gen, loss, rationed, status }
                    }
                    Err(e) => Outcome::Failed { what: "commit", error: e.to_string() },
                }
            }
            Job::Snapshot(job) => {
                let t0 = snapshot_write_us.as_ref().map(|_| std::time::Instant::now());
                let res = write_snapshot_job(job);
                if let (Some(h), Some(t)) = (&snapshot_write_us, t0) {
                    h.observe(t.elapsed().as_micros() as u64);
                }
                match res {
                    Ok(path) => Outcome::Snapshot { path },
                    Err(e) => Outcome::Failed { what: "snapshot", error: e.to_string() },
                }
            }
            Job::Restore { params, wear } => {
                let mut res = engine.restore_params(&params);
                if res.is_ok() {
                    if let Some(w) = &wear {
                        res = engine.restore_wear(w);
                    }
                }
                match res {
                    Ok(()) => {
                        cell.store(Arc::new(WeightSnapshot::new(
                            cell.gen(),
                            engine.backend().effective_params(),
                        )));
                        Outcome::Restored { status: SubstrateStatus::of(engine.backend()) }
                    }
                    Err(e) => Outcome::Failed { what: "restore", error: e.to_string() },
                }
            }
            Job::ReadWear => Outcome::Wear { wear: engine.backend().wear_state() },
        };
        if out.send(outcome).is_err() {
            // the serve side is gone; nothing left to report to
            break;
        }
    }
    engine.drain();
}
